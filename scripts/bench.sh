#!/usr/bin/env bash
# Run the JSON-emitting bench targets and leave their machine-readable
# results (BENCH_<suite>.json) at the repo root.
#
#   scripts/bench.sh              # streaming + microbench suites
#   scripts/bench.sh streaming    # one suite only
#
# Each bench binary writes its own BENCH_*.json via benchkit::Suite;
# this script just sequences them from the repo root so the output
# lands in a predictable place. CI uploads BENCH_*.json as artifacts.

set -euo pipefail
cd "$(dirname "$0")/.."

targets=("$@")
if [ ${#targets[@]} -eq 0 ]; then
    targets=(streaming microbench)
fi

for t in "${targets[@]}"; do
    echo
    echo "==> cargo bench --bench $t"
    cargo bench --bench "$t"
done

echo
echo "==> bench artifacts:"
ls -l BENCH_*.json
