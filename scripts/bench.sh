#!/usr/bin/env bash
# Run the JSON-emitting bench targets and leave their machine-readable
# results (BENCH_<suite>.json) at the repo root.
#
#   scripts/bench.sh              # every JSON suite
#   scripts/bench.sh streaming    # one suite only
#   DEEPCA_BENCH_SCALE=small scripts/bench.sh   # CI-sized figure benches
#
# Each bench binary writes its own BENCH_*.json via benchkit::Suite;
# this script just sequences them from the repo root so the output
# lands in a predictable place. CI uploads BENCH_*.json as artifacts,
# gates the microbench suite against an in-job merge-base baseline with
# scripts/bench_diff (blocking), and additionally diffs it against the
# committed baseline (warn-only long-horizon drift check).

set -euo pipefail
cd "$(dirname "$0")/.."

targets=("$@")
if [ ${#targets[@]} -eq 0 ]; then
    targets=(streaming microbench fig1_w8a fig2_a9a table_comm ablations)
fi

for t in "${targets[@]}"; do
    echo
    echo "==> cargo bench --bench $t"
    cargo bench --bench "$t"
done

echo
echo "==> bench artifacts:"
ls -l BENCH_*.json
