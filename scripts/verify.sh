#!/usr/bin/env bash
# Tier-1 verification gate for the deepca crate.
#
#   scripts/verify.sh            # build + tests + doc build, lint advisory
#   STRICT=1 scripts/verify.sh   # additionally fail on fmt/clippy findings
#
# The build is fully offline (dependencies vendored under rust/vendor),
# so this runs anywhere a Rust toolchain exists. fmt/clippy run in
# advisory mode by default so toolchain-version drift in style lints
# never masks a real build/test regression; CI runs them as separate
# non-blocking jobs and STRICT=1 promotes them to hard failures.

set -uo pipefail
cd "$(dirname "$0")/.."

fail=0
warn=0

step() {
    echo
    echo "==> $*"
}

run_required() {
    step "$*"
    if ! "$@"; then
        echo "FAIL: $*"
        fail=1
    fi
}

run_advisory() {
    step "$* (advisory)"
    if ! "$@"; then
        if [ "${STRICT:-0}" = "1" ]; then
            echo "FAIL (strict): $*"
            fail=1
        else
            echo "WARN: $* reported findings (non-blocking; STRICT=1 to enforce)"
            warn=1
        fi
    fi
}

# Tier-1 gate.
run_required cargo build --release
run_required cargo test -q

# Examples must keep compiling (they are the documented entry points).
run_required cargo build --release --examples

# Bench targets must keep compiling (scripts/bench.sh runs them; this
# stops them bit-rotting without paying their runtime here).
run_required cargo bench --no-run

# Documentation must build cleanly with no external deps.
run_required cargo doc --no-deps --quiet

# Repo invariant lint (blocking): hot-path allocation bans, hash-iteration
# bans, thread/clock seams, SAFETY comments. See rust/xtask/src/lib.rs.
run_required cargo xtask lint
run_required cargo test -q -p xtask

# Style / lint, advisory unless STRICT=1.
run_advisory cargo fmt --all --check
run_advisory cargo clippy --workspace --all-targets -- -D warnings

echo
if [ "$fail" -ne 0 ]; then
    echo "verify: FAILED"
    exit 1
fi
if [ "$warn" -ne 0 ]; then
    echo "verify: OK (with advisory warnings)"
else
    echo "verify: OK"
fi
