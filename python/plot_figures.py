#!/usr/bin/env python
"""Render the regenerated paper figures from the bench CSV output.

Build-time/analysis tool only (like everything in python/ — never on the
request path). After `cargo bench --bench fig1_w8a --bench fig2_a9a`:

    python python/plot_figures.py --results results --out results

produces `fig1.png` / `fig2.png` with the paper's three panels:
‖Sᵗ−S̄ᵗ⊗1‖, ‖Wᵗ−W̄ᵗ⊗1‖, and (1/m)Σ tanθ_k(U, W_jᵗ), each against the
number of communication rounds — directly comparable to Figures 1–2 of
Ye & Zhang (2021).
"""

import argparse
import csv
import glob
import os

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

PANELS = [
    ("s_deviation", r"$\|\mathbf{S}^t - \bar{S}^t \otimes 1\|$"),
    ("w_deviation", r"$\|\mathbf{W}^t - \bar{W}^t \otimes 1\|$"),
    ("mean_tan_theta", r"$\frac{1}{m}\sum_j \tan\theta_k(U, W_j^t)$"),
]


def load_series(path):
    with open(path) as f:
        rows = list(csv.DictReader(f))
    return {
        "comm": [int(r["comm_rounds"]) for r in rows],
        **{
            key: [float(r[key]) for r in rows]
            for key, _ in PANELS
        },
    }


def label_from_filename(fname, fig):
    stem = os.path.basename(fname)[len(fig) + 1 : -4]
    return stem.replace("_", " ").strip()


def style(label):
    if label.startswith("DeEPCA"):
        return {"linestyle": "-", "linewidth": 1.6}
    if label.startswith("DePCA"):
        return {"linestyle": "--", "linewidth": 1.4}
    return {"linestyle": ":", "linewidth": 1.4, "color": "black"}


def plot_figure(fig_id, results_dir, out_dir):
    paths = sorted(glob.glob(os.path.join(results_dir, f"{fig_id}_*.csv")))
    series = [
        (label_from_filename(p, fig_id), load_series(p))
        for p in paths
        if "cpca" not in p
    ]
    if not series:
        print(f"no CSVs for {fig_id} in {results_dir} — run the bench first")
        return False

    # Cap the x-axis at ~1.5× the largest constant-K budget so the paper's
    # plateaus are visible (the increasing-K series alone would stretch
    # the axis by 10×; it keeps descending off-plot).
    xmax = 1.5 * max(
        data["comm"][-1]
        for label, data in series
        if label.startswith("DeEPCA") or (label.startswith("DePCA") and "+t" not in label)
    )

    fig, axes = plt.subplots(1, 3, figsize=(15, 4.2))
    for ax, (key, title) in zip(axes, PANELS):
        for label, data in series:
            vals = [max(v, 1e-17) for v in data[key]]
            ax.semilogy(data["comm"], vals, label=label, **style(label))
        ax.set_xlabel("# communication rounds")
        ax.set_xlim(0, xmax)
        ax.set_title(title)
        ax.grid(True, which="both", alpha=0.25)
    axes[0].legend(fontsize=7, loc="lower left")
    dataset = "w8a" if fig_id == "fig1" else "a9a"
    fig.suptitle(f"{fig_id}: DeEPCA vs DePCA on '{dataset}'-like data (Ye & Zhang 2021 reproduction)")
    fig.tight_layout()
    out = os.path.join(out_dir, f"{fig_id}.png")
    fig.savefig(out, dpi=130)
    print(f"wrote {out} ({len(series)} series)")
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default="results")
    ap.add_argument("--out", default="results")
    ap.add_argument("--figures", default="fig1,fig2")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    any_ok = False
    for fig_id in args.figures.split(","):
        any_ok |= plot_figure(fig_id.strip(), args.results, args.out)
    return 0 if any_ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
