"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (d, k, n, block size) and seeds; fixed cases pin
the paper's exact shapes. interpret=True keeps everything on CPU.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gram import gram_pallas
from compile.kernels.power_step import power_step_pallas
from compile.kernels.tracking import tracking_update_pallas

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ------------------------------------------------------------- power_step


@settings(**SETTINGS)
@given(
    d=st.integers(2, 96),
    k=st.integers(1, 8),
    bm=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_power_step_matches_ref(d, k, bm, seed):
    rng = np.random.default_rng(seed)
    a, w = rand(rng, d, d), rand(rng, d, k)
    got = power_step_pallas(a, w, block_rows=bm)
    np.testing.assert_allclose(got, ref.power_step(a, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d,k", [(300, 5), (123, 5), (64, 4), (32, 2)])
def test_power_step_paper_shapes(d, k):
    rng = np.random.default_rng(7)
    a, w = rand(rng, d, d), rand(rng, d, k)
    got = power_step_pallas(a, w)
    np.testing.assert_allclose(got, ref.power_step(a, w), rtol=1e-4, atol=1e-4)
    assert np.asarray(got).dtype == np.float32


def test_power_step_block_size_invariance():
    rng = np.random.default_rng(11)
    a, w = rand(rng, 70, 70), rand(rng, 70, 3)
    outs = [np.asarray(power_step_pallas(a, w, block_rows=bm)) for bm in (7, 16, 70, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_power_step_rejects_bad_shapes():
    rng = np.random.default_rng(1)
    with pytest.raises(AssertionError):
        power_step_pallas(rand(rng, 4, 5), rand(rng, 5, 2))
    with pytest.raises(AssertionError):
        power_step_pallas(rand(rng, 4, 4), rand(rng, 5, 2))


def test_power_step_bf16_inputs_upcast():
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    a, w = rand(rng, 24, 24), rand(rng, 24, 2)
    got = power_step_pallas(jnp.asarray(a, jnp.bfloat16), jnp.asarray(w, jnp.bfloat16))
    assert np.asarray(got).dtype == np.float32
    np.testing.assert_allclose(got, ref.power_step(a, w), rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------- tracking


@settings(**SETTINGS)
@given(
    d=st.integers(2, 96),
    k=st.integers(1, 8),
    bm=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tracking_matches_ref(d, k, bm, seed):
    rng = np.random.default_rng(seed)
    s, a = rand(rng, d, k), rand(rng, d, d)
    w, wp = rand(rng, d, k), rand(rng, d, k)
    got = tracking_update_pallas(s, a, w, wp, block_rows=bm)
    np.testing.assert_allclose(
        got, ref.tracking_update(s, a, w, wp), rtol=1e-4, atol=1e-4
    )


def test_tracking_stationary_point():
    """W == W_prev ⇒ S returned untouched (the tracking telescoping)."""
    rng = np.random.default_rng(5)
    s, a, w = rand(rng, 40, 3), rand(rng, 40, 40), rand(rng, 40, 3)
    got = np.asarray(tracking_update_pallas(s, a, w, w))
    np.testing.assert_allclose(got, s, rtol=1e-6, atol=1e-6)


def test_tracking_equals_two_products():
    """Fused form == S + A·W − A·W_prev computed as two power steps."""
    rng = np.random.default_rng(6)
    s, a = rand(rng, 50, 4), rand(rng, 50, 50)
    w, wp = rand(rng, 50, 4), rand(rng, 50, 4)
    fused = np.asarray(tracking_update_pallas(s, a, w, wp))
    two = (
        s
        + np.asarray(power_step_pallas(a, w))
        - np.asarray(power_step_pallas(a, wp))
    )
    np.testing.assert_allclose(fused, two, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------- gram


@settings(**SETTINGS)
@given(
    n=st.integers(2, 200),
    d=st.integers(2, 64),
    bm=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(n, d, bm, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d)
    got = gram_pallas(x, block_rows=bm)
    np.testing.assert_allclose(got, ref.gram(x), rtol=1e-4, atol=1e-4)


def test_gram_padded_tail_masked():
    """n not divisible by block_rows must not leak padding (NaN) rows."""
    rng = np.random.default_rng(9)
    x = rand(rng, 53, 37)
    got = np.asarray(gram_pallas(x, block_rows=16))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, ref.gram(x), rtol=1e-4, atol=1e-4)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(10)
    x = rand(rng, 80, 12)
    g = np.asarray(gram_pallas(x))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-6)
    evals = np.linalg.eigvalsh(g.astype(np.float64))
    assert evals.min() > -1e-5


def test_gram_paper_shapes():
    rng = np.random.default_rng(12)
    for n, d in [(800, 300), (600, 123)]:
        x = rand(rng, n, d)
        np.testing.assert_allclose(
            gram_pallas(x), ref.gram(x), rtol=1e-4, atol=1e-4
        )
