"""AOT pipeline: lowering produces loadable HLO text + a sane manifest."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_emits_hlo(tmp_path):
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(model.power_step).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,2]" in text


def test_main_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    rc = aot.main(["--out", str(out), "--shapes", "12:2", "--gram-shapes", "16:6"])
    assert rc == 0
    manifest = json.loads((out / "manifest.json").read_text())
    kinds = sorted(a["kind"] for a in manifest["artifacts"])
    assert kinds == ["deepca_step", "gram", "orthonormalize", "power_step"]
    for a in manifest["artifacts"]:
        path = out / a["file"]
        assert path.exists(), a
        head = path.read_text()[:2000]
        assert "HloModule" in head
    # Shape metadata is coherent.
    by_kind = {a["kind"]: a for a in manifest["artifacts"]}
    assert by_kind["power_step"]["d"] == 12 and by_kind["power_step"]["k"] == 2
    assert by_kind["gram"]["d"] == 6 and by_kind["gram"]["k"] == 16


def test_lowered_artifact_executes_correctly(tmp_path):
    """The lowered computation is numerically correct and its HLO text is
    a single well-formed module. (Parsing the *text* back and executing
    it through PJRT is covered by the Rust integration test — that is the
    exact consumer.)"""
    import jax
    import jax.numpy as jnp

    d, k = 10, 3
    lowered = jax.jit(model.deepca_local_step).lower(
        jax.ShapeDtypeStruct((d, k), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, k), jnp.float32),
        jax.ShapeDtypeStruct((d, k), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.count("HloModule") == 1

    rng = np.random.default_rng(0)
    s = rng.standard_normal((d, k)).astype(np.float32)
    a = rng.standard_normal((d, d)).astype(np.float32)
    w = rng.standard_normal((d, k)).astype(np.float32)
    wp = rng.standard_normal((d, k)).astype(np.float32)
    (got,) = jax.jit(model.deepca_local_step)(s, a, w, wp)
    want = s + a @ (w - wp)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_shape_parsing_errors():
    with pytest.raises(ValueError):
        aot.main(["--out", "/tmp/x", "--shapes", "notashape"])


def test_default_shapes_cover_paper():
    assert (300, 5) in aot.STEP_SHAPES  # w8a
    assert (123, 5) in aot.STEP_SHAPES  # a9a
    assert (800, 300) in aot.GRAM_SHAPES
    assert (600, 123) in aot.GRAM_SHAPES
