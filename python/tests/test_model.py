"""L2 correctness: the jax model functions (composition of L1 kernels +
orthonormalization) against numpy ground truth."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------- orthonormalize


@settings(**SETTINGS)
@given(d=st.integers(3, 80), k=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_orthonormalize_is_orthonormal(d, k, seed):
    k = min(k, d - 1)
    rng = np.random.default_rng(seed)
    s, w0 = rand(rng, d, k), rand(rng, d, k)
    (q,) = model.orthonormalize(s, w0)
    q = np.asarray(q)
    np.testing.assert_allclose(q.T @ q, np.eye(k), rtol=0, atol=5e-5)


def test_orthonormalize_positive_diag_matches_numpy_qr():
    """Same Q as numpy's QR normalized to positive-diagonal R — i.e. the
    same convention the Rust Householder backend uses."""
    rng = np.random.default_rng(21)
    s = rand(rng, 30, 4).astype(np.float64)
    w0 = np.abs(rand(rng, 30, 4)).astype(np.float64)  # positive ⇒ rarely flips
    qn, rn = np.linalg.qr(s)
    flip = np.sign(np.diag(rn))
    qn = qn * flip[None, :]
    # Sign adjust against w0 may flip further; apply the same to qn.
    dots = np.sum(qn * w0, axis=0)
    qn = qn * np.where(dots < 0, -1.0, 1.0)[None, :]
    (q,) = model.orthonormalize(s.astype(np.float32), w0.astype(np.float32))
    np.testing.assert_allclose(np.asarray(q), qn, rtol=1e-3, atol=1e-4)


def test_orthonormalize_sign_alignment():
    rng = np.random.default_rng(22)
    s, w0 = rand(rng, 25, 3), rand(rng, 25, 3)
    (q,) = model.orthonormalize(s, w0)
    dots = np.sum(np.asarray(q) * w0, axis=0)
    assert (dots >= -1e-6).all(), f"columns misaligned: {dots}"


def test_orthonormalize_preserves_column_space():
    rng = np.random.default_rng(23)
    s, w0 = rand(rng, 40, 3), rand(rng, 40, 3)
    (q,) = model.orthonormalize(s, w0)
    q = np.asarray(q).astype(np.float64)
    s64 = s.astype(np.float64)
    # Projection of S onto span(Q) must equal S.
    proj = q @ (q.T @ s64)
    np.testing.assert_allclose(proj, s64, rtol=1e-3, atol=1e-3)


def test_orthonormalize_matches_ref():
    rng = np.random.default_rng(24)
    s, w0 = rand(rng, 35, 4), rand(rng, 35, 4)
    (q,) = model.orthonormalize(s, w0)
    np.testing.assert_allclose(
        np.asarray(q), np.asarray(ref.orthonormalize(s, w0)), rtol=1e-4, atol=1e-5
    )


# ------------------------------------------------------------- composition


@settings(**SETTINGS)
@given(d=st.integers(4, 64), k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
def test_deepca_local_step_matches_ref(d, k, seed):
    k = min(k, d - 1)
    rng = np.random.default_rng(seed)
    s, a = rand(rng, d, k), rand(rng, d, d)
    w, wp = rand(rng, d, k), rand(rng, d, k)
    (got,) = model.deepca_local_step(s, a, w, wp)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.tracking_update(s, a, w, wp)),
        rtol=1e-4, atol=1e-4,
    )


def test_full_iteration_composition():
    rng = np.random.default_rng(25)
    d, k = 30, 3
    s, a = rand(rng, d, k), rand(rng, d, d)
    w, wp, w0 = rand(rng, d, k), rand(rng, d, k), rand(rng, d, k)
    s_new, w_new = model.deepca_full_iteration(s, a, w, wp, w0)
    (s_expect,) = model.deepca_local_step(s, a, w, wp)
    (w_expect,) = model.orthonormalize(np.asarray(s_expect), w0)
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(s_expect), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w_new), np.asarray(w_expect), rtol=1e-6)


def test_power_iteration_converges_via_model():
    """Sanity: iterating power_step + orthonormalize on a gapped PSD
    matrix converges to its top-k eigenspace (the L2 graph really is a
    power method)."""
    rng = np.random.default_rng(26)
    d, k = 20, 2
    basis, _ = np.linalg.qr(rng.standard_normal((d, d)))
    evals = np.array([10.0, 6.0] + [0.5] * (d - 2))
    a = (basis * evals) @ basis.T
    a = a.astype(np.float32)
    w0 = np.linalg.qr(rng.standard_normal((d, k)))[0].astype(np.float32)
    w = w0
    for _ in range(60):
        (p,) = model.power_step(a, w)
        (w,) = model.orthonormalize(np.asarray(p), w0)
    w = np.asarray(w).astype(np.float64)
    u = basis[:, :k]
    # Projector distance ≈ 0.
    dist = np.linalg.norm(w @ w.T - u @ u.T)
    assert dist < 1e-3, f"projector distance {dist}"


def test_gram_model_wrapper():
    rng = np.random.default_rng(27)
    x = rand(rng, 64, 10)
    (g,) = model.gram(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref.gram(x)), rtol=1e-4, atol=1e-4)


def test_mgs_near_degenerate_columns():
    """Nearly colinear columns: Q must stay orthonormal (MGS2 pass)."""
    rng = np.random.default_rng(28)
    d = 40
    v = rand(rng, d, 1)
    s = np.concatenate([v, v + 1e-3 * rand(rng, d, 1), rand(rng, d, 1)], axis=1)
    (q,) = model.orthonormalize(s, rand(rng, d, 3))
    q = np.asarray(q)
    np.testing.assert_allclose(q.T @ q, np.eye(3), atol=5e-3)
