"""Tests for scripts/bench_diff (stdlib only — runs in the CI python job).

Covers the three exit paths: 0 (ok / improvements / explicit
empty-baseline skip), 1 (median regression beyond threshold), and
2 (usage errors).
"""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
BENCH_DIFF = REPO / "scripts" / "bench_diff"


def suite(tmp_path, name, medians):
    path = tmp_path / f"{name}.json"
    path.write_text(json.dumps({
        "suite": name,
        "results": [{"name": k, "median": v} for k, v in medians.items()],
    }))
    return path


def run(*args):
    return subprocess.run(
        [sys.executable, str(BENCH_DIFF), *[str(a) for a in args]],
        capture_output=True,
        text=True,
    )


def test_identical_suites_pass(tmp_path):
    base = suite(tmp_path, "base", {"matmul": 1.0, "qr": 2.0})
    cur = suite(tmp_path, "cur", {"matmul": 1.0, "qr": 2.0})
    proc = run(base, cur)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_regression_beyond_threshold_fails(tmp_path):
    base = suite(tmp_path, "base", {"matmul": 1.0})
    cur = suite(tmp_path, "cur", {"matmul": 1.5})
    proc = run(base, cur)
    assert proc.returncode == 1
    assert "REGRESSED" in proc.stdout


def test_improvements_always_pass(tmp_path):
    base = suite(tmp_path, "base", {"matmul": 1.0})
    cur = suite(tmp_path, "cur", {"matmul": 0.2})
    proc = run(base, cur)
    assert proc.returncode == 0
    assert "improved" in proc.stdout


def test_empty_baseline_skips_explicitly(tmp_path):
    # The committed-baseline-starts-empty case: must take the distinct
    # "skipping" path (announced, exit 0), not silently pass a
    # comparison over zero shared benchmarks.
    base = suite(tmp_path, "base", {})
    cur = suite(tmp_path, "cur", {"matmul": 1.0})
    proc = run(base, cur)
    assert proc.returncode == 0, proc.stderr
    assert "baseline empty" in proc.stdout
    assert "skipping" in proc.stdout
    assert "OK" not in proc.stdout


def test_empty_current_is_not_the_skip_path(tmp_path):
    # Only an empty *baseline* skips; an armed baseline against an empty
    # current run reports the missing benchmarks and passes normally.
    base = suite(tmp_path, "base", {"matmul": 1.0})
    cur = suite(tmp_path, "cur", {})
    proc = run(base, cur)
    assert proc.returncode == 0
    assert "baseline empty" not in proc.stdout
    assert "only in baseline" in proc.stdout


def test_disjoint_names_warn_and_skip(tmp_path):
    # Names present in only one file are warned about and skipped, and a
    # fully disjoint pair is announced as "nothing compared" rather than
    # passing a vacuous 0-shared comparison — either way exit 0 (suites
    # grow and shrink over time; only shared-name regressions are fatal).
    base = suite(tmp_path, "base", {"matmul_thin": 1.0})
    cur = suite(tmp_path, "cur", {"matmul_packed/simd": 0.5})
    proc = run(base, cur)
    assert proc.returncode == 0, proc.stderr
    assert "warning: matmul_thin: only in baseline" in proc.stdout
    assert "warning: matmul_packed/simd: new benchmark" in proc.stdout
    assert "no shared benchmarks" in proc.stdout
    assert "OK" not in proc.stdout


def test_custom_threshold_both_forms(tmp_path):
    base = suite(tmp_path, "base", {"matmul": 1.0})
    cur = suite(tmp_path, "cur", {"matmul": 1.3})
    assert run(base, cur, "--threshold", "0.5").returncode == 0
    assert run(base, cur, "--threshold=0.5").returncode == 0
    assert run(base, cur, "--threshold", "0.1").returncode == 1


def test_unknown_flag_is_usage_error(tmp_path):
    base = suite(tmp_path, "base", {"matmul": 1.0})
    proc = run(base, base, "--bogus")
    assert proc.returncode == 2


def test_unreadable_file_is_usage_error(tmp_path):
    base = suite(tmp_path, "base", {"matmul": 1.0})
    proc = run(base, tmp_path / "missing.json")
    assert proc.returncode == 2
