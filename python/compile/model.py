"""Layer 2: the per-agent DeEPCA compute graph in JAX.

Everything an agent computes locally per power iteration, authored as
jax functions over the Layer-1 Pallas kernels:

- ``deepca_local_step``  — Eqn. 3.1 fused tracking update (Pallas).
- ``power_step``         — Eqn. 3.4 / centralized product (Pallas).
- ``orthonormalize``     — Eqn. 3.3: MGS thin-QR (positive-diagonal
  convention, loop unrolled over the compile-time constant k ≤ 16) +
  Algorithm-2 SignAdjust. Written in plain jnp ops so it lowers to
  ordinary HLO (no LAPACK custom-calls the CPU PJRT plugin could trip
  on).
- ``gram``               — Eqn. 5.1 local matrix construction (Pallas).

These are lowered ONCE per shape by ``aot.py`` into
``artifacts/*.hlo.txt``; the Rust coordinator loads and executes them via
PJRT. Python never runs at request time.
"""

import jax.numpy as jnp

from .kernels.gram import gram_pallas
from .kernels.power_step import power_step_pallas
from .kernels.tracking import tracking_update_pallas


def power_step(a, w):
    """``A_j @ W`` — the per-agent power product (L1 Pallas)."""
    return (power_step_pallas(a, w),)


def deepca_local_step(s, a, w, w_prev):
    """Eqn. 3.1: ``S + A_j (W − W_prev)`` fused (L1 Pallas)."""
    return (tracking_update_pallas(s, a, w, w_prev),)


def gram(x):
    """Eqn. 5.1 per-row-scaled local Gram ``XᵀX/n`` (L1 Pallas)."""
    return (gram_pallas(x),)


def _mgs_q(s):
    """Modified Gram–Schmidt (two passes) thin-Q, positive-diagonal
    convention; k is static so the loop unrolls at trace time."""
    d, k = s.shape
    cols = []
    for i in range(k):
        v = s[:, i]
        for j in range(i):
            v = v - jnp.dot(cols[j], v) * cols[j]
        for j in range(i):  # re-orthogonalization pass (MGS2)
            v = v - jnp.dot(cols[j], v) * cols[j]
        nrm = jnp.linalg.norm(v)
        cols.append(v / nrm)
    return jnp.stack(cols, axis=1)


def orthonormalize(s, w0):
    """Eqn. 3.3: ``SignAdjust(QR(S), W0)``.

    MGS's Q already has positive-diagonal R (matching the Rust
    Householder backend), so SignAdjust only repairs genuine subspace
    sign rotations relative to the shared ``W0``.
    """
    q = _mgs_q(s.astype(jnp.float32))
    dots = jnp.sum(q * w0.astype(jnp.float32), axis=0)
    signs = jnp.where(dots < 0, -1.0, 1.0)
    return (q * signs[None, :],)


def deepca_full_iteration(s, a, w, w_prev, w0):
    """A complete local iteration minus communication: tracking update
    followed by orthonormalize of the *pre-mix* S. Used as a shape/
    composition check in tests; the deployed artifacts keep the two
    halves separate because FastMix happens between them."""
    (s_new,) = deepca_local_step(s, a, w, w_prev)
    (w_new,) = orthonormalize(s_new, w0)
    return (s_new, w_new)
