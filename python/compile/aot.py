"""AOT compiler: lower the L2/L1 graphs to HLO text artifacts.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Emits per (d, k) shape variant:
  - ``power_step_d{d}_k{k}.hlo.txt``      (A[d,d], W[d,k]) -> (A·W,)
  - ``deepca_step_d{d}_k{k}.hlo.txt``     (S, A, W, W_prev) -> (S+A(W−W_prev),)
  - ``orthonormalize_d{d}_k{k}.hlo.txt``  (S, W0) -> (SignAdjust(QR(S), W0),)
and per (n, d):
  - ``gram_n{n}_d{d}.hlo.txt``            (X[n,d]) -> (XᵀX/n,)
plus ``manifest.json`` for the Rust registry.

Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and DESIGN.md §7).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default shape variants: the paper's two datasets (d=300 w8a, d=123 a9a,
# k=5) plus the example/driver shapes.
STEP_SHAPES = [(300, 5), (123, 5), (64, 4), (32, 2)]
GRAM_SHAPES = [(800, 300), (600, 123)]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation (return_tuple) -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts(step_shapes, gram_shapes):
    """Yield (name, kind, d, k, hlo_text) for every artifact."""
    for d, k in step_shapes:
        a = f32((d, d))
        dk = f32((d, k))
        yield (
            f"power_step_d{d}_k{k}",
            "power_step",
            d,
            k,
            to_hlo_text(lower_fn(model.power_step, (a, dk))),
        )
        yield (
            f"deepca_step_d{d}_k{k}",
            "deepca_step",
            d,
            k,
            to_hlo_text(lower_fn(model.deepca_local_step, (dk, a, dk, dk))),
        )
        yield (
            f"orthonormalize_d{d}_k{k}",
            "orthonormalize",
            d,
            k,
            to_hlo_text(lower_fn(model.orthonormalize, (dk, dk))),
        )
    for n, d in gram_shapes:
        yield (
            f"gram_n{n}_d{d}",
            "gram",
            d,
            n,
            to_hlo_text(lower_fn(model.gram, (f32((n, d)),))),
        )


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--shapes",
        default=None,
        help="comma list of d:k step shapes, e.g. '300:5,64:4' (default: built-ins)",
    )
    parser.add_argument(
        "--gram-shapes",
        default=None,
        help="comma list of n:d gram shapes, e.g. '800:300'",
    )
    args = parser.parse_args(argv)

    step_shapes = STEP_SHAPES
    if args.shapes:
        step_shapes = [
            tuple(int(x) for x in pair.split(":")) for pair in args.shapes.split(",")
        ]
    gram_shapes = GRAM_SHAPES
    if args.gram_shapes is not None:
        gram_shapes = [
            tuple(int(x) for x in pair.split(":")) for pair in args.gram_shapes.split(",")
        ] if args.gram_shapes else []

    os.makedirs(args.out, exist_ok=True)
    manifest = {"jax_version": jax.__version__, "generated_by": "compile/aot.py", "artifacts": []}
    for name, kind, d, k, hlo in build_artifacts(step_shapes, gram_shapes):
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(hlo)
        manifest["artifacts"].append(
            {"name": name, "kind": kind, "d": d, "k": k, "file": fname}
        )
        print(f"wrote {path} ({len(hlo)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts, jax {jax.__version__})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
