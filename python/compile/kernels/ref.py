"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the L1 kernels are validated against at build
time (pytest + hypothesis): any kernel change must keep
``assert_allclose(kernel(...), ref(...))`` green across the shape/dtype
sweep in ``python/tests/test_kernels.py``.
"""

import jax.numpy as jnp


def power_step(a, w):
    """Power-iteration product: ``A @ W`` (A: [d,d], W: [d,k])."""
    return jnp.matmul(a, w, preferred_element_type=jnp.float32)


def tracking_update(s, a, w, w_prev):
    """DeEPCA Eqn. 3.1 fused update: ``S + A @ (W - W_prev)``.

    One pass over ``A`` instead of two products — the kernel-level
    expression of the paper's "one new product per iteration" property.
    """
    return s + jnp.matmul(a, w - w_prev, preferred_element_type=jnp.float32)


def gram(x):
    """Per-agent Gram matrix (paper Eqn. 5.1, PerRow scaling):
    ``XᵀX / n`` for X: [n, d]."""
    n = x.shape[0]
    return jnp.matmul(x.T, x, preferred_element_type=jnp.float32) / n


def mgs_orthonormalize(s):
    """Modified Gram–Schmidt thin-Q with positive-diagonal convention.

    Matches the Rust Householder QR's Q for full-rank input (thin QR with
    R_ii > 0 is unique), which is what makes the PJRT and Rust backends
    interchangeable.
    """
    d, k = s.shape
    cols = []
    for i in range(k):
        v = s[:, i]
        for j in range(i):
            v = v - jnp.dot(cols[j], v) * cols[j]
        # Second orthogonalization pass for numerical robustness (MGS2).
        for j in range(i):
            v = v - jnp.dot(cols[j], v) * cols[j]
        nrm = jnp.linalg.norm(v)
        cols.append(v / nrm)
    return jnp.stack(cols, axis=1)


def sign_adjust(w, w0):
    """Paper Algorithm 2: flip columns of ``w`` whose inner product with
    the same column of ``w0`` is negative."""
    dots = jnp.sum(w * w0, axis=0)
    signs = jnp.where(dots < 0, -1.0, 1.0)
    return w * signs[None, :]


def orthonormalize(s, w0):
    """Eqn. 3.3 composite: ``SignAdjust(QR(S), W0)``."""
    return sign_adjust(mgs_orthonormalize(s), w0)
