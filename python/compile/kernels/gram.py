"""L1 Pallas kernel: per-agent Gram matrix ``XᵀX / n`` (Eqn. 5.1).

Builds the local matrix A_j from an agent's raw feature rows. Grid over
row blocks of X with an accumulating output: every grid step adds its
tile's ``blockᵀ @ block`` into the same (d, d) output block (revisited
output + ``pl.when`` init — the standard Pallas reduction pattern).

VMEM: a (bm, d) tile plus the (d, d) accumulator; for d=300 f32 the
accumulator is 352 KiB, fine. For much larger d one would tile the output
too ((d/bd)² grid) — not needed at the paper's scales.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref, *, inv_n, n, bm):
    """Accumulate one row-block's Gram contribution.

    The final grid step may be padded (n % bm != 0); padded rows contain
    unspecified values (NaN under interpret=True) and MUST be masked out
    before the accumulation — unlike the power-step kernels, where padded
    rows only ever write to masked-out output rows.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    block = x_ref[...]
    row_ids = i * bm + jax.lax.broadcasted_iota(jnp.int32, block.shape, 0)
    block = jnp.where(row_ids < n, block, 0.0)
    o_ref[...] += (
        jnp.dot(block.T, block, preferred_element_type=jnp.float32) * inv_n
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def gram_pallas(x, block_rows: int = 128):
    """``XᵀX / n`` for X: [n, d] (PerRow scaling of DESIGN.md §5)."""
    n, d = x.shape
    bm = min(block_rows, n)
    grid = (pl.cdiv(n, bm),)
    kernel = functools.partial(_gram_kernel, inv_n=1.0 / n, n=n, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
