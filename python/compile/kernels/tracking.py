"""L1 Pallas kernel: the fused DeEPCA tracking update (Eqn. 3.1).

``S + A @ (W − W_prev)`` in a single pass over ``A``:

- The naive form runs two d×d×k products per iteration (A·W and A·W_prev)
  and reads A twice from HBM. Caching G = A·W_prev (the Rust coordinator
  does this too) leaves one product; fusing the subtraction into the
  kernel keeps the paper's exact arithmetic while touching A once and
  S once per tile.
- ΔW = W − W_prev is recomputed per grid step — d·k flops against the
  bm·d·k of the tile matmul, i.e. noise — which keeps the kernel free of
  cross-step state.

Same BlockSpec schedule as ``power_step``; see that module and
DESIGN.md §6 for the VMEM/MXU analysis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tracking_kernel(s_ref, a_ref, w_ref, wp_ref, o_ref):
    """One row-block: o = s_block + a_block @ (W − W_prev)."""
    dw = w_ref[...] - wp_ref[...]
    o_ref[...] = s_ref[...] + jnp.dot(
        a_ref[...], dw, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def tracking_update_pallas(s, a, w, w_prev, block_rows: int = 128):
    """Fused ``S + A(W − W_prev)`` (all f32).

    Args:
      s: [d, k] tracked variable.
      a: [d, d] local matrix.
      w: [d, k] current iterate.
      w_prev: [d, k] previous iterate.
      block_rows: row-tile height.
    """
    d, d2 = a.shape
    assert d == d2, f"A must be square, got {a.shape}"
    dk, k = s.shape
    assert dk == d and w.shape == s.shape and w_prev.shape == s.shape
    bm = min(block_rows, d)
    grid = (pl.cdiv(d, bm),)
    return pl.pallas_call(
        _tracking_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),   # S row-tile
            pl.BlockSpec((bm, d), lambda i: (i, 0)),   # A row-tile
            pl.BlockSpec((d, k), lambda i: (0, 0)),    # W resident
            pl.BlockSpec((d, k), lambda i: (0, 0)),    # W_prev resident
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, k), jnp.float32),
        interpret=True,
    )(
        s.astype(jnp.float32),
        a.astype(jnp.float32),
        w.astype(jnp.float32),
        w_prev.astype(jnp.float32),
    )
