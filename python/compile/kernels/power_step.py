"""L1 Pallas kernel: the power-iteration product ``A @ W``.

The per-agent hot-spot of every algorithm in the paper (DeEPCA Eqn. 3.1,
DePCA Eqn. 3.4) is the tall-thin product A[d,d] @ W[d,k] with k ≤ 16.

TPU mapping (DESIGN.md §6): grid over row blocks of ``A``; each grid step
streams one (bm, d) tile of A through VMEM against the whole of W (d·k
floats — tiny, broadcast to every step) and writes a (bm, k) output tile.
With bm=128, d=300, k=8 in f32 the working set is ~185 KiB — far under
VMEM, leaving room for double buffering the A stream. k ≤ 16 underfills
the 128-lane MXU; production TPU deployments would batch agents or pad k
(recorded as the utilization estimate in DESIGN.md, since interpret=True
runs on CPU and gives no TPU wallclock).

``interpret=True`` everywhere: the kernels must lower to plain HLO so the
CPU PJRT plugin (and the Rust runtime) can execute them.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _power_step_kernel(a_ref, w_ref, o_ref):
    """One row-block: o = a_block @ W."""
    o_ref[...] = jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def power_step_pallas(a, w, block_rows: int = 128):
    """``A @ W`` as a Pallas kernel (grid over row blocks of A).

    Args:
      a: [d, d] local matrix.
      w: [d, k] iterate.
      block_rows: row-tile height (VMEM knob; any value works, padded
        grid cells are masked on write).
    """
    d, d2 = a.shape
    assert d == d2, f"A must be square, got {a.shape}"
    dk, k = w.shape
    assert dk == d, f"W rows {dk} != A dim {d}"
    bm = min(block_rows, d)
    grid = (pl.cdiv(d, bm),)
    return pl.pallas_call(
        _power_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),   # stream A row-tiles
            pl.BlockSpec((d, k), lambda i: (0, 0)),    # W resident in VMEM
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, k), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), w.astype(jnp.float32))
