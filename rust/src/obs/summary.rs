//! `deepca trace <file>` — summarize an exported JSONL trace: top spans
//! by self-time, per-worker utilization and chunk counts, gossip
//! round/byte totals, and the fault timeline.
//!
//! Input is the JSONL format written by [`super::export::write_jsonl`]
//! (one flat object per line). Parsing is hand-rolled field extraction —
//! the repo vendors no serde, and the exporter's output is flat enough
//! that substring scanning is exact.

use super::trace::EventKind;
use std::collections::BTreeMap;

/// Extract an unsigned integer field (`"key":123`) from a flat JSON line.
fn field_u64(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract a string field (`"key":"value"`) from a flat JSON line.
/// Escaped quotes never match because they appear as `\"` in the text.
fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    rest.find('"').map(|end| &rest[..end])
}

struct Line {
    tid: u64,
    kind: EventKind,
    t_ns: u64,
    a: u64,
    b: u64,
}

fn parse_line(line: &str) -> Option<Line> {
    Some(Line {
        tid: field_u64(line, "tid")?,
        kind: EventKind::from_name(field_str(line, "kind")?)?,
        t_ns: field_u64(line, "t_ns")?,
        a: field_u64(line, "a")?,
        b: field_u64(line, "b")?,
    })
}

#[derive(Default)]
struct SpanAgg {
    count: u64,
    total_ns: u64,
    self_ns: u64,
}

/// Summarize an exported JSONL trace into a human-readable report.
/// Returns `Err` with a hint for non-JSONL input (e.g. a Chrome Trace
/// Format file, which `deepca trace` does not read).
pub fn summarize(text: &str) -> Result<String, String> {
    let trimmed = text.trim_start();
    if trimmed.is_empty() {
        return Err(String::from("empty trace file"));
    }
    let head = &trimmed[..trimmed.len().min(2000)];
    if trimmed.starts_with('[') || head.contains("\"traceEvents\"") {
        return Err(String::from(
            "this looks like a Chrome Trace Format file (load it in Perfetto); \
             `deepca trace` reads the JSONL export — re-run with a `.jsonl` path",
        ));
    }

    let mut events: Vec<Line> = Vec::new();
    let mut thread_names: BTreeMap<u64, String> = BTreeMap::new();
    let mut skipped = 0usize;
    for raw in text.lines() {
        if raw.trim().is_empty() {
            continue;
        }
        match parse_line(raw) {
            Some(line) => {
                if let Some(name) = field_str(raw, "thread") {
                    thread_names.entry(line.tid).or_insert_with(|| name.to_string());
                }
                events.push(line);
            }
            None => skipped += 1,
        }
    }
    if events.is_empty() {
        return Err(String::from("no parseable events in trace file"));
    }

    // Span self-time: per-tid stack of open spans; a child's duration is
    // charged against its parent's self-time when the child closes.
    let mut spans: BTreeMap<&'static str, SpanAgg> = BTreeMap::new();
    let mut stacks: BTreeMap<u64, Vec<(&'static str, u64, u64)>> = BTreeMap::new();
    // Workers: busy intervals from WorkerBusy..WorkerIdle, chunk counts
    // from ChunkClaim (payload `a` = worker id in both).
    let mut workers: BTreeMap<u64, (u64, u64, Option<u64>)> = BTreeMap::new();
    let mut rounds = 0u64;
    let mut dropped = 0u64;
    let mut vticks = 0u64;
    let mut bytes = 0u64;
    let mut faults: Vec<(u64, u64, u64)> = Vec::new();
    let mut ring_lost = 0u64;

    for ev in &events {
        if let Some(label) = ev.kind.span_label() {
            let stack = stacks.entry(ev.tid).or_default();
            if ev.kind.is_begin() {
                stack.push((label, ev.t_ns, 0));
            } else if ev.kind.is_end() {
                if let Some((open_label, t0, child_ns)) = stack.pop() {
                    let dur = ev.t_ns.saturating_sub(t0);
                    let agg = spans.entry(open_label).or_default();
                    agg.count += 1;
                    agg.total_ns += dur;
                    agg.self_ns += dur.saturating_sub(child_ns);
                    if let Some(parent) = stack.last_mut() {
                        parent.2 += dur;
                    }
                }
            }
        }
        match ev.kind {
            EventKind::GossipRound => {
                rounds += 1;
                dropped += ev.b;
            }
            EventKind::GossipRoundIo => {
                vticks += ev.a;
                bytes += ev.b;
            }
            EventKind::LinkDrop => faults.push((ev.t_ns, ev.a, ev.b)),
            EventKind::WorkerBusy => {
                workers.entry(ev.a).or_insert((0, 0, None)).2 = Some(ev.t_ns);
            }
            EventKind::WorkerIdle => {
                let w = workers.entry(ev.a).or_insert((0, 0, None));
                if let Some(t0) = w.2.take() {
                    w.0 += ev.t_ns.saturating_sub(t0);
                }
            }
            EventKind::ChunkClaim => {
                workers.entry(ev.a).or_insert((0, 0, None)).1 += 1;
            }
            EventKind::RingDropped => ring_lost += ev.a,
            _ => {}
        }
    }

    let mut out = String::new();
    out.push_str("trace summary\n");
    out.push_str(&format!("threads: {}\n", thread_names.len()));
    out.push_str(&format!("events: {}\n", events.len()));
    if ring_lost > 0 {
        out.push_str(&format!(
            "warning: {ring_lost} events lost to ring overflow (raise capacity)\n"
        ));
    }
    if skipped > 0 {
        out.push_str(&format!("warning: {skipped} unparseable lines skipped\n"));
    }

    if !spans.is_empty() {
        out.push_str("\ntop spans by self-time:\n");
        let mut ranked: Vec<(&&str, &SpanAgg)> = spans.iter().collect();
        ranked.sort_by(|x, y| y.1.self_ns.cmp(&x.1.self_ns).then(x.0.cmp(y.0)));
        for (label, agg) in ranked {
            out.push_str(&format!(
                "  {:<16} n={} total={}ns self={}ns\n",
                label, agg.count, agg.total_ns, agg.self_ns
            ));
        }
    }

    if rounds > 0 || bytes > 0 {
        out.push_str(&format!(
            "\ngossip: rounds={rounds} dropped={dropped} vticks={vticks} bytes={bytes}\n"
        ));
    }

    if !workers.is_empty() {
        out.push_str("\nworkers:\n");
        for (id, (busy_ns, chunks, _)) in &workers {
            out.push_str(&format!(
                "  worker {id}: busy={busy_ns}ns chunks={chunks}\n"
            ));
        }
    }

    if !faults.is_empty() {
        out.push_str("\nfaults:\n");
        for (t_ns, from, to) in &faults {
            out.push_str(&format!("  t={t_ns}ns link {from} -> {to}\n"));
        }
    }

    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(tid: u64, kind: &str, t_ns: u64, a: u64, b: u64) -> String {
        format!(
            "{{\"tid\":{tid},\"thread\":\"t{tid}\",\"kind\":\"{kind}\",\"t_ns\":{t_ns},\"a\":{a},\"b\":{b}}}"
        )
    }

    #[test]
    fn field_extraction_is_exact() {
        let l = line(3, "GossipRound", 1500, 6, 1);
        assert_eq!(field_u64(&l, "tid"), Some(3));
        assert_eq!(field_u64(&l, "t_ns"), Some(1500));
        assert_eq!(field_u64(&l, "a"), Some(6));
        assert_eq!(field_u64(&l, "b"), Some(1));
        assert_eq!(field_str(&l, "kind"), Some("GossipRound"));
        assert_eq!(field_str(&l, "thread"), Some("t3"));
        assert_eq!(field_u64(&l, "missing"), None);
    }

    #[test]
    fn chrome_input_is_rejected_with_hint() {
        let err = summarize("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[]}").unwrap_err();
        assert!(err.contains("Perfetto"));
        assert!(summarize("   ").is_err());
    }

    #[test]
    fn span_self_time_subtracts_children() {
        let text = [
            line(0, "StepBegin", 0, 0, 0),
            line(0, "GossipBegin", 100, 2, 0),
            line(0, "GossipEnd", 400, 0, 0),
            line(0, "StepEnd", 1000, 0, 0),
        ]
        .join("\n");
        let out = summarize(&text).unwrap();
        assert!(out.contains("step"), "{out}");
        assert!(out.contains("total=1000ns self=700ns"), "{out}");
        assert!(out.contains("total=300ns self=300ns"), "{out}");
    }

    #[test]
    fn workers_gossip_and_faults_are_reported() {
        let text = [
            line(0, "GossipRound", 200, 6, 1),
            line(0, "LinkDrop", 210, 3, 4),
            line(0, "GossipRoundIo", 250, 2, 960),
            line(1, "WorkerBusy", 120, 1, 0),
            line(1, "ChunkClaim", 125, 1, 1),
            line(1, "WorkerIdle", 220, 1, 0),
        ]
        .join("\n");
        let out = summarize(&text).unwrap();
        assert!(out.contains("gossip: rounds=1 dropped=1 vticks=2 bytes=960"), "{out}");
        assert!(out.contains("worker 1: busy=100ns chunks=1"), "{out}");
        assert!(out.contains("t=210ns link 3 -> 4"), "{out}");
    }

    #[test]
    fn unparseable_lines_are_counted_not_fatal() {
        let text = format!("{}\nnot json at all\n", line(0, "StepBegin", 0, 0, 0));
        let out = summarize(&text).unwrap();
        assert!(out.contains("events: 1"), "{out}");
        assert!(out.contains("1 unparseable"), "{out}");
    }
}
