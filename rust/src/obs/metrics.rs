//! Static metrics registry: named counters and log-scale histograms,
//! preregistered as `static`s so steady state allocates nothing.
//!
//! This unifies what `CommStats`, `RunRecorder`, and benchkit each
//! half-did: one process-wide place where event payloads accumulate
//! under atomic increments. [`bump`] is fed by every
//! [`crate::obs::trace::record`] call (registered hot region);
//! [`observe_span`] is fed by span guards on drop. [`reset`] runs at
//! every capture start so the registry describes exactly one run.

use super::trace::{EventKind, SpanKind};
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic named counter.
pub struct Counter {
    name: &'static str,
    val: AtomicU64,
}

impl Counter {
    const fn new(name: &'static str) -> Counter {
        Counter { name, val: AtomicU64::new(0) }
    }

    /// Registry name (dotted, e.g. `gossip.rounds`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.val.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.val.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.val.store(0, Ordering::Relaxed);
    }
}

/// Number of power-of-two buckets per histogram: bucket `i` holds
/// values `v` with `i = bit_length(v)` (so bucket 0 is exactly `v = 0`,
/// bucket 1 is `v = 1`, bucket 11 is `1024 ≤ v < 2048`, …), saturating
/// at the top bucket.
pub const HISTOGRAM_BUCKETS: usize = 40;

/// Fixed log₂-bucket histogram (durations in nanoseconds): 40 buckets
/// cover 1 ns … ~9 minutes, each observation is two atomic adds and one
/// atomic increment, and the bucket array is a `static` — nothing ever
/// grows.
pub struct Histogram {
    name: &'static str,
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    const fn new(name: &'static str) -> Histogram {
        Histogram {
            name,
            buckets: [const { AtomicU64::new(0) }; HISTOGRAM_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Registry name (dotted, e.g. `span.gossip.ns`).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Bucket index of a value: its bit length, saturated to the table.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        ((u64::BITS - v.leading_zeros()) as usize).min(HISTOGRAM_BUCKETS - 1)
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean observation, or 0 when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Bucket counts (index = bit length of the value).
    pub fn bucket_counts(&self) -> [u64; HISTOGRAM_BUCKETS] {
        let mut out = [0u64; HISTOGRAM_BUCKETS];
        for (slot, bucket) in out.iter_mut().zip(self.buckets.iter()) {
            *slot = bucket.load(Ordering::Relaxed);
        }
        out
    }

    fn reset(&self) {
        for bucket in &self.buckets {
            bucket.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }
}

// ------------------------------------------------------------ registry

/// Events recorded (all kinds).
pub static TRACE_EVENTS: Counter = Counter::new("trace.events");
/// Solver steps started.
pub static SOLVER_STEPS: Counter = Counter::new("solver.steps");
/// FastMix calls (mixes).
pub static GOSSIP_MIXES: Counter = Counter::new("gossip.mixes");
/// Gossip rounds executed.
pub static GOSSIP_ROUNDS: Counter = Counter::new("gossip.rounds");
/// Messages dropped by the fault model.
pub static GOSSIP_DROPPED: Counter = Counter::new("gossip.dropped");
/// Payload bytes moved by gossip rounds.
pub static GOSSIP_BYTES: Counter = Counter::new("gossip.bytes");
/// SimNet virtual ticks elapsed in gossip rounds.
pub static GOSSIP_VTICKS: Counter = Counter::new("gossip.vticks");
/// Parallel regions dispatched by the executor.
pub static EXEC_JOBS: Counter = Counter::new("exec.jobs");
/// Chunks claimed across all workers.
pub static EXEC_CHUNKS: Counter = Counter::new("exec.chunks");
/// Streaming epochs started.
pub static STREAM_EPOCHS: Counter = Counter::new("stream.epochs");

/// Span-duration histograms, one per [`SpanKind`].
pub static SPAN_STEP_NS: Histogram = Histogram::new("span.step.ns");
pub static SPAN_LOCAL_PRODUCT_NS: Histogram = Histogram::new("span.local_product.ns");
pub static SPAN_TRACKING_UPDATE_NS: Histogram = Histogram::new("span.tracking_update.ns");
pub static SPAN_GOSSIP_NS: Histogram = Histogram::new("span.gossip.ns");
pub static SPAN_QR_NS: Histogram = Histogram::new("span.qr.ns");
pub static SPAN_EPOCH_NS: Histogram = Histogram::new("span.epoch.ns");
pub static SPAN_INGEST_NS: Histogram = Histogram::new("span.ingest.ns");
pub static SPAN_REFRESH_NS: Histogram = Histogram::new("span.refresh.ns");
pub static SPAN_EPOCH_SOLVE_NS: Histogram = Histogram::new("span.epoch_solve.ns");

/// Every registered counter, in render order.
pub fn counters() -> [&'static Counter; 10] {
    [
        &TRACE_EVENTS,
        &SOLVER_STEPS,
        &GOSSIP_MIXES,
        &GOSSIP_ROUNDS,
        &GOSSIP_DROPPED,
        &GOSSIP_BYTES,
        &GOSSIP_VTICKS,
        &EXEC_JOBS,
        &EXEC_CHUNKS,
        &STREAM_EPOCHS,
    ]
}

/// Every registered histogram, in render order.
pub fn histograms() -> [&'static Histogram; 9] {
    [
        &SPAN_STEP_NS,
        &SPAN_LOCAL_PRODUCT_NS,
        &SPAN_TRACKING_UPDATE_NS,
        &SPAN_GOSSIP_NS,
        &SPAN_QR_NS,
        &SPAN_EPOCH_NS,
        &SPAN_INGEST_NS,
        &SPAN_REFRESH_NS,
        &SPAN_EPOCH_SOLVE_NS,
    ]
}

/// The histogram a span kind's durations land in.
pub fn span_histogram(kind: SpanKind) -> &'static Histogram {
    match kind {
        SpanKind::Step => &SPAN_STEP_NS,
        SpanKind::LocalProduct => &SPAN_LOCAL_PRODUCT_NS,
        SpanKind::TrackingUpdate => &SPAN_TRACKING_UPDATE_NS,
        SpanKind::Gossip => &SPAN_GOSSIP_NS,
        SpanKind::Qr => &SPAN_QR_NS,
        SpanKind::Epoch => &SPAN_EPOCH_NS,
        SpanKind::Ingest => &SPAN_INGEST_NS,
        SpanKind::Refresh => &SPAN_REFRESH_NS,
        SpanKind::EpochSolve => &SPAN_EPOCH_SOLVE_NS,
    }
}

/// Route one recorded event's payload into the registry — atomic adds
/// against preregistered statics only (registered hot region).
#[inline]
pub fn bump(kind: EventKind, a: u64, b: u64) {
    TRACE_EVENTS.add(1);
    match kind {
        EventKind::StepBegin => SOLVER_STEPS.add(1),
        EventKind::GossipBegin => GOSSIP_MIXES.add(1),
        EventKind::GossipRound => {
            GOSSIP_ROUNDS.add(1);
            GOSSIP_DROPPED.add(b);
        }
        EventKind::GossipRoundIo => {
            GOSSIP_VTICKS.add(a);
            GOSSIP_BYTES.add(b);
        }
        EventKind::JobPublish => EXEC_JOBS.add(1),
        EventKind::ChunkClaim => EXEC_CHUNKS.add(1),
        EventKind::EpochBegin => STREAM_EPOCHS.add(1),
        _ => {}
    }
}

/// Record one span duration (called by span guards on drop).
#[inline]
pub fn observe_span(kind: SpanKind, ns: u64) {
    span_histogram(kind).observe(ns);
}

/// Zero every counter and histogram (capture start).
pub fn reset() {
    for c in counters() {
        c.reset();
    }
    for h in histograms() {
        h.reset();
    }
}

/// Human-readable registry dump (the CLI prints this after a traced
/// run). Counters first, then non-empty span histograms.
pub fn render() -> String {
    let mut out = String::new();
    for c in counters() {
        out.push_str(&format!("{:<24} {}\n", c.name(), c.get()));
    }
    for h in histograms() {
        if h.count() > 0 {
            out.push_str(&format!(
                "{:<24} n={} mean={:.0}ns\n",
                h.name(),
                h.count(),
                h.mean()
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_is_log2() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(1024), 11);
        assert_eq!(Histogram::bucket_of(u64::MAX), HISTOGRAM_BUCKETS - 1);
    }

    #[test]
    fn bump_routes_payloads() {
        let _guard = crate::obs::trace::test_lock();
        reset();
        bump(EventKind::GossipRound, 6, 2);
        bump(EventKind::GossipRound, 6, 1);
        bump(EventKind::GossipRoundIo, 3, 960);
        bump(EventKind::StepBegin, 0, 0);
        assert_eq!(TRACE_EVENTS.get(), 4);
        assert_eq!(GOSSIP_ROUNDS.get(), 2);
        assert_eq!(GOSSIP_DROPPED.get(), 3);
        assert_eq!(GOSSIP_VTICKS.get(), 3);
        assert_eq!(GOSSIP_BYTES.get(), 960);
        assert_eq!(SOLVER_STEPS.get(), 1);
        reset();
        assert_eq!(TRACE_EVENTS.get(), 0);
        assert_eq!(GOSSIP_BYTES.get(), 0);
    }

    #[test]
    fn histogram_accumulates_and_resets() {
        let _guard = crate::obs::trace::test_lock();
        reset();
        SPAN_QR_NS.observe(100);
        SPAN_QR_NS.observe(300);
        assert_eq!(SPAN_QR_NS.count(), 2);
        assert_eq!(SPAN_QR_NS.sum(), 400);
        assert!((SPAN_QR_NS.mean() - 200.0).abs() < 1e-9);
        let buckets = SPAN_QR_NS.bucket_counts();
        assert_eq!(buckets[Histogram::bucket_of(100)], 1);
        assert_eq!(buckets[Histogram::bucket_of(300)], 1);
        assert_eq!(buckets.iter().sum::<u64>(), 2);
        reset();
        assert_eq!(SPAN_QR_NS.count(), 0);
    }

    #[test]
    fn render_lists_every_counter() {
        let _guard = crate::obs::trace::test_lock();
        reset();
        let out = render();
        for c in counters() {
            assert!(out.contains(c.name()), "render missing {}", c.name());
        }
    }
}
