//! Post-run trace exporters: JSON Lines (the `deepca trace` summarizer
//! input) and Chrome Trace Format (loadable in Perfetto /
//! `chrome://tracing`).
//!
//! Exporters run *after* a capture — they drain ring snapshots and may
//! allocate freely; nothing here is on a hot path. Both formats are
//! written with hand-rolled formatting (the repo vendors no serde).

use super::trace::{Event, EventKind, ThreadEvents};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Minimal JSON string escape (thread names are the only free-form
/// strings in either format).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One event per line:
/// `{"tid":0,"thread":"main","kind":"StepBegin","code":1,"t_ns":12,"a":7,"b":0}`.
/// A ring that overflowed leads with a synthetic
/// [`EventKind::RingDropped`] line (`a` = events lost).
pub fn write_jsonl(path: &Path, snapshot: &[ThreadEvents]) -> std::io::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for (tid, thread) in snapshot.iter().enumerate() {
        let name = escape(&thread.name);
        if thread.dropped > 0 {
            write_jsonl_line(
                &mut w,
                tid,
                &name,
                &Event {
                    kind: EventKind::RingDropped,
                    t_ns: 0,
                    a: thread.dropped,
                    b: 0,
                },
            )?;
        }
        for ev in &thread.events {
            if ev.kind == EventKind::Nop {
                continue;
            }
            write_jsonl_line(&mut w, tid, &name, ev)?;
        }
    }
    w.flush()
}

fn write_jsonl_line(
    w: &mut impl Write,
    tid: usize,
    thread: &str,
    ev: &Event,
) -> std::io::Result<()> {
    writeln!(
        w,
        "{{\"tid\":{tid},\"thread\":\"{thread}\",\"kind\":\"{}\",\"code\":{},\"t_ns\":{},\"a\":{},\"b\":{}}}",
        ev.kind.name(),
        ev.kind.code(),
        ev.t_ns,
        ev.a,
        ev.b
    )
}

/// Chrome Trace Format: `{"displayTimeUnit":"ns","traceEvents":[...]}`
/// with thread-name metadata, `B`/`E` duration events for spans, and
/// `i` instants (scope `t`) for everything else. `ts` is microseconds
/// (the format's unit) at nanosecond precision.
pub fn write_chrome(path: &Path, snapshot: &[ThreadEvents]) -> std::io::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    write!(w, "{{\"displayTimeUnit\":\"ns\",\"traceEvents\":[")?;
    let mut first = true;
    let mut emit = |w: &mut BufWriter<std::fs::File>, body: &str| -> std::io::Result<()> {
        if first {
            first = false;
        } else {
            write!(w, ",")?;
        }
        write!(w, "\n{body}")
    };
    for (tid, thread) in snapshot.iter().enumerate() {
        let name = escape(&thread.name);
        emit(
            &mut w,
            &format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{name}\"}}}}"
            ),
        )?;
        if thread.dropped > 0 {
            emit(
                &mut w,
                &format!(
                    "{{\"ph\":\"i\",\"pid\":1,\"tid\":{tid},\"ts\":0,\"s\":\"t\",\
                     \"name\":\"RingDropped\",\"args\":{{\"a\":{},\"b\":0}}}}",
                    thread.dropped
                ),
            )?;
        }
        for ev in &thread.events {
            if ev.kind == EventKind::Nop {
                continue;
            }
            let ts = format_us(ev.t_ns);
            let body = if ev.kind.is_begin() {
                format!(
                    "{{\"ph\":\"B\",\"pid\":1,\"tid\":{tid},\"ts\":{ts},\"name\":\"{}\",\
                     \"args\":{{\"a\":{},\"b\":{}}}}}",
                    ev.kind.span_label().unwrap_or("span"),
                    ev.a,
                    ev.b
                )
            } else if ev.kind.is_end() {
                format!(
                    "{{\"ph\":\"E\",\"pid\":1,\"tid\":{tid},\"ts\":{ts},\"name\":\"{}\"}}",
                    ev.kind.span_label().unwrap_or("span")
                )
            } else {
                format!(
                    "{{\"ph\":\"i\",\"pid\":1,\"tid\":{tid},\"ts\":{ts},\"s\":\"t\",\
                     \"name\":\"{}\",\"args\":{{\"a\":{},\"b\":{}}}}}",
                    ev.kind.name(),
                    ev.a,
                    ev.b
                )
            };
            emit(&mut w, &body)?;
        }
    }
    write!(w, "\n]}}")?;
    w.flush()
}

/// Microseconds with nanosecond precision, without float formatting
/// drift: `1234567 ns` → `"1234.567"`.
fn format_us(t_ns: u64) -> String {
    format!("{}.{:03}", t_ns / 1000, t_ns % 1000)
}

/// Pick the format from the extension: `.json` writes Chrome Trace
/// Format (drop the file straight into Perfetto); anything else writes
/// JSON Lines (the `deepca trace` summarizer input).
pub fn write_auto(path: &Path, snapshot: &[ThreadEvents]) -> std::io::Result<()> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("json") => write_chrome(path, snapshot),
        _ => write_jsonl(path, snapshot),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> Vec<ThreadEvents> {
        vec![
            ThreadEvents {
                name: String::from("main"),
                dropped: 0,
                events: vec![
                    Event { kind: EventKind::StepBegin, t_ns: 1000, a: 0, b: 0 },
                    Event { kind: EventKind::GossipRound, t_ns: 1500, a: 6, b: 1 },
                    Event { kind: EventKind::StepEnd, t_ns: 2500, a: 0, b: 0 },
                ],
            },
            ThreadEvents {
                name: String::from("deepca-worker-1"),
                dropped: 3,
                events: vec![Event { kind: EventKind::ChunkClaim, t_ns: 1200, a: 1, b: 1 }],
            },
        ]
    }

    #[test]
    fn jsonl_export_round_trips_lines() {
        let dir = std::env::temp_dir().join("deepca_obs_test_jsonl");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        write_jsonl(&path, &sample_snapshot()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // 3 main events + RingDropped marker + 1 worker event.
        assert_eq!(lines.len(), 5);
        assert!(lines[0].contains("\"kind\":\"StepBegin\""));
        assert!(lines[1].contains("\"a\":6"));
        assert!(lines[1].contains("\"b\":1"));
        assert!(lines[3].contains("\"kind\":\"RingDropped\""));
        assert!(lines[3].contains("\"a\":3"));
        assert!(lines[4].contains("\"thread\":\"deepca-worker-1\""));
        // Every line is a standalone JSON object.
        assert!(lines.iter().all(|l| l.starts_with('{') && l.ends_with('}')));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn chrome_export_is_balanced_json() {
        let dir = std::env::temp_dir().join("deepca_obs_test_chrome");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome(&path, &sample_snapshot()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with('{') && text.trim_end().ends_with('}'));
        assert!(text.contains("\"traceEvents\""));
        assert!(text.contains("\"ph\":\"B\""));
        assert!(text.contains("\"ph\":\"E\""));
        assert!(text.contains("\"ph\":\"M\""));
        assert!(text.contains("\"name\":\"step\""));
        // µs timestamps at ns precision: 1500 ns → 1.500 µs.
        assert!(text.contains("\"ts\":1.500"));
        // Structural balance (no nested objects are left open).
        let opens = text.matches('{').count();
        let closes = text.matches('}').count();
        assert_eq!(opens, closes);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn auto_picks_format_by_extension() {
        let dir = std::env::temp_dir().join("deepca_obs_test_auto");
        std::fs::create_dir_all(&dir).unwrap();
        let chrome = dir.join("t.json");
        let jsonl = dir.join("t.jsonl");
        write_auto(&chrome, &sample_snapshot()).unwrap();
        write_auto(&jsonl, &sample_snapshot()).unwrap();
        assert!(std::fs::read_to_string(&chrome).unwrap().contains("traceEvents"));
        assert!(!std::fs::read_to_string(&jsonl).unwrap().contains("traceEvents"));
        std::fs::remove_file(&chrome).unwrap();
        std::fs::remove_file(&jsonl).unwrap();
    }

    #[test]
    fn escape_handles_quotes_and_controls() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("a\nb"), "a\\u000ab");
    }
}
