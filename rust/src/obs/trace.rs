//! The flight-recorder core: fixed-size events, per-thread ring
//! buffers, span guards, and the global enable/snapshot switchboard.
//!
//! Recording discipline (the zero-allocation contract): [`record`] is an
//! atomic enabled check, a metrics bump, and one indexed store into a
//! preallocated ring ([`Recorder::push`]). The only allocating moment is
//! a thread's *first* event — ring registration — which happens inside
//! the warm-up window of every audited steady state. Both fast paths
//! are registered hot regions in `cargo xtask lint`.

use crate::util::timer::Timer;
use std::cell::OnceCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Default per-thread ring capacity (events). At 32 bytes per event
/// this is ~1 MiB per recording thread — enough for a few hundred
/// power iterations with per-round gossip events; older events are
/// overwritten (and counted) once a ring fills.
pub const DEFAULT_CAPACITY: usize = 1 << 15;

/// What one event records. Codes are part of the JSONL export format —
/// append new kinds, never renumber.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum EventKind {
    /// Preallocation filler; never exported.
    Nop = 0,
    /// Solver step span (`a` = iteration).
    StepBegin = 1,
    StepEnd = 2,
    /// Per-agent Gram product phase.
    LocalProductBegin = 3,
    LocalProductEnd = 4,
    /// DeEPCA tracking update (S += AW − G).
    TrackingUpdateBegin = 5,
    TrackingUpdateEnd = 6,
    /// One FastMix call (`a` = requested rounds).
    GossipBegin = 7,
    GossipEnd = 8,
    /// QR / orthonormalization phase.
    QrBegin = 9,
    QrEnd = 10,
    /// Sign-adjust applied this step (`a` = agents).
    SignAdjust = 11,
    /// One gossip round (`a` = live edges, `b` = messages dropped).
    GossipRound = 12,
    /// Round I/O accounting (`a` = virtual ticks, `b` = payload bytes).
    GossipRoundIo = 13,
    /// SimNet dropped the round's message on link `a` → `b`.
    LinkDrop = 14,
    /// Executor published a parallel region (`a` = job seq, `b` = chunks).
    JobPublish = 15,
    /// A worker claimed a chunk (`a` = worker id, `b` = chunk index).
    ChunkClaim = 16,
    /// Worker busy/idle transitions (`a` = worker id, `b` = chunk index).
    WorkerBusy = 17,
    WorkerIdle = 18,
    /// Streaming epoch span (`a` = epoch index).
    EpochBegin = 19,
    EpochEnd = 20,
    /// Stream ingest phase.
    IngestBegin = 21,
    IngestEnd = 22,
    /// Covariance refresh phase.
    RefreshBegin = 23,
    RefreshEnd = 24,
    /// Inner warm-started solve of one epoch.
    EpochSolveBegin = 25,
    EpochSolveEnd = 26,
    /// Synthetic export-time marker: `a` events were overwritten after
    /// the ring filled.
    RingDropped = 27,
    /// SimNet materialized one round's fault schedule on the caller
    /// thread (`a` = drops, `b` = stored eventful-link entries).
    /// Scheduling-class: only emitted on the pooled faulty path, so it
    /// is masked from the deterministic stream (the sequential path
    /// never builds a plan).
    FaultPlanBuild = 28,
    /// SimNet applied a fault plan through the executor (`a` = agent
    /// rows, `b` = round's slowest delivery). Scheduling-class, like
    /// [`EventKind::FaultPlanBuild`].
    FaultPlanApply = 29,
}

impl EventKind {
    /// Stable wire code.
    pub fn code(self) -> u16 {
        self as u16
    }

    /// Inverse of [`EventKind::code`] (None for unknown codes, so
    /// foreign JSONL degrades gracefully in the summarizer).
    pub fn from_code(code: u16) -> Option<EventKind> {
        use EventKind::*;
        Some(match code {
            0 => Nop,
            1 => StepBegin,
            2 => StepEnd,
            3 => LocalProductBegin,
            4 => LocalProductEnd,
            5 => TrackingUpdateBegin,
            6 => TrackingUpdateEnd,
            7 => GossipBegin,
            8 => GossipEnd,
            9 => QrBegin,
            10 => QrEnd,
            11 => SignAdjust,
            12 => GossipRound,
            13 => GossipRoundIo,
            14 => LinkDrop,
            15 => JobPublish,
            16 => ChunkClaim,
            17 => WorkerBusy,
            18 => WorkerIdle,
            19 => EpochBegin,
            20 => EpochEnd,
            21 => IngestBegin,
            22 => IngestEnd,
            23 => RefreshBegin,
            24 => RefreshEnd,
            25 => EpochSolveBegin,
            26 => EpochSolveEnd,
            27 => RingDropped,
            28 => FaultPlanBuild,
            29 => FaultPlanApply,
            _ => return None,
        })
    }

    /// Export name (also the JSONL `kind` field).
    pub fn name(self) -> &'static str {
        use EventKind::*;
        match self {
            Nop => "Nop",
            StepBegin => "StepBegin",
            StepEnd => "StepEnd",
            LocalProductBegin => "LocalProductBegin",
            LocalProductEnd => "LocalProductEnd",
            TrackingUpdateBegin => "TrackingUpdateBegin",
            TrackingUpdateEnd => "TrackingUpdateEnd",
            GossipBegin => "GossipBegin",
            GossipEnd => "GossipEnd",
            QrBegin => "QrBegin",
            QrEnd => "QrEnd",
            SignAdjust => "SignAdjust",
            GossipRound => "GossipRound",
            GossipRoundIo => "GossipRoundIo",
            LinkDrop => "LinkDrop",
            JobPublish => "JobPublish",
            ChunkClaim => "ChunkClaim",
            WorkerBusy => "WorkerBusy",
            WorkerIdle => "WorkerIdle",
            EpochBegin => "EpochBegin",
            EpochEnd => "EpochEnd",
            IngestBegin => "IngestBegin",
            IngestEnd => "IngestEnd",
            RefreshBegin => "RefreshBegin",
            RefreshEnd => "RefreshEnd",
            EpochSolveBegin => "EpochSolveBegin",
            EpochSolveEnd => "EpochSolveEnd",
            RingDropped => "RingDropped",
            FaultPlanBuild => "FaultPlanBuild",
            FaultPlanApply => "FaultPlanApply",
        }
    }

    /// Parse an export name back to a kind (summarizer input path).
    pub fn from_name(name: &str) -> Option<EventKind> {
        (0..=29).map(|c| EventKind::from_code(c).unwrap()).find(|k| k.name() == name)
    }

    /// Span name for Begin/End pairs (Chrome trace + summarizer label);
    /// None for instants.
    pub fn span_label(self) -> Option<&'static str> {
        use EventKind::*;
        Some(match self {
            StepBegin | StepEnd => "step",
            LocalProductBegin | LocalProductEnd => "local_product",
            TrackingUpdateBegin | TrackingUpdateEnd => "tracking_update",
            GossipBegin | GossipEnd => "gossip",
            QrBegin | QrEnd => "qr",
            EpochBegin | EpochEnd => "epoch",
            IngestBegin | IngestEnd => "ingest",
            RefreshBegin | RefreshEnd => "refresh",
            EpochSolveBegin | EpochSolveEnd => "epoch_solve",
            _ => return None,
        })
    }

    /// Does this kind open a span?
    pub fn is_begin(self) -> bool {
        use EventKind::*;
        matches!(
            self,
            StepBegin
                | LocalProductBegin
                | TrackingUpdateBegin
                | GossipBegin
                | QrBegin
                | EpochBegin
                | IngestBegin
                | RefreshBegin
                | EpochSolveBegin
        )
    }

    /// Does this kind close a span?
    pub fn is_end(self) -> bool {
        use EventKind::*;
        matches!(
            self,
            StepEnd
                | LocalProductEnd
                | TrackingUpdateEnd
                | GossipEnd
                | QrEnd
                | EpochEnd
                | IngestEnd
                | RefreshEnd
                | EpochSolveEnd
        )
    }

    /// Events describing algorithmic progress — recorded on the caller
    /// thread in program order, so their (kind, a, b) stream is
    /// bit-identical across thread counts and seeded replays. Scheduling
    /// events (executor dispatch, the fault-plan stage markers that only
    /// exist on the pooled path) and export-time markers are excluded:
    /// chunk counts and claim patterns legitimately vary with the pool.
    pub fn is_deterministic(self) -> bool {
        use EventKind::*;
        !matches!(
            self,
            Nop | JobPublish
                | ChunkClaim
                | WorkerBusy
                | WorkerIdle
                | RingDropped
                | FaultPlanBuild
                | FaultPlanApply
        )
    }
}

/// One fixed-size trace record. `t_ns` is wall time against the process
/// trace epoch (masked in determinism comparisons); `a`/`b` are
/// kind-specific payloads (see [`EventKind`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub kind: EventKind,
    pub t_ns: u64,
    pub a: u64,
    pub b: u64,
}

impl Event {
    /// Ring preallocation filler.
    pub const NOP: Event = Event { kind: EventKind::Nop, t_ns: 0, a: 0, b: 0 };
}

/// Preallocated single-thread ring buffer of [`Event`]s. Once full, new
/// events overwrite the oldest (the `dropped` counter records how many
/// were lost; the exporters surface it as a [`EventKind::RingDropped`]
/// marker).
pub struct Recorder {
    buf: Vec<Event>,
    /// Next write index.
    head: usize,
    /// Valid events (≤ capacity).
    len: usize,
    /// Events overwritten after the ring filled.
    dropped: u64,
    /// Thread label captured at registration.
    name: String,
}

impl Recorder {
    /// Ring with room for `capacity` events, fully preallocated up
    /// front so recording never grows anything.
    pub fn with_capacity(capacity: usize) -> Recorder {
        Recorder::named(capacity, String::from("thread"))
    }

    fn named(capacity: usize, name: String) -> Recorder {
        Recorder { buf: vec![Event::NOP; capacity.max(16)], head: 0, len: 0, dropped: 0, name }
    }

    /// Append one event — a single indexed store plus ring bookkeeping.
    /// This is the per-event fast path (registered hot region).
    #[inline]
    pub fn push(&mut self, ev: Event) {
        let cap = self.buf.len();
        self.buf[self.head] = ev;
        self.head = if self.head + 1 == cap { 0 } else { self.head + 1 };
        if self.len < cap {
            self.len += 1;
        } else {
            self.dropped += 1;
        }
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing has been recorded since the last reset.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Events lost to ring overwrite since the last reset.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drain to a linear oldest → newest copy (export path, post-run).
    pub fn events(&self) -> Vec<Event> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(|i| self.buf[(start + i) % cap]).collect()
    }

    /// Clear and (if needed) re-size for a fresh capture.
    fn reset(&mut self, capacity: usize) {
        let capacity = capacity.max(16);
        if self.buf.len() != capacity {
            self.buf = vec![Event::NOP; capacity];
        }
        self.head = 0;
        self.len = 0;
        self.dropped = 0;
    }
}

/// One thread's drained events, as returned by [`snapshot`].
pub struct ThreadEvents {
    /// Thread label ("main", "deepca-worker-1", …).
    pub name: String,
    /// Events lost to ring overwrite.
    pub dropped: u64,
    /// Oldest → newest.
    pub events: Vec<Event>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);
/// Trace epoch: all timestamps are nanoseconds since the first
/// [`enable`]. A `Timer` (the sanctioned wall-clock seam) rather than a
/// raw `Instant` so this module performs no clock reads of its own.
static EPOCH: OnceLock<Timer> = OnceLock::new();
/// Every ring ever registered, in registration order. Rings live for
/// the process (threads park and die; their captured events remain
/// exportable) and are reset wholesale by [`enable`].
static REGISTRY: Mutex<Vec<Arc<Mutex<Recorder>>>> = Mutex::new(Vec::new());

thread_local! {
    /// This thread's ring, registered on first use.
    static LOCAL: OnceCell<Arc<Mutex<Recorder>>> = const { OnceCell::new() };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Cold path: allocate and register this thread's ring. Runs once per
/// thread, on its first recorded event (inside every audited warm-up
/// window) or via [`register_current_thread`].
fn register_ring() -> Arc<Mutex<Recorder>> {
    let capacity = CAPACITY.load(Ordering::Relaxed);
    let name = std::thread::current()
        .name()
        .unwrap_or("thread")
        .to_string();
    let rec = Arc::new(Mutex::new(Recorder::named(capacity, name)));
    lock(&REGISTRY).push(Arc::clone(&rec));
    rec
}

/// Pre-register the calling thread's ring (so its registration
/// allocation happens *now*, not inside a measured region).
pub fn register_current_thread() {
    LOCAL.with(|cell| {
        let _ = cell.get_or_init(register_ring);
    });
}

/// Is recording live? Instrumentation call sites may use this to skip
/// payload computation; [`record`] checks it itself.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the trace epoch (0 before the first [`enable`]).
#[inline]
pub fn now_ns() -> u64 {
    match EPOCH.get() {
        Some(t) => t.elapsed_nanos(),
        None => 0,
    }
}

/// Start a capture: fix the ring capacity, reset every registered ring
/// (a fresh capture never carries a prior run's events), reset the
/// metrics registry, register the calling thread, and open recording.
pub fn enable(capacity: usize) {
    let capacity = capacity.max(16);
    CAPACITY.store(capacity, Ordering::Relaxed);
    let _ = EPOCH.get_or_init(Timer::start);
    {
        let registry = lock(&REGISTRY);
        for rec in registry.iter() {
            lock(rec).reset(capacity);
        }
    }
    super::metrics::reset();
    register_current_thread();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording. Captured events stay in their rings for [`snapshot`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Record one event on the calling thread's ring. The per-event fast
/// path (registered hot region): enabled check → metrics bump →
/// timestamp → indexed ring store. No-op when disabled.
#[inline]
pub fn record(kind: EventKind, a: u64, b: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    super::metrics::bump(kind, a, b);
    let t_ns = now_ns();
    LOCAL.with(|cell| {
        let rec = cell.get_or_init(register_ring);
        let mut guard = match rec.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        guard.push(Event { kind, t_ns, a, b });
    });
}

/// Drain every registered ring (registration order, oldest → newest
/// within each thread). Usually called after [`disable`].
pub fn snapshot() -> Vec<ThreadEvents> {
    let registry = lock(&REGISTRY);
    registry
        .iter()
        .map(|rec| {
            let guard = lock(rec);
            ThreadEvents {
                name: guard.name.clone(),
                dropped: guard.dropped,
                events: guard.events(),
            }
        })
        .collect()
}

/// The deterministic event stream of a snapshot: (code, a, b) triples
/// with timestamps masked and scheduling-class kinds removed. This is
/// the stream the determinism tests compare across thread counts and
/// seeded replays.
pub fn deterministic_events(snapshot: &[ThreadEvents]) -> Vec<(u16, u64, u64)> {
    snapshot
        .iter()
        .flat_map(|t| t.events.iter())
        .filter(|e| e.kind != EventKind::Nop && e.kind.is_deterministic())
        .map(|e| (e.kind.code(), e.a, e.b))
        .collect()
}

/// Serializes tests that toggle the global recording state. Every test
/// that calls [`enable`] must hold this guard for its whole body.
pub fn test_lock() -> MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    match TEST_LOCK.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Span identities for [`SpanGuard`] — each maps to a Begin/End
/// [`EventKind`] pair and a duration histogram in the metrics registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Step,
    LocalProduct,
    TrackingUpdate,
    Gossip,
    Qr,
    Epoch,
    Ingest,
    Refresh,
    EpochSolve,
}

impl SpanKind {
    fn begin(self) -> EventKind {
        use SpanKind::*;
        match self {
            Step => EventKind::StepBegin,
            LocalProduct => EventKind::LocalProductBegin,
            TrackingUpdate => EventKind::TrackingUpdateBegin,
            Gossip => EventKind::GossipBegin,
            Qr => EventKind::QrBegin,
            Epoch => EventKind::EpochBegin,
            Ingest => EventKind::IngestBegin,
            Refresh => EventKind::RefreshBegin,
            EpochSolve => EventKind::EpochSolveBegin,
        }
    }

    fn end(self) -> EventKind {
        use SpanKind::*;
        match self {
            Step => EventKind::StepEnd,
            LocalProduct => EventKind::LocalProductEnd,
            TrackingUpdate => EventKind::TrackingUpdateEnd,
            Gossip => EventKind::GossipEnd,
            Qr => EventKind::QrEnd,
            Epoch => EventKind::EpochEnd,
            Ingest => EventKind::IngestEnd,
            Refresh => EventKind::RefreshEnd,
            EpochSolve => EventKind::EpochSolveEnd,
        }
    }
}

/// RAII span: records the Begin event on construction and the End event
/// (plus a duration histogram observation) on drop. Inert — zero work,
/// zero stores — when recording is disabled at entry.
pub struct SpanGuard {
    kind: SpanKind,
    t0_ns: u64,
    active: bool,
}

impl SpanGuard {
    /// Open a span (`a`/`b` ride on the Begin event). This is cheap
    /// enough for per-iteration scopes; per-*agent* scopes should stay
    /// uninstrumented (one event per agent per step would dominate the
    /// ring at fleet scale).
    #[inline]
    pub fn enter(kind: SpanKind, a: u64, b: u64) -> SpanGuard {
        if !ENABLED.load(Ordering::Relaxed) {
            return SpanGuard { kind, t0_ns: 0, active: false };
        }
        let t0_ns = now_ns();
        record(kind.begin(), a, b);
        SpanGuard { kind, t0_ns, active: true }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if self.active {
            let now = now_ns();
            record(self.kind.end(), 0, 0);
            super::metrics::observe_span(self.kind, now.saturating_sub(self.t0_ns));
        }
    }
}

/// Open a trace span for the enclosing scope; bind the result
/// (`let _span = trace_span!(Step);`) or the guard drops immediately.
/// Payloads: `trace_span!(Gossip, rounds)` / `trace_span!(Step, t, m)`.
#[macro_export]
macro_rules! trace_span {
    ($kind:ident) => {
        $crate::trace_span!($kind, 0u64, 0u64)
    };
    ($kind:ident, $a:expr) => {
        $crate::trace_span!($kind, $a, 0u64)
    };
    ($kind:ident, $a:expr, $b:expr) => {
        $crate::obs::trace::SpanGuard::enter(
            $crate::obs::trace::SpanKind::$kind,
            $a as u64,
            $b as u64,
        )
    };
}

/// Record one instant event (counter semantics — the metrics registry
/// accumulates payloads by kind): `trace_event!(GossipRound, edges,
/// dropped)`.
#[macro_export]
macro_rules! trace_event {
    ($kind:ident) => {
        $crate::trace_event!($kind, 0u64, 0u64)
    };
    ($kind:ident, $a:expr) => {
        $crate::trace_event!($kind, $a, 0u64)
    };
    ($kind:ident, $a:expr, $b:expr) => {
        $crate::obs::trace::record($crate::obs::trace::EventKind::$kind, $a as u64, $b as u64)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut rec = Recorder::with_capacity(16);
        for i in 0..20u64 {
            rec.push(Event { kind: EventKind::GossipRound, t_ns: i, a: i, b: 0 });
        }
        assert_eq!(rec.len(), 16);
        assert_eq!(rec.dropped(), 4);
        let events = rec.events();
        assert_eq!(events.len(), 16);
        // Oldest surviving event is #4; newest is #19.
        assert_eq!(events[0].a, 4);
        assert_eq!(events[15].a, 19);
    }

    #[test]
    fn ring_linearizes_before_wrap() {
        let mut rec = Recorder::with_capacity(32);
        for i in 0..5u64 {
            rec.push(Event { kind: EventKind::StepBegin, t_ns: i, a: i, b: 0 });
        }
        let events = rec.events();
        assert_eq!(events.len(), 5);
        assert!(events.iter().enumerate().all(|(i, e)| e.a == i as u64));
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn codes_round_trip() {
        for code in 0..=29u16 {
            let kind = EventKind::from_code(code).expect("contiguous codes");
            assert_eq!(kind.code(), code);
            assert_eq!(EventKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(EventKind::from_code(999), None);
        assert_eq!(EventKind::from_name("NotAKind"), None);
    }

    #[test]
    fn begin_end_pairing_is_consistent() {
        for code in 0..=29u16 {
            let kind = EventKind::from_code(code).unwrap();
            if kind.is_begin() || kind.is_end() {
                assert!(kind.span_label().is_some(), "{kind:?} needs a span label");
            }
            assert!(!(kind.is_begin() && kind.is_end()));
        }
    }

    #[test]
    fn record_and_snapshot_round_trip() {
        let _guard = test_lock();
        enable(64);
        record(EventKind::StepBegin, 7, 0);
        record(EventKind::GossipRound, 12, 3);
        record(EventKind::StepEnd, 0, 0);
        disable();
        let snap = snapshot();
        let det = deterministic_events(&snap);
        assert_eq!(det, vec![(1, 7, 0), (12, 12, 3), (2, 0, 0)]);
        // Re-enable resets the rings: the previous capture is gone.
        enable(64);
        disable();
        assert!(deterministic_events(&snapshot()).is_empty());
    }

    #[test]
    fn disabled_recording_is_inert() {
        let _guard = test_lock();
        disable();
        record(EventKind::StepBegin, 1, 2);
        let span = SpanGuard::enter(SpanKind::Qr, 0, 0);
        assert!(!span.active);
        drop(span);
        // Nothing above may have opened recording.
        assert!(!enabled());
    }

    #[test]
    fn span_guard_emits_begin_and_end() {
        let _guard = test_lock();
        enable(64);
        {
            let _span = trace_span!(Gossip, 8u64);
            trace_event!(GossipRound, 4u64, 1u64);
        }
        disable();
        let det = deterministic_events(&snapshot());
        assert_eq!(
            det,
            vec![
                (EventKind::GossipBegin.code(), 8, 0),
                (EventKind::GossipRound.code(), 4, 1),
                (EventKind::GossipEnd.code(), 0, 0),
            ]
        );
    }

    #[test]
    fn scheduling_kinds_are_masked_from_determinism() {
        let _guard = test_lock();
        enable(64);
        record(EventKind::JobPublish, 1, 4);
        record(EventKind::ChunkClaim, 2, 3);
        record(EventKind::WorkerBusy, 2, 1);
        record(EventKind::WorkerIdle, 2, 1);
        record(EventKind::GossipRound, 6, 0);
        disable();
        let det = deterministic_events(&snapshot());
        assert_eq!(det, vec![(EventKind::GossipRound.code(), 6, 0)]);
    }
}
