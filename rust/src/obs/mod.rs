//! `obs` — the flight recorder: structured tracing, a metrics registry,
//! and post-run exporters.
//!
//! The repo's three standing contracts shape every piece of this
//! subsystem:
//!
//! - **Zero steady-state allocation.** Events are fixed-size 32-byte
//!   records written into preallocated per-thread ring buffers
//!   ([`trace::Recorder::with_capacity`]); recording is an atomic
//!   enabled check, a couple of counter bumps, and one indexed store.
//!   No formatting, boxing, or channel nodes anywhere on the hot path —
//!   `rust/tests/alloc_free.rs` audits a traced `Solver::step` at zero
//!   allocations after warm-up.
//! - **Bit-determinism.** Every event that describes *algorithmic*
//!   progress (solver phases, gossip rounds, SimNet drops) is recorded
//!   on the caller thread in program order, so the deterministic event
//!   stream — timestamps masked — is identical across thread counts and
//!   seeded replays ([`trace::deterministic_events`]). Events that
//!   describe *scheduling* (job publish, chunk claims, worker busy/idle)
//!   are inherently thread-count-dependent and are excluded from the
//!   comparison by kind.
//! - **One timing seam.** Timestamps come only from
//!   [`crate::util::timer::Timer::elapsed_nanos`] against a process
//!   epoch; no other wall-clock read exists in this module (enforced by
//!   `cargo xtask lint`). Timestamps order events for humans and
//!   Perfetto; they carry no algorithmic meaning and are masked in
//!   determinism comparisons.
//!
//! Layout:
//!
//! - [`trace`] — event kinds, the per-thread ring recorder, span guards,
//!   and the `trace_span!` / `trace_event!` macros.
//! - [`metrics`] — a static registry of named counters and log-scale
//!   histograms, preregistered so steady state allocates nothing.
//! - [`export`] — JSON Lines and Chrome Trace Format (Perfetto) writers
//!   that drain the rings *after* a run.
//! - [`summary`] — the `deepca trace <file>` summarizer over exported
//!   JSONL: top spans by self-time, per-worker utilization, gossip
//!   round/byte totals, and the fault timeline.
//!
//! Capture a trace from the CLI with `--trace <path>` on `run`,
//! `stream`, or `gossip` (a `.json` extension writes Chrome Trace
//! Format for Perfetto; anything else writes JSONL for `deepca trace`),
//! or from code via `Session::trace(path)` / `OnlineSession::trace(path)`.

pub mod export;
pub mod metrics;
pub mod summary;
pub mod trace;
