//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`make artifacts`) runs Python exactly once:
//! `python/compile/aot.py` lowers the Layer-2 JAX model (which calls the
//! Layer-1 Pallas kernels) to **HLO text** per shape variant, plus a
//! `manifest.json`. This module is the request-path half:
//!
//! - [`json`] — minimal JSON parser (no `serde` offline) for the manifest;
//! - [`artifact`] — manifest discovery & shape-keyed artifact registry;
//! - [`executable`] — compile HLO text through the PJRT CPU client and
//!   execute with `f64` matrices (converted to the artifact's f32 at the
//!   boundary);
//! - [`backend`] — [`backend::PjrtBackend`] implementing
//!   [`crate::algo::backend::PowerBackend`] so DeEPCA/DePCA run their
//!   power steps through the compiled artifacts, plus the fused
//!   tracking-step engine used by the end-to-end example.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md §7).

pub mod json;
pub mod artifact;
pub mod executable;
pub mod backend;
