//! Artifact manifest: what `make artifacts` produced.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json`:
//!
//! ```json
//! {
//!   "jax_version": "0.8.2",
//!   "artifacts": [
//!     {"name": "power_step_d300_k5", "kind": "power_step",
//!      "d": 300, "k": 5, "file": "power_step_d300_k5.hlo.txt"},
//!     ...
//!   ]
//! }
//! ```
//!
//! The registry is shape-keyed: algorithms ask for `(kind, d, k)` and get
//! the artifact path (or `None`, at which point callers fall back to the
//! Rust backend and say so).

use super::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// The role an artifact plays.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(A[d,d], W[d,k]) -> A·W` — Pallas matmul power step.
    PowerStep,
    /// `(S, A, W, W_prev) -> S + A(W−W_prev)` — fused tracking update.
    DeepcaStep,
    /// `(S[d,k], W0[d,k]) -> SignAdjust(MGS(S), W0)` — L2 orthonormalize.
    Orthonormalize,
    /// `(X[n,d]) -> XᵀX/n` — Pallas Gram/covariance builder.
    Gram,
}

impl ArtifactKind {
    /// Manifest string → kind.
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "power_step" => Some(Self::PowerStep),
            "deepca_step" => Some(Self::DeepcaStep),
            "orthonormalize" => Some(Self::Orthonormalize),
            "gram" => Some(Self::Gram),
            _ => None,
        }
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Artifact name (file stem).
    pub name: String,
    /// Role.
    pub kind: ArtifactKind,
    /// Primary dimension d (rows for Gram).
    pub d: usize,
    /// Secondary dimension: k for steps, n for Gram.
    pub k: usize,
    /// HLO text file path (absolute).
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// All entries.
    pub entries: Vec<ArtifactEntry>,
    /// jax version recorded at build time.
    pub jax_version: String,
    /// Directory the manifest lives in.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let jax_version = j
            .get("jax_version")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown")
            .to_string();
        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .context("manifest missing `artifacts` array")?;
        let mut entries = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .context("artifact missing name")?
                .to_string();
            let kind_str = a
                .get("kind")
                .and_then(|v| v.as_str())
                .context("artifact missing kind")?;
            let Some(kind) = ArtifactKind::from_str(kind_str) else {
                // Forward-compat: skip unknown kinds.
                continue;
            };
            let d = a.get("d").and_then(|v| v.as_usize()).context("missing d")?;
            let k = a.get("k").and_then(|v| v.as_usize()).context("missing k")?;
            let file = a
                .get("file")
                .and_then(|v| v.as_str())
                .context("artifact missing file")?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("manifest references missing file {}", path.display());
            }
            entries.push(ArtifactEntry { name, kind, d, k, path });
        }
        Ok(Manifest { entries, jax_version, dir: dir.to_path_buf() })
    }

    /// Default artifacts directory: `$DEEPCA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DEEPCA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Find the artifact for `(kind, d, k)`.
    pub fn find(&self, kind: ArtifactKind, d: usize, k: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.d == d && e.k == k)
    }

    /// All (d, k) shape pairs available for a kind.
    pub fn shapes(&self, kind: ArtifactKind) -> Vec<(usize, usize)> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| (e.d, e.k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("deepca_manifest_test1");
        let _ = std::fs::remove_dir_all(&dir);
        write_manifest(
            &dir,
            r#"{"jax_version": "0.8.2", "artifacts": [
                {"name": "power_step_d8_k2", "kind": "power_step", "d": 8, "k": 2, "file": "p.hlo.txt"},
                {"name": "future_thing", "kind": "hologram", "d": 1, "k": 1, "file": "p.hlo.txt"}
            ]}"#,
        );
        std::fs::write(dir.join("p.hlo.txt"), "HloModule x").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.entries.len(), 1, "unknown kinds skipped");
        assert!(m.find(ArtifactKind::PowerStep, 8, 2).is_some());
        assert!(m.find(ArtifactKind::PowerStep, 8, 3).is_none());
        assert_eq!(m.shapes(ArtifactKind::PowerStep), vec![(8, 2)]);
    }

    #[test]
    fn missing_file_errors() {
        let dir = std::env::temp_dir().join("deepca_manifest_test2");
        let _ = std::fs::remove_dir_all(&dir);
        write_manifest(
            &dir,
            r#"{"artifacts": [{"name": "x", "kind": "gram", "d": 4, "k": 4, "file": "nope.hlo.txt"}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_helpful_error() {
        let dir = std::env::temp_dir().join("deepca_manifest_test3_nonexistent");
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn kind_roundtrip() {
        for (s, k) in [
            ("power_step", ArtifactKind::PowerStep),
            ("deepca_step", ArtifactKind::DeepcaStep),
            ("orthonormalize", ArtifactKind::Orthonormalize),
            ("gram", ArtifactKind::Gram),
        ] {
            assert_eq!(ArtifactKind::from_str(s), Some(k));
        }
        assert_eq!(ArtifactKind::from_str("nope"), None);
    }
}
