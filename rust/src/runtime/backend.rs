//! PJRT-backed local compute: the production three-layer path.
//!
//! [`PjrtBackend`] implements [`PowerBackend`] by executing the
//! `power_step_d{d}_k{k}` artifact (Layer-1 Pallas matmul lowered through
//! the Layer-2 JAX model). [`PjrtStepEngine`] additionally drives the
//! fused `deepca_step` tracking artifact and the `orthonormalize`
//! (MGS + SignAdjust) artifact, so an end-to-end DeEPCA iteration's
//! numerics run entirely inside compiled XLA — Rust only orchestrates
//! and communicates.
//!
//! The local matrices `A_j` are converted to f32 literals **once** at
//! construction and reused every iteration (they are the big operands:
//! d² floats vs d·k for the iterate) — see EXPERIMENTS.md §Perf.

use super::artifact::{ArtifactKind, Manifest};
use super::executable::{Executable, PjrtContext};
use crate::algo::backend::PowerBackend;
use crate::consensus::AgentStack;
use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::rc::Rc;

/// PJRT implementation of the power-step backend.
pub struct PjrtBackend {
    power_step: Executable,
    locals_lit: Vec<xla::Literal>,
    m: usize,
    d: usize,
    k: usize,
}

fn mat_to_f32_literal(m: &Mat) -> Result<xla::Literal> {
    let data: Vec<f32> = m.data().iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&data)
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .context("reshaping literal")
}

impl PjrtBackend {
    /// Load the `(d, k)` power-step artifact and pre-upload the locals.
    pub fn new(
        ctx: &Rc<PjrtContext>,
        manifest: &Manifest,
        locals: &[Mat],
        k: usize,
    ) -> Result<Self> {
        anyhow::ensure!(!locals.is_empty());
        let d = locals[0].rows();
        let entry = manifest
            .find(ArtifactKind::PowerStep, d, k)
            .with_context(|| {
                format!(
                    "no power_step artifact for d={d}, k={k}; available: {:?}",
                    manifest.shapes(ArtifactKind::PowerStep)
                )
            })?;
        let power_step = ctx.load_hlo(&entry.path)?;
        let locals_lit = locals
            .iter()
            .map(mat_to_f32_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(PjrtBackend { power_step, locals_lit, m: locals.len(), d, k })
    }

    /// Execute `A_j · w` through the artifact.
    fn product(&self, agent: usize, w: &Mat) -> Result<Mat> {
        assert_eq!(w.shape(), (self.d, self.k), "iterate shape mismatch");
        let w_lit = mat_to_f32_literal(w)?;
        let inputs: Vec<&xla::Literal> = vec![&self.locals_lit[agent], &w_lit];
        let result = self
            .power_step
            .run_literals(&inputs)
            .context("power_step execution")?;
        anyhow::ensure!(result.len() == 1, "power_step must return 1 output");
        Ok(result.into_iter().next().unwrap())
    }

    /// Execute `A_j · w` through the artifact, landing directly in a
    /// caller-owned buffer (no intermediate `Mat`).
    fn product_into(&self, agent: usize, w: &Mat, out: &mut Mat) -> Result<()> {
        assert_eq!(w.shape(), (self.d, self.k), "iterate shape mismatch");
        let w_lit = mat_to_f32_literal(w)?;
        let inputs: Vec<&xla::Literal> = vec![&self.locals_lit[agent], &w_lit];
        self.power_step
            .run_literals_into(&inputs, out)
            .context("power_step execution")
    }
}

impl PowerBackend for PjrtBackend {
    fn m(&self) -> usize {
        self.m
    }

    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        self.product(agent, w)
            .expect("PJRT power_step execution failed")
    }

    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        // Lowered through the executable path straight into the caller's
        // buffer instead of inheriting the allocating trait default
        // (which would materialize a Mat per product and copy it over).
        self.product_into(agent, w, out)
            .expect("PJRT power_step execution failed")
    }

    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        // The batched per-iteration form the solvers drive: every
        // agent's product runs through the compiled power_step artifact,
        // landing in the solver's persistent product stack. The PJRT
        // client is Rc-based and single-threaded, so the batch stays on
        // the leader thread; the per-agent A_j literals were uploaded
        // once at construction.
        assert_eq!(ws.m(), self.m);
        assert_eq!(out.m(), self.m);
        for j in 0..self.m {
            self.product_into(j, ws.slice(j), out.slice_mut(j))
                .expect("PJRT power_step execution failed");
        }
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Full PJRT iteration engine: fused tracking step + orthonormalize.
pub struct PjrtStepEngine {
    deepca_step: Executable,
    orthonormalize: Executable,
    locals_lit: Vec<xla::Literal>,
    d: usize,
    k: usize,
}

impl PjrtStepEngine {
    /// Load the fused artifacts for `(d, k)`.
    pub fn new(
        ctx: &Rc<PjrtContext>,
        manifest: &Manifest,
        locals: &[Mat],
        k: usize,
    ) -> Result<Self> {
        anyhow::ensure!(!locals.is_empty());
        let d = locals[0].rows();
        let step_entry = manifest
            .find(ArtifactKind::DeepcaStep, d, k)
            .with_context(|| format!("no deepca_step artifact for d={d}, k={k}"))?;
        let orth_entry = manifest
            .find(ArtifactKind::Orthonormalize, d, k)
            .with_context(|| format!("no orthonormalize artifact for d={d}, k={k}"))?;
        Ok(PjrtStepEngine {
            deepca_step: ctx.load_hlo(&step_entry.path)?,
            orthonormalize: ctx.load_hlo(&orth_entry.path)?,
            locals_lit: locals.iter().map(mat_to_f32_literal).collect::<Result<_>>()?,
            d,
            k,
        })
    }

    /// Number of agents.
    pub fn m(&self) -> usize {
        self.locals_lit.len()
    }

    /// Eqn. 3.1 fused: `S + A_j(W − W_prev)` for agent j.
    pub fn tracking_update(&self, agent: usize, s: &Mat, w: &Mat, w_prev: &Mat) -> Result<Mat> {
        assert_eq!(s.shape(), (self.d, self.k));
        let s_lit = mat_to_f32_literal(s)?;
        let w_lit = mat_to_f32_literal(w)?;
        let wp_lit = mat_to_f32_literal(w_prev)?;
        let inputs: Vec<&xla::Literal> =
            vec![&s_lit, &self.locals_lit[agent], &w_lit, &wp_lit];
        let out = self.deepca_step.run_literals(&inputs)?;
        anyhow::ensure!(out.len() == 1);
        Ok(out.into_iter().next().unwrap())
    }

    /// Eqn. 3.3: `SignAdjust(MGS(S), W0)` through the artifact.
    pub fn orthonormalize(&self, s: &Mat, w0: &Mat) -> Result<Mat> {
        let s_lit = mat_to_f32_literal(s)?;
        let w0_lit = mat_to_f32_literal(w0)?;
        let inputs: Vec<&xla::Literal> = vec![&s_lit, &w0_lit];
        let out = self.orthonormalize.run_literals(&inputs)?;
        anyhow::ensure!(out.len() == 1);
        Ok(out.into_iter().next().unwrap())
    }
}

#[cfg(test)]
mod tests {
    // Needs built artifacts — exercised in rust/tests/pjrt_integration.rs.
}
