//! Minimal recursive-descent JSON parser (offline stand-in for `serde_json`).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP only). Used to read `artifacts/manifest.json`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true`/`false`
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (order-insensitive).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.pos;
                    while !matches!(self.peek(), None | Some(b'"') | Some(b'\\')) {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} found {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"artifacts": [{"name": "a", "d": 300, "k": 5, "ok": true}], "v": null}"#;
        let j = Json::parse(doc).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(arts[0].get("d").unwrap().as_usize(), Some(300));
        assert_eq!(j.get("v"), Some(&Json::Null));
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n  \"x\" :\t[ 1 , 2 ]\n} ").unwrap();
        assert_eq!(j.get("x").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
