//! HLO-text → PJRT executable, with `Mat`-level execute helpers.
//!
//! All artifacts are lowered with `return_tuple=True`, so outputs are
//! N-tuples of f32 arrays; inputs are f32 arrays. The boundary converts
//! the crate's `f64` [`Mat`] to f32 on the way in and back on the way
//! out (artifact numerics are validated against the Rust backend to
//! ~1e-4 relative in the integration tests — single precision, not a
//! bug).

use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::path::Path;
use std::rc::Rc;

/// Shared PJRT CPU client (single-threaded; the client is `Rc`-based).
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Create a CPU client.
    pub fn cpu() -> Result<Rc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Rc::new(PjrtContext { client }))
    }

    /// Platform string for reports.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text from `path` and compile it.
    pub fn load_hlo(self: &Rc<Self>, path: &Path) -> Result<Executable> {
        let path_str = path
            .to_str()
            .context("artifact path is not valid UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { _ctx: Rc::clone(self), exe, name: path.display().to_string() })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    _ctx: Rc<PjrtContext>,
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with `Mat` inputs; returns the tuple elements as `Mat`s.
    ///
    /// Every input is converted to a f32 literal of its exact shape;
    /// outputs are read back as f32 and widened to f64.
    pub fn run(&self, inputs: &[&Mat]) -> Result<Vec<Mat>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| mat_to_literal(m))
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("decomposing result tuple")?;
        parts.into_iter().map(|l| literal_to_mat(&l)).collect()
    }

    /// Execute with pre-built literals and decompose the output tuple
    /// (the shared execute → fetch → untuple pipeline behind both the
    /// allocating and `_into` literal entry points).
    fn run_literal_parts(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        out.to_tuple().context("decomposing result tuple")
    }

    /// Execute with pre-built literals (lets callers cache the big,
    /// iteration-invariant operands like `A_j`); returns tuple elements
    /// as `Mat`s.
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<Mat>> {
        self.run_literal_parts(inputs)?
            .into_iter()
            .map(|l| literal_to_mat(&l))
            .collect()
    }

    /// Execute expecting exactly one output.
    pub fn run1(&self, inputs: &[&Mat]) -> Result<Mat> {
        let mut outs = self.run(inputs)?;
        anyhow::ensure!(outs.len() == 1, "{}: expected 1 output, got {}", self.name, outs.len());
        Ok(outs.pop().unwrap())
    }

    /// Execute with pre-built literals, expecting exactly one output,
    /// widened straight into a caller-owned buffer (shape-checked) — the
    /// `_into` form of [`Executable::run_literals`] for hot loops that
    /// keep their landing stacks across iterations (the batched
    /// power-step products). Skips the intermediate `Mat` the allocating
    /// form materializes per call.
    pub fn run_literals_into(&self, inputs: &[&xla::Literal], out: &mut Mat) -> Result<()> {
        let parts = self.run_literal_parts(inputs)?;
        anyhow::ensure!(
            parts.len() == 1,
            "{}: expected 1 output, got {}",
            self.name,
            parts.len()
        );
        literal_into_mat(&parts[0], out)
    }
}

/// `Mat` (f64) → f32 literal with shape `[rows, cols]`.
fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    let f32data: Vec<f32> = m.data().iter().map(|&x| x as f32).collect();
    let lit = xla::Literal::vec1(&f32data);
    lit.reshape(&[m.rows() as i64, m.cols() as i64])
        .context("reshaping input literal")
}

/// f32 literal → caller-owned `Mat` (f64), shape-checked against the
/// buffer (the zero-extra-allocation landing used by
/// [`Executable::run_literals_into`]; `to_vec` still materializes the
/// f32 host copy — that is the PJRT readback, not avoidable here).
fn literal_into_mat(l: &xla::Literal, out: &mut Mat) -> Result<()> {
    let shape = l.array_shape().context("output shape")?;
    let dims = shape.dims();
    anyhow::ensure!(dims.len() == 2, "expected rank-2 output, got {:?}", dims);
    let (r, c) = (dims[0] as usize, dims[1] as usize);
    anyhow::ensure!(
        out.shape() == (r, c),
        "output buffer is {:?}, artifact produced ({r}, {c})",
        out.shape()
    );
    let data: Vec<f32> = l.to_vec().context("reading output literal")?;
    anyhow::ensure!(data.len() == r * c, "output size mismatch");
    for (dst, src) in out.data_mut().iter_mut().zip(&data) {
        *dst = *src as f64;
    }
    Ok(())
}

/// f32 literal → `Mat` (f64).
fn literal_to_mat(l: &xla::Literal) -> Result<Mat> {
    let shape = l.array_shape().context("output shape")?;
    let dims = shape.dims();
    anyhow::ensure!(dims.len() == 2, "expected rank-2 output, got {:?}", dims);
    let data: Vec<f32> = l.to_vec().context("reading output literal")?;
    let (r, c) = (dims[0] as usize, dims[1] as usize);
    anyhow::ensure!(data.len() == r * c, "output size mismatch");
    Ok(Mat::from_vec(r, c, data.into_iter().map(|x| x as f64).collect()))
}

#[cfg(test)]
mod tests {
    // Compile/execute tests live in `rust/tests/pjrt_integration.rs` —
    // they need the artifacts built by `make artifacts`. Here we only
    // test the pure conversion helpers.
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mat_literal_roundtrip() {
        let mut rng = Rng::seed_from(231);
        let m = Mat::randn(5, 3, &mut rng);
        let lit = mat_to_literal(&m).unwrap();
        let back = literal_to_mat(&lit).unwrap();
        assert_eq!(back.shape(), (5, 3));
        // f32 round trip: 1e-6 relative.
        for (a, b) in m.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= 1e-6 * (1.0 + a.abs()));
        }
    }
}
