//! `deepca` — launcher CLI for the DeEPCA reproduction.
//!
//! ```text
//! deepca experiment <fig1|fig2|comm-table|ablations|robustness|all> [--scale full|small]
//! deepca run   [--config file.toml] [--algo deepca|depca|local-power|centralized]
//!              [--engine dense|parallel|threaded|distributed|sim]
//!              [--m 50] [--n 800] [--k 5] [--rounds 8] [--iters 60] [--tol 1e-9]
//!              [--k-policy fixed|increasing] [--k-base 8] [--k-slope 1.0]
//!              [--drop-prob 0.05] [--latency 3] [--noise 0.01] [--churn 0.2]   # sim engine
//!              [--dataset w8a|a9a] [--data path/to/libsvm] [--topology er|ring|grid|star|complete]
//! deepca info  [--dataset w8a|a9a] [--data path]   # spectrum / network diagnostics
//! ```

use anyhow::{bail, Context, Result};
use deepca::algo::centralized::CentralizedConfig;
use deepca::algo::local_power::LocalPowerConfig;
use deepca::algo::problem::Problem;
use deepca::cli::Args;
use deepca::config::ConfigMap;
use deepca::consensus::simnet::SimConfig;
use deepca::coordinator::session::Session;
use deepca::data::{libsvm, synthetic, Dataset};
use deepca::experiments::{ablations, comm_table, figures, robustness, Scale};
use deepca::graph::dynamic::TopologySchedule;
use deepca::graph::gossip::GossipMatrix;
use deepca::graph::topology::Topology;
use deepca::prelude::{Algo, DeepcaConfig, DepcaConfig, Engine, KPolicy, Rng};
use std::path::Path;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("run") => cmd_run(&args),
        Some("info") => cmd_info(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}` (try `deepca help`)"),
    }
}

fn print_help() {
    println!(
        "deepca — Decentralized Exact PCA (Ye & Zhang 2021) reproduction

USAGE:
  deepca experiment <fig1|fig2|comm-table|ablations|robustness|all> [--scale full|small]
  deepca run  [--config cfg.toml] [--algo deepca|depca|local-power|centralized]
              [--engine dense|parallel|threaded|distributed|sim]
              [--m N] [--n N] [--k N] [--rounds K] [--iters T] [--tol EPS]
              [--k-policy fixed|increasing] [--k-base K0] [--k-slope S]
              [--drop-prob P] [--latency L] [--noise STD] [--churn P]
              [--dataset w8a|a9a] [--data libsvm-file] [--topology er|ring|grid|star|complete]
              [--seed S]
  deepca info [--dataset w8a|a9a] [--data libsvm-file] [--m N] [--k N]

DePCA consensus schedule (--algo depca):
  --k-policy fixed       K = --k-base (default: --rounds) every iteration
  --k-policy increasing  K_t = --k-base + ceil(--k-slope * t)   (Eqn. 3.12)

SimNet fault model (--engine sim; all seeded, bit-reproducible):
  --drop-prob P   per-link message drop probability per gossip round
  --latency L     max per-link latency in virtual ticks (reported as vticks)
  --noise STD     additive Gaussian payload noise (std per scalar)
  --churn P       Markov per-link up/down churn over the base topology
                  (connectivity-floored; epoch = --rounds gossip rounds)

Outputs land in ./results (override with DEEPCA_RESULTS)."
    );
}

fn scale_of(args: &Args) -> Result<Scale> {
    let s = args.str_or("scale", "full");
    Scale::parse(&s).with_context(|| format!("bad --scale `{s}` (full|small)"))
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let scale = scale_of(args)?;
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    match which {
        "fig1" => {
            figures::run_figure(figures::Figure::Fig1W8a, scale)?;
        }
        "fig2" => {
            figures::run_figure(figures::Figure::Fig2A9a, scale)?;
        }
        "comm-table" => {
            comm_table::run(scale)?;
        }
        "ablations" => ablations::run_all(scale)?,
        "robustness" => {
            robustness::run(scale)?;
        }
        "all" => {
            figures::run_figure(figures::Figure::Fig1W8a, scale)?;
            figures::run_figure(figures::Figure::Fig2A9a, scale)?;
            comm_table::run(scale)?;
            ablations::run_all(scale)?;
            robustness::run(scale)?;
        }
        other => bail!("unknown experiment `{other}`"),
    }
    Ok(())
}

fn load_dataset(args: &Args, cfg: &ConfigMap, m: usize, n: usize) -> Result<Dataset> {
    if let Some(path) = args
        .options
        .get("data")
        .cloned()
        .or_else(|| cfg.get("data.path").map(String::from))
    {
        let dim = match args.str_or("dataset", &cfg.str_or("data.kind", "w8a")).as_str() {
            "w8a" => Some(300),
            "a9a" => Some(123),
            _ => None,
        };
        return libsvm::load(Path::new(&path), dim, Some(m * n));
    }
    let seed = args.usize_or("seed", cfg.usize_or("seed", 701)?)? as u64;
    let mut rng = Rng::seed_from(seed);
    match args.str_or("dataset", &cfg.str_or("data.kind", "w8a")).as_str() {
        "w8a" => Ok(synthetic::w8a_like_scaled(m, n, &mut rng)),
        "a9a" => Ok(synthetic::a9a_like_scaled(m, n, &mut rng)),
        other => bail!("unknown dataset `{other}` (w8a|a9a or --data <file>)"),
    }
}

fn build_topology(kind: &str, m: usize, seed: u64) -> Result<Topology> {
    Ok(match kind {
        "er" => Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(seed)),
        "ring" => Topology::ring(m),
        "grid" => {
            let rows = (1..=m)
                .rev()
                .find(|r| m % r == 0 && *r * *r <= m)
                .unwrap_or(1);
            Topology::grid(rows, m / rows)
        }
        "star" => Topology::star(m),
        "complete" => Topology::complete(m),
        other => bail!("unknown topology `{other}`"),
    })
}

/// DePCA consensus schedule from CLI flags / config keys
/// (`--k-policy/--k-base/--k-slope`, `[depca] k_policy/k_base/k_slope`).
fn build_k_policy(args: &Args, cfg: &ConfigMap, rounds: usize) -> Result<KPolicy> {
    let kind = args.str_or("k-policy", &cfg.str_or("depca.k_policy", "fixed"));
    let base = args.usize_or("k-base", cfg.usize_or("depca.k_base", rounds)?)?;
    let slope = args.f64_or("k-slope", cfg.f64_or("depca.k_slope", 1.0)?)?;
    match kind.as_str() {
        "fixed" => Ok(KPolicy::Fixed(base)),
        "increasing" => Ok(KPolicy::Increasing { base, slope }),
        other => bail!("unknown --k-policy `{other}` (fixed|increasing)"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = match args.options.get("config") {
        Some(path) => ConfigMap::load(Path::new(path))?,
        None => ConfigMap::default(),
    };
    let m = args.usize_or("m", cfg.usize_or("m", 50)?)?;
    let n = args.usize_or("n", cfg.usize_or("n", 800)?)?;
    let k = args.usize_or("k", cfg.usize_or("k", 5)?)?;
    let rounds = args.usize_or("rounds", cfg.usize_or("deepca.consensus_rounds", 8)?)?;
    let iters = args.usize_or("iters", cfg.usize_or("iters", 60)?)?;
    let tol = args.f64_or("tol", cfg.f64_or("tol", 0.0)?)?;
    let seed = args.usize_or("seed", cfg.usize_or("seed", 701)?)? as u64;
    let init_seed = cfg.usize_or("init_seed", 2021)? as u64;

    let ds = load_dataset(args, &cfg, m, n)?;
    println!(
        "dataset {} rows={} d={} density={:.4}",
        ds.name,
        ds.num_rows(),
        ds.dim(),
        ds.density()
    );
    let problem = Problem::from_dataset(&ds, m, k);
    let topo = build_topology(
        &args.str_or("topology", &cfg.str_or("topology", "er")),
        m,
        seed + 1,
    )?;
    let gossip = GossipMatrix::from_laplacian(&topo);
    println!(
        "network {} m={} edges={} 1−λ₂={:.4}",
        topo.name,
        topo.n(),
        topo.num_edges(),
        gossip.gap()
    );
    println!(
        "problem λ_k={:.4e} λ_k+1={:.4e} γ={:.4} heterogeneity={:.1}",
        problem.lambda_k(),
        problem.lambda_k1(),
        problem.gamma(),
        problem.heterogeneity()
    );

    let engine = match args.str_or("engine", &cfg.str_or("engine", "dense")).as_str() {
        "dense" => Engine::Dense,
        "parallel" => Engine::DenseParallel,
        "threaded" => Engine::Threaded,
        "distributed" => Engine::Distributed,
        "sim" => {
            let drop_prob = args.f64_or("drop-prob", cfg.f64_or("sim.drop_prob", 0.0)?)?;
            let noise_std = args.f64_or("noise", cfg.f64_or("sim.noise_std", 0.0)?)?;
            if !(0.0..=1.0).contains(&drop_prob) {
                bail!("--drop-prob {drop_prob}: must be in [0, 1]");
            }
            if noise_std < 0.0 {
                bail!("--noise {noise_std}: must be ≥ 0");
            }
            Engine::Sim(SimConfig {
                drop_prob,
                max_latency: args.usize_or("latency", cfg.usize_or("sim.latency", 0)?)? as u64,
                noise_std,
                seed,
            })
        }
        other => bail!("unknown engine `{other}`"),
    };
    // Fault-model *flags* only have meaning on the sim engine — reject
    // rather than silently run an ideal network. (Config-file `sim.*`
    // keys are engine defaults, not requests, so they are ignored on
    // other engines.)
    if !matches!(engine, Engine::Sim(_)) {
        for key in ["drop-prob", "latency", "noise", "churn"] {
            if args.options.contains_key(key) {
                bail!("--{key} requires --engine sim");
            }
        }
    }
    // Markov per-link churn: one epoch per power iteration's mix. Read
    // (and range-check) only on the sim engine, consistent with the
    // other sim.* config keys being engine defaults.
    let schedule = if matches!(engine, Engine::Sim(_)) {
        let churn = args.f64_or("churn", cfg.f64_or("sim.churn", 0.0)?)?;
        if !(0.0..=1.0).contains(&churn) {
            bail!("--churn {churn}: must be in [0, 1]");
        }
        (churn > 0.0).then(|| {
            TopologySchedule::markov(topo.clone(), churn, 0.5, seed + 2, rounds.max(1))
        })
    } else {
        None
    };
    let algo_name = args.str_or("algo", &cfg.str_or("algo", "deepca"));
    let algo = match algo_name.as_str() {
        "deepca" => Algo::Deepca(DeepcaConfig {
            consensus_rounds: rounds,
            max_iters: iters,
            tol,
            init_seed,
            sign_adjust: cfg.bool_or("deepca.sign_adjust", true)?,
            qr_canonical: cfg.bool_or("deepca.qr_canonical", true)?,
        }),
        "depca" => Algo::Depca(DepcaConfig {
            k_policy: build_k_policy(args, &cfg, rounds)?,
            max_iters: iters,
            tol,
            init_seed,
            sign_adjust: cfg.bool_or("depca.sign_adjust", true)?,
        }),
        "local-power" | "local" => Algo::LocalPower(LocalPowerConfig {
            max_iters: iters,
            init_seed,
        }),
        "centralized" | "cpca" => Algo::Centralized(CentralizedConfig {
            max_iters: iters,
            tol,
            init_seed,
        }),
        other => bail!("unknown algo `{other}` (deepca|depca|local-power|centralized)"),
    };

    let mut session = Session::on(&problem, &topo).engine(engine).algo(algo);
    if let Some(sched) = schedule {
        session = session.schedule(sched);
    }
    let report = session.solve();
    println!(
        "{algo_name} finished: {} iters ({:?}), tanθ={:.3e}, {}, {:.2}s{}",
        report.iters,
        report.reason,
        report.final_tan_theta,
        report.comm,
        report.elapsed_secs,
        if report.diverged { " [DIVERGED]" } else { "" }
    );
    deepca::experiments::report::emit_series("run", &algo_name, &report.trace)?;
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = args.usize_or("m", 50)?;
    let n = args.usize_or("n", 800)?;
    let k = args.usize_or("k", 5)?;
    let ds = load_dataset(args, &ConfigMap::default(), m, n)?;
    println!(
        "dataset {} rows={} d={} density={:.4}",
        ds.name,
        ds.num_rows(),
        ds.dim(),
        ds.density()
    );
    let problem = Problem::from_dataset(&ds, m, k);
    println!("top-{} eigenvalues:", (k + 3).min(problem.dim()));
    for (i, v) in problem.truth.values.iter().take(k + 3).enumerate() {
        println!("  λ_{} = {v:.6e}", i + 1);
    }
    println!(
        "gap (λ_k−λ_k+1)/λ_k = {:.4}, γ = {:.4}, L = {:.4e}, heterogeneity = {:.1}",
        problem.truth.relative_gap(k),
        problem.gamma(),
        problem.spectral_bound,
        problem.heterogeneity()
    );
    Ok(())
}
