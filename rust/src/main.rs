//! `deepca` — launcher CLI for the DeEPCA reproduction.
//!
//! ```text
//! deepca experiment <fig1|fig2|comm-table|ablations|robustness|tracking|all> [--scale full|small]
//! deepca stream [--drift rate|--change-at E|--fade rate] [--window rows|--forget beta]
//!              [--cold] [--epochs E] [--batch N] [--rounds K] [--power-iters T]
//! deepca run   [--config file.toml] [--algo deepca|depca|local-power|centralized]
//!              [--engine dense|parallel|threaded|distributed|sim]
//!              [--m 50] [--n 800] [--k 5] [--rounds 8] [--iters 60] [--tol 1e-9]
//!              [--k-policy fixed|increasing] [--k-base 8] [--k-slope 1.0]
//!              [--drop-prob 0.05] [--latency 3] [--noise 0.01] [--churn 0.2]   # sim engine
//!              [--dataset w8a|a9a] [--data path/to/libsvm] [--topology er|ring|grid|star|complete|rr]
//! deepca info  [--dataset w8a|a9a] [--data path]   # spectrum / network diagnostics
//! deepca gossip [--agents 100000] [--topology ring|grid|rr|er|file] [--degree 4]
//!              [--edge-file path] [--rounds 8] [--d 8] [--k 2] [--threads N] [--seed S]
//!              [--drop-prob 0.05] [--latency 2] [--noise 0.01]   # faulty fleet-scale rounds
//! deepca trace <trace.jsonl>   # summarize a --trace capture
//! ```

use anyhow::{bail, Context, Result};
use deepca::algo::centralized::CentralizedConfig;
use deepca::algo::local_power::LocalPowerConfig;
use deepca::algo::problem::Problem;
use deepca::cli::Args;
use deepca::config::ConfigMap;
use deepca::consensus::comm::{Communicator, SparseComm};
use deepca::consensus::metrics::CommStats;
use deepca::consensus::simnet::{SimConfig, SimNet};
use deepca::consensus::AgentStack;
use deepca::exec::Executor;
use deepca::coordinator::online::{OnlineConfig, OnlineSession};
use deepca::coordinator::session::Session;
use deepca::data::{libsvm, synthetic, Dataset};
use deepca::experiments::{ablations, comm_table, figures, robustness, tracking, Scale};
use deepca::graph::dynamic::TopologySchedule;
use deepca::stream::cov::Forgetting;
use deepca::stream::source::{Drift, StreamParams, SyntheticStream};
use deepca::graph::gossip::GossipMatrix;
use deepca::graph::sparse::SparseGossip;
use deepca::graph::topology::Topology;
use deepca::linalg::Mat;
use deepca::prelude::{Algo, DeepcaConfig, DepcaConfig, Engine, KPolicy, Rng};
use deepca::util::timer::Timer;
use std::path::Path;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("run") => cmd_run(&args),
        Some("stream") => cmd_stream(&args),
        Some("info") => cmd_info(&args),
        Some("gossip") => cmd_gossip(&args),
        Some("trace") => cmd_trace(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}` (try `deepca help`)"),
    }
}

fn print_help() {
    println!(
        "deepca — Decentralized Exact PCA (Ye & Zhang 2021) reproduction

USAGE:
  deepca experiment <fig1|fig2|comm-table|ablations|robustness|tracking|all> [--scale full|small]
  deepca run  [--config cfg.toml] [--algo deepca|depca|local-power|centralized]
              [--engine dense|parallel|threaded|distributed|sim] [--threads N]
              [--m N] [--n N] [--k N] [--rounds K] [--iters T] [--tol EPS]
              [--k-policy fixed|increasing] [--k-base K0] [--k-slope S]
              [--drop-prob P] [--latency L] [--noise STD] [--churn P]
              [--dataset w8a|a9a] [--data libsvm-file]
              [--topology er|ring|grid|star|complete|rr|file] [--edge-file PATH]
              [--seed S] [--trace PATH]
  deepca stream [--drift RATE | --change-at E | --fade RATE]
              [--window ROWS | --forget BETA] [--cold]
              [--m N] [--d N] [--k N] [--batch N] [--epochs E]
              [--rounds K] [--power-iters T]
              [--engine dense|parallel|threaded|sim|sparse]
              [--threads N] [--drop-prob P] [--latency L] [--noise STD] [--churn P]
              [--topology er|ring|grid|star|complete|rr|file] [--edge-file PATH]
              [--seed S] [--trace PATH]
  deepca info [--dataset w8a|a9a] [--data libsvm-file] [--m N] [--k N]
  deepca gossip [--agents 100000] [--topology ring|grid|rr|er|file] [--degree 4]
              [--edge-file PATH] [--rounds 8] [--d 8] [--k 2] [--threads N]
              [--drop-prob P] [--latency L] [--noise STD]
              [--seed S] [--trace PATH]
  deepca trace <trace.jsonl>

Flight recorder (--trace PATH): records solver phases, gossip rounds,
SimNet faults, and executor dispatch into preallocated per-thread ring
buffers, then writes PATH on exit — `.json` is Chrome Trace Format
(load in Perfetto / chrome://tracing), anything else is JSONL for
`deepca trace`, which prints top spans by self-time, per-worker
utilization, gossip volume, and the fault timeline.

Edge-list topologies (--topology file --edge-file PATH): whitespace-
separated `u v` node-id pairs, one edge per line (`#` comments and
blank lines ignored); the file fixes the agent count.

Fleet-scale smoke (deepca gossip): builds sparse CSR Metropolis gossip
weights over --agents nodes (no n×n matrix anywhere), estimates λ₂ by
seeded Lanczos, runs --rounds FastMix rounds over d×k iterates, and
fails (exit 1) on non-finite values or mean drift above 1e-9 — the CI
large-n regression gate. --topology rr is a seeded random regular
graph of even --degree.

Worker pool (--threads N): per-agent products, gossip row blocks, and
QR loops run on a persistent deterministic pool. N=0 (the default)
resolves to DEEPCA_THREADS or all cores; results are bit-identical for
every N (use --threads 1 for tiny problems where dispatch overhead
dominates).

DePCA consensus schedule (--algo depca):
  --k-policy fixed       K = --k-base (default: --rounds) every iteration
  --k-policy increasing  K_t = --k-base + ceil(--k-slope * t)   (Eqn. 3.12)

Streaming workloads (deepca stream): per epoch every agent ingests a
fresh --batch of rows into its covariance tracker, then one short
warm-started DeEPCA session (--power-iters × --rounds gossip rounds)
re-tracks the drifting subspace:
  --drift RATE      slow subspace rotation, radians per epoch
  --change-at E     abrupt change-point at epoch E
  --fade RATE       k-th spike fades while a challenger rises (crossing)
  --window ROWS     sliding-window covariance (rank-1 update/downdate)
  --forget BETA     exponential forgetting (default 0.7; 1.0 = keep all)
  --cold            restart every epoch from random (baseline contrast)
  --churn P         per-epoch Markov topology churn (any engine here;
                    the other fault flags still need --engine sim)

SimNet fault model (--engine sim, or directly on `deepca gossip` for
fleet-scale faulty rounds; all seeded, bit-reproducible):
  --drop-prob P   per-link message drop probability per gossip round
  --latency L     max per-link latency in virtual ticks (reported as vticks)
  --noise STD     additive Gaussian payload noise (std per scalar)
  --churn P       Markov per-link up/down churn over the base topology
                  (connectivity-floored; epoch = --rounds gossip rounds)

Outputs land in ./results (override with DEEPCA_RESULTS)."
    );
}

fn scale_of(args: &Args) -> Result<Scale> {
    let s = args.str_or("scale", "full");
    Scale::parse(&s).with_context(|| format!("bad --scale `{s}` (full|small)"))
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let scale = scale_of(args)?;
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    match which {
        "fig1" => {
            figures::run_figure(figures::Figure::Fig1W8a, scale)?;
        }
        "fig2" => {
            figures::run_figure(figures::Figure::Fig2A9a, scale)?;
        }
        "comm-table" => {
            comm_table::run(scale)?;
        }
        "ablations" => ablations::run_all(scale)?,
        "robustness" => {
            robustness::run(scale)?;
        }
        "tracking" => {
            tracking::run(scale)?;
        }
        "all" => {
            figures::run_figure(figures::Figure::Fig1W8a, scale)?;
            figures::run_figure(figures::Figure::Fig2A9a, scale)?;
            comm_table::run(scale)?;
            ablations::run_all(scale)?;
            robustness::run(scale)?;
            tracking::run(scale)?;
        }
        other => bail!("unknown experiment `{other}`"),
    }
    Ok(())
}

fn load_dataset(args: &Args, cfg: &ConfigMap, m: usize, n: usize) -> Result<Dataset> {
    if let Some(path) = args
        .options
        .get("data")
        .cloned()
        .or_else(|| cfg.get("data.path").map(String::from))
    {
        let dim = match args.str_or("dataset", &cfg.str_or("data.kind", "w8a")).as_str() {
            "w8a" => Some(300),
            "a9a" => Some(123),
            _ => None,
        };
        return libsvm::load(Path::new(&path), dim, Some(m * n));
    }
    let seed = args.usize_or("seed", cfg.usize_or("seed", 701)?)? as u64;
    let mut rng = Rng::seed_from(seed);
    match args.str_or("dataset", &cfg.str_or("data.kind", "w8a")).as_str() {
        "w8a" => Ok(synthetic::w8a_like_scaled(m, n, &mut rng)),
        "a9a" => Ok(synthetic::a9a_like_scaled(m, n, &mut rng)),
        other => bail!("unknown dataset `{other}` (w8a|a9a or --data <file>)"),
    }
}

/// Resolve `--topology`, including the `file` kind (`--edge-file
/// <path>`: whitespace-separated `u v` lines). A file topology fixes
/// the agent count itself; `m_from_file` says whether the caller can
/// adopt it (`deepca gossip` without an explicit `--agents`) or must
/// see it match the problem's agent count.
fn resolve_topology(
    args: &Args,
    kind: &str,
    m: usize,
    m_from_file: bool,
    seed: u64,
    degree: usize,
) -> Result<Topology> {
    if kind == "file" {
        let path = args
            .options
            .get("edge-file")
            .ok_or_else(|| anyhow::anyhow!("--topology file requires --edge-file <path>"))?;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading edge list {path}"))?;
        let topo = Topology::from_edge_list_text(&text, &format!("file({path})"))
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        if !m_from_file && topo.n() != m {
            bail!(
                "{path}: edge list spans {} agents but the run asked for {m}",
                topo.n()
            );
        }
        if !topo.is_connected() {
            bail!("{path}: edge-list graph is not connected");
        }
        return Ok(topo);
    }
    build_topology(kind, m, seed, degree)
}

fn build_topology(kind: &str, m: usize, seed: u64, degree: usize) -> Result<Topology> {
    Ok(match kind {
        "er" => Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(seed)),
        "ring" => Topology::ring(m),
        "grid" => {
            let rows = (1..=m)
                .rev()
                .find(|r| m % r == 0 && *r * *r <= m)
                .unwrap_or(1);
            Topology::grid(rows, m / rows)
        }
        "star" => Topology::star(m),
        "complete" => Topology::complete(m),
        "rr" => {
            if degree % 2 != 0 || degree == 0 {
                bail!("--degree {degree}: random regular needs an even degree ≥ 2");
            }
            if m <= degree {
                bail!("--degree {degree}: need more than `degree` agents (got {m})");
            }
            Topology::random_regular(m, degree, &mut Rng::seed_from(seed))
        }
        other => bail!("unknown topology `{other}`"),
    })
}

/// Execution engine from CLI flags / config keys. Fault-model *flags*
/// only have meaning on the sim engine — reject rather than silently
/// run an ideal network. (Config-file `sim.*` keys are engine defaults,
/// not requests, so they are ignored on other engines.)
fn parse_engine(args: &Args, cfg: &ConfigMap, seed: u64) -> Result<Engine> {
    let engine = match args.str_or("engine", &cfg.str_or("engine", "dense")).as_str() {
        "dense" => Engine::Dense,
        "parallel" => Engine::DenseParallel,
        "threaded" => Engine::Threaded,
        "distributed" => Engine::Distributed,
        "sparse" => Engine::Sparse,
        "sim" => {
            let drop_prob = args.f64_or("drop-prob", cfg.f64_or("sim.drop_prob", 0.0)?)?;
            let noise_std = args.f64_or("noise", cfg.f64_or("sim.noise_std", 0.0)?)?;
            if !(0.0..=1.0).contains(&drop_prob) {
                bail!("--drop-prob {drop_prob}: must be in [0, 1]");
            }
            if noise_std < 0.0 {
                bail!("--noise {noise_std}: must be ≥ 0");
            }
            Engine::Sim(SimConfig {
                drop_prob,
                max_latency: args.usize_or("latency", cfg.usize_or("sim.latency", 0)?)? as u64,
                noise_std,
                seed,
            })
        }
        other => bail!("unknown engine `{other}`"),
    };
    if !matches!(engine, Engine::Sim(_)) {
        // (--churn is validated per subcommand: `run` needs the sim
        // engine's round-level schedule, `stream` redraws the topology
        // per epoch on any engine.)
        for key in ["drop-prob", "latency", "noise"] {
            if args.options.contains_key(key) {
                bail!("--{key} requires --engine sim");
            }
        }
    }
    Ok(engine)
}

/// DePCA consensus schedule from CLI flags / config keys
/// (`--k-policy/--k-base/--k-slope`, `[depca] k_policy/k_base/k_slope`).
fn build_k_policy(args: &Args, cfg: &ConfigMap, rounds: usize) -> Result<KPolicy> {
    let kind = args.str_or("k-policy", &cfg.str_or("depca.k_policy", "fixed"));
    let base = args.usize_or("k-base", cfg.usize_or("depca.k_base", rounds)?)?;
    let slope = args.f64_or("k-slope", cfg.f64_or("depca.k_slope", 1.0)?)?;
    match kind.as_str() {
        "fixed" => Ok(KPolicy::Fixed(base)),
        "increasing" => Ok(KPolicy::Increasing { base, slope }),
        other => bail!("unknown --k-policy `{other}` (fixed|increasing)"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = match args.options.get("config") {
        Some(path) => ConfigMap::load(Path::new(path))?,
        None => ConfigMap::default(),
    };
    let m = args.usize_or("m", cfg.usize_or("m", 50)?)?;
    let n = args.usize_or("n", cfg.usize_or("n", 800)?)?;
    let k = args.usize_or("k", cfg.usize_or("k", 5)?)?;
    let rounds = args.usize_or("rounds", cfg.usize_or("deepca.consensus_rounds", 8)?)?;
    let iters = args.usize_or("iters", cfg.usize_or("iters", 60)?)?;
    let tol = args.f64_or("tol", cfg.f64_or("tol", 0.0)?)?;
    let seed = args.usize_or("seed", cfg.usize_or("seed", 701)?)? as u64;
    let init_seed = cfg.usize_or("init_seed", 2021)? as u64;

    let ds = load_dataset(args, &cfg, m, n)?;
    println!(
        "dataset {} rows={} d={} density={:.4}",
        ds.name,
        ds.num_rows(),
        ds.dim(),
        ds.density()
    );
    let problem = Problem::from_dataset(&ds, m, k);
    let topo = resolve_topology(
        args,
        &args.str_or("topology", &cfg.str_or("topology", "er")),
        m,
        false,
        seed + 1,
        args.usize_or("degree", cfg.usize_or("degree", 4)?)?,
    )?;
    let gossip = GossipMatrix::from_laplacian(&topo);
    println!(
        "network {} m={} edges={} 1−λ₂={:.4}",
        topo.name,
        topo.n(),
        topo.num_edges(),
        gossip.gap()
    );
    println!(
        "problem λ_k={:.4e} λ_k+1={:.4e} γ={:.4} heterogeneity={:.1}",
        problem.lambda_k(),
        problem.lambda_k1(),
        problem.gamma(),
        problem.heterogeneity()
    );

    let engine = parse_engine(args, &cfg, seed)?;
    // Round-level churn schedules only exist on the sim engine.
    if !matches!(engine, Engine::Sim(_)) && args.options.contains_key("churn") {
        bail!("--churn requires --engine sim");
    }
    // Markov per-link churn: one epoch per power iteration's mix. Read
    // (and range-check) only on the sim engine, consistent with the
    // other sim.* config keys being engine defaults.
    let schedule = if matches!(engine, Engine::Sim(_)) {
        let churn = args.f64_or("churn", cfg.f64_or("sim.churn", 0.0)?)?;
        if !(0.0..=1.0).contains(&churn) {
            bail!("--churn {churn}: must be in [0, 1]");
        }
        (churn > 0.0).then(|| {
            TopologySchedule::markov(topo.clone(), churn, 0.5, seed + 2, rounds.max(1))
        })
    } else {
        None
    };
    let algo_name = args.str_or("algo", &cfg.str_or("algo", "deepca"));
    let algo = match algo_name.as_str() {
        "deepca" => Algo::Deepca(DeepcaConfig {
            consensus_rounds: rounds,
            max_iters: iters,
            tol,
            init_seed,
            sign_adjust: cfg.bool_or("deepca.sign_adjust", true)?,
            qr_canonical: cfg.bool_or("deepca.qr_canonical", true)?,
        }),
        "depca" => Algo::Depca(DepcaConfig {
            k_policy: build_k_policy(args, &cfg, rounds)?,
            max_iters: iters,
            tol,
            init_seed,
            sign_adjust: cfg.bool_or("depca.sign_adjust", true)?,
        }),
        "local-power" | "local" => Algo::LocalPower(LocalPowerConfig {
            max_iters: iters,
            init_seed,
        }),
        "centralized" | "cpca" => Algo::Centralized(CentralizedConfig {
            max_iters: iters,
            tol,
            init_seed,
        }),
        other => bail!("unknown algo `{other}` (deepca|depca|local-power|centralized)"),
    };

    // 0 = auto (DEEPCA_THREADS or available_parallelism); results are
    // bit-identical for any value.
    let threads = args.usize_or("threads", cfg.usize_or("threads", 0)?)?;
    let mut session = Session::on(&problem, &topo)
        .engine(engine)
        .algo(algo)
        .threads(threads);
    if let Some(sched) = schedule {
        session = session.schedule(sched);
    }
    if let Some(path) = args.options.get("trace") {
        session = session.trace(path);
    }
    let report = session.solve();
    println!(
        "{algo_name} finished: {} iters ({:?}), tanθ={:.3e}, {}, {:.2}s{}",
        report.iters,
        report.reason,
        report.final_tan_theta,
        report.comm,
        report.elapsed_secs,
        if report.diverged { " [DIVERGED]" } else { "" }
    );
    deepca::experiments::report::emit_series("run", &algo_name, &report.trace)?;
    Ok(())
}

/// `deepca stream` — online DeEPCA over a drifting synthetic stream.
fn cmd_stream(args: &Args) -> Result<()> {
    let cfg = match args.options.get("config") {
        Some(path) => ConfigMap::load(Path::new(path))?,
        None => ConfigMap::default(),
    };
    let m = args.usize_or("m", 8)?;
    let d = args.usize_or("d", 32)?;
    let k = args.usize_or("k", 2)?;
    let batch = args.usize_or("batch", 150)?;
    let epochs = args.usize_or("epochs", 40)?;
    let rounds = args.usize_or("rounds", 8)?;
    let power_iters = args.usize_or("power-iters", 1)?;
    let seed = args.usize_or("seed", 701)? as u64;
    // Validate up front with CLI errors; the library constructors only
    // assert.
    if m < 2 {
        bail!("--m {m}: need at least 2 agents");
    }
    if k == 0 || k >= d {
        bail!("--k {k}: need 1 ≤ k < d (got d={d})");
    }
    if batch == 0 {
        bail!("--batch {batch}: must be ≥ 1 row per epoch");
    }
    if epochs == 0 {
        bail!("--epochs {epochs}: must be ≥ 1");
    }
    if rounds == 0 {
        bail!("--rounds {rounds}: must be ≥ 1 gossip round per iteration");
    }
    if power_iters == 0 {
        bail!("--power-iters {power_iters}: must be ≥ 1");
    }

    // Drift scenario: at most one of --drift / --change-at / --fade.
    let drift_flags = ["drift", "change-at", "fade"]
        .iter()
        .filter(|f| args.options.contains_key(**f))
        .count();
    if drift_flags > 1 {
        bail!("--drift, --change-at, and --fade are mutually exclusive");
    }
    let drift = if args.options.contains_key("change-at") {
        Drift::ChangePoint { at: args.usize_or("change-at", 0)? as u64 }
    } else if args.options.contains_key("fade") {
        let rate = args.f64_or("fade", 0.05)?;
        if rate <= 0.0 {
            bail!("--fade {rate}: must be > 0");
        }
        Drift::SpikeFade { rate }
    } else {
        let rate = args.f64_or("drift", 0.0)?;
        if rate < 0.0 {
            bail!("--drift {rate}: must be ≥ 0");
        }
        if rate > 0.0 {
            Drift::Rotation { rate }
        } else {
            Drift::Stationary
        }
    };
    // Only the rotation scenario pairs each signal direction with a
    // bulk direction, so only it constrains k against d.
    if matches!(drift, Drift::Rotation { .. }) && 2 * k > d {
        bail!("--drift rotation needs 2k ≤ d (got k={k}, d={d})");
    }

    // Covariance memory: --window (rows) XOR --forget (decay per epoch).
    let forgetting = match (args.options.get("window"), args.options.get("forget")) {
        (Some(_), Some(_)) => bail!("--window and --forget are mutually exclusive"),
        (Some(_), None) => {
            let rows = args.usize_or("window", 1)?;
            if rows == 0 {
                bail!("--window {rows}: must hold at least one row");
            }
            Forgetting::SlidingWindow(rows)
        }
        _ => {
            let beta = args.f64_or("forget", 0.7)?;
            if !(beta > 0.0 && beta <= 1.0) {
                bail!("--forget {beta}: must be in (0, 1]");
            }
            Forgetting::Exponential(beta)
        }
    };

    // Geometric spike profile floored above the unit bulk so every k
    // keeps a genuine eigengap (spike_i = 1 + 9·0.55^i > noise = 1).
    let spikes: Vec<f64> = (0..k).map(|i| 1.0 + 9.0 * 0.55f64.powi(i as i32)).collect();
    let mut source = SyntheticStream::new(StreamParams {
        m,
        dim: d,
        batch,
        spikes,
        noise: 1.0,
        drift,
        seed,
    });
    let topo = resolve_topology(
        args,
        &args.str_or("topology", "er"),
        m,
        false,
        seed + 1,
        args.usize_or("degree", 4)?,
    )?;
    let engine = parse_engine(args, &cfg, seed)?;
    // The per-agent-thread engine would run only the first (cold) epoch
    // and silently fall back to Threaded on every warm-started one —
    // reject rather than mix engines across epochs.
    if engine == Engine::Distributed {
        bail!("--engine distributed is not supported by `deepca stream` (dense|parallel|threaded|sim|sparse)");
    }

    let threads = args.usize_or("threads", cfg.usize_or("threads", 0)?)?;
    let mut session = OnlineSession::on(&topo).engine(engine).threads(threads).config(OnlineConfig {
        epochs,
        consensus_rounds: rounds,
        power_iters,
        warm_start: !args.flag("cold"),
        forgetting,
        init_seed: args.usize_or("init-seed", 2021)? as u64,
    });
    // Per-epoch topology churn — honored on any engine, because the
    // epoch's topology is materialized before each inner run starts.
    let churn = args.f64_or("churn", 0.0)?;
    if !(0.0..=1.0).contains(&churn) {
        bail!("--churn {churn}: must be in [0, 1]");
    }
    if churn > 0.0 {
        session = session.schedule(TopologySchedule::markov(topo.clone(), churn, 0.5, seed + 2, 1));
    }
    if let Some(path) = args.options.get("trace") {
        session = session.trace(path);
    }

    println!(
        "stream {} epochs={epochs} batch={batch} K={rounds} iters/epoch={power_iters} \
         warm={} {:?}",
        source.label(),
        !args.flag("cold"),
        forgetting,
    );
    let report = session.run(&mut source);

    let stride = (epochs / 20).max(1);
    println!("epoch  oracle-tanθ  empirical-tanθ  rounds  vticks  dropped");
    for r in report
        .records
        .iter()
        .filter(|r| r.epoch % stride as u64 == 0 || r.epoch + 1 == epochs as u64)
    {
        println!(
            "{:>5}  {:>11.3e}  {:>14.3e}  {:>6}  {:>6}  {:>7}{}",
            r.epoch,
            r.oracle_tan_theta,
            r.empirical_tan_theta,
            r.rounds,
            r.virtual_time,
            r.dropped,
            if r.diverged { "  [DIVERGED]" } else { "" }
        );
    }
    let burn = epochs / 4;
    println!(
        "tracking error after burn-in ({burn} epochs): mean {:.3e}, max {:.3e}; {}",
        report.mean_oracle_after(burn),
        report.max_oracle_after(burn),
        report.comm
    );
    let fname = format!(
        "stream_{}.csv",
        report.scenario.replace(['=', ' ', '(', ')', ','], "_")
    );
    let path = deepca::experiments::report::write_result(&fname, &report.to_csv())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `deepca trace <file>` — summarize a JSONL flight-recorder trace:
/// top spans by self-time, per-worker utilization, gossip volume, and
/// the fault timeline.
fn cmd_trace(args: &Args) -> Result<()> {
    let Some(path) = args.positionals.first() else {
        bail!("usage: deepca trace <trace.jsonl> (captured via --trace)");
    };
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let summary = deepca::obs::summary::summarize(&text)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    print!("{summary}");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = args.usize_or("m", 50)?;
    let n = args.usize_or("n", 800)?;
    let k = args.usize_or("k", 5)?;
    let ds = load_dataset(args, &ConfigMap::default(), m, n)?;
    println!(
        "dataset {} rows={} d={} density={:.4}",
        ds.name,
        ds.num_rows(),
        ds.dim(),
        ds.density()
    );
    let problem = Problem::from_dataset(&ds, m, k);
    println!("top-{} eigenvalues:", (k + 3).min(problem.dim()));
    for (i, v) in problem.truth.values.iter().take(k + 3).enumerate() {
        println!("  λ_{} = {v:.6e}", i + 1);
    }
    println!(
        "gap (λ_k−λ_k+1)/λ_k = {:.4}, γ = {:.4}, L = {:.4e}, heterogeneity = {:.1}",
        problem.truth.relative_gap(k),
        problem.gamma(),
        problem.spectral_bound,
        problem.heterogeneity()
    );
    Ok(())
}

/// `deepca gossip` — fleet-scale FastMix smoke test. Builds a sparse
/// CSR Metropolis gossip operator over `--agents` nodes (no n×n matrix
/// anywhere in the process), runs `--rounds` FastMix rounds over random
/// d×k iterates on the worker pool, and verifies the doubly-stochastic
/// invariant (mean preservation) and finiteness — exiting nonzero on
/// violation so CI can gate large-n regressions on it. With
/// `--drop-prob/--latency/--noise` the rounds go through the sparse
/// SimNet's fault-plan path instead, and the gate becomes deviation
/// contraction (drops break exact mean preservation by design).
fn cmd_gossip(args: &Args) -> Result<()> {
    let m = args.usize_or("agents", 100_000)?;
    let d = args.usize_or("d", 8)?;
    let k = args.usize_or("k", 2)?;
    let rounds = args.usize_or("rounds", 8)?;
    let seed = args.usize_or("seed", 701)? as u64;
    let threads = args.usize_or("threads", 0)?;
    if m < 3 {
        bail!("--agents {m}: need at least 3 agents");
    }
    if d == 0 || k == 0 {
        bail!("--d {d} / --k {k}: iterate shape must be nonzero");
    }
    if rounds == 0 {
        bail!("--rounds {rounds}: must run at least one round");
    }
    let kind = args.str_or("topology", "ring");
    let topo = resolve_topology(
        args,
        &kind,
        m,
        !args.options.contains_key("agents"),
        seed + 1,
        args.usize_or("degree", 4)?,
    )?;
    // A file topology fixes the agent count itself.
    let m = topo.n();

    // Fault flags route the rounds through the sparse-weight SimNet —
    // the same CSR Metropolis operator, with seeded drops / latency /
    // noise generated per round into a fault plan and applied on the
    // worker pool (bit-reproducible for any --threads).
    let drop_prob = args.f64_or("drop-prob", 0.0)?;
    let latency = args.usize_or("latency", 0)? as u64;
    let noise_std = args.f64_or("noise", 0.0)?;
    if !(0.0..=1.0).contains(&drop_prob) {
        bail!("--drop-prob {drop_prob}: must be in [0, 1]");
    }
    if noise_std < 0.0 {
        bail!("--noise {noise_std}: must be ≥ 0");
    }
    let faulty = drop_prob > 0.0 || latency > 0 || noise_std > 0.0;

    let exec = Arc::new(Executor::new(threads));
    let t = Timer::start();
    let (comm, edges): (Box<dyn Communicator>, usize) = if faulty {
        let edges = topo.num_edges();
        let net = SimNet::sparse(
            TopologySchedule::fixed(topo.clone()),
            SimConfig { drop_prob, max_latency: latency, noise_std, seed: seed + 2 },
        )
        .with_executor(Arc::clone(&exec));
        println!(
            "network {} m={} edges={} faulty sim: drop {drop_prob:.3} latency {latency} \
             noise {noise_std:.1e} (CSR build + Lanczos: {:.2}s)",
            topo.name,
            m,
            edges,
            t.elapsed_secs()
        );
        (Box::new(net), edges)
    } else {
        let sparse = SparseGossip::metropolis(&topo);
        let build_secs = t.elapsed_secs();
        let info = sparse.info();
        println!(
            "network {} m={} edges={} λ₂≈{:.6} η={:.4} (CSR build + Lanczos: {build_secs:.2}s)",
            topo.name,
            m,
            sparse.edges(),
            info.lambda2,
            info.chebyshev_eta()
        );
        let edges = sparse.edges();
        (
            Box::new(SparseComm::from_sparse(sparse).with_executor(Arc::clone(&exec))),
            edges,
        )
    };
    let mut rng = Rng::seed_from(seed);
    let mut stack = AgentStack::new((0..m).map(|_| Mat::randn(d, k, &mut rng)).collect());
    let mean0 = stack.mean();
    let dev0 = stack.deviation_from_mean();

    let trace_path = args.options.get("trace");
    if trace_path.is_some() {
        deepca::obs::trace::enable(deepca::obs::trace::DEFAULT_CAPACITY);
    }
    let mut stats = CommStats::default();
    let t = Timer::start();
    comm.fastmix(&mut stack, rounds, &mut stats);
    let mix_secs = t.elapsed_secs();
    if let Some(path) = trace_path {
        deepca::obs::trace::disable();
        let snap = deepca::obs::trace::snapshot();
        deepca::obs::export::write_auto(Path::new(path), &snap)
            .with_context(|| format!("writing trace {path}"))?;
        println!("wrote trace {path}");
    }
    println!(
        "{rounds} FastMix rounds over {d}x{k} iterates in {mix_secs:.3}s \
         ({:.1} ms/round, {:.3e} edge-scalars/s)",
        1e3 * mix_secs / rounds as f64,
        (2 * edges * d * k * rounds) as f64 / mix_secs.max(1e-12),
    );

    if !stack.is_finite() {
        bail!("non-finite values after {rounds} rounds");
    }
    let drift = (&stack.mean() - &mean0).fro_norm() / mean0.fro_norm().max(1e-300);
    let dev1 = stack.deviation_from_mean();
    if faulty {
        // Dropped links substitute the sender's own row, so the exact
        // mean-preservation invariant does not hold mid-disagreement;
        // the gate becomes contraction: faults may slow consensus but
        // must not break it.
        if dev1 >= dev0 {
            bail!(
                "deviation did not contract under faults: {dev0:.3e} -> {dev1:.3e}"
            );
        }
        println!(
            "deviation {dev0:.3e} -> {dev1:.3e} under faults \
             (dropped {}, virtual time {} ticks, mean drift {drift:.3e}) — OK",
            stats.dropped, stats.virtual_time
        );
    } else {
        if drift > 1e-9 {
            bail!(
                "mean drift {drift:.3e} exceeds tolerance 1e-9 — gossip is not doubly stochastic"
            );
        }
        println!(
            "mean drift {drift:.3e} (tol 1e-9), deviation {dev0:.3e} -> {dev1:.3e} — OK"
        );
    }
    Ok(())
}
