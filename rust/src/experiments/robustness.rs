//! Robustness sweep: DeEPCA convergence under lossy links.
//!
//! Runs DeEPCA through the deterministic [`SimNet`] engine over a
//! drop-rate × consensus-rounds grid and tabulates the final subspace
//! error, plus the virtual time each cell consumed. The table makes the
//! paper's headline knob quantitative under faults: a lossy network
//! behaves like a smaller effective K, and raising K buys the precision
//! back — drops inject perturbations proportional to the current
//! disagreement, so (unlike additive channel noise) they do not impose
//! an accuracy floor.
//!
//! [`SimNet`]: crate::consensus::simnet::SimNet

use super::report;
use super::Scale;
use crate::algo::deepca::DeepcaConfig;
use crate::algo::problem::Problem;
use crate::algo::solver::{Algo, Engine};
use crate::consensus::simnet::SimConfig;
use crate::coordinator::session::Session;
use crate::data::synthetic;
use crate::graph::topology::Topology;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// One sweep cell.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Per-link drop probability.
    pub drop_prob: f64,
    /// Consensus rounds K per power iteration.
    pub rounds: usize,
    /// Final mean tan θ.
    pub final_tan: f64,
    /// Virtual ticks the run consumed.
    pub virtual_time: u64,
}

/// Run the sweep and return the grid (row-major: drops × rounds).
pub fn sweep(scale: Scale) -> Vec<Cell> {
    let (m, dim, iters, drops, rounds): (usize, usize, usize, Vec<f64>, Vec<usize>) = match scale {
        Scale::Full => (
            16,
            24,
            60,
            vec![0.0, 0.01, 0.02, 0.05, 0.10, 0.20],
            vec![4, 8, 16, 32, 48],
        ),
        // 50 iterations: the power rate here is λ₃/λ₂ = 5/8, so the
        // clean runs reach ~1e-10 — deep enough to expose drop floors.
        Scale::Small => (8, 16, 50, vec![0.0, 0.05, 0.20], vec![4, 16, 32]),
    };
    let ds = synthetic::spiked_covariance(
        m * 50,
        dim,
        &[12.0, 8.0, 5.0],
        0.3,
        &mut Rng::seed_from(0xB0B),
    );
    let problem = Problem::from_dataset(&ds, m, 2);
    // Ring: the sparse, badly-connected regime where K matters most.
    let topo = Topology::ring(m);

    let mut cells = Vec::with_capacity(drops.len() * rounds.len());
    for &drop in &drops {
        for &k in &rounds {
            let rep = Session::on(&problem, &topo)
                .engine(Engine::Sim(SimConfig {
                    drop_prob: drop,
                    ..SimConfig::ideal(2027)
                }))
                .algo(Algo::Deepca(DeepcaConfig {
                    consensus_rounds: k,
                    max_iters: iters,
                    ..Default::default()
                }))
                .executor(super::sweep_executor())
                .solve();
            cells.push(Cell {
                drop_prob: drop,
                rounds: k,
                final_tan: if rep.diverged { f64::INFINITY } else { rep.final_tan_theta },
                virtual_time: rep.virtual_time(),
            });
        }
    }
    cells
}

/// Run the sweep and emit the convergence table.
pub fn run(scale: Scale) -> Result<()> {
    let cells = sweep(scale);
    let mut rounds: Vec<usize> = cells.iter().map(|c| c.rounds).collect();
    rounds.sort_unstable();
    rounds.dedup();
    let mut drops: Vec<f64> = cells.iter().map(|c| c.drop_prob).collect();
    drops.sort_by(|a, b| a.partial_cmp(b).unwrap());
    drops.dedup();

    let mut text = String::from("robustness: final mean tanθ, DeEPCA on a ring via SimNet\n");
    text.push_str("drop\\K  ");
    for k in &rounds {
        text.push_str(&format!("{k:>12}"));
    }
    text.push('\n');
    for &d in &drops {
        text.push_str(&format!("{d:<8.2}"));
        for &k in &rounds {
            let cell = cells
                .iter()
                .find(|c| c.rounds == k && (c.drop_prob - d).abs() < 1e-12)
                .expect("grid cell");
            text.push_str(&format!("{:>12.3e}", cell.final_tan));
        }
        text.push('\n');
    }
    text.push_str("\ncsv: drop_prob,consensus_rounds,final_tan_theta,virtual_time\n");
    for c in &cells {
        text.push_str(&format!(
            "{},{},{:.6e},{}\n",
            c.drop_prob, c.rounds, c.final_tan, c.virtual_time
        ));
    }
    report::emit_table("robustness", &text, Path::new("robustness.txt"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shapes_and_recovery() {
        let cells = sweep(Scale::Small);
        assert_eq!(cells.len(), 3 * 3);
        assert!(cells.iter().all(|c| c.final_tan.is_finite()));
        // The ideal column converges deep with enough rounds…
        let clean = cells
            .iter()
            .find(|c| c.drop_prob == 0.0 && c.rounds == 32)
            .unwrap();
        assert!(clean.final_tan < 1e-8, "clean K=32: {:.3e}", clean.final_tan);
        // …and raising K keeps mild drops converging…
        let mild = cells
            .iter()
            .find(|c| c.drop_prob == 0.05 && c.rounds == 32)
            .unwrap();
        assert!(mild.final_tan < 1e-4, "5% drops, K=32: {:.3e}", mild.final_tan);
        // …while even heavy drops stay stable (no divergence/blow-up).
        let lossy_hi_k = cells
            .iter()
            .find(|c| c.drop_prob == 0.2 && c.rounds == 32)
            .unwrap();
        assert!(
            lossy_hi_k.final_tan < 1e-1,
            "lossy K=32: {:.3e}",
            lossy_hi_k.final_tan
        );
        // Virtual time scales with K (one tick per round, zero latency).
        assert!(lossy_hi_k.virtual_time > clean.virtual_time / 2);
    }
}
