//! Experiment harness: regenerates every figure/table in the paper's
//! evaluation section plus the ablations DESIGN.md calls out.
//!
//! | id | paper object | module |
//! |----|--------------|--------|
//! | `fig1` | Figure 1 ('w8a', 3 panels × series) | [`figures`] |
//! | `fig2` | Figure 2 ('a9a') | [`figures`] |
//! | `table_comm` | Remark 2 / Theorem 1 comm-to-ε comparison | [`comm_table`] |
//! | `ablations` | sign-adjust, topology, min-K vs heterogeneity, non-PSD | [`ablations`] |
//! | `robustness` | drop-rate × consensus-rounds sweep via SimNet | [`robustness`] |
//! | `tracking` | online warm-start vs cold-start over drifting streams | [`tracking`] |
//!
//! Every experiment prints CSV blocks (machine-readable, one per series)
//! and a human summary; EXPERIMENTS.md records paper-vs-measured.

pub mod figures;
pub mod comm_table;
pub mod ablations;
pub mod robustness;
pub mod tracking;
pub mod report;

use crate::exec::Executor;
use std::sync::{Arc, OnceLock};

/// One worker pool shared by every experiment sweep in this process.
/// Sweeps run hundreds of small solves from a single driver thread; a
/// per-solve pool would pay thread spawn/teardown on each of them,
/// while sharing is contention-free (the driver dispatches one region
/// at a time) and changes no results (bit-identical for any pool).
pub(crate) fn sweep_executor() -> Arc<Executor> {
    static EXEC: OnceLock<Arc<Executor>> = OnceLock::new();
    Arc::clone(EXEC.get_or_init(|| Arc::new(Executor::new(0))))
}

/// Experiment scale: paper-sized or CI-sized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// The paper's setup (m=50, n=800/600, full iteration budget).
    Full,
    /// Shrunk setup for tests and quick runs (same qualitative shapes).
    Small,
}

impl Scale {
    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "full" => Some(Scale::Full),
            "small" => Some(Scale::Small),
            _ => None,
        }
    }
}
