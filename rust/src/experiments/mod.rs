//! Experiment harness: regenerates every figure/table in the paper's
//! evaluation section plus the ablations DESIGN.md calls out.
//!
//! | id | paper object | module |
//! |----|--------------|--------|
//! | `fig1` | Figure 1 ('w8a', 3 panels × series) | [`figures`] |
//! | `fig2` | Figure 2 ('a9a') | [`figures`] |
//! | `table_comm` | Remark 2 / Theorem 1 comm-to-ε comparison | [`comm_table`] |
//! | `ablations` | sign-adjust, topology, min-K vs heterogeneity, non-PSD | [`ablations`] |
//! | `robustness` | drop-rate × consensus-rounds sweep via SimNet | [`robustness`] |
//! | `tracking` | online warm-start vs cold-start over drifting streams | [`tracking`] |
//!
//! Every experiment prints CSV blocks (machine-readable, one per series)
//! and a human summary; EXPERIMENTS.md records paper-vs-measured.

pub mod figures;
pub mod comm_table;
pub mod ablations;
pub mod robustness;
pub mod tracking;
pub mod report;

/// Experiment scale: paper-sized or CI-sized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// The paper's setup (m=50, n=800/600, full iteration budget).
    Full,
    /// Shrunk setup for tests and quick runs (same qualitative shapes).
    Small,
}

impl Scale {
    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "full" => Some(Scale::Full),
            "small" => Some(Scale::Small),
            _ => None,
        }
    }
}
