//! The Remark-2 / Theorem-1 comparison as a table: communication to
//! reach precision ε, DeEPCA (constant K) vs DePCA (schedule tuned per
//! ε), across an ε grid. The paper states this as complexity bounds
//! (Eqns. 3.9–3.12); we *measure* it, which is the honest version of the
//! same claim: DeEPCA's advantage grows like log(1/ε).

use super::report;
use super::Scale;
use crate::algo::deepca::DeepcaConfig;
use crate::algo::depca::{DepcaConfig, KPolicy};
use crate::algo::problem::Problem;
use crate::algo::solver::Algo;
use crate::coordinator::session::Session;
use crate::data::synthetic;
use crate::graph::gossip::GossipMatrix;
use crate::graph::topology::Topology;
use crate::util::format;
use crate::util::rng::Rng;
use anyhow::Result;

/// One ε row of the table.
#[derive(Clone, Debug)]
pub struct CommRow {
    /// Target precision.
    pub eps: f64,
    /// DeEPCA rounds to reach ε (None = not reached).
    pub deepca_rounds: Option<u64>,
    /// DePCA (best schedule for this ε) rounds to reach ε.
    pub depca_rounds: Option<u64>,
    /// Theorem-1 bound T(ε)·K for reference.
    pub theory_bound: f64,
}

/// Run the sweep and emit the table.
pub fn run(scale: Scale) -> Result<Vec<CommRow>> {
    // 300 iterations cover the deepest ε row for both methods (the
    // increasing-K DePCA reaches 1e-10 by iteration ~210).
    let (m, n, iters) = match scale {
        Scale::Full => (50, 800, 300),
        Scale::Small => (10, 80, 200),
    };
    let ds = synthetic::w8a_like_scaled(m, n, &mut Rng::seed_from(711));
    let problem = Problem::from_dataset(&ds, m, 5.min(ds.dim() - 1));
    let topo = Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(712));
    let gossip = GossipMatrix::from_laplacian(&topo);

    // DeEPCA: one constant-K run covers every ε (that's the point).
    let k_deepca = pick_deepca_k(&problem, &gossip);
    let run_deepca = Session::on(&problem, &topo)
        .algo(Algo::Deepca(DeepcaConfig {
            consensus_rounds: k_deepca,
            max_iters: iters,
            ..Default::default()
        }))
        .executor(super::sweep_executor())
        .solve();
    let rec_deepca = run_deepca.trace;

    // DePCA: increasing schedule, also a single run (rounds grow as it
    // descends — the measured analogue of K(ε) = O(log 1/ε) per step).
    let run_depca = Session::on(&problem, &topo)
        .algo(Algo::Depca(DepcaConfig {
            k_policy: KPolicy::Increasing { base: k_deepca, slope: 1.0 },
            max_iters: iters,
            ..Default::default()
        }))
        .executor(super::sweep_executor())
        .solve();
    let rec_depca = run_depca.trace;

    let eps_grid: Vec<f64> = (1..=5).map(|i| 10f64.powi(-2 * i)).collect();
    let tan0 = 1.0_f64.max(problem.initial_w(2021).cols() as f64); // coarse tanθ₀ proxy

    let mut rows = Vec::new();
    for &eps in &eps_grid {
        let deepca_rounds = rec_deepca.first_below(eps).map(|(_, r)| r);
        let depca_rounds = rec_depca.first_below(eps).map(|(_, r)| r);
        let theory_bound = problem.iteration_bound(eps, tan0) * k_deepca as f64;
        rows.push(CommRow { eps, deepca_rounds, depca_rounds, theory_bound });
    }

    // Render.
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.eps),
                r.deepca_rounds
                    .map(|x| x.to_string())
                    .unwrap_or_else(|| "—".into()),
                r.depca_rounds
                    .map(|x| x.to_string())
                    .unwrap_or_else(|| "—".into()),
                match (r.deepca_rounds, r.depca_rounds) {
                    (Some(a), Some(b)) if a > 0 => format!("{:.2}×", b as f64 / a as f64),
                    _ => "—".into(),
                },
                format!("{:.0}", r.theory_bound),
            ]
        })
        .collect();
    let text = format!(
        "table_comm (DeEPCA K={k_deepca} constant vs DePCA increasing schedule; m={m}, 1−λ₂={:.3})\n{}",
        gossip.gap(),
        format::table(
            &["eps", "DeEPCA rounds", "DePCA rounds", "DePCA/DeEPCA", "T(ε)·K bound"],
            &table_rows,
        )
    );
    report::emit_table("table_comm", &text, std::path::Path::new("table_comm.txt"))?;
    Ok(rows)
}

/// Heuristic constant K for DeEPCA from the Theorem-1 expression: enough
/// rounds that ρ(K) clears the heterogeneity-dependent threshold.
pub fn pick_deepca_k(problem: &Problem, gossip: &GossipMatrix) -> usize {
    let l = problem.spectral_bound;
    let lk = problem.lambda_k();
    let lk1 = problem.lambda_k1();
    let k = problem.k as f64;
    let gamma = problem.gamma();
    // Eqn. 3.11's argument (constants included, tanθ₀ ≈ √k).
    let tan0 = k.sqrt();
    let num = 96.0 * k * l * (k.sqrt() + 1.0) * (lk + 2.0 * l) * (1.0 + tan0).powi(4);
    let den = lk1 * (lk - lk1) * gamma * gamma;
    let target = (num / den).max(2.0);
    let rho_target = 1.0 / target;
    gossip.rounds_for_rho(rho_target.clamp(1e-16, 0.5))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shows_growing_advantage() {
        std::env::set_var(
            "DEEPCA_RESULTS",
            std::env::temp_dir().join("deepca_comm_table_test"),
        );
        let rows = run(Scale::Small).unwrap();
        assert!(!rows.is_empty());
        // DeEPCA reaches the loosest ε.
        assert!(rows[0].deepca_rounds.is_some());
        // Where both reach ε, DePCA pays at least as much; the ratio
        // grows with 1/ε (paper's log 1/ε factor).
        let ratios: Vec<f64> = rows
            .iter()
            .filter_map(|r| match (r.deepca_rounds, r.depca_rounds) {
                (Some(a), Some(b)) => Some(b as f64 / a as f64),
                _ => None,
            })
            .collect();
        assert!(ratios.len() >= 2, "need at least two comparable rows");
        assert!(
            ratios.last().unwrap() > ratios.first().unwrap(),
            "advantage should grow with precision: {ratios:?}"
        );
        assert!(ratios.iter().all(|&r| r >= 1.0), "DePCA never cheaper: {ratios:?}");
        std::env::remove_var("DEEPCA_RESULTS");
    }

    #[test]
    fn pick_k_reasonable() {
        let ds = synthetic::w8a_like_scaled(6, 40, &mut Rng::seed_from(713));
        let p = Problem::from_dataset(&ds, 6, 3);
        let topo = Topology::erdos_renyi(6, 0.5, &mut Rng::seed_from(714));
        let g = GossipMatrix::from_laplacian(&topo);
        let k = pick_deepca_k(&p, &g);
        assert!(k >= 1 && k < 200, "k={k}");
    }
}
