//! Ablations for the design choices DESIGN.md calls out.
//!
//! - `sign_adjust`: Algorithm 2 on vs off on a rank-k problem where the
//!   QR output genuinely sign-flips → off diverges/stalls (paper §3.1's
//!   "necessary to make DeEPCA converge stably").
//! - `topology`: required consensus rounds K* vs the network's
//!   `1/√(1−λ₂)` across ring/grid/star/ER/complete/barbell — the
//!   Theorem-1 network factor.
//! - `min_k`: measured minimal K for convergence vs data heterogeneity
//!   `L²/(λ_kλ_{k+1})` (Remark 2: K grows with heterogeneity).
//! - `non_psd`: Remark 1 robustness — mean-shifted non-PSD locals.

use super::report;
use super::Scale;
use crate::algo::deepca::DeepcaConfig;
use crate::algo::problem::Problem;
use crate::algo::solver::Algo;
use crate::coordinator::session::Session;
use crate::data::partition::{make_non_psd, partition_gram, GramScaling};
use crate::data::synthetic::{self, SparseBinaryParams};
use crate::graph::gossip::GossipMatrix;
use crate::graph::topology::Topology;
use crate::util::format;
use crate::util::rng::Rng;
use anyhow::Result;

/// Outcome of one ablation cell.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Row label.
    pub label: String,
    /// Final mean tan θ.
    pub final_tan: f64,
    /// Extra context (e.g. K used, spectral gap).
    pub note: String,
}

fn run_deepca(problem: &Problem, topo: &Topology, k: usize, iters: usize, sign: bool) -> f64 {
    run_deepca_qr(problem, topo, k, iters, sign, true)
}

fn run_deepca_qr(
    problem: &Problem,
    topo: &Topology,
    k: usize,
    iters: usize,
    sign: bool,
    qr_canonical: bool,
) -> f64 {
    let cfg = DeepcaConfig {
        consensus_rounds: k,
        max_iters: iters,
        sign_adjust: sign,
        qr_canonical,
        ..Default::default()
    };
    let out = Session::on(problem, topo)
        .algo(Algo::Deepca(cfg))
        .executor(super::sweep_executor())
        .solve();
    if out.diverged {
        f64::INFINITY
    } else {
        out.final_tan_theta
    }
}

fn hetero_problem(m: usize, n: usize, dim: usize, drift: f64, seed: u64, k: usize) -> Problem {
    let ds = synthetic::sparse_binary(
        &SparseBinaryParams {
            rows: m * n,
            dim,
            density: 0.12,
            popularity_exponent: 0.9,
            blocks: m,
            drift,
        },
        &mut Rng::seed_from(seed),
    );
    Problem::from_dataset(&ds, m, k)
}

/// Adversarial instance for the sign ablation: the planted top-k
/// eigenvectors have *zero first coordinate*, so the Householder pivot
/// of every QR column sits at ≈0 ± consensus noise — raw (LAPACK-style)
/// QR signs are then decided by per-agent noise and flip independently
/// across agents, wrecking the average unless SignAdjust repairs them.
/// This is not exotic: any dataset where some feature is uncorrelated
/// with the leading factors produces pivots near zero.
fn sign_adversarial_problem(m: usize, k: usize, seed: u64) -> Problem {
    let d = 24;
    let mut rng = Rng::seed_from(seed);
    // Orthonormal basis with first row zeroed in the first k columns.
    let mut g = crate::linalg::Mat::randn(d, d, &mut rng);
    for c in 0..k {
        g[(0, c)] = 0.0;
    }
    let (q, _r) = crate::linalg::qr::thin_qr(&g);
    // Descending spectrum with a clean gap at k.
    let spectrum: Vec<f64> = (0..d)
        .map(|i| {
            if i < k {
                10.0 - i as f64
            } else {
                1.0 / (1.0 + i as f64 - k as f64)
            }
        })
        .collect();
    let base = q
        .matmul(&crate::linalg::Mat::diag(&spectrum))
        .matmul(&q.t());
    // Heterogeneous locals with exactly-zero-mean symmetric perturbations.
    let mut locals = Vec::with_capacity(m);
    let mut sum_e = crate::linalg::Mat::zeros(d, d);
    for j in 0..m {
        let e = if j + 1 == m {
            sum_e.scaled(-1.0)
        } else {
            let g = crate::linalg::Mat::randn(d, d, &mut rng);
            let mut e = &g + &g.t();
            e.scale(0.35);
            sum_e.axpy(1.0, &e);
            e
        };
        let mut a_j = base.clone();
        a_j.axpy(1.0, &e);
        a_j.symmetrize();
        locals.push(a_j);
    }
    Problem::new(locals, k, "sign-adversarial")
}

/// Sign-adjust ablation: the 2×2 of QR sign convention × SignAdjust.
///
/// Reproduction note (recorded in EXPERIMENTS.md): with the crate's
/// canonical positive-diagonal QR, column signs are already consistent
/// across agents and SignAdjust is a no-op — DeEPCA converges either
/// way. With raw Householder/LAPACK-style QR signs (what a stock-LAPACK
/// implementation of the paper would use), pivot-sign flips differ
/// across agents and SignAdjust is *necessary*, exactly as §3.1 claims.
pub fn sign_adjust(scale: Scale) -> Result<Vec<Cell>> {
    let m = match scale {
        Scale::Full => 20,
        Scale::Small => 8,
    };
    let iters = 150;
    let k_rounds = 12;
    let seeds: &[u64] = &[721, 731, 741];

    let mut worst = [0.0f64; 4]; // [raw+off, raw+on, canon+off, canon+on]
    for &seed in seeds {
        let problem = sign_adversarial_problem(m, 3, seed);
        let topo = Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(seed + 1));
        let cases = [
            run_deepca_qr(&problem, &topo, k_rounds, iters, false, false),
            run_deepca_qr(&problem, &topo, k_rounds, iters, true, false),
            run_deepca_qr(&problem, &topo, k_rounds, iters, false, true),
            run_deepca_qr(&problem, &topo, k_rounds, iters, true, true),
        ];
        for (w, c) in worst.iter_mut().zip(cases) {
            *w = w.max(c);
        }
    }
    let note = format!("K={k_rounds}, worst over {} seeds", seeds.len());
    let cells = vec![
        Cell { label: "raw QR, SignAdjust OFF".into(), final_tan: worst[0], note: note.clone() },
        Cell { label: "raw QR, SignAdjust ON".into(), final_tan: worst[1], note: note.clone() },
        Cell { label: "canonical QR, SignAdjust OFF".into(), final_tan: worst[2], note: note.clone() },
        Cell { label: "canonical QR, SignAdjust ON".into(), final_tan: worst[3], note },
    ];
    emit("abl_sign", &cells)?;
    Ok(cells)
}

/// Topology sweep: measured minimal K vs 1/√(1−λ₂).
pub fn topology(scale: Scale) -> Result<Vec<Cell>> {
    let m = match scale {
        Scale::Full => 50,
        Scale::Small => 12,
    };
    let problem = hetero_problem(m, 100, 40, 0.6, 723, 2);
    let iters = 60;
    let tol = 1e-6;

    let topos: Vec<Topology> = vec![
        Topology::complete(m),
        Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(724)),
        Topology::erdos_renyi(m, 0.15, &mut Rng::seed_from(725)),
        Topology::grid(grid_rows(m), m / grid_rows(m)),
        Topology::star(m),
        Topology::ring(m),
    ];

    let mut cells = Vec::new();
    for topo in &topos {
        let gossip = GossipMatrix::from_laplacian(topo);
        let kstar = minimal_k(&problem, topo, iters, tol, 64);
        cells.push(Cell {
            label: topo.name.clone(),
            final_tan: kstar.map(|k| run_deepca(&problem, topo, k, iters, true)).unwrap_or(f64::INFINITY),
            note: format!(
                "K*={} | 1/√(1−λ₂)={:.2}",
                kstar.map(|k| k.to_string()).unwrap_or_else(|| ">64".into()),
                1.0 / gossip.gap().sqrt()
            ),
        });
    }
    emit("abl_topology", &cells)?;
    Ok(cells)
}

/// Largest divisor of m that is <= sqrt(m) (grid row count).
fn grid_rows(m: usize) -> usize {
    (1..=m).rev().find(|r| m % r == 0 && r * r <= m).unwrap_or(1)
}

/// Minimal consensus rounds to reach `tol` within `iters` (doubling +
/// binary search over K).
pub fn minimal_k(
    problem: &Problem,
    topo: &Topology,
    iters: usize,
    tol: f64,
    k_cap: usize,
) -> Option<usize> {
    let reaches = |k: usize| run_deepca(problem, topo, k, iters, true) <= tol;
    // Exponential probe.
    let mut hi = 1;
    while hi <= k_cap && !reaches(hi) {
        hi *= 2;
    }
    if hi > k_cap {
        return None;
    }
    let mut lo = hi / 2; // lo fails (or is 0)
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if reaches(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Heterogeneity sweep: minimal K vs drift (Remark 2).
pub fn min_k_vs_heterogeneity(scale: Scale) -> Result<Vec<Cell>> {
    let m = match scale {
        Scale::Full => 20,
        Scale::Small => 8,
    };
    let topo = Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(726));
    let mut cells = Vec::new();
    for &drift in &[0.0, 0.3, 0.6, 0.9] {
        let problem = hetero_problem(m, 120, 40, drift, 727, 2);
        // Generous iteration budget so K* measures the *consensus*
        // requirement, not the spectral-gap iteration limit.
        let iters = 200;
        let kstar = minimal_k(&problem, &topo, iters, 1e-6, 64);
        cells.push(Cell {
            label: format!("drift={drift}"),
            final_tan: kstar
                .map(|k| run_deepca(&problem, &topo, k, iters, true))
                .unwrap_or(f64::INFINITY),
            note: format!(
                "K*={} | heterogeneity={:.1}",
                kstar.map(|k| k.to_string()).unwrap_or_else(|| ">64".into()),
                problem.heterogeneity()
            ),
        });
    }
    emit("abl_min_k", &cells)?;
    Ok(cells)
}

/// Remark-1 robustness: non-PSD locals.
pub fn non_psd(scale: Scale) -> Result<Vec<Cell>> {
    let (m, n) = match scale {
        Scale::Full => (20, 200),
        Scale::Small => (8, 100),
    };
    let ds = synthetic::spiked_covariance(m * n, 24, &[12.0, 7.0, 4.0], 0.3, &mut Rng::seed_from(728));
    let topo = Topology::erdos_renyi(m, 0.5, &mut Rng::seed_from(729));
    let mut cells = Vec::new();
    for &shift in &[0.0, 2.0, 8.0] {
        let mut part = partition_gram(&ds, m, GramScaling::PerRow);
        if shift > 0.0 {
            make_non_psd(&mut part, shift);
        }
        let problem = Problem::from_partition(part, 2, "non-psd");
        let tan = run_deepca(&problem, &topo, 12, 100, true);
        cells.push(Cell {
            label: format!("shift={shift}"),
            final_tan: tan,
            note: format!("L={:.2}", problem.spectral_bound),
        });
    }
    emit("abl_non_psd", &cells)?;
    Ok(cells)
}

fn emit(id: &str, cells: &[Cell]) -> Result<()> {
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.label.clone(),
                format!("{:.3e}", c.final_tan),
                c.note.clone(),
            ]
        })
        .collect();
    let text = format!(
        "{id}\n{}",
        format::table(&["case", "final tanθ", "notes"], &rows)
    );
    report::emit_table(id, &text, std::path::Path::new(&format!("{id}.txt")))?;
    Ok(())
}

/// Run every ablation.
pub fn run_all(scale: Scale) -> Result<()> {
    sign_adjust(scale)?;
    topology(scale)?;
    min_k_vs_heterogeneity(scale)?;
    non_psd(scale)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn with_tmp_results<T>(f: impl FnOnce() -> T) -> T {
        std::env::set_var(
            "DEEPCA_RESULTS",
            std::env::temp_dir().join("deepca_abl_test"),
        );
        let out = f();
        std::env::remove_var("DEEPCA_RESULTS");
        out
    }

    #[test]
    fn sign_adjust_matters() {
        let cells = with_tmp_results(|| sign_adjust(Scale::Small).unwrap());
        let raw_off = cells[0].final_tan;
        let raw_on = cells[1].final_tan;
        let canon_off = cells[2].final_tan;
        let canon_on = cells[3].final_tan;
        // With SignAdjust (the paper's Algorithm 2) both QR conventions
        // converge deep.
        assert!(raw_on < 1e-8, "raw QR + SignAdjust: {raw_on:.3e}");
        assert!(canon_on < 1e-8, "canonical QR + SignAdjust: {canon_on:.3e}");
        // Canonical QR is sign-stable on its own.
        assert!(canon_off < 1e-8, "canonical QR alone: {canon_off:.3e}");
        // Raw (LAPACK-style) QR without SignAdjust hits the sign
        // instability on at least one seed — the §3.1 failure mode.
        assert!(
            raw_off > 1e4 * raw_on.max(1e-14),
            "raw QR without SignAdjust should fail somewhere: worst={raw_off:.3e} vs {raw_on:.3e}"
        );
    }

    #[test]
    fn minimal_k_monotone_in_connectivity() {
        let m = 8;
        let problem = hetero_problem(m, 80, 30, 0.6, 730, 2);
        let good = Topology::complete(m);
        let bad = Topology::ring(m);
        let k_good = minimal_k(&problem, &good, 50, 1e-6, 64).unwrap();
        let k_bad = minimal_k(&problem, &bad, 50, 1e-6, 64).unwrap();
        assert!(
            k_bad >= k_good,
            "worse connectivity should need ≥ rounds: ring {k_bad} vs complete {k_good}"
        );
    }

    #[test]
    fn non_psd_still_converges() {
        let cells = with_tmp_results(|| non_psd(Scale::Small).unwrap());
        for c in &cells {
            assert!(
                c.final_tan < 1e-7,
                "{}: tanθ={:.3e} (Remark 1 violated)",
                c.label,
                c.final_tan
            );
        }
    }
}
