//! Report emission: CSV blocks to stdout + optional files under
//! `results/`.

use crate::algo::metrics::RunRecorder;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Where experiment outputs land (`$DEEPCA_RESULTS` or `./results`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("DEEPCA_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Write `content` to `<results>/<name>` (creating directories).
pub fn write_result(name: &str, content: &str) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir).context("creating results dir")?;
    let path = dir.join(name);
    std::fs::write(&path, content).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Print a labelled CSV block for one series (stdout protocol used by
/// the plotting script and the bench logs).
pub fn print_series(experiment: &str, label: &str, rec: &RunRecorder) {
    println!("### series experiment={experiment} label={label}");
    print!("{}", rec.to_csv());
    println!("### end");
}

/// Print + persist one series.
pub fn emit_series(experiment: &str, label: &str, rec: &RunRecorder) -> Result<()> {
    print_series(experiment, label, rec);
    let fname = format!(
        "{experiment}_{}.csv",
        label.replace(['=', ' ', '(', ')', ','], "_")
    );
    write_result(&fname, &rec.to_csv())?;
    Ok(())
}

/// Print + persist a one-off text table.
pub fn emit_table(experiment: &str, text: &str, path: &Path) -> Result<()> {
    println!("{text}");
    write_result(&path.display().to_string(), text)?;
    let _ = experiment;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_result_roundtrip() {
        std::env::set_var("DEEPCA_RESULTS", std::env::temp_dir().join("deepca_results_test"));
        let p = write_result("unit.csv", "a,b\n1,2\n").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "a,b\n1,2\n");
        std::env::remove_var("DEEPCA_RESULTS");
    }
}
