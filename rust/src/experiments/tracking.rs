//! Tracking sweep: warm-started online DeEPCA vs a cold-start baseline
//! over drifting streams.
//!
//! For a grid of drift rates × per-epoch consensus budgets K, run the
//! [`OnlineSession`] driver twice on the *same* stream (identical rows,
//! identical per-epoch budget `power_iters × K`): once warm-started from
//! the previous epoch's subspace, once restarting every epoch from a
//! fresh random iterate. The table shows the paper's subspace-tracking
//! claim extended to live data: warm starting holds the tracking error
//! near the estimation floor with a small constant budget, while the
//! cold baseline burns the identical budget and never locks on.
//!
//! Also emits per-epoch tracking-error-vs-time series (warm vs cold) for
//! a representative cell, so the time axis of the contrast is plottable.
//!
//! [`OnlineSession`]: crate::coordinator::online::OnlineSession

use super::report;
use super::Scale;
use crate::coordinator::online::{OnlineConfig, OnlineReport, OnlineSession};
use crate::graph::topology::Topology;
use crate::stream::cov::Forgetting;
use crate::stream::source::{Drift, StreamParams, SyntheticStream};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Epochs ignored when summarizing tracking error (cold-start ramp-in).
pub const BURN_IN_FRACTION: f64 = 0.25;

/// The fixed tracking-error threshold of the acceptance contrast: on a
/// slow-rotation stream the warm run must stay below it while the
/// equal-budget cold baseline stays above (`rust/tests/streaming.rs`
/// asserts the same numbers this experiment prints).
pub const TRACKING_THRESHOLD: f64 = 0.4;

/// One sweep cell: a (drift rate, K) pair measured warm and cold.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Subspace rotation rate (radians/epoch; 0 = stationary).
    pub rate: f64,
    /// Consensus rounds K per power iteration.
    pub rounds: usize,
    /// Mean oracle tracking error after burn-in, warm-started.
    pub warm_mean: f64,
    /// Max oracle tracking error after burn-in, warm-started.
    pub warm_max: f64,
    /// Mean oracle tracking error after burn-in, cold-start baseline.
    pub cold_mean: f64,
    /// Gossip rounds per epoch (identical for warm and cold).
    pub rounds_per_epoch: f64,
}

/// Sweep shape per scale.
struct Setup {
    m: usize,
    dim: usize,
    batch: usize,
    epochs: usize,
    rates: Vec<f64>,
    rounds: Vec<usize>,
}

fn setup(scale: Scale) -> Setup {
    match scale {
        Scale::Full => Setup {
            m: 16,
            dim: 24,
            batch: 200,
            epochs: 60,
            rates: vec![0.0, 0.005, 0.01, 0.02, 0.05],
            rounds: vec![2, 4, 8, 16],
        },
        Scale::Small => Setup {
            m: 8,
            dim: 16,
            batch: 200,
            epochs: 30,
            rates: vec![0.0, 0.01, 0.05],
            rounds: vec![4, 8],
        },
    }
}

/// One online run over a freshly built stream (same seed ⇒ same rows).
pub fn run_once(
    scale: Scale,
    rate: f64,
    rounds: usize,
    warm_start: bool,
    seed: u64,
) -> OnlineReport {
    let s = setup(scale);
    let drift = if rate > 0.0 {
        Drift::Rotation { rate }
    } else {
        Drift::Stationary
    };
    // Spectrum chosen so one power iteration contracts by ~λ₃/λ₂ = 0.3:
    // enough for a warm start to keep up, nowhere near enough for a
    // cold start to lock on within the same budget.
    let mut source = SyntheticStream::new(StreamParams {
        m: s.m,
        dim: s.dim,
        batch: s.batch,
        spikes: vec![10.0, 5.0],
        noise: 1.5,
        drift,
        seed,
    });
    let topo = Topology::erdos_renyi(s.m, 0.5, &mut Rng::seed_from(seed ^ 0xA5));
    OnlineSession::on(&topo)
        .config(OnlineConfig {
            epochs: s.epochs,
            consensus_rounds: rounds,
            power_iters: 1,
            warm_start,
            forgetting: Forgetting::Exponential(0.6),
            init_seed: 2021,
        })
        .executor(super::sweep_executor())
        .run(&mut source)
}

/// Burn-in epochs for a scale.
pub fn burn_in(scale: Scale) -> usize {
    (setup(scale).epochs as f64 * BURN_IN_FRACTION).ceil() as usize
}

/// Run the grid and collect the cells (row-major: rates × rounds).
pub fn sweep(scale: Scale) -> Vec<Cell> {
    sweep_with_series(scale).0
}

/// The representative cell whose per-epoch series `run` emits: mid
/// drift rate, largest K.
fn representative(s: &Setup) -> (f64, usize) {
    (s.rates[s.rates.len() / 2], *s.rounds.last().expect("rounds non-empty"))
}

/// As [`sweep`], additionally handing back the warm/cold per-epoch
/// reports of the representative cell so `run` does not re-execute it.
fn sweep_with_series(scale: Scale) -> (Vec<Cell>, OnlineReport, OnlineReport) {
    let s = setup(scale);
    let burn = burn_in(scale);
    let (rep_rate, rep_k) = representative(&s);
    let mut rep: Option<(OnlineReport, OnlineReport)> = None;
    let mut cells = Vec::with_capacity(s.rates.len() * s.rounds.len());
    for &rate in &s.rates {
        for &k in &s.rounds {
            let warm = run_once(scale, rate, k, true, 0xD21F7);
            let cold = run_once(scale, rate, k, false, 0xD21F7);
            cells.push(Cell {
                rate,
                rounds: k,
                warm_mean: warm.mean_oracle_after(burn),
                warm_max: warm.max_oracle_after(burn),
                cold_mean: cold.mean_oracle_after(burn),
                rounds_per_epoch: warm.comm.rounds_per_epoch(),
            });
            if (rate - rep_rate).abs() < 1e-12 && k == rep_k {
                rep = Some((warm, cold));
            }
        }
    }
    let (warm, cold) = rep.expect("representative cell is on the grid");
    (cells, warm, cold)
}

/// Run the sweep, print/persist the table and the representative
/// warm-vs-cold time series.
pub fn run(scale: Scale) -> Result<()> {
    let (cells, warm, cold) = sweep_with_series(scale);
    let s = setup(scale);

    let mut text = String::from(
        "tracking: mean oracle tan θ after burn-in, online DeEPCA over a rotating stream\n\
         (per cell: warm-started / cold-start baseline, identical per-epoch budget)\n",
    );
    text.push_str("rate\\K  ");
    for k in &s.rounds {
        text.push_str(&format!("{k:>23}"));
    }
    text.push('\n');
    for &rate in &s.rates {
        text.push_str(&format!("{rate:<8.3}"));
        for &k in &s.rounds {
            let cell = cells
                .iter()
                .find(|c| c.rounds == k && (c.rate - rate).abs() < 1e-12)
                .expect("grid cell");
            text.push_str(&format!(
                "{:>11.3e}/{:<11.3e}",
                cell.warm_mean, cell.cold_mean
            ));
        }
        text.push('\n');
    }
    text.push_str("\ncsv: rate,consensus_rounds,warm_mean,warm_max,cold_mean,rounds_per_epoch\n");
    for c in &cells {
        text.push_str(&format!(
            "{},{},{:.6e},{:.6e},{:.6e},{}\n",
            c.rate, c.rounds, c.warm_mean, c.warm_max, c.cold_mean, c.rounds_per_epoch
        ));
    }
    report::emit_table("tracking", &text, Path::new("tracking.txt"))?;

    // Representative time series: mid drift rate, largest K (captured
    // during the sweep — not re-run).
    let (rate, k) = representative(&s);
    report::write_result(&format!("tracking_warm_rate{rate}_K{k}.csv"), &warm.to_csv())?;
    report::write_result(&format!("tracking_cold_rate{rate}_K{k}.csv"), &cold.to_csv())?;
    println!(
        "tracking: rate={rate} K={k} warm max (post burn-in) {:.3e} vs cold mean {:.3e} \
         (threshold {TRACKING_THRESHOLD})",
        warm.max_oracle_after(burn_in(scale)),
        cold.mean_oracle_after(burn_in(scale)),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The acceptance cell itself (rate 0.01, K=8) is asserted in
    // `rust/tests/streaming.rs` through the same `run_once` path; the
    // full grid would cost 12 online runs here for no extra coverage.
    // This test covers a *different* cell cheaply: even on a stationary
    // stream, the equal-budget cold baseline never locks on.
    #[test]
    fn stationary_cell_still_shows_the_warm_vs_cold_contrast() {
        let burn = burn_in(Scale::Small);
        let warm = run_once(Scale::Small, 0.0, 4, true, 0xD21F7);
        let cold = run_once(Scale::Small, 0.0, 4, false, 0xD21F7);
        // Budget really is constant and identical across the contrast.
        assert!((warm.comm.rounds_per_epoch() - 4.0).abs() < 1e-9);
        assert_eq!(warm.comm.rounds, cold.comm.rounds);
        let warm_max = warm.max_oracle_after(burn);
        let cold_mean = cold.mean_oracle_after(burn);
        assert!(warm_max.is_finite() && cold_mean.is_finite());
        assert!(
            warm_max < TRACKING_THRESHOLD,
            "warm max {warm_max:.3e} ≥ threshold"
        );
        assert!(
            cold_mean > TRACKING_THRESHOLD,
            "cold mean {cold_mean:.3e} ≤ threshold"
        );
        assert!(warm.mean_oracle_after(burn) < 0.5 * cold_mean);
    }

    #[test]
    fn representative_cell_is_on_the_grid() {
        let s = setup(Scale::Small);
        let (rate, k) = representative(&s);
        assert!(s.rates.contains(&rate));
        assert!(s.rounds.contains(&k));
    }
}
