//! Figures 1 and 2: convergence vs. communication on 'w8a' / 'a9a'.
//!
//! Paper setup (§5): m = 50 agents, Erdős–Rényi p = 0.5 network
//! (`1 − λ₂(L) ≈ 0.4563` for their draw), datasets partitioned per
//! Eqn. 5.1. Each figure has three panels over #communications:
//!
//! 1. `‖Sᵗ − S̄ᵗ⊗1‖`   (tracked-variable consensus error)
//! 2. `‖Wᵗ − W̄ᵗ⊗1‖`   (iterate consensus error)
//! 3. `(1/m) Σ tanθ_k(U, W_jᵗ)` (subspace error)
//!
//! Series: DeEPCA across several K (small K stalls — their K=3 case),
//! DePCA with fixed K (plateaus) and an increasing schedule, and CPCA as
//! the rate reference. We additionally run the local-only strawman to
//! report the heterogeneity floor. Every series runs through the unified
//! [`Session`] builder — one driver, one report shape.

use super::report;
use super::Scale;
use crate::algo::centralized::CentralizedConfig;
use crate::algo::deepca::DeepcaConfig;
use crate::algo::depca::{DepcaConfig, KPolicy};
use crate::algo::local_power::LocalPowerConfig;
use crate::algo::metrics::RunRecorder;
use crate::algo::problem::Problem;
use crate::algo::solver::Algo;
use crate::coordinator::session::Session;
use crate::data::synthetic;
use crate::data::Dataset;
use crate::graph::gossip::GossipMatrix;
use crate::graph::topology::Topology;
use crate::util::format::sci;
use crate::util::rng::Rng;
use anyhow::Result;

/// Which paper figure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Figure {
    /// Figure 1: 'w8a' (d=300, n=800/agent).
    Fig1W8a,
    /// Figure 2: 'a9a' (d=123, n=600/agent).
    Fig2A9a,
}

impl Figure {
    /// Experiment id string.
    pub fn id(&self) -> &'static str {
        match self {
            Figure::Fig1W8a => "fig1",
            Figure::Fig2A9a => "fig2",
        }
    }
}

/// One convergence series of a figure.
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Trace (empty for scalar-only series).
    pub recorder: RunRecorder,
}

/// Everything a figure run produced.
pub struct FigureResult {
    /// Figure id.
    pub figure: Figure,
    /// Problem diagnostics (λ_k, gap, heterogeneity, network gap).
    pub summary: String,
    /// All series.
    pub series: Vec<Series>,
    /// CPCA tan trace (per power iteration).
    pub cpca_tan: Vec<f64>,
    /// Local-only heterogeneity floor.
    pub local_floor: f64,
}

/// Build the figure's dataset at the given scale.
pub fn dataset(figure: Figure, scale: Scale, rng: &mut Rng) -> Dataset {
    match (figure, scale) {
        (Figure::Fig1W8a, Scale::Full) => synthetic::w8a_like(rng),
        (Figure::Fig1W8a, Scale::Small) => synthetic::w8a_like_scaled(10, 80, rng),
        (Figure::Fig2A9a, Scale::Full) => synthetic::a9a_like(rng),
        (Figure::Fig2A9a, Scale::Small) => synthetic::a9a_like_scaled(10, 60, rng),
    }
}

/// Figure hyperparameters at a scale.
pub struct FigureSpec {
    /// Agents.
    pub m: usize,
    /// Rank.
    pub k: usize,
    /// Power iterations per run.
    pub iters: usize,
    /// DeEPCA consensus-round sweep.
    pub deepca_ks: Vec<usize>,
    /// DePCA schedules (label, policy).
    pub depca: Vec<(String, KPolicy)>,
    /// Seeds (data, graph, init).
    pub seeds: (u64, u64, u64),
}

impl FigureSpec {
    /// The paper's configuration (scaled down for `Scale::Small`).
    pub fn paper(scale: Scale) -> Self {
        match scale {
            // 250 iterations: the w8a-like spectrum has a small gap at
            // k=5 (γ ≈ 0.95), so CPCA needs ~250 power iterations to hit
            // the fp floor — that depth is exactly where fixed-K DePCA's
            // consensus plateau separates from DeEPCA (paper Figure 1).
            Scale::Full => FigureSpec {
                m: 50,
                k: 5,
                iters: 250,
                deepca_ks: vec![1, 3, 5, 8, 12],
                depca: vec![
                    ("DePCA K=5".into(), KPolicy::Fixed(5)),
                    ("DePCA K=20".into(), KPolicy::Fixed(20)),
                    (
                        "DePCA K=3+t".into(),
                        KPolicy::Increasing { base: 3, slope: 1.0 },
                    ),
                ],
                seeds: (701, 702, 2021),
            },
            Scale::Small => FigureSpec {
                m: 10,
                k: 3,
                iters: 120,
                deepca_ks: vec![1, 4, 8],
                depca: vec![
                    ("DePCA K=4".into(), KPolicy::Fixed(4)),
                    (
                        "DePCA K=2+t".into(),
                        KPolicy::Increasing { base: 2, slope: 1.0 },
                    ),
                ],
                seeds: (701, 702, 2021),
            },
        }
    }
}

/// Run one figure end to end and emit its series.
pub fn run_figure(figure: Figure, scale: Scale) -> Result<FigureResult> {
    let spec = FigureSpec::paper(scale);
    let mut data_rng = Rng::seed_from(spec.seeds.0);
    let ds = dataset(figure, scale, &mut data_rng);
    let problem = Problem::from_dataset(&ds, spec.m, spec.k);
    let topo = Topology::erdos_renyi(spec.m, 0.5, &mut Rng::seed_from(spec.seeds.1));
    let gossip = GossipMatrix::from_laplacian(&topo);

    let summary = format!(
        "{} [{}]: d={} m={} k={} | λ_k={} λ_k+1={} gap={:.4} γ={:.4} | L={} heterogeneity={:.1} | 1−λ₂(L)={:.4} (paper: 0.4563) | density={:.4}",
        figure.id(),
        ds.name,
        problem.dim(),
        problem.m(),
        problem.k,
        sci(problem.lambda_k()),
        sci(problem.lambda_k1()),
        problem.truth.relative_gap(problem.k),
        problem.gamma(),
        sci(problem.spectral_bound),
        problem.heterogeneity(),
        gossip.gap(),
        ds.density(),
    );
    println!("{summary}");

    let mut series = Vec::new();

    // DeEPCA sweep over K.
    for &k_rounds in &spec.deepca_ks {
        let cfg = DeepcaConfig {
            consensus_rounds: k_rounds,
            max_iters: spec.iters,
            init_seed: spec.seeds.2,
            ..Default::default()
        };
        let run = Session::on(&problem, &topo)
            .algo(Algo::Deepca(cfg))
            .executor(super::sweep_executor())
            .solve();
        let label = format!("DeEPCA K={k_rounds}");
        println!(
            "  {label:<16} tanθ={:.3e} after {} iters ({}) {}",
            run.final_tan_theta,
            run.iters,
            run.comm,
            if run.diverged { "[DIVERGED]" } else { "" },
        );
        report::emit_series(figure.id(), &label, &run.trace)?;
        series.push(Series { label, recorder: run.trace });
    }

    // DePCA schedules.
    for (label, policy) in &spec.depca {
        let cfg = DepcaConfig {
            k_policy: *policy,
            max_iters: spec.iters,
            init_seed: spec.seeds.2,
            ..Default::default()
        };
        let run = Session::on(&problem, &topo)
            .algo(Algo::Depca(cfg))
            .executor(super::sweep_executor())
            .solve();
        println!(
            "  {label:<16} tanθ={:.3e} after {} iters ({})",
            run.final_tan_theta, run.iters, run.comm
        );
        report::emit_series(figure.id(), label, &run.trace)?;
        series.push(Series { label: label.clone(), recorder: run.trace });
    }

    // CPCA reference — same builder, single-iterate solver.
    let cpca = Session::on(&problem, &topo)
        .algo(Algo::Centralized(CentralizedConfig {
            max_iters: spec.iters,
            init_seed: spec.seeds.2,
            ..Default::default()
        }))
        .solve();
    let cpca_tan: Vec<f64> = cpca.trace.records.iter().map(|r| r.mean_tan_theta).collect();
    println!(
        "  {:<16} tanθ={:.3e} after {} iters (centralized)",
        "CPCA", cpca.final_tan_theta, cpca.iters
    );
    let cpca_csv: String = std::iter::once("iter,tan_theta\n".to_string())
        .chain(
            cpca_tan
                .iter()
                .enumerate()
                .map(|(i, t)| format!("{i},{t:.6e}\n")),
        )
        .collect();
    report::write_result(&format!("{}_cpca.csv", figure.id()), &cpca_csv)?;

    // Local-only floor.
    let local = Session::on(&problem, &topo)
        .algo(Algo::LocalPower(LocalPowerConfig {
            max_iters: spec.iters.min(40),
            init_seed: 2021,
        }))
        .executor(super::sweep_executor())
        .solve();
    let local_floor = local.final_tan_theta;
    println!("  {:<16} floor tanθ={local_floor:.3e} (no communication)", "Local-only");

    Ok(FigureResult {
        figure,
        summary,
        series,
        cpca_tan,
        local_floor,
    })
}

/// The qualitative claims a figure must reproduce (used by tests and the
/// bench harness to self-check the regenerated figure against the paper).
pub struct FigureClaims {
    /// Best DeEPCA final tanθ across the K sweep.
    pub deepca_best: f64,
    /// DeEPCA with the smallest swept K.
    pub deepca_smallest_k: f64,
    /// Best fixed-K DePCA final tanθ.
    pub depca_fixed_best: f64,
    /// The matched-budget comparison: max over fixed K of
    /// `DePCA(K) / DeEPCA(K)` at the *same* K — the paper's plateau
    /// claim is per-budget, not best-vs-best (a huge fixed K can push
    /// DePCA's floor below the iteration-limited CPCA level).
    pub matched_k_ratio: f64,
    /// Increasing-K DePCA final tanθ (if present).
    pub depca_increasing: Option<f64>,
    /// CPCA final tanθ.
    pub cpca: f64,
}

/// Extract the claim numbers from a result.
pub fn claims(res: &FigureResult) -> FigureClaims {
    let mut deepca_best = f64::INFINITY;
    let mut deepca_smallest_k = f64::INFINITY;
    let mut smallest_k = usize::MAX;
    let mut depca_fixed_best = f64::INFINITY;
    let mut depca_increasing = None;
    let mut deepca_by_k: Vec<(usize, f64)> = Vec::new();
    let mut depca_by_k: Vec<(usize, f64)> = Vec::new();
    for s in &res.series {
        let final_tan = s.recorder.final_tan_theta();
        if let Some(kstr) = s.label.strip_prefix("DeEPCA K=") {
            let k: usize = kstr.parse().unwrap();
            deepca_by_k.push((k, final_tan));
            deepca_best = deepca_best.min(final_tan);
            if k < smallest_k {
                smallest_k = k;
                deepca_smallest_k = final_tan;
            }
        } else if s.label.contains("+t") {
            depca_increasing = Some(final_tan);
        } else if let Some(kstr) = s.label.strip_prefix("DePCA K=") {
            let k: usize = kstr.parse().unwrap();
            depca_by_k.push((k, final_tan));
            depca_fixed_best = depca_fixed_best.min(final_tan);
        }
    }
    let mut matched_k_ratio: f64 = 0.0;
    for &(k, depca_tan) in &depca_by_k {
        if let Some(&(_, deepca_tan)) = deepca_by_k.iter().find(|(dk, _)| *dk == k) {
            matched_k_ratio = matched_k_ratio.max(depca_tan / deepca_tan.max(1e-14));
        }
    }
    FigureClaims {
        deepca_best,
        deepca_smallest_k,
        depca_fixed_best,
        matched_k_ratio,
        depca_increasing,
        cpca: *res.cpca_tan.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fig1_reproduces_paper_shape() {
        std::env::set_var(
            "DEEPCA_RESULTS",
            std::env::temp_dir().join("deepca_fig_test"),
        );
        let res = run_figure(Figure::Fig1W8a, Scale::Small).unwrap();
        let c = claims(&res);
        // Claim 1: DeEPCA (enough K) matches the centralized rate — its
        // final error tracks CPCA's (the paper's headline comparison).
        assert!(c.cpca < 1e-6, "CPCA should be deep by now: {:.3e}", c.cpca);
        assert!(
            c.deepca_best < 200.0 * c.cpca.max(1e-14) && c.deepca_best < 1e-8,
            "best DeEPCA {:.3e} vs CPCA {:.3e}",
            c.deepca_best,
            c.cpca
        );
        // Claim 2: smallest K stalls well above.
        assert!(
            c.deepca_smallest_k > 1e2 * c.deepca_best.max(1e-14),
            "K=1 should stall: {:.3e} vs best {:.3e}",
            c.deepca_smallest_k,
            c.deepca_best
        );
        // Claim 3: fixed-K DePCA plateaus above DeEPCA at the same K.
        assert!(
            c.matched_k_ratio > 1e2,
            "matched-K DePCA/DeEPCA ratio {:.1}",
            c.matched_k_ratio
        );
        // Claim 4: increasing-K DePCA keeps descending below fixed-K.
        let inc = c.depca_increasing.unwrap();
        assert!(inc < 0.5 * c.depca_fixed_best);
        std::env::remove_var("DEEPCA_RESULTS");
    }
}
