//! SimNet — deterministic simulation of unreliable, time-varying networks.
//!
//! A single-threaded, discrete-event [`Communicator`]: gossip rounds are
//! barrier-synchronized events on a virtual clock, and *every* random
//! choice — packet drops, payload noise — comes from one seeded [`Rng`]
//! consumed in a fixed order, so a run replays bit-for-bit from its seed.
//! This is the substrate for fault/async scenarios the threaded engines
//! cannot reproduce deterministically:
//!
//! - **Per-link packet drops** — each directed message is lost with
//!   probability [`SimConfig::drop_prob`]. The receiver substitutes its
//!   *own* current state for the lost payload (self-weight fallback), so
//!   each round remains a well-defined row-stochastic averaging — the
//!   perturbation a drop injects is proportional to the current
//!   disagreement and vanishes at consensus.
//! - **Per-link latency** — every directed link gets a fixed latency in
//!   `[0, max_latency]` virtual ticks (derived from the seed). A round
//!   completes when its slowest delivered message lands; the elapsed
//!   ticks accumulate into [`CommStats::virtual_time`], giving experiments
//!   a wall-clock-free time axis.
//! - **Additive payload noise** — i.i.d. Gaussian noise of std
//!   [`SimConfig::noise_std`] on every delivered scalar (the noisy power
//!   method regime; unlike drops, this sets a hard accuracy floor).
//! - **Time-varying topology** — the engine consults a
//!   [`TopologySchedule`] on every gossip round through
//!   [`TopologySchedule::advance_to`]: an [`EpochStep::Unchanged`] tick
//!   is O(1) (no weight rebuild at all), and a changed epoch rebuilds
//!   weights in O(n + edges) — never O(n²).
//!
//! Everything per-round is sparse: weights live in a CSR
//! [`SparseGossip`] (O(edges) storage, O(edges · d · k) per round) and
//! link latencies are CSR-aligned per live directed edge rather than an
//! n × n table, so the simulator scales to fleet-sized agent counts.
//! Two weight modes share the machinery:
//!
//! - [`SimNet::new`] (default) keeps the paper's Laplacian weights: each
//!   changed epoch builds the validated dense [`GossipMatrix`] and
//!   compresses it to CSR. The compressed rows hold exactly the
//!   nonzeros in ascending column order — the identical floating-point
//!   operation sequence as the dense kernel — so with an ideal config
//!   and a static schedule results match [`super::comm::DenseComm`]
//!   bit-for-bit (the parity tests in `tests/solver_api.rs` pin this).
//! - [`SimNet::sparse`] never materializes anything dense in the agent
//!   count: Metropolis–Hastings weights built straight from the
//!   adjacency lists, λ₂ via the seeded deterministic Lanczos estimate
//!   (persistent workspace across epochs). This is the fleet-scale
//!   mode, and on a static topology it is bit-identical to
//!   [`super::comm::SparseComm`].

use super::comm::Communicator;
use super::fastmix::{chebyshev_row_update_sparse, PingPong};
use super::metrics::CommStats;
use super::stack::AgentStack;
use crate::exec::Executor;
use crate::graph::dynamic::TopologySchedule;
use crate::graph::gossip::{GossipInfo, GossipMatrix};
use crate::graph::sparse::{SparseGossip, SpectrumWorkspace};
use crate::graph::topology::Topology;
use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Fault-model knobs for one [`SimNet`] run. All zeros = ideal network.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimConfig {
    /// Probability each directed message is lost in flight, per round.
    pub drop_prob: f64,
    /// Maximum per-link latency in virtual ticks (each link's fixed
    /// latency is derived deterministically from `seed`; 0 = instant).
    pub max_latency: u64,
    /// Std of i.i.d. Gaussian noise added to every delivered scalar.
    pub noise_std: f64,
    /// Master seed for drops and noise (and, via hashing, latencies).
    pub seed: u64,
}

impl SimConfig {
    /// Ideal network: no drops, no latency, no noise — bit-identical to
    /// [`super::comm::DenseComm`] on a static topology.
    pub fn ideal(seed: u64) -> Self {
        SimConfig { drop_prob: 0.0, max_latency: 0, noise_std: 0.0, seed }
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig::ideal(0x51AE7)
    }
}

/// How a [`SimNet`] turns each epoch's topology into gossip weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WeightMode {
    /// The paper's `L = I − M/λ_max(M)` via the dense [`GossipMatrix`]
    /// (exact spectrum, bit-compatible with `DenseComm`; epoch rebuilds
    /// are O(n²) so this is the small-fleet default).
    DenseLaplacian,
    /// Metropolis–Hastings CSR weights with a Lanczos spectrum estimate —
    /// nothing dense in the agent count, ever.
    SparseMetropolis,
}

/// Fixed latency of the directed link `from → to`, in virtual ticks,
/// derived from the run seed (stable across rounds and epochs — a link
/// that leaves and re-enters the live graph keeps its latency).
fn link_latency(seed: u64, from: usize, to: usize, max_latency: u64) -> u64 {
    if max_latency == 0 {
        return 0;
    }
    let key = seed ^ ((from as u64) << 32) ^ (to as u64) ^ 0xD15C_EE7E_5EED_F00D;
    Rng::seed_from(key).next_u64() % (max_latency + 1)
}

/// Gossip weights + FastMix step size for one schedule epoch.
///
/// `latency[idx]` is the latency of the directed link `cols[idx] → j`
/// for `idx` in row `j`'s CSR span (diagonal entries hold 0) — per live
/// directed edge, not an n × n table. Values come from the pure
/// [`link_latency`], so the round's slowest-delivery maximum is
/// independent of the storage layout.
struct Epoch {
    index: u64,
    sparse: SparseGossip,
    eta: f64,
    edges: usize,
    latency: Vec<u64>,
    /// CSR-aligned prefix: `diag_before[idx]` = number of diagonal
    /// entries at flat CSR indices `< idx`. The fault-plan apply stage
    /// uses it to turn a flat edge index into that edge's noise-draw
    /// slot (its delivered off-diagonal ordinal) in O(1) — only built
    /// when payload noise is enabled (empty otherwise).
    diag_before: Vec<usize>,
}

/// Rebuild the CSR-aligned latency vector for the current weights.
fn rebuild_latency(latency: &mut Vec<u64>, sparse: &SparseGossip, cfg: &SimConfig) {
    latency.clear();
    if cfg.max_latency == 0 {
        return;
    }
    latency.reserve(sparse.nnz());
    for j in 0..sparse.m() {
        let (cols, _) = sparse.row(j);
        for &i in cols {
            let l = if i == j { 0 } else { link_latency(cfg.seed, i, j, cfg.max_latency) };
            latency.push(l);
        }
    }
}

/// Rebuild the [`Epoch::diag_before`] prefix for the current weights.
/// Skipped (left empty) when noise is off — the apply stage never
/// consults it then.
fn rebuild_diag_before(diag_before: &mut Vec<usize>, sparse: &SparseGossip, cfg: &SimConfig) {
    diag_before.clear();
    if cfg.noise_std == 0.0 {
        return;
    }
    diag_before.reserve(sparse.nnz());
    let mut count = 0usize;
    for j in 0..sparse.m() {
        let (cols, _) = sparse.row(j);
        for &i in cols {
            diag_before.push(count);
            if i == j {
                count += 1;
            }
        }
    }
}

/// Rebuild `epoch`'s weights, step size, and latencies for a changed
/// topology. O(n + edges) in sparse mode (plus the capped Lanczos
/// sweep); the dense mode pays the O(n²) [`GossipMatrix`] build to keep
/// its exact spectrum and `DenseComm` bit-compatibility.
fn rebuild_epoch(
    epoch: &mut Epoch,
    topo: &Topology,
    mode: WeightMode,
    ws: &mut SpectrumWorkspace,
    cfg: &SimConfig,
) {
    match mode {
        WeightMode::DenseLaplacian => {
            let gossip = GossipMatrix::from_laplacian(topo);
            epoch.eta = gossip.chebyshev_eta();
            epoch.sparse = SparseGossip::from_gossip(&gossip);
        }
        WeightMode::SparseMetropolis => {
            epoch.sparse.rebuild_metropolis(topo);
            epoch.sparse.estimate_spectrum(ws);
            epoch.eta = epoch.sparse.chebyshev_eta();
        }
    }
    epoch.edges = topo.num_edges();
    rebuild_latency(&mut epoch.latency, &epoch.sparse, cfg);
    rebuild_diag_before(&mut epoch.diag_before, &epoch.sparse, cfg);
}

/// One round's materialized fault schedule: which directed links drop,
/// the noise draws for every delivered noisy link, and the round's
/// latency/drop aggregates. Only *eventful* links are stored —
/// O(dropped + delivered-noisy), not O(edges) — and the buffers persist
/// across rounds at their high-water mark, so steady-state rounds are
/// allocation-free.
///
/// The plan is what lets faulty rounds run on the executor: [`build`]
/// consumes the seeded `Rng` on the caller thread in exactly the
/// sequential order, then the row updates become pure functions of
/// (plan, flat CSR index) and parallelize like the ideal path with
/// bit-identical results.
///
/// [`build`]: FaultPlan::build
#[derive(Default)]
struct FaultPlan {
    /// Flat CSR indices of dropped directed links, strictly ascending
    /// (the build walk is j-ascending, CSR-column-ascending).
    drops: Vec<usize>,
    /// Noise draws for delivered noisy links: `d·k` consecutive values
    /// per link, in the same fixed walk order.
    noise: Vec<f64>,
    /// Drops this round (the `CommStats::dropped` increment).
    dropped: u64,
    /// Max latency over *delivered* links this round (dropped messages
    /// never land, so they cannot gate the round barrier).
    slowest: u64,
}

impl FaultPlan {
    /// Consume the fault rng for one round in exactly the order the
    /// sequential loop uses — j ascending, CSR column-ascending i, the
    /// drop draw before the per-element noise draws, diagonal entries
    /// consuming nothing — materializing only the eventful links.
    /// Runs on the caller thread; `LinkDrop` trace events fire here, so
    /// the deterministic event stream matches the sequential path
    /// exactly.
    fn build(&mut self, rng: &mut Rng, epoch: &Epoch, cfg: &SimConfig, d: usize, k: usize) {
        self.drops.clear();
        self.noise.clear();
        self.dropped = 0;
        self.slowest = 0;
        let sparse = &epoch.sparse;
        for j in 0..sparse.m() {
            let (lo, hi) = sparse.row_span(j);
            let (cols, _) = sparse.row(j);
            let lat: &[u64] = if cfg.max_latency > 0 { &epoch.latency[lo..hi] } else { &[] };
            for (e, &i) in cols.iter().enumerate() {
                if i == j {
                    continue;
                }
                if cfg.drop_prob > 0.0 && rng.chance(cfg.drop_prob) {
                    self.dropped += 1;
                    self.drops.push(lo + e);
                    crate::trace_event!(LinkDrop, i as u64, j as u64);
                    continue;
                }
                if cfg.max_latency > 0 {
                    self.slowest = self.slowest.max(lat[e]);
                }
                if cfg.noise_std > 0.0 {
                    for _ in 0..d * k {
                        self.noise.push(rng.normal());
                    }
                }
            }
        }
    }

    /// Reserve worst-case capacity (every off-diagonal link dropped /
    /// noisy) so later rounds never grow the buffers mid-solve — the
    /// zero-steady-state-allocation contract `alloc_free.rs` audits.
    fn reserve_worst_case(&mut self, sparse: &SparseGossip, d: usize, k: usize, cfg: &SimConfig) {
        let nnz = sparse.nnz();
        self.drops.reserve(nnz.saturating_sub(self.drops.len()));
        if cfg.noise_std > 0.0 {
            let want = nnz * d * k;
            self.noise.reserve(want.saturating_sub(self.noise.len()));
        }
    }
}

/// Mutable simulation state behind the [`Communicator`]'s `&self` API.
struct SimState {
    rng: Rng,
    schedule: TopologySchedule,
    epoch: Epoch,
    /// Global gossip-round counter (drives the schedule's epochs).
    round: u64,
    /// FastMix recursion buffers (shared shape with the dense engine —
    /// see [`PingPong`]), persistent across `fastmix` calls so
    /// steady-state rounds perform zero heap allocation.
    bufs: PingPong,
    /// Scratch for noised payloads (sequential faulty path).
    noisy: Mat,
    /// Per-round fault schedule for pooled faulty rounds, persistent at
    /// its high-water capacity.
    plan: FaultPlan,
    /// Per-chunk noised-payload scratch for the pooled faulty path (one
    /// `d × k` Mat per executor chunk; contents never influence
    /// results).
    chunk_noisy: Vec<Mat>,
    /// Persistent Lanczos workspace for sparse-mode epoch rebuilds.
    spectrum_ws: SpectrumWorkspace,
}

/// The deterministic unreliable-network engine. See the module docs.
pub struct SimNet {
    cfg: SimConfig,
    m: usize,
    mode: WeightMode,
    /// Epoch-0 spectral summary, reported through [`Communicator::info`]
    /// (spectral quantities of later epochs live inside the state).
    base_info: GossipInfo,
    state: Mutex<SimState>,
    /// Worker pool for the per-agent row blocks of every round. Ideal
    /// rounds dispatch directly (the row update is the shared
    /// [`chebyshev_row_update_sparse`] kernel). Faulty rounds split
    /// generation from application: a [`FaultPlan`] build pass on the
    /// caller thread consumes the seeded `Rng` in the same fixed
    /// (j, then CSR-ascending i) order as the sequential loop, after
    /// which the row updates are pure functions of (plan, flat CSR
    /// index) and dispatch through the executor's weighted chunks
    /// (`row_ptr` as the cost prefix). Results, stats, and the
    /// deterministic trace stream are bit-identical for every thread
    /// count; `threads() == 1` keeps the original single-pass
    /// sequential loop.
    exec: Arc<Executor>,
}

impl SimNet {
    fn build(mut schedule: TopologySchedule, cfg: SimConfig, mode: WeightMode) -> Self {
        assert!(
            (0.0..=1.0).contains(&cfg.drop_prob),
            "drop_prob must be in [0, 1]"
        );
        assert!(cfg.noise_std >= 0.0, "noise_std must be ≥ 0");
        let m = schedule.n();
        let mut spectrum_ws = SpectrumWorkspace::new();
        let (epoch, base_info) = {
            let step = schedule.advance_to(0);
            let topo0 = step.topology();
            let (sparse, eta) = match mode {
                WeightMode::DenseLaplacian => {
                    let g = GossipMatrix::from_laplacian(topo0);
                    let eta = g.chebyshev_eta();
                    (SparseGossip::from_gossip(&g), eta)
                }
                WeightMode::SparseMetropolis => {
                    // Checks connectivity; fills `spectrum_ws` so later
                    // churn epochs re-estimate without allocating.
                    let mut sg = SparseGossip::metropolis(topo0);
                    sg.estimate_spectrum(&mut spectrum_ws);
                    let eta = sg.chebyshev_eta();
                    (sg, eta)
                }
            };
            let mut latency = Vec::new();
            rebuild_latency(&mut latency, &sparse, &cfg);
            let mut diag_before = Vec::new();
            rebuild_diag_before(&mut diag_before, &sparse, &cfg);
            let info = sparse.info();
            let epoch = Epoch {
                index: 0,
                eta,
                edges: topo0.num_edges(),
                sparse,
                latency,
                diag_before,
            };
            (epoch, info)
        };
        SimNet {
            cfg,
            m,
            mode,
            base_info,
            state: Mutex::new(SimState {
                rng: Rng::seed_from(cfg.seed),
                schedule,
                epoch,
                round: 0,
                bufs: PingPong::default(),
                noisy: Mat::zeros(0, 0),
                plan: FaultPlan::default(),
                chunk_noisy: Vec::new(),
                spectrum_ws,
            }),
            exec: Arc::new(Executor::sequential()),
        }
    }

    /// Build over a (possibly time-varying) schedule with the paper's
    /// dense Laplacian weights (bit-compatible with `DenseComm`).
    pub fn new(schedule: TopologySchedule, cfg: SimConfig) -> Self {
        Self::build(schedule, cfg, WeightMode::DenseLaplacian)
    }

    /// Build over a schedule with sparse Metropolis weights and a
    /// Lanczos spectrum estimate — nothing dense in the agent count, so
    /// this is the constructor for fleet-scale simulations. On a static
    /// topology it is bit-identical to [`super::comm::SparseComm`].
    pub fn sparse(schedule: TopologySchedule, cfg: SimConfig) -> Self {
        Self::build(schedule, cfg, WeightMode::SparseMetropolis)
    }

    /// Build over a static topology (dense Laplacian weights).
    pub fn from_topology(topo: &Topology, cfg: SimConfig) -> Self {
        Self::new(TopologySchedule::fixed(topo.clone()), cfg)
    }

    /// Run each round's per-agent row blocks on `exec`'s worker pool.
    /// Faulty configs parallelize too: fault generation stays a
    /// sequential [`FaultPlan`] build on the caller thread (the seeded
    /// stream's order never changes), and only the pure index-based
    /// application fans out — results are bit-identical to the
    /// executor-less engine at every thread count.
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = exec;
        self
    }

    /// The fault-model configuration.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }
}

impl Communicator for SimNet {
    fn m(&self) -> usize {
        self.m
    }

    fn info(&self) -> GossipInfo {
        self.base_info
    }

    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        stats.record_mix();
        if rounds == 0 {
            return;
        }
        let m = self.m;
        assert_eq!(stack.m(), m, "stack size != network size");
        let (d, k) = stack.slice_shape();
        let _span = crate::trace_span!(Gossip, rounds as u64);

        let mut st = self.state.lock().expect("SimNet state poisoned");
        let st = &mut *st;

        // FastMix recursion buffers (same rotation scheme as DenseComm,
        // same [`PingPong`] helper), persistent in the state across
        // mixes — zero allocation in steady state.
        let SimState { rng, schedule, epoch, round, bufs, noisy, plan, chunk_noisy, spectrum_ws } =
            st;
        bufs.ensure(m, d, k);
        if noisy.shape() != (d, k) {
            // lint: allow(alloc, one-time rebuild when the problem shape changes; steady state reuses the buffer)
            *noisy = Mat::zeros(d, k);
        }
        bufs.load(stack);

        // Ideal rounds dispatch straight to the pool; faulty rounds run
        // pooled too via the fault-plan split (build sequential, apply
        // parallel — see the `exec` field). `threads() == 1` keeps the
        // original single-pass sequential loop.
        let ideal = self.cfg.drop_prob == 0.0
            && self.cfg.noise_std == 0.0
            && self.cfg.max_latency == 0;
        let pooled = self.exec.threads() > 1;
        if !ideal && pooled {
            let nchunks = self.exec.chunk_count(m);
            if chunk_noisy.len() < nchunks || chunk_noisy.iter().any(|s| s.shape() != (d, k)) {
                chunk_noisy.clear();
                // lint: allow(alloc, one-time scratch build on shape or pool change; steady state reuses the bank)
                chunk_noisy.resize_with(nchunks, || Mat::zeros(d, k));
            }
        }

        for _ in 0..rounds {
            // Consult the schedule. An Unchanged epoch tick is O(1);
            // only genuinely changed topologies rebuild weights (and
            // in sparse mode the rebuild reuses every buffer).
            let epoch_idx = schedule.epoch_of(*round);
            if epoch_idx != epoch.index {
                let step = schedule.advance_to(epoch_idx);
                if step.changed() {
                    rebuild_epoch(epoch, step.topology(), self.mode, spectrum_ws, &self.cfg);
                }
                epoch.index = epoch_idx;
            }
            let eta = epoch.eta;
            let one_plus_eta = 1.0 + eta;

            let mut dropped_this_round = 0u64;
            let mut slowest_delivery = 0u64;
            if ideal && pooled {
                // Ideal round on the pool: per-agent row blocks are
                // independent, and each accumulates through the same
                // fixed-order CSR kernel as the sequential branch below
                // (whose i == j arm is exactly the generic term) —
                // bit-identical for any thread count, and still
                // bit-identical to DenseComm in dense mode.
                let PingPong { prev, cur, next } = &mut *bufs;
                let prev: &[Mat] = prev;
                let cur: &[Mat] = cur;
                let sparse = &epoch.sparse;
                // Cost-aware chunks (CSR row pointer as the per-row
                // work prefix); boundaries are index-pure, so this is
                // bit-identical to uniform chunking.
                self.exec.par_weighted(next.as_mut_slice(), sparse.row_ptr(), |j, acc| {
                    let (cols, vals) = sparse.row(j);
                    chebyshev_row_update_sparse(cols, vals, eta, &prev[j], cur, acc);
                });
                bufs.rotate();
                *round += 1;
                stats.record_round(epoch.edges, d, k);
                stats.virtual_time += 1;
                crate::trace_event!(GossipRound, epoch.edges as u64);
                crate::trace_event!(GossipRoundIo, 1u64, (2 * epoch.edges * d * k) as u64 * 8);
                continue;
            }
            if !ideal && pooled {
                // Faulty round on the pool: generation is split from
                // application. The plan build consumes the seeded rng on
                // this thread in exactly the sequential branch's order
                // (so replay and the LinkDrop event stream are
                // unchanged), then the row updates — now pure functions
                // of (plan, flat CSR index) — fan out over weighted
                // chunks with the CSR row pointer as the cost prefix, so
                // hub rows don't serialize a chunk.
                plan.reserve_worst_case(&epoch.sparse, d, k, &self.cfg);
                plan.build(rng, epoch, &self.cfg, d, k);
                crate::trace_event!(
                    FaultPlanBuild,
                    plan.dropped,
                    (plan.drops.len() + plan.noise.len()) as u64
                );
                {
                    let PingPong { prev, cur, next } = &mut *bufs;
                    let prev: &[Mat] = prev;
                    let cur: &[Mat] = cur;
                    let sparse = &epoch.sparse;
                    let diag_before: &[usize] = &epoch.diag_before;
                    let drops: &[usize] = &plan.drops;
                    let noise: &[f64] = &plan.noise;
                    let cfg = self.cfg;
                    let noise_dim = d * k;
                    self.exec.par_weighted_chunks_ctx(
                        next.as_mut_slice(),
                        sparse.row_ptr(),
                        chunk_noisy,
                        |lo, rows, noisy| {
                            for (off, acc) in rows.iter_mut().enumerate() {
                                let j = lo + off;
                                let (rlo, _rhi) = sparse.row_span(j);
                                let (cols, vals) = sparse.row(j);
                                // Cursor over this row's drops: after the
                                // binary search it advances in lockstep
                                // with the edge walk, so at each edge it
                                // equals the global count of drops at
                                // flat indices below it.
                                let mut dcur = drops.partition_point(|&x| x < rlo);
                                // acc = −η · prev_j (overwrite, no zero pass).
                                acc.data_mut().copy_from_slice(prev[j].data());
                                acc.scale(-eta);
                                for (e, (&i, &w)) in cols.iter().zip(vals).enumerate() {
                                    if i == j {
                                        acc.axpy(one_plus_eta * w, &cur[j]);
                                        continue;
                                    }
                                    let flat = rlo + e;
                                    if dcur < drops.len() && drops[dcur] == flat {
                                        // Dropped: self-weight fallback,
                                        // same as the sequential branch.
                                        dcur += 1;
                                        acc.axpy(one_plus_eta * w, &cur[j]);
                                        continue;
                                    }
                                    if cfg.noise_std > 0.0 {
                                        // This delivered link's draws sit at
                                        // its delivered off-diagonal ordinal:
                                        // off-diagonals before `flat` minus
                                        // drops before `flat`.
                                        let slot = flat - diag_before[flat] - dcur;
                                        let z = &noise[slot * noise_dim..(slot + 1) * noise_dim];
                                        let nd = noisy.data_mut();
                                        for ((nv, &cv), &zv) in
                                            nd.iter_mut().zip(cur[i].data()).zip(z)
                                        {
                                            *nv = cv + cfg.noise_std * zv;
                                        }
                                        acc.axpy(one_plus_eta * w, noisy);
                                    } else {
                                        acc.axpy(one_plus_eta * w, &cur[i]);
                                    }
                                }
                            }
                        },
                    );
                }
                crate::trace_event!(FaultPlanApply, m as u64, plan.slowest);
                bufs.rotate();
                *round += 1;
                stats.record_round(epoch.edges, d, k);
                stats.dropped += plan.dropped;
                stats.virtual_time += 1 + plan.slowest;
                crate::trace_event!(GossipRound, epoch.edges as u64, plan.dropped);
                crate::trace_event!(
                    GossipRoundIo,
                    1 + plan.slowest,
                    (2 * epoch.edges * d * k) as u64 * 8
                );
                continue;
            }
            // One barrier-synchronized event per round: every directed
            // link carries one message; the deterministic (j, then CSR
            // column-ascending i) order below fixes both the Rng
            // consumption and the floating-point accumulation order.
            // Dense-mode CSR rows hold exactly the nonzeros the old
            // dense scan visited, in the same order, so faulty runs
            // replay bit-for-bit across the representation change.
            for j in 0..m {
                let (lo, hi) = epoch.sparse.row_span(j);
                let (cols, vals) = epoch.sparse.row(j);
                let lat: &[u64] =
                    if self.cfg.max_latency > 0 { &epoch.latency[lo..hi] } else { &[] };
                let acc = &mut bufs.next[j];
                // acc = −η · prev_j (overwrite, no zero pass).
                acc.data_mut().copy_from_slice(bufs.prev[j].data());
                acc.scale(-eta);
                for (e, (&i, &w)) in cols.iter().zip(vals).enumerate() {
                    if i == j {
                        acc.axpy(one_plus_eta * w, &bufs.cur[j]);
                        continue;
                    }
                    // Directed link i → j: one message this round.
                    if self.cfg.drop_prob > 0.0 && rng.chance(self.cfg.drop_prob) {
                        dropped_this_round += 1;
                        crate::trace_event!(LinkDrop, i as u64, j as u64);
                        // Self-weight fallback: substitute the receiver's
                        // own state so the row stays stochastic.
                        acc.axpy(one_plus_eta * w, &bufs.cur[j]);
                        continue;
                    }
                    if self.cfg.max_latency > 0 {
                        slowest_delivery = slowest_delivery.max(lat[e]);
                    }
                    if self.cfg.noise_std > 0.0 {
                        noisy.data_mut().copy_from_slice(bufs.cur[i].data());
                        for v in noisy.data_mut() {
                            *v += self.cfg.noise_std * rng.normal();
                        }
                        acc.axpy(one_plus_eta * w, noisy);
                    } else {
                        acc.axpy(one_plus_eta * w, &bufs.cur[i]);
                    }
                }
            }
            bufs.rotate();
            *round += 1;
            stats.record_round(epoch.edges, d, k);
            stats.dropped += dropped_this_round;
            // Discrete-event barrier: the round completes one baseline
            // tick after its slowest delivered message lands.
            stats.virtual_time += 1 + slowest_delivery;
            crate::trace_event!(GossipRound, epoch.edges as u64, dropped_this_round);
            crate::trace_event!(
                GossipRoundIo,
                1 + slowest_delivery,
                (2 * epoch.edges * d * k) as u64 * 8
            );
        }
        bufs.store(stack);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::comm::{DenseComm, SparseComm};

    fn random_stack(m: usize, d: usize, k: usize, seed: u64) -> AgentStack {
        let mut rng = Rng::seed_from(seed);
        AgentStack::new((0..m).map(|_| Mat::randn(d, k, &mut rng)).collect())
    }

    #[test]
    fn ideal_matches_dense() {
        // Same topology, same rounds: the ideal SimNet executes the
        // identical operation sequence as DenseComm — expected
        // bit-for-bit, asserted to the issue's 1e-12.
        let topo = Topology::erdos_renyi(12, 0.4, &mut Rng::seed_from(301));
        let dense = DenseComm::from_topology(&topo);
        let sim = SimNet::from_topology(&topo, SimConfig::ideal(0));

        let stack0 = random_stack(12, 6, 3, 302);
        let mut a = stack0.clone();
        let mut b = stack0;
        dense.fastmix(&mut a, 7, &mut CommStats::default());
        sim.fastmix(&mut b, 7, &mut CommStats::default());
        assert!(
            a.distance(&b) < 1e-12,
            "ideal SimNet deviates from DenseComm by {}",
            a.distance(&b)
        );
    }

    #[test]
    fn ideal_parity_survives_consecutive_mixes() {
        let topo = Topology::ring(8);
        let dense = DenseComm::from_topology(&topo);
        let sim = SimNet::from_topology(&topo, SimConfig::ideal(1));
        let stack0 = random_stack(8, 4, 2, 303);
        let mut a = stack0.clone();
        let mut b = stack0;
        for _ in 0..3 {
            dense.fastmix(&mut a, 5, &mut CommStats::default());
            sim.fastmix(&mut b, 5, &mut CommStats::default());
        }
        assert!(a.distance(&b) < 1e-12, "drift across mixes: {}", a.distance(&b));
    }

    #[test]
    fn pooled_ideal_bit_identical_to_sequential_and_dense() {
        let topo = Topology::erdos_renyi(11, 0.4, &mut Rng::seed_from(316));
        let stack0 = random_stack(11, 5, 2, 317);

        let mut seq = stack0.clone();
        SimNet::from_topology(&topo, SimConfig::ideal(3))
            .fastmix(&mut seq, 6, &mut CommStats::default());
        let mut dense = stack0.clone();
        DenseComm::from_topology(&topo).fastmix(&mut dense, 6, &mut CommStats::default());

        for threads in [2usize, 4, 8] {
            let sim = SimNet::from_topology(&topo, SimConfig::ideal(3))
                .with_executor(Arc::new(Executor::new(threads)));
            let mut got = stack0.clone();
            let mut stats = CommStats::default();
            sim.fastmix(&mut got, 6, &mut stats);
            assert_eq!(seq, got, "threads={threads}");
            assert_eq!(stats.virtual_time, 6, "one tick per ideal round");
            assert!(
                dense.distance(&got) < 1e-12,
                "pooled ideal SimNet deviates from DenseComm (threads={threads})"
            );
        }
    }

    #[test]
    fn pooled_faulty_bit_identical_to_sequential() {
        // The fault plan consumes the rng in the same fixed order the
        // sequential loop does and the apply stage is index-pure, so a
        // pooled faulty run (drops + noise + latency all active) must
        // match the executor-less engine bit for bit — stats included.
        let topo = Topology::erdos_renyi(9, 0.4, &mut Rng::seed_from(340));
        let cfg = SimConfig {
            drop_prob: 0.25,
            noise_std: 0.01,
            max_latency: 3,
            ..SimConfig::ideal(29)
        };
        let stack0 = random_stack(9, 4, 2, 318);

        let mut want = stack0.clone();
        let mut want_stats = CommStats::default();
        SimNet::from_topology(&topo, cfg).fastmix(&mut want, 9, &mut want_stats);
        assert!(want_stats.dropped > 0, "drops must actually fire in this fixture");

        for threads in [2usize, 4, 8] {
            let sim = SimNet::from_topology(&topo, cfg)
                .with_executor(Arc::new(Executor::new(threads)));
            let mut got = stack0.clone();
            let mut stats = CommStats::default();
            sim.fastmix(&mut got, 9, &mut stats);
            assert_eq!(want, got, "faulty rounds must be executor-invariant (threads={threads})");
            assert_eq!(want_stats, stats, "stats must be executor-invariant (threads={threads})");
        }
    }

    #[test]
    fn pooled_faulty_single_fault_axes_match_sequential() {
        // Each fault axis exercises a different plan field (drops →
        // drop mask, latency → slowest, noise → draw buffer); pin each
        // one alone against the sequential engine.
        let topo = Topology::erdos_renyi(10, 0.45, &mut Rng::seed_from(343));
        let axes = [
            SimConfig { drop_prob: 0.3, ..SimConfig::ideal(71) },
            SimConfig { max_latency: 4, ..SimConfig::ideal(72) },
            SimConfig { noise_std: 0.05, ..SimConfig::ideal(73) },
        ];
        for cfg in axes {
            let stack0 = random_stack(10, 3, 2, 344);
            let mut want = stack0.clone();
            let mut want_stats = CommStats::default();
            SimNet::from_topology(&topo, cfg).fastmix(&mut want, 7, &mut want_stats);

            let sim = SimNet::from_topology(&topo, cfg)
                .with_executor(Arc::new(Executor::new(4)));
            let mut got = stack0;
            let mut stats = CommStats::default();
            sim.fastmix(&mut got, 7, &mut stats);
            assert_eq!(want, got, "cfg={cfg:?}");
            assert_eq!(want_stats, stats, "cfg={cfg:?}");
        }
    }

    #[test]
    fn pooled_faulty_sparse_mode_with_churn_matches_sequential() {
        // Fleet-scale shape: Metropolis CSR weights, Markov churn
        // (epoch rebuilds mid-mix resize the plan's aux arrays), all
        // three fault axes on — still executor-invariant to the bit.
        let base = Topology::erdos_renyi(12, 0.5, &mut Rng::seed_from(341));
        let cfg = SimConfig {
            drop_prob: 0.15,
            noise_std: 0.02,
            max_latency: 2,
            ..SimConfig::ideal(57)
        };
        let stack0 = random_stack(12, 4, 2, 342);
        let run = |threads: usize| {
            let sched = TopologySchedule::markov(base.clone(), 0.3, 0.5, 61, 3);
            let mut sim = SimNet::sparse(sched, cfg);
            if threads > 1 {
                sim = sim.with_executor(Arc::new(Executor::new(threads)));
            }
            let mut s = stack0.clone();
            let mut stats = CommStats::default();
            sim.fastmix(&mut s, 20, &mut stats);
            (s, stats)
        };
        let (want, want_stats) = run(1);
        for threads in [2usize, 8] {
            let (got, stats) = run(threads);
            assert_eq!(want, got, "threads={threads}");
            assert_eq!(want_stats, stats, "threads={threads}");
        }
    }

    #[test]
    fn constant_stack_immune_to_drops() {
        // At consensus the self-weight fallback substitutes an identical
        // value, so even 50% drops change nothing — the property that
        // lets DeEPCA converge *exactly* through a lossy network.
        let topo = Topology::erdos_renyi(9, 0.5, &mut Rng::seed_from(304));
        let sim = SimNet::from_topology(
            &topo,
            SimConfig { drop_prob: 0.5, ..SimConfig::ideal(7) },
        );
        let w = Mat::randn(5, 2, &mut Rng::seed_from(305));
        let mut stack = AgentStack::replicate(9, &w);
        let mut stats = CommStats::default();
        sim.fastmix(&mut stack, 10, &mut stats);
        assert!(stats.dropped > 0, "50% drops must actually fire");
        for s in stack.iter() {
            assert!((s - &w).fro_norm() < 1e-10);
        }
    }

    #[test]
    fn deterministic_per_seed_and_seeds_differ() {
        let topo = Topology::ring(7);
        let cfg = SimConfig { drop_prob: 0.3, noise_std: 0.01, ..SimConfig::ideal(41) };
        let stack0 = random_stack(7, 4, 2, 306);

        let run = |cfg: SimConfig| {
            let sim = SimNet::from_topology(&topo, cfg);
            let mut s = stack0.clone();
            let mut stats = CommStats::default();
            sim.fastmix(&mut s, 12, &mut stats);
            (s, stats)
        };

        let (s1, st1) = run(cfg);
        let (s2, st2) = run(cfg);
        assert_eq!(s1, s2, "same seed must replay bit-for-bit");
        assert_eq!(st1, st2, "stats must replay too");

        let (s3, _) = run(SimConfig { seed: 42, ..cfg });
        assert!(s1.distance(&s3) > 1e-12, "different seeds should diverge");
    }

    #[test]
    fn drops_still_reach_consensus() {
        let topo = Topology::complete(8);
        let sim = SimNet::from_topology(
            &topo,
            SimConfig { drop_prob: 0.1, ..SimConfig::ideal(11) },
        );
        let mut stack = random_stack(8, 3, 2, 307);
        let dev0 = stack.deviation_from_mean();
        sim.fastmix(&mut stack, 30, &mut CommStats::default());
        let dev1 = stack.deviation_from_mean();
        assert!(stack.is_finite());
        assert!(
            dev1 < 1e-3 * dev0,
            "drops should slow, not stop, consensus: {dev0} -> {dev1}"
        );
    }

    #[test]
    fn latency_accrues_virtual_time_deterministically() {
        let topo = Topology::ring(6);
        let cfg = SimConfig { max_latency: 3, ..SimConfig::ideal(13) };
        let run = || {
            let sim = SimNet::from_topology(&topo, cfg);
            let mut s = random_stack(6, 3, 2, 308);
            let mut stats = CommStats::default();
            sim.fastmix(&mut s, 5, &mut stats);
            stats.virtual_time
        };
        let vt = run();
        assert!(vt >= 5, "at least one tick per round, got {vt}");
        assert!(vt <= 5 * 4, "latency bounded by max_latency, got {vt}");
        assert_eq!(vt, run(), "virtual time must be deterministic");
    }

    #[test]
    fn latency_invariant_to_weight_mode() {
        // The CSR-aligned latency entries come from the same pure
        // per-directed-link function in both modes, and both modes put
        // the same off-diagonal links in the live graph — so the
        // virtual clock is a property of the network, not the weights.
        let topo = Topology::erdos_renyi(10, 0.5, &mut Rng::seed_from(320));
        let cfg = SimConfig { max_latency: 5, ..SimConfig::ideal(21) };
        let run = |sim: SimNet| {
            let mut s = random_stack(10, 3, 2, 321);
            let mut stats = CommStats::default();
            sim.fastmix(&mut s, 6, &mut stats);
            stats.virtual_time
        };
        let dense_vt = run(SimNet::from_topology(&topo, cfg));
        let sparse_vt = run(SimNet::sparse(TopologySchedule::fixed(topo.clone()), cfg));
        assert_eq!(dense_vt, sparse_vt);
    }

    #[test]
    fn zero_latency_costs_one_tick_per_round() {
        let topo = Topology::star(5);
        let sim = SimNet::from_topology(&topo, SimConfig::ideal(17));
        let mut s = random_stack(5, 3, 2, 309);
        let mut stats = CommStats::default();
        sim.fastmix(&mut s, 9, &mut stats);
        assert_eq!(stats.virtual_time, 9);
    }

    #[test]
    fn noise_breaks_exact_consensus() {
        let topo = Topology::complete(6);
        let sim = SimNet::from_topology(
            &topo,
            SimConfig { noise_std: 0.1, ..SimConfig::ideal(19) },
        );
        let w = Mat::randn(4, 2, &mut Rng::seed_from(310));
        let mut stack = AgentStack::replicate(6, &w);
        sim.fastmix(&mut stack, 5, &mut CommStats::default());
        // Additive channel noise perturbs a perfectly-agreed stack…
        assert!(stack.deviation_from_mean() > 1e-6);
        // …but boundedly (no blow-up).
        assert!(stack.is_finite());
    }

    #[test]
    fn periodic_schedule_preserves_mean() {
        // Every epoch's gossip matrix is doubly stochastic, so switching
        // topologies mid-mix must still preserve the stack mean exactly.
        let sched = TopologySchedule::periodic(
            vec![Topology::ring(6), Topology::star(6)],
            2,
        );
        let sim = SimNet::new(sched, SimConfig::ideal(23));
        let mut stack = random_stack(6, 4, 2, 311);
        let mean0 = stack.mean();
        sim.fastmix(&mut stack, 12, &mut CommStats::default());
        assert!((&stack.mean() - &mean0).fro_norm() < 1e-9);
    }

    #[test]
    fn markov_churn_still_mixes() {
        let base = Topology::erdos_renyi(10, 0.5, &mut Rng::seed_from(312));
        let sched = TopologySchedule::markov(base, 0.3, 0.5, 29, 1);
        let sim = SimNet::new(
            sched,
            SimConfig { drop_prob: 0.05, ..SimConfig::ideal(31) },
        );
        let mut stack = random_stack(10, 4, 2, 313);
        let dev0 = stack.deviation_from_mean();
        sim.fastmix(&mut stack, 40, &mut CommStats::default());
        assert!(stack.is_finite());
        assert!(
            stack.deviation_from_mean() < 0.1 * dev0,
            "churned network failed to mix: {} -> {}",
            dev0,
            stack.deviation_from_mean()
        );
    }

    #[test]
    fn sparse_mode_static_matches_sparse_comm() {
        // Same Metropolis construction, same Lanczos seed → same η bits;
        // same CSR kernel in the same order → bit-identical mixing.
        let topo = Topology::erdos_renyi(14, 0.35, &mut Rng::seed_from(322));
        let sc = SparseComm::metropolis(&topo);
        let sim = SimNet::sparse(TopologySchedule::fixed(topo.clone()), SimConfig::ideal(5));
        let stack0 = random_stack(14, 5, 2, 323);
        let mut a = stack0.clone();
        let mut b = stack0;
        sc.fastmix(&mut a, 8, &mut CommStats::default());
        sim.fastmix(&mut b, 8, &mut CommStats::default());
        assert_eq!(a, b, "sparse SimNet must match SparseComm bit-for-bit");
    }

    #[test]
    fn sparse_mode_markov_churn_mixes_and_preserves_mean() {
        // The fleet-scale path: incremental churn epochs, Metropolis CSR
        // rebuilds, Lanczos η — still doubly stochastic every epoch.
        let base = Topology::erdos_renyi(12, 0.5, &mut Rng::seed_from(324));
        let sched = TopologySchedule::markov(base, 0.2, 0.6, 47, 2);
        let sim = SimNet::sparse(sched, SimConfig::ideal(9));
        let mut stack = random_stack(12, 4, 2, 325);
        let mean0 = stack.mean();
        let dev0 = stack.deviation_from_mean();
        sim.fastmix(&mut stack, 40, &mut CommStats::default());
        assert!(stack.is_finite());
        assert!((&stack.mean() - &mean0).fro_norm() < 1e-9);
        assert!(
            stack.deviation_from_mean() < 0.1 * dev0,
            "sparse churned network failed to mix: {} -> {}",
            dev0,
            stack.deviation_from_mean()
        );
    }

    #[test]
    fn sparse_mode_replays_bit_for_bit() {
        let base = Topology::erdos_renyi(11, 0.5, &mut Rng::seed_from(326));
        let cfg = SimConfig { drop_prob: 0.2, noise_std: 0.02, ..SimConfig::ideal(53) };
        let stack0 = random_stack(11, 4, 2, 327);
        let run = || {
            let sched = TopologySchedule::markov(base.clone(), 0.3, 0.5, 61, 3);
            let sim = SimNet::sparse(sched, cfg);
            let mut s = stack0.clone();
            let mut stats = CommStats::default();
            sim.fastmix(&mut s, 20, &mut stats);
            (s, stats)
        };
        let (s1, st1) = run();
        let (s2, st2) = run();
        assert_eq!(s1, s2, "sparse-mode faulty churn must replay bit-for-bit");
        assert_eq!(st1, st2, "stats must replay too");
    }

    #[test]
    fn zero_rounds_noop() {
        let topo = Topology::ring(5);
        let sim = SimNet::from_topology(
            &topo,
            SimConfig { drop_prob: 0.2, ..SimConfig::ideal(37) },
        );
        let mut stack = random_stack(5, 3, 2, 314);
        let before = stack.clone();
        let mut stats = CommStats::default();
        sim.fastmix(&mut stack, 0, &mut stats);
        assert_eq!(stack, before);
        assert_eq!(stats.mixes, 1);
        assert_eq!(stats.rounds, 0);
    }

    #[test]
    fn stats_accounting_matches_dense_shape() {
        let topo = Topology::ring(6); // 6 edges
        let sim = SimNet::from_topology(&topo, SimConfig::ideal(43));
        let mut stack = random_stack(6, 3, 2, 315);
        let mut stats = CommStats::default();
        sim.fastmix(&mut stack, 4, &mut stats);
        assert_eq!(stats.rounds, 4);
        assert_eq!(stats.mixes, 1);
        assert_eq!(stats.messages, 4 * 2 * 6);
        assert_eq!(stats.dropped, 0);
        assert_eq!(stats.scalars_sent, 4 * 12 * 6);
    }
}
