//! The aggregate variable of §4.1: one d×k matrix per agent.
//!
//! `AgentStack` is the paper's `W ∈ R^{d×k×m}` with slice
//! `W(:,:,j) = W_j`. It owns the mean / deviation operators that appear
//! throughout the analysis and in the Figure 1–2 metrics:
//! `W̄ = (1/m) Σ_j W_j` and `‖W − W̄ ⊗ 1‖`.

use crate::linalg::Mat;

/// Per-agent stack of equally-shaped matrices.
#[derive(Clone, Debug, PartialEq)]
pub struct AgentStack {
    slices: Vec<Mat>,
}

impl AgentStack {
    /// Build from per-agent slices (all must share a shape).
    pub fn new(slices: Vec<Mat>) -> Self {
        assert!(!slices.is_empty(), "empty stack");
        let shape = slices[0].shape();
        assert!(
            slices.iter().all(|s| s.shape() == shape),
            "inconsistent slice shapes"
        );
        AgentStack { slices }
    }

    /// `m` copies of one matrix (the paper's shared initialization
    /// `S_j⁰ = W⁰` for every agent).
    pub fn replicate(m: usize, w: &Mat) -> Self {
        AgentStack::new(vec![w.clone(); m])
    }

    /// Number of agents m.
    pub fn m(&self) -> usize {
        self.slices.len()
    }

    /// Shape of each slice.
    pub fn slice_shape(&self) -> (usize, usize) {
        self.slices[0].shape()
    }

    /// Agent j's slice.
    pub fn slice(&self, j: usize) -> &Mat {
        &self.slices[j]
    }

    /// Mutable access to agent j's slice.
    pub fn slice_mut(&mut self, j: usize) -> &mut Mat {
        &mut self.slices[j]
    }

    /// Iterate over slices.
    pub fn iter(&self) -> impl Iterator<Item = &Mat> {
        self.slices.iter()
    }

    /// Mutable iteration.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Mat> {
        self.slices.iter_mut()
    }

    /// Mutable view of all slices (for parallel writers that split the
    /// stack across threads; the slice shapes must be preserved).
    pub fn slices_mut(&mut self) -> &mut [Mat] {
        &mut self.slices
    }

    /// Overwrite every slice from `other` (same m, same slice shape)
    /// without touching the allocations — the stack-level `copy_from`.
    pub fn copy_from(&mut self, other: &AgentStack) {
        assert_eq!(self.m(), other.m(), "copy_from agent count mismatch");
        for (dst, src) in self.slices.iter_mut().zip(&other.slices) {
            dst.copy_from(src);
        }
    }

    /// The mean slice `(1/m) Σ_j W_j` (the bar variables of Eqn. 4.4).
    pub fn mean(&self) -> Mat {
        let (d, k) = self.slice_shape();
        let mut out = Mat::zeros(d, k);
        let inv_m = 1.0 / self.m() as f64;
        for s in &self.slices {
            out.axpy(inv_m, s);
        }
        out
    }

    /// Frobenius deviation from the mean: `‖W − W̄ ⊗ 1‖` — the consensus
    /// error plotted in the paper's first figure column.
    pub fn deviation_from_mean(&self) -> f64 {
        let mean = self.mean();
        self.slices
            .iter()
            .map(|s| {
                let d = s - &mean;
                let n = d.fro_norm();
                n * n
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Stack-wide Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.slices
            .iter()
            .map(|s| {
                let n = s.fro_norm();
                n * n
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Elementwise `self += alpha · other`.
    pub fn axpy(&mut self, alpha: f64, other: &AgentStack) {
        assert_eq!(self.m(), other.m());
        for (a, b) in self.slices.iter_mut().zip(&other.slices) {
            a.axpy(alpha, b);
        }
    }

    /// Stack distance `‖self − other‖` (used for `‖Wᵗ − Wᵗ⁻¹‖`, Lemma 8).
    pub fn distance(&self, other: &AgentStack) -> f64 {
        assert_eq!(self.m(), other.m());
        self.slices
            .iter()
            .zip(&other.slices)
            .map(|(a, b)| {
                let n = (a - b).fro_norm();
                n * n
            })
            .sum::<f64>()
            .sqrt()
    }

    /// True iff every slice is finite.
    pub fn is_finite(&self) -> bool {
        self.slices.iter().all(|s| s.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_stack(m: usize, d: usize, k: usize, seed: u64) -> AgentStack {
        let mut rng = Rng::seed_from(seed);
        AgentStack::new((0..m).map(|_| Mat::randn(d, k, &mut rng)).collect())
    }

    #[test]
    fn replicate_has_zero_deviation() {
        let mut rng = Rng::seed_from(91);
        let w = Mat::randn(6, 2, &mut rng);
        let s = AgentStack::replicate(5, &w);
        assert_eq!(s.m(), 5);
        assert!(s.deviation_from_mean() < 1e-15);
        assert!((&s.mean() - &w).fro_norm() < 1e-15);
    }

    #[test]
    fn mean_is_linear() {
        let a = random_stack(4, 5, 3, 92);
        let b = random_stack(4, 5, 3, 93);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        let want = {
            let mut w = a.mean();
            w.axpy(2.0, &b.mean());
            w
        };
        assert!((&c.mean() - &want).fro_norm() < 1e-12);
    }

    #[test]
    fn deviation_detects_outlier() {
        let mut rng = Rng::seed_from(94);
        let w = Mat::randn(4, 2, &mut rng);
        let mut s = AgentStack::replicate(3, &w);
        s.slice_mut(1).axpy(1.0, &Mat::eye(4).cols_range(0, 2));
        assert!(s.deviation_from_mean() > 0.5);
    }

    #[test]
    fn distance_zero_iff_equal() {
        let a = random_stack(3, 4, 2, 95);
        assert_eq!(a.distance(&a), 0.0);
        let b = random_stack(3, 4, 2, 96);
        assert!(a.distance(&b) > 0.0);
    }

    #[test]
    fn fro_norm_pythagorean() {
        let a = random_stack(3, 4, 2, 97);
        let direct: f64 = a
            .iter()
            .map(|s| s.fro_norm().powi(2))
            .sum::<f64>()
            .sqrt();
        assert!((a.fro_norm() - direct).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn rejects_mixed_shapes() {
        let _ = AgentStack::new(vec![Mat::zeros(2, 2), Mat::zeros(3, 2)]);
    }
}
