//! Communication accounting.
//!
//! The paper's x-axis is the number of communication (gossip) rounds; its
//! headline claim is a communication-complexity bound (Theorem 1,
//! Eqn. 3.9). Both engines in [`super::comm`] report through this struct
//! so experiments can plot error-vs-communication exactly like Figures
//! 1–2, and the threaded runtime additionally counts real bytes.

/// Cumulative communication statistics for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Gossip rounds executed (each round = one neighbor exchange
    /// network-wide; the paper's "communication" unit).
    pub rounds: u64,
    /// Number of FastMix invocations (= power iterations that communicated).
    pub mixes: u64,
    /// Total scalar values exchanged over all edges (both directions).
    pub scalars_sent: u64,
    /// Total bytes on the wire. Two accounting modes, never combined for
    /// the same traffic: the in-process engines *model* bytes via
    /// [`CommStats::record_round`] (scalars × 8 for f64 payloads), while
    /// the threaded engine *measures* its serialized channel payloads and
    /// reports them through [`CommStats::record_measured`]. Each
    /// transmission is counted by exactly one of the two paths.
    pub bytes_sent: u64,
    /// Messages (edge-transmissions) sent.
    pub messages: u64,
    /// Virtual clock ticks elapsed (SimNet only: each gossip round costs
    /// one tick plus the slowest delivered link's latency; the real-time
    /// engines leave this at 0).
    pub virtual_time: u64,
    /// Messages lost in flight (SimNet's per-link drop model; receivers
    /// fall back to their self-weight so gossip stays well-defined).
    pub dropped: u64,
    /// Stream epochs this accounting spans (online runs only: the
    /// [`crate::coordinator::online::OnlineSession`] driver counts one
    /// per epoch when it merges the inner run's stats; batch runs leave
    /// this at 0).
    pub epochs: u64,
}

impl CommStats {
    /// Record one gossip round over `edges` undirected edges where each
    /// transmission carries a d×k matrix.
    pub fn record_round(&mut self, edges: usize, d: usize, k: usize) {
        self.rounds += 1;
        // Undirected edge = two directed transmissions per round.
        let tx = 2 * edges as u64;
        let scalars = tx * (d * k) as u64;
        self.messages += tx;
        self.scalars_sent += scalars;
        self.bytes_sent += scalars * 8;
    }

    /// Record traffic whose serialized size was *measured* by the engine
    /// (the threaded runtime's channel payloads), as opposed to the
    /// modeled `scalars × 8` of [`CommStats::record_round`]. Callers use
    /// one mode or the other for a given transmission — never both — so
    /// byte totals are never double-counted.
    pub fn record_measured(&mut self, scalars: u64, bytes: u64) {
        self.scalars_sent += scalars;
        self.bytes_sent += bytes;
    }

    /// Record the start of a FastMix invocation.
    pub fn record_mix(&mut self) {
        self.mixes += 1;
    }

    /// Record one completed stream epoch (online driver).
    pub fn record_epoch(&mut self) {
        self.epochs += 1;
    }

    /// Merge another stats block (e.g. from a worker thread).
    pub fn merge(&mut self, other: &CommStats) {
        self.rounds += other.rounds;
        self.mixes += other.mixes;
        self.scalars_sent += other.scalars_sent;
        self.bytes_sent += other.bytes_sent;
        self.messages += other.messages;
        self.virtual_time += other.virtual_time;
        self.dropped += other.dropped;
        self.epochs += other.epochs;
    }

    /// Mean gossip rounds per stream epoch (0 when not an online run).
    pub fn rounds_per_epoch(&self) -> f64 {
        if self.epochs == 0 {
            0.0
        } else {
            self.rounds as f64 / self.epochs as f64
        }
    }

    /// Mean gossip rounds per mix (the effective K actually used).
    pub fn rounds_per_mix(&self) -> f64 {
        if self.mixes == 0 {
            0.0
        } else {
            self.rounds as f64 / self.mixes as f64
        }
    }
}

impl std::fmt::Display for CommStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} rounds ({} mixes, K̄={:.1}), {} msgs, {}",
            self.rounds,
            self.mixes,
            self.rounds_per_mix(),
            self.messages,
            crate::util::format::bytes(self.bytes_sent)
        )?;
        if self.dropped > 0 {
            write!(f, ", {} dropped", self.dropped)?;
        }
        if self.virtual_time > 0 {
            write!(f, ", {} vticks", self.virtual_time)?;
        }
        if self.epochs > 0 {
            write!(f, ", {} epochs", self.epochs)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_round_counts() {
        let mut s = CommStats::default();
        s.record_round(10, 300, 5); // 10 edges, 300x5 matrices
        assert_eq!(s.rounds, 1);
        assert_eq!(s.messages, 20);
        assert_eq!(s.scalars_sent, 20 * 1500);
        assert_eq!(s.bytes_sent, 20 * 1500 * 8);
    }

    #[test]
    fn record_measured_counts_real_bytes() {
        // The threaded engine measures serialized sizes; its payloads go
        // through record_measured instead of the modeled scalars×8 path.
        let mut s = CommStats::default();
        s.record_measured(1500, 12_345);
        assert_eq!(s.scalars_sent, 1500);
        assert_eq!(s.bytes_sent, 12_345);
        assert_eq!(s.rounds, 0, "measured traffic does not add rounds");
    }

    #[test]
    fn merge_adds() {
        let mut a = CommStats::default();
        a.record_mix();
        a.record_round(3, 2, 2);
        let mut b = CommStats::default();
        b.record_mix();
        b.record_round(3, 2, 2);
        b.record_round(3, 2, 2);
        a.merge(&b);
        assert_eq!(a.rounds, 3);
        assert_eq!(a.mixes, 2);
        assert!((a.rounds_per_mix() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn merge_carries_sim_fields() {
        let mut a = CommStats::default();
        a.virtual_time = 5;
        a.dropped = 2;
        let mut b = CommStats::default();
        b.virtual_time = 7;
        b.dropped = 1;
        a.merge(&b);
        assert_eq!(a.virtual_time, 12);
        assert_eq!(a.dropped, 3);
        let txt = format!("{a}");
        assert!(txt.contains("dropped") && txt.contains("vticks"));
    }

    #[test]
    fn epoch_accounting() {
        let mut a = CommStats::default();
        a.record_epoch();
        a.record_round(2, 4, 1);
        a.record_round(2, 4, 1);
        let mut b = CommStats::default();
        b.record_epoch();
        b.record_round(2, 4, 1);
        a.merge(&b);
        assert_eq!(a.epochs, 2);
        assert!((a.rounds_per_epoch() - 1.5).abs() < 1e-12);
        assert!(format!("{a}").contains("epochs"));
        assert_eq!(CommStats::default().rounds_per_epoch(), 0.0);
    }

    #[test]
    fn display_is_humane() {
        let mut s = CommStats::default();
        s.record_mix();
        s.record_round(5, 10, 2);
        let txt = format!("{s}");
        assert!(txt.contains("rounds"));
        assert!(txt.contains("msgs"));
    }
}
