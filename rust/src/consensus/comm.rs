//! Communication engines.
//!
//! Interchangeable implementations of [`Communicator`]:
//!
//! - [`DenseComm`] — single-process: validated dense gossip weights,
//!   mixed through their CSR compression. Used by the experiment sweeps
//!   where we want thousands of runs per minute.
//! - [`SparseComm`] — single-process, sparse-native: Metropolis CSR
//!   weights with a Lanczos λ₂ estimate, never materializing anything
//!   n×n. The fleet-scale engine (n = 10⁵–10⁶ agents).
//! - [`ThreadedNetwork`] — a real message-passing runtime: one OS thread
//!   per agent, one `std::sync::mpsc` channel per *directed edge*, every
//!   payload serialized length counted. Each FastMix round is a genuine
//!   neighbor exchange; nothing is shared between agents but channels.
//!   This is the engine the end-to-end examples run on, and integration
//!   tests assert it produces the same numbers as [`DenseComm`].
//!
//! Both run the identical Algorithm-3 recursion, so Proposition 1 applies
//! to either.

use super::fastmix::FastMix;
use super::metrics::CommStats;
use super::stack::AgentStack;
use crate::exec::Executor;
use crate::graph::gossip::{GossipInfo, GossipMatrix};
use crate::graph::sparse::SparseGossip;
use crate::graph::topology::Topology;
use crate::linalg::Mat;
use std::sync::{mpsc, Arc};

/// Abstraction over "run K gossip rounds across the network".
pub trait Communicator: Send + Sync {
    /// Number of agents.
    fn m(&self) -> usize;
    /// Spectral summary of the gossip weights (for round-count planning
    /// and reporting). A `Copy` struct rather than a borrow of any
    /// particular matrix representation, so sparse engines don't need an
    /// n×n matrix to answer it.
    fn info(&self) -> GossipInfo;
    /// In-place FastMix over the stack, accumulating stats. Engines keep
    /// their recursion buffers across calls, so steady-state gossip
    /// performs no payload cloning or allocation (Dense/Sim engines; the
    /// threaded engines still allocate per *message*, which is the
    /// serialization they exist to model).
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats);
    /// Mean-reduce `src` into `dst` without mutating `src`: copy, then
    /// run `rounds` FastMix rounds in place. `dst` must already have
    /// `src`'s shape — callers keep a long-lived output stack so the
    /// whole reduction is allocation-free in steady state.
    fn reduce_into(
        &self,
        src: &AgentStack,
        dst: &mut AgentStack,
        rounds: usize,
        stats: &mut CommStats,
    ) {
        dst.copy_from(src);
        self.fastmix(dst, rounds, stats);
    }
}

// Forwarding impl so a borrowed communicator can be boxed into a solver
// (external backends drive the step-wise API over `&dyn Communicator`).
impl Communicator for &dyn Communicator {
    fn m(&self) -> usize {
        (**self).m()
    }
    fn info(&self) -> GossipInfo {
        (**self).info()
    }
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        (**self).fastmix(stack, rounds, stats)
    }
    fn reduce_into(
        &self,
        src: &AgentStack,
        dst: &mut AgentStack,
        rounds: usize,
        stats: &mut CommStats,
    ) {
        (**self).reduce_into(src, dst, rounds, stats)
    }
}

// --------------------------------------------------------------- DenseComm

/// Single-process dense engine (fast path for sweeps).
pub struct DenseComm {
    fm: FastMix,
}

impl DenseComm {
    /// Build from a topology using the paper's Laplacian weights.
    pub fn from_topology(topo: &Topology) -> Self {
        let g = GossipMatrix::from_laplacian(topo);
        DenseComm { fm: FastMix::new(g, topo.num_edges()) }
    }

    /// Build from an explicit gossip matrix (edges for accounting).
    pub fn new(gossip: GossipMatrix, edges: usize) -> Self {
        DenseComm { fm: FastMix::new(gossip, edges) }
    }

    /// Run each gossip round's per-agent row blocks on `exec`'s worker
    /// pool (bit-identical to the sequential path for any thread count
    /// — see [`FastMix::with_executor`]).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.fm = self.fm.with_executor(exec);
        self
    }

    /// The validated dense gossip matrix (always present for this
    /// engine; tests and diagnostics inspect it directly).
    pub fn gossip(&self) -> &GossipMatrix {
        self.fm
            .dense_gossip()
            .expect("DenseComm is densely constructed")
    }
}

impl Communicator for DenseComm {
    fn m(&self) -> usize {
        self.fm.m()
    }
    fn info(&self) -> GossipInfo {
        self.fm.info()
    }
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        self.fm.mix(stack, rounds, stats);
    }
}

// -------------------------------------------------------------- SparseComm

/// Sparse-native single-process engine: CSR Metropolis weights, Lanczos
/// λ₂ estimate, O(edges · d · k) per round and O(n · d · k + edges)
/// memory — nothing dense in the agent count anywhere. This is the
/// engine for fleet-scale networks (n = 10⁵–10⁶); at paper scale
/// (n ≲ 10³) [`DenseComm`] is equivalent and its Laplacian weights
/// usually have the larger spectral gap.
pub struct SparseComm {
    fm: FastMix,
}

impl SparseComm {
    /// Metropolis–Hastings weights over `topo`, built directly in CSR.
    pub fn metropolis(topo: &Topology) -> Self {
        SparseComm { fm: FastMix::from_sparse(SparseGossip::metropolis(topo)) }
    }

    /// Wrap prebuilt CSR weights.
    pub fn from_sparse(sparse: SparseGossip) -> Self {
        SparseComm { fm: FastMix::from_sparse(sparse) }
    }

    /// Run each gossip round's per-agent row blocks on `exec`'s worker
    /// pool (bit-identical to the sequential path for any thread count
    /// — see [`FastMix::with_executor`]).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.fm = self.fm.with_executor(exec);
        self
    }

    /// The CSR weights this engine mixes over.
    pub fn sparse(&self) -> &SparseGossip {
        self.fm.sparse_gossip()
    }
}

impl Communicator for SparseComm {
    fn m(&self) -> usize {
        self.fm.m()
    }
    fn info(&self) -> GossipInfo {
        self.fm.info()
    }
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        self.fm.mix(stack, rounds, stats);
    }
}

// --------------------------------------------------------- ThreadedNetwork

/// Fault injection: agent `agent` transmits zeros during gossip round
/// `round` (0-based, within one `fastmix` call) — models a transient
/// corrupted/blanked transmission.
#[derive(Clone, Copy, Debug)]
pub struct Fault {
    /// Misbehaving agent id.
    pub agent: usize,
    /// Round index within the mix at which the fault fires.
    pub round: usize,
}

/// Per-edge channel endpoints, built once per engine and reused across
/// `fastmix` calls (constructing one mpsc channel per directed edge on
/// every mix dominated small-problem runtimes). Safe to reuse: each
/// round every sender pushes exactly one message per out-edge and every
/// receiver pops exactly one per in-edge, so the queues drain by the end
/// of each mix and no state leaks between calls.
struct EdgeChannels {
    /// Per agent: (destination, sender) for each out-edge.
    outs: Vec<Vec<(usize, mpsc::Sender<Vec<f64>>)>>,
    /// Per agent: (source, receiver) for each in-edge.
    ins: Vec<Vec<(usize, mpsc::Receiver<Vec<f64>>)>>,
}

impl EdgeChannels {
    fn for_topology(topo: &Topology) -> Self {
        let m = topo.n();
        let mut outs: Vec<Vec<(usize, mpsc::Sender<Vec<f64>>)>> =
            (0..m).map(|_| Vec::new()).collect();
        let mut ins: Vec<Vec<(usize, mpsc::Receiver<Vec<f64>>)>> =
            (0..m).map(|_| Vec::new()).collect();
        for i in 0..m {
            for &j in topo.neighbors(i) {
                let (tx, rx) = mpsc::channel::<Vec<f64>>();
                outs[i].push((j, tx));
                ins[j].push((i, rx));
            }
        }
        EdgeChannels { outs, ins }
    }
}

/// Message-passing engine: persistent agent threads + per-edge channels.
pub struct ThreadedNetwork {
    topo: Topology,
    gossip: GossipMatrix,
    eta: f64,
    fault: Option<Fault>,
    /// Reused across mixes; the mutex also serializes concurrent
    /// `fastmix` calls on one engine (each call needs the full set).
    channels: std::sync::Mutex<EdgeChannels>,
    /// Hosts the agent threads on its blocking tier: one dedicated
    /// persistent thread per agent, created on the first mix and reused
    /// for every later one (agents park on channel `recv` mid-round, so
    /// they need real threads, not pool slots — see
    /// [`Executor::scoped_blocking`]). Replaces the per-call
    /// `std::thread::scope` spawns that dominated small-problem mixes.
    exec: Arc<Executor>,
}

impl ThreadedNetwork {
    /// Build with the paper's Laplacian gossip weights.
    pub fn from_topology(topo: &Topology) -> Self {
        let gossip = GossipMatrix::from_laplacian(topo);
        let eta = gossip.chebyshev_eta();
        let channels = std::sync::Mutex::new(EdgeChannels::for_topology(topo));
        ThreadedNetwork {
            topo: topo.clone(),
            gossip,
            eta,
            fault: None,
            channels,
            exec: Arc::new(Executor::sequential()),
        }
    }

    /// Enable fault injection (see [`Fault`]).
    pub fn with_fault(mut self, fault: Fault) -> Self {
        self.fault = Some(fault);
        self
    }

    /// Host the agent threads on a shared executor's blocking tier
    /// (e.g. the session-wide pool) instead of a private one.
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = exec;
        self
    }
}

impl Communicator for ThreadedNetwork {
    fn m(&self) -> usize {
        self.topo.n()
    }

    fn info(&self) -> GossipInfo {
        self.gossip.info()
    }

    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        stats.record_mix();
        if rounds == 0 {
            return;
        }
        let m = self.topo.n();
        assert_eq!(stack.m(), m);
        let (d, k) = stack.slice_shape();
        let _span = crate::trace_span!(Gossip, rounds as u64, self.topo.num_edges() as u64);

        // Channels are built once per engine (see [`EdgeChannels`]) and
        // lent to the agent threads for this mix. Each agent sends
        // exactly one message per out-edge per round and receives one
        // per in-edge, so rounds are self-synchronizing — a receiver
        // blocks until its neighbors' round-r messages arrive — and the
        // queues are empty again when the threads join.
        // Recover from a prior mix that panicked mid-flight: a poisoned
        // lock or an incomplete endpoint set (only the threads joined
        // before the panic handed their channels back, and surviving
        // queues may hold residue) is discarded and rebuilt, so the
        // engine stays usable for callers that caught the panic.
        let mut guard = match self.channels.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let mut senders = std::mem::take(&mut guard.outs);
        let mut receivers = std::mem::take(&mut guard.ins);
        if senders.len() != m || receivers.len() != m {
            let fresh = EdgeChannels::for_topology(&self.topo);
            senders = fresh.outs;
            receivers = fresh.ins;
        }

        let eta = self.eta;
        let weights = &self.gossip.weights;
        let fault = self.fault;

        // Take each agent's slice out so agent tasks own their state.
        // Each task runs on a dedicated persistent thread from the
        // executor's blocking tier (agents block on `recv` mid-round;
        // see the `exec` field) and hands its results — iterate,
        // byte count, channel endpoints — back through its slot.
        type AgentOutcome = (
            Mat,
            u64, // scalars sent
            Vec<(usize, mpsc::Sender<Vec<f64>>)>,
            Vec<(usize, mpsc::Receiver<Vec<f64>>)>,
        );
        let mut results: Vec<Option<AgentOutcome>> = (0..m).map(|_| None).collect();
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m);
            for ((j, (outs, ins)), slot) in senders
                .into_iter()
                .zip(receivers)
                .enumerate()
                .zip(results.iter_mut())
            {
                let init = stack.slice(j).clone();
                let wrow: Vec<f64> = weights.row(j).to_vec();
                tasks.push(Box::new(move || {
                    // Three task-local recursion buffers rotated by
                    // swap — no per-round Mat allocation. The per-edge
                    // payload Vecs remain: they model real serialization
                    // and are what this engine exists to measure.
                    let mut prev = init.clone();
                    let mut cur = init;
                    let mut next = Mat::zeros(d, k);
                    let mut scalars_sent: u64 = 0;
                    for r in 0..rounds {
                        // 1. Transmit current state to every neighbor.
                        let payload: Vec<f64> = if matches!(fault, Some(f) if f.agent == j && f.round == r)
                        {
                            vec![0.0; d * k]
                        } else {
                            cur.data().to_vec()
                        };
                        for (_to, tx) in &outs {
                            tx.send(payload.clone()).expect("receiver alive");
                            scalars_sent += (d * k) as u64;
                        }
                        // 2. Collect neighbor states for this round.
                        next.copy_from(&cur);
                        next.scale(wrow[j]);
                        for (from, rx) in &ins {
                            let data = rx.recv().expect("sender alive");
                            let neighbor = Mat::from_vec(d, k, data);
                            next.axpy(wrow[*from], &neighbor);
                        }
                        // 3. Chebyshev update.
                        next.scale(1.0 + eta);
                        next.axpy(-eta, &prev);
                        // Rotate: prev ← cur ← next ← (old prev, reused).
                        std::mem::swap(&mut prev, &mut cur);
                        std::mem::swap(&mut cur, &mut next);
                    }
                    *slot = Some((cur, scalars_sent, outs, ins));
                }));
            }
            // Blocks until every agent finishes; a panicking agent drops
            // its senders, unwinding its peers, and `scoped_blocking`
            // re-raises after all tasks end — the channel endpoints are
            // then missing from the guard and the next mix rebuilds them
            // (the recovery path documented above).
            self.exec.scoped_blocking(tasks);
        }

        let mut total_scalars = 0u64;
        for (j, res) in results.into_iter().enumerate() {
            let (mat, scalars, outs, ins) = res.expect("agent task completed");
            *stack.slice_mut(j) = mat;
            total_scalars += scalars;
            // Hand the channel endpoints back for the next mix
            // (harvested in agent order, so the layout is preserved).
            guard.outs.push(outs);
            guard.ins.push(ins);
        }
        stats.rounds += rounds as u64;
        stats.messages += (rounds * 2 * self.topo.num_edges()) as u64;
        // Measured mode: the agents counted the scalars they actually
        // serialized into channel payloads (including zeroed fault
        // payloads); bytes are the serialized size of exactly those
        // scalars — never also pushed through the modeled
        // `record_round` path, so nothing is double-counted.
        let measured_bytes = total_scalars * std::mem::size_of::<f64>() as u64;
        stats.record_measured(total_scalars, measured_bytes);
        let edges = self.topo.num_edges() as u64;
        let bytes_per_round = measured_bytes / rounds as u64;
        for _ in 0..rounds {
            crate::trace_event!(GossipRound, edges);
            crate::trace_event!(GossipRoundIo, 0u64, bytes_per_round);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_stack(m: usize, d: usize, k: usize, seed: u64) -> AgentStack {
        let mut rng = Rng::seed_from(seed);
        AgentStack::new((0..m).map(|_| Mat::randn(d, k, &mut rng)).collect())
    }

    #[test]
    fn threaded_matches_dense_exactly() {
        let topo = Topology::erdos_renyi(12, 0.4, &mut Rng::seed_from(111));
        let dense = DenseComm::from_topology(&topo);
        let threaded = ThreadedNetwork::from_topology(&topo);

        let stack0 = random_stack(12, 6, 3, 112);
        let mut a = stack0.clone();
        let mut b = stack0;
        dense.fastmix(&mut a, 6, &mut CommStats::default());
        threaded.fastmix(&mut b, 6, &mut CommStats::default());
        assert!(
            a.distance(&b) < 1e-10,
            "engines disagree: {}",
            a.distance(&b)
        );
    }

    #[test]
    fn channel_reuse_across_consecutive_mixes() {
        // Channels are constructed once per engine; two consecutive
        // `fastmix` calls must leave no residue (every queue drains each
        // mix) and match the dense engine driven the same way. Note the
        // FastMix recursion restarts `W^{-1} = W^0` at each call, so two
        // K-round calls are *not* the same map as one 2K-round call —
        // the invariant is per-call parity with DenseComm plus the
        // shared consensus limit (the mean) of the 2K-round call.
        let topo = Topology::erdos_renyi(10, 0.4, &mut Rng::seed_from(118));
        let dense = DenseComm::from_topology(&topo);
        let threaded = ThreadedNetwork::from_topology(&topo);

        let stack0 = random_stack(10, 5, 2, 119);
        let mut a = stack0.clone();
        let mut b = stack0.clone();
        let mut stats = CommStats::default();
        dense.fastmix(&mut a, 4, &mut CommStats::default());
        dense.fastmix(&mut a, 4, &mut CommStats::default());
        threaded.fastmix(&mut b, 4, &mut stats);
        threaded.fastmix(&mut b, 4, &mut stats);
        assert!(
            a.distance(&b) < 1e-10,
            "reused channels corrupted the second mix: {}",
            a.distance(&b)
        );
        assert_eq!(stats.mixes, 2);
        assert_eq!(stats.rounds, 8);

        // Same total communication as a single 2x-rounds call, and the
        // same preserved mean.
        let mut c = stack0;
        dense.fastmix(&mut c, 8, &mut CommStats::default());
        assert!((&b.mean() - &c.mean()).fro_norm() < 1e-10);
    }

    #[test]
    fn threaded_preserves_mean() {
        let topo = Topology::ring(9);
        let net = ThreadedNetwork::from_topology(&topo);
        let mut stack = random_stack(9, 4, 2, 113);
        let mean0 = stack.mean();
        net.fastmix(&mut stack, 8, &mut CommStats::default());
        assert!((&stack.mean() - &mean0).fro_norm() < 1e-10);
    }

    #[test]
    fn threaded_counts_bytes() {
        let topo = Topology::ring(6); // 6 edges
        let net = ThreadedNetwork::from_topology(&topo);
        let mut stack = random_stack(6, 5, 2, 114);
        let mut stats = CommStats::default();
        net.fastmix(&mut stack, 3, &mut stats);
        // Each round: every directed edge (12) carries 5*2 scalars.
        assert_eq!(stats.scalars_sent, 3 * 12 * 10);
        assert_eq!(stats.bytes_sent, 3 * 12 * 10 * 8);
        assert_eq!(stats.rounds, 3);
        assert_eq!(stats.messages, 3 * 12);
    }

    #[test]
    fn fault_perturbs_then_recontracts() {
        let topo = Topology::complete(8);
        let clean = ThreadedNetwork::from_topology(&topo);
        let faulty = ThreadedNetwork::from_topology(&topo)
            .with_fault(Fault { agent: 2, round: 0 });

        let stack0 = random_stack(8, 3, 2, 115);
        let mut a = stack0.clone();
        let mut b = stack0;
        clean.fastmix(&mut a, 10, &mut CommStats::default());
        faulty.fastmix(&mut b, 10, &mut CommStats::default());
        // The corrupted transmission shifts the consensus value...
        assert!(a.distance(&b) > 1e-6, "fault had no effect");
        // ...but the network still reaches (a different) consensus.
        assert!(
            b.deviation_from_mean() < 1e-6,
            "post-fault deviation {}",
            b.deviation_from_mean()
        );
    }

    #[test]
    fn zero_rounds_noop_threaded() {
        let topo = Topology::ring(5);
        let net = ThreadedNetwork::from_topology(&topo);
        let mut stack = random_stack(5, 3, 2, 116);
        let before = stack.clone();
        net.fastmix(&mut stack, 0, &mut CommStats::default());
        assert_eq!(stack, before);
    }

    #[test]
    fn sparse_comm_preserves_mean_and_contracts() {
        let topo = Topology::ring(24);
        let sc = SparseComm::metropolis(&topo);
        let mut stack = random_stack(24, 4, 2, 120);
        let mean0 = stack.mean();
        let dev0 = stack.deviation_from_mean();
        let k = sc.info().rounds_for_rho(0.1).min(200);
        sc.fastmix(&mut stack, k, &mut CommStats::default());
        assert!((&stack.mean() - &mean0).fro_norm() < 1e-9);
        let bound = sc.info().rho(k) * dev0 * 1.3 + 1e-12;
        assert!(
            stack.deviation_from_mean() <= bound,
            "dev {} > {bound}",
            stack.deviation_from_mean()
        );
    }

    #[test]
    fn works_on_sparse_topologies() {
        for topo in [Topology::path(7), Topology::star(7), Topology::grid(2, 4)] {
            let net = ThreadedNetwork::from_topology(&topo);
            let m = topo.n();
            let mut stack = random_stack(m, 3, 2, 117);
            let mean0 = stack.mean();
            net.fastmix(&mut stack, 25, &mut CommStats::default());
            assert!((&stack.mean() - &mean0).fro_norm() < 1e-9);
            assert!(stack.deviation_from_mean() < 0.2 * m as f64);
        }
    }
}
