//! Communication engines.
//!
//! Two interchangeable implementations of [`Communicator`]:
//!
//! - [`DenseComm`] — single-process: applies the gossip weight matrix
//!   directly (exploiting its sparsity). Used by the experiment sweeps
//!   where we want thousands of runs per minute.
//! - [`ThreadedNetwork`] — a real message-passing runtime: one OS thread
//!   per agent, one `std::sync::mpsc` channel per *directed edge*, every
//!   payload serialized length counted. Each FastMix round is a genuine
//!   neighbor exchange; nothing is shared between agents but channels.
//!   This is the engine the end-to-end examples run on, and integration
//!   tests assert it produces the same numbers as [`DenseComm`].
//!
//! Both run the identical Algorithm-3 recursion, so Proposition 1 applies
//! to either.

use super::fastmix::FastMix;
use super::metrics::CommStats;
use super::stack::AgentStack;
use crate::graph::gossip::GossipMatrix;
use crate::graph::topology::Topology;
use crate::linalg::Mat;
use std::sync::mpsc;

/// Abstraction over "run K gossip rounds across the network".
pub trait Communicator: Send + Sync {
    /// Number of agents.
    fn m(&self) -> usize;
    /// The gossip matrix (for spectral quantities / reporting).
    fn gossip(&self) -> &GossipMatrix;
    /// In-place FastMix over the stack, accumulating stats.
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats);
}

// Forwarding impl so a borrowed communicator can be boxed into a solver
// (used by the deprecated `run_with` shims).
impl Communicator for &dyn Communicator {
    fn m(&self) -> usize {
        (**self).m()
    }
    fn gossip(&self) -> &GossipMatrix {
        (**self).gossip()
    }
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        (**self).fastmix(stack, rounds, stats)
    }
}

// --------------------------------------------------------------- DenseComm

/// Single-process dense engine (fast path for sweeps).
pub struct DenseComm {
    fm: FastMix,
}

impl DenseComm {
    /// Build from a topology using the paper's Laplacian weights.
    pub fn from_topology(topo: &Topology) -> Self {
        let g = GossipMatrix::from_laplacian(topo);
        DenseComm { fm: FastMix::new(g, topo.num_edges()) }
    }

    /// Build from an explicit gossip matrix (edges for accounting).
    pub fn new(gossip: GossipMatrix, edges: usize) -> Self {
        DenseComm { fm: FastMix::new(gossip, edges) }
    }
}

impl Communicator for DenseComm {
    fn m(&self) -> usize {
        self.fm.gossip().m()
    }
    fn gossip(&self) -> &GossipMatrix {
        self.fm.gossip()
    }
    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        self.fm.mix(stack, rounds, stats);
    }
}

// --------------------------------------------------------- ThreadedNetwork

/// Fault injection: agent `agent` transmits zeros during gossip round
/// `round` (0-based, within one `fastmix` call) — models a transient
/// corrupted/blanked transmission.
#[derive(Clone, Copy, Debug)]
pub struct Fault {
    /// Misbehaving agent id.
    pub agent: usize,
    /// Round index within the mix at which the fault fires.
    pub round: usize,
}

/// Message-passing engine: threads + per-edge channels.
pub struct ThreadedNetwork {
    topo: Topology,
    gossip: GossipMatrix,
    eta: f64,
    fault: Option<Fault>,
}

impl ThreadedNetwork {
    /// Build with the paper's Laplacian gossip weights.
    pub fn from_topology(topo: &Topology) -> Self {
        let gossip = GossipMatrix::from_laplacian(topo);
        let l2 = gossip.lambda2;
        let root = (1.0 - l2 * l2).sqrt();
        let eta = (1.0 - root) / (1.0 + root);
        ThreadedNetwork { topo: topo.clone(), gossip, eta, fault: None }
    }

    /// Enable fault injection (see [`Fault`]).
    pub fn with_fault(mut self, fault: Fault) -> Self {
        self.fault = Some(fault);
        self
    }
}

impl Communicator for ThreadedNetwork {
    fn m(&self) -> usize {
        self.topo.n()
    }

    fn gossip(&self) -> &GossipMatrix {
        &self.gossip
    }

    fn fastmix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        stats.record_mix();
        if rounds == 0 {
            return;
        }
        let m = self.topo.n();
        assert_eq!(stack.m(), m);
        let (d, k) = stack.slice_shape();

        // One channel per directed edge (i -> j). Each agent sends exactly
        // one message per out-edge per round and receives one per in-edge,
        // so rounds are self-synchronizing: a receiver blocks until its
        // neighbors' round-r messages arrive.
        let mut senders: Vec<Vec<(usize, mpsc::Sender<Vec<f64>>)>> = (0..m).map(|_| Vec::new()).collect();
        let mut receivers: Vec<Vec<(usize, mpsc::Receiver<Vec<f64>>)>> = (0..m).map(|_| Vec::new()).collect();
        for i in 0..m {
            for &j in self.topo.neighbors(i) {
                let (tx, rx) = mpsc::channel::<Vec<f64>>();
                senders[i].push((j, tx));
                receivers[j].push((i, rx));
            }
        }

        let eta = self.eta;
        let weights = &self.gossip.weights;
        let fault = self.fault;

        // Take each agent's slice out so threads own their state.
        let mut results: Vec<Option<(Mat, u64 /*scalars sent*/)>> = (0..m).map(|_| None).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(m);
            for (j, (outs, ins)) in senders
                .drain(..)
                .zip(receivers.drain(..))
                .enumerate()
            {
                let init = stack.slice(j).clone();
                let wrow: Vec<f64> = weights.row(j).to_vec();
                let handle = scope.spawn(move || {
                    let mut prev = init.clone();
                    let mut cur = init;
                    let mut scalars_sent: u64 = 0;
                    for r in 0..rounds {
                        // 1. Transmit current state to every neighbor.
                        let payload: Vec<f64> = if matches!(fault, Some(f) if f.agent == j && f.round == r)
                        {
                            vec![0.0; d * k]
                        } else {
                            cur.data().to_vec()
                        };
                        for (_to, tx) in &outs {
                            tx.send(payload.clone()).expect("receiver alive");
                            scalars_sent += (d * k) as u64;
                        }
                        // 2. Collect neighbor states for this round.
                        let mut acc = cur.scaled(wrow[j]);
                        for (from, rx) in &ins {
                            let data = rx.recv().expect("sender alive");
                            let neighbor = Mat::from_vec(d, k, data);
                            acc.axpy(wrow[*from], &neighbor);
                        }
                        // 3. Chebyshev update.
                        acc.scale(1.0 + eta);
                        acc.axpy(-eta, &prev);
                        prev = std::mem::replace(&mut cur, acc);
                    }
                    (cur, scalars_sent)
                });
                handles.push(handle);
            }
            for (j, h) in handles.into_iter().enumerate() {
                results[j] = Some(h.join().expect("agent thread panicked"));
            }
        });

        let mut total_scalars = 0u64;
        for (j, res) in results.into_iter().enumerate() {
            let (mat, scalars) = res.unwrap();
            *stack.slice_mut(j) = mat;
            total_scalars += scalars;
        }
        stats.rounds += rounds as u64;
        stats.messages += (rounds * 2 * self.topo.num_edges()) as u64;
        stats.scalars_sent += total_scalars;
        stats.bytes_sent += total_scalars * 8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_stack(m: usize, d: usize, k: usize, seed: u64) -> AgentStack {
        let mut rng = Rng::seed_from(seed);
        AgentStack::new((0..m).map(|_| Mat::randn(d, k, &mut rng)).collect())
    }

    #[test]
    fn threaded_matches_dense_exactly() {
        let topo = Topology::erdos_renyi(12, 0.4, &mut Rng::seed_from(111));
        let dense = DenseComm::from_topology(&topo);
        let threaded = ThreadedNetwork::from_topology(&topo);

        let stack0 = random_stack(12, 6, 3, 112);
        let mut a = stack0.clone();
        let mut b = stack0;
        dense.fastmix(&mut a, 6, &mut CommStats::default());
        threaded.fastmix(&mut b, 6, &mut CommStats::default());
        assert!(
            a.distance(&b) < 1e-10,
            "engines disagree: {}",
            a.distance(&b)
        );
    }

    #[test]
    fn threaded_preserves_mean() {
        let topo = Topology::ring(9);
        let net = ThreadedNetwork::from_topology(&topo);
        let mut stack = random_stack(9, 4, 2, 113);
        let mean0 = stack.mean();
        net.fastmix(&mut stack, 8, &mut CommStats::default());
        assert!((&stack.mean() - &mean0).fro_norm() < 1e-10);
    }

    #[test]
    fn threaded_counts_bytes() {
        let topo = Topology::ring(6); // 6 edges
        let net = ThreadedNetwork::from_topology(&topo);
        let mut stack = random_stack(6, 5, 2, 114);
        let mut stats = CommStats::default();
        net.fastmix(&mut stack, 3, &mut stats);
        // Each round: every directed edge (12) carries 5*2 scalars.
        assert_eq!(stats.scalars_sent, 3 * 12 * 10);
        assert_eq!(stats.bytes_sent, 3 * 12 * 10 * 8);
        assert_eq!(stats.rounds, 3);
        assert_eq!(stats.messages, 3 * 12);
    }

    #[test]
    fn fault_perturbs_then_recontracts() {
        let topo = Topology::complete(8);
        let clean = ThreadedNetwork::from_topology(&topo);
        let faulty = ThreadedNetwork::from_topology(&topo)
            .with_fault(Fault { agent: 2, round: 0 });

        let stack0 = random_stack(8, 3, 2, 115);
        let mut a = stack0.clone();
        let mut b = stack0;
        clean.fastmix(&mut a, 10, &mut CommStats::default());
        faulty.fastmix(&mut b, 10, &mut CommStats::default());
        // The corrupted transmission shifts the consensus value...
        assert!(a.distance(&b) > 1e-6, "fault had no effect");
        // ...but the network still reaches (a different) consensus.
        assert!(
            b.deviation_from_mean() < 1e-6,
            "post-fault deviation {}",
            b.deviation_from_mean()
        );
    }

    #[test]
    fn zero_rounds_noop_threaded() {
        let topo = Topology::ring(5);
        let net = ThreadedNetwork::from_topology(&topo);
        let mut stack = random_stack(5, 3, 2, 116);
        let before = stack.clone();
        net.fastmix(&mut stack, 0, &mut CommStats::default());
        assert_eq!(stack, before);
    }

    #[test]
    fn works_on_sparse_topologies() {
        for topo in [Topology::path(7), Topology::star(7), Topology::grid(2, 4)] {
            let net = ThreadedNetwork::from_topology(&topo);
            let m = topo.n();
            let mut stack = random_stack(m, 3, 2, 117);
            let mean0 = stack.mean();
            net.fastmix(&mut stack, 25, &mut CommStats::default());
            assert!((&stack.mean() - &mean0).fro_norm() < 1e-9);
            assert!(stack.deviation_from_mean() < 0.2 * m as f64);
        }
    }
}
