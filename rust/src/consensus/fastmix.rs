//! FastMix — Algorithm 3 (Liu & Morse 2011 accelerated gossip).
//!
//! Chebyshev-accelerated distributed averaging:
//!
//! ```text
//! W^{k+1} = (1 + η) · W^k · L − η · W^{k−1},   η = (1−√(1−λ₂²))/(1+√(1−λ₂²))
//! ```
//!
//! with `W^{-1} = W^0`. Proposition 1 guarantees the mean is preserved
//! exactly (it is a fixed point of the recursion) and the deviation from
//! the mean contracts by `ρ = (1 − √(1−λ₂))^K` after K rounds — the √
//! acceleration over plain gossip's `λ₂^K` is what makes the Theorem-1
//! communication bound carry the `1/√(1−λ₂)` factor instead of `1/(1−λ₂)`.
//!
//! The operator is *linear* in the stack — Lemma 2's proof leans on this,
//! and `tests::linearity` checks it directly.

use super::metrics::CommStats;
use super::stack::AgentStack;
use crate::exec::Executor;
use crate::graph::gossip::{GossipInfo, GossipMatrix};
use crate::graph::sparse::SparseGossip;
use crate::linalg::Mat;
use std::sync::{Arc, Mutex};

/// Three-stack Chebyshev ping-pong buffers shared by the in-process
/// engines ([`FastMix`] behind `DenseComm`, and
/// [`crate::consensus::simnet::SimNet`]): allocated on first use, reused
/// across mixes, rebuilt only when the stack shape changes. Holding them
/// in the engine makes every steady-state gossip round allocation-free —
/// DeEPCA mixes once per power iteration, thousands of times per solve.
#[derive(Debug, Default)]
pub(crate) struct PingPong {
    pub(crate) prev: Vec<Mat>,
    pub(crate) cur: Vec<Mat>,
    pub(crate) next: Vec<Mat>,
}

impl PingPong {
    /// Fit the buffers to an m-agent stack of d×k slices (no-op when
    /// they already fit — the steady-state path).
    pub(crate) fn ensure(&mut self, m: usize, d: usize, k: usize) {
        let fits =
            self.prev.len() == m && self.prev.first().map(|s| s.shape()) == Some((d, k));
        if !fits {
            self.prev = vec![Mat::zeros(d, k); m];
            self.cur = vec![Mat::zeros(d, k); m];
            self.next = vec![Mat::zeros(d, k); m];
        }
    }

    /// Start a mix: `prev = cur = stack` (the recursion's `W⁻¹ = W⁰`).
    pub(crate) fn load(&mut self, stack: &AgentStack) {
        for (b, s) in self.prev.iter_mut().zip(stack.iter()) {
            b.copy_from(s);
        }
        for (b, s) in self.cur.iter_mut().zip(stack.iter()) {
            b.copy_from(s);
        }
    }

    /// Rotate after a round: prev ← cur ← next ← (old prev, reused).
    pub(crate) fn rotate(&mut self) {
        std::mem::swap(&mut self.prev, &mut self.cur);
        std::mem::swap(&mut self.cur, &mut self.next);
    }

    /// Finish a mix: copy the current stacks back into the caller's.
    pub(crate) fn store(&self, stack: &mut AgentStack) {
        for (dst, src) in stack.iter_mut().zip(&self.cur) {
            dst.copy_from(src);
        }
    }
}

/// One Chebyshev round's update for agent `j` over a *dense* weight row:
/// `acc = (1+η) Σ_i w_{ji} cur_i − η prev_j`, accumulated in ascending
/// `i` order, skipping `w == 0.0`. This is the reference accumulation
/// sequence; [`chebyshev_row_update_sparse`] performs the identical
/// floating-point operations from a CSR row (which stores exactly the
/// nonzeros in ascending column order), so dense-vs-sparse results are
/// bit-identical — the parity tests in `tests/sparse_gossip.rs` pin
/// this. Exposed so those tests can drive both kernels directly.
#[inline]
pub fn chebyshev_row_update(
    weights_row: &[f64],
    eta: f64,
    prev_j: &Mat,
    cur: &[Mat],
    acc: &mut Mat,
) {
    let one_plus_eta = 1.0 + eta;
    // acc = −η · prev_j: a single fused multiply per element (SIMD
    // fill-scaled kernel) — bit-identical to the copy-then-scale
    // sequence it replaces, one memory sweep instead of two.
    acc.fill_scaled_from(-eta, prev_j);
    for (i, &w) in weights_row.iter().enumerate() {
        if w != 0.0 {
            acc.axpy(one_plus_eta * w, &cur[i]);
        }
    }
}

/// The CSR twin of [`chebyshev_row_update`]: iterates one agent's sparse
/// row (`cols`/`vals` in ascending column order, diagonal included) —
/// O(degree · d · k) per agent instead of O(n · d · k), and the same
/// fixed accumulation order as the dense kernel, so results match
/// bit-for-bit wherever both representations exist. The single per-agent
/// kernel shared by every sparse engine path (FastMix, `SparseComm`,
/// SimNet), sequential or executor-parallel: the bit-determinism
/// contract.
#[inline]
pub fn chebyshev_row_update_sparse(
    cols: &[usize],
    vals: &[f64],
    eta: f64,
    prev_j: &Mat,
    cur: &[Mat],
    acc: &mut Mat,
) {
    let one_plus_eta = 1.0 + eta;
    // acc = −η · prev_j: same single-multiply seed as the dense kernel.
    acc.fill_scaled_from(-eta, prev_j);
    for (&i, &w) in cols.iter().zip(vals) {
        acc.axpy(one_plus_eta * w, &cur[i]);
    }
}

/// Reusable FastMix operator bound to one gossip-weight operator.
///
/// Rounds always run over the CSR representation — O(edges · d · k) per
/// round. Densely-constructed operators ([`FastMix::new`]) additionally
/// keep the validated [`GossipMatrix`] for diagnostics and the engines
/// that genuinely need a dense row (`ThreadedNetwork`); sparse-native
/// operators ([`FastMix::from_sparse`]) never materialize anything n×n.
pub struct FastMix {
    sparse: SparseGossip,
    dense: Option<GossipMatrix>,
    /// Chebyshev step size η_w.
    pub eta: f64,
    edges: usize,
    /// See [`PingPong`]; the mutex keeps the `&self` Communicator API
    /// (and serializes concurrent mixes on one operator).
    buffers: Mutex<PingPong>,
    /// Worker pool for the per-agent row blocks of each round (the
    /// sequential executor runs them inline). Agents' row updates are
    /// independent and each accumulates in the same fixed order, so
    /// results are bit-identical for any thread count.
    exec: Arc<Executor>,
}

impl Clone for FastMix {
    fn clone(&self) -> Self {
        // Scratch buffers are not part of the operator's value; a clone
        // starts cold and re-warms on its first mix. The executor is
        // shared (it is the session-wide pool).
        FastMix {
            sparse: self.sparse.clone(),
            dense: self.dense.clone(),
            eta: self.eta,
            edges: self.edges,
            buffers: Mutex::new(PingPong::default()),
            exec: Arc::clone(&self.exec),
        }
    }
}

impl std::fmt::Debug for FastMix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FastMix")
            .field("sparse", &self.sparse.info())
            .field("eta", &self.eta)
            .field("edges", &self.edges)
            .finish_non_exhaustive()
    }
}

impl FastMix {
    /// Bind to a validated dense gossip matrix; `edges` is the physical
    /// undirected edge count of the underlying topology (for byte
    /// accounting). The rows are compressed to CSR up front — mixing
    /// never scans the dense matrix again.
    pub fn new(gossip: GossipMatrix, edges: usize) -> Self {
        let sparse = SparseGossip::from_gossip(&gossip);
        // Algorithm 3's step size uses λ₂² under the root.
        let eta = sparse.chebyshev_eta();
        FastMix {
            sparse,
            dense: Some(gossip),
            eta,
            edges,
            buffers: Mutex::new(PingPong::default()),
            exec: Arc::new(Executor::sequential()),
        }
    }

    /// Bind to CSR weights directly — the fleet-scale constructor:
    /// nothing dense in the agent count is ever allocated.
    pub fn from_sparse(sparse: SparseGossip) -> Self {
        let eta = sparse.chebyshev_eta();
        let edges = sparse.edges();
        FastMix {
            sparse,
            dense: None,
            eta,
            edges,
            buffers: Mutex::new(PingPong::default()),
            exec: Arc::new(Executor::sequential()),
        }
    }

    /// Run each round's per-agent row blocks on `exec`'s worker pool
    /// (see the `exec` field for the determinism argument).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = exec;
        self
    }

    /// Number of agents.
    pub fn m(&self) -> usize {
        self.sparse.m()
    }

    /// Spectral summary of the bound weights.
    pub fn info(&self) -> GossipInfo {
        self.sparse.info()
    }

    /// The CSR weights every round runs over.
    pub fn sparse_gossip(&self) -> &SparseGossip {
        &self.sparse
    }

    /// The validated dense matrix, if this operator was densely
    /// constructed ([`FastMix::new`]); `None` for sparse-native
    /// operators.
    pub fn dense_gossip(&self) -> Option<&GossipMatrix> {
        self.dense.as_ref()
    }

    /// Apply `rounds` accelerated gossip iterations in place.
    ///
    /// `stats` accrues one round per iteration with the stack's slice
    /// shape as payload size.
    pub fn mix(&self, stack: &mut AgentStack, rounds: usize, stats: &mut CommStats) {
        stats.record_mix();
        if rounds == 0 {
            return;
        }
        let (d, k) = stack.slice_shape();
        let m = stack.m();
        assert_eq!(m, self.sparse.m(), "stack size != network size");
        let _span = crate::trace_span!(Gossip, rounds as u64, self.edges as u64);
        let round_bytes = (2 * self.edges * d * k) as u64 * 8;

        // Maintain current and previous stacks; each round computes
        //   next_j = (1+η) Σ_i w_{ij} cur_i − η prev_j.
        // With symmetric L, Σ_i w_{ij} cur_i = Σ_i w_{ji} cur_i — each
        // agent j only touches its neighbors (w_{ji} ≠ 0 ⇔ edge).
        //
        // Perf (§Perf): the three ping-pong stacks persist in the
        // operator across mixes (allocated on the first call, rotated by
        // pointer swap every round); the Chebyshev (1+η) factor is
        // folded into the accumulation weights so each round is pure
        // fused multiply-adds over contiguous buffers — zero allocation
        // in steady state, no scale pass.
        let mut guard = match self.buffers.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let bufs = &mut *guard;
        bufs.ensure(m, d, k);
        bufs.load(stack);

        for _round in 0..rounds {
            {
                let PingPong { prev, cur, next } = &mut *bufs;
                let prev: &[Mat] = prev;
                let cur: &[Mat] = cur;
                let sparse = &self.sparse;
                let eta = self.eta;
                // Cost-aware dispatch: a row's work is ∝ its neighbor
                // count, so the CSR row pointer is the exact per-row
                // cost prefix — hub rows no longer serialize one chunk
                // on irregular topologies. Boundaries are a pure
                // function of the prefix, so results stay bit-identical
                // to `par_for_each_agent` at every thread count.
                self.exec.par_weighted(next.as_mut_slice(), sparse.row_ptr(), |j, acc| {
                    let (cols, vals) = sparse.row(j);
                    chebyshev_row_update_sparse(cols, vals, eta, &prev[j], cur, acc);
                });
            }
            bufs.rotate();
            stats.record_round(self.edges, d, k);
            crate::trace_event!(GossipRound, self.edges as u64);
            crate::trace_event!(GossipRoundIo, 0u64, round_bytes);
        }
        bufs.store(stack);
    }

    /// Convenience: the implied contraction bound ρ(K).
    pub fn rho(&self, rounds: usize) -> f64 {
        self.info().rho(rounds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::Topology;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> FastMix {
        let topo = Topology::ring(n);
        let edges = topo.num_edges();
        FastMix::new(GossipMatrix::from_laplacian(&topo), edges)
    }

    fn random_stack(m: usize, d: usize, k: usize, seed: u64) -> AgentStack {
        let mut rng = Rng::seed_from(seed);
        AgentStack::new((0..m).map(|_| Mat::randn(d, k, &mut rng)).collect())
    }

    #[test]
    fn preserves_mean_exactly() {
        let fm = setup(8);
        let mut stack = random_stack(8, 5, 3, 101);
        let mean_before = stack.mean();
        let mut stats = CommStats::default();
        fm.mix(&mut stack, 7, &mut stats);
        let mean_after = stack.mean();
        assert!(
            (&mean_before - &mean_after).fro_norm() < 1e-10,
            "FastMix must preserve the average (Proposition 1)"
        );
    }

    #[test]
    fn contracts_at_least_at_proposition_rate() {
        let fm = setup(10);
        let mut stack = random_stack(10, 4, 2, 102);
        let dev0 = stack.deviation_from_mean();
        let k = 12;
        let mut stats = CommStats::default();
        fm.mix(&mut stack, k, &mut stats);
        let dev1 = stack.deviation_from_mean();
        let rho = fm.rho(k);
        assert!(
            dev1 <= rho * dev0 * 1.05 + 1e-12,
            "dev {dev1} > ρ·dev₀ = {}",
            rho * dev0
        );
    }

    #[test]
    fn faster_than_plain_gossip() {
        // Plain gossip contracts like λ₂^K; FastMix like (1−√(1−λ₂))^K.
        // On a poorly-connected ring the difference is stark.
        let topo = Topology::ring(20);
        let g = GossipMatrix::from_laplacian(&topo);
        let fm = FastMix::new(g.clone(), topo.num_edges());
        let k = 20;

        let stack0 = random_stack(20, 3, 2, 103);

        let mut fast = stack0.clone();
        fm.mix(&mut fast, k, &mut CommStats::default());

        // Plain gossip: W ← L·W k times.
        let mut plain = stack0.clone();
        for _ in 0..k {
            let cur: Vec<Mat> = plain.iter().cloned().collect();
            for j in 0..20 {
                let mut acc = Mat::zeros(3, 2);
                for (i, &w) in g.weights.row(j).iter().enumerate() {
                    if w != 0.0 {
                        acc.axpy(w, &cur[i]);
                    }
                }
                *plain.slice_mut(j) = acc;
            }
        }
        assert!(
            fast.deviation_from_mean() < 0.2 * plain.deviation_from_mean(),
            "fastmix {} vs plain {}",
            fast.deviation_from_mean(),
            plain.deviation_from_mean()
        );
    }

    #[test]
    fn zero_rounds_is_identity() {
        let fm = setup(6);
        let mut stack = random_stack(6, 3, 2, 104);
        let before = stack.clone();
        fm.mix(&mut stack, 0, &mut CommStats::default());
        assert_eq!(stack, before);
    }

    #[test]
    fn linearity() {
        // T(aX + bY) = aT(X) + bT(Y) — Lemma 2 depends on this.
        let fm = setup(7);
        let x = random_stack(7, 4, 2, 105);
        let y = random_stack(7, 4, 2, 106);
        let (a, b) = (2.5, -1.25);

        let mut combo = {
            let mut c = x.clone();
            for (cs, ys) in c.iter_mut().zip(y.iter()) {
                cs.scale(a);
                cs.axpy(b, ys);
            }
            c
        };
        fm.mix(&mut combo, 5, &mut CommStats::default());

        let mut tx = x.clone();
        fm.mix(&mut tx, 5, &mut CommStats::default());
        let mut ty = y.clone();
        fm.mix(&mut ty, 5, &mut CommStats::default());
        let mut want = tx.clone();
        for (ws, ts) in want.iter_mut().zip(ty.iter()) {
            ws.scale(a);
            ws.axpy(b, ts);
        }
        assert!(combo.distance(&want) < 1e-9);
    }

    #[test]
    fn consensus_on_constant_stack_is_noop() {
        let fm = setup(5);
        let mut rng = Rng::seed_from(107);
        let w = Mat::randn(4, 2, &mut rng);
        let mut stack = AgentStack::replicate(5, &w);
        fm.mix(&mut stack, 9, &mut CommStats::default());
        for s in stack.iter() {
            assert!((s - &w).fro_norm() < 1e-10);
        }
    }

    #[test]
    fn stats_accrue() {
        let topo = Topology::ring(6);
        let fm = FastMix::new(GossipMatrix::from_laplacian(&topo), topo.num_edges());
        let mut stack = random_stack(6, 3, 2, 108);
        let mut stats = CommStats::default();
        fm.mix(&mut stack, 4, &mut stats);
        assert_eq!(stats.rounds, 4);
        assert_eq!(stats.mixes, 1);
        assert_eq!(stats.messages, 4 * 2 * 6); // 4 rounds × 2 dir × 6 edges
        assert_eq!(stats.scalars_sent, 4 * 12 * 6);
    }

    #[test]
    fn buffer_reuse_matches_fresh_operator_across_shapes() {
        // One operator mixing twice (buffers warm) must equal a fresh
        // operator per mix (buffers cold), including across a shape
        // change that forces a buffer rebuild mid-life.
        let fm = setup(6);
        let a0 = random_stack(6, 5, 3, 109);
        let b0 = random_stack(6, 2, 1, 110);

        let mut a_warm = a0.clone();
        fm.mix(&mut a_warm, 4, &mut CommStats::default());
        let mut b_warm = b0.clone();
        fm.mix(&mut b_warm, 4, &mut CommStats::default()); // shape change
        let mut a_again = a0.clone();
        fm.mix(&mut a_again, 4, &mut CommStats::default()); // change back

        let mut a_cold = a0.clone();
        setup(6).mix(&mut a_cold, 4, &mut CommStats::default());
        let mut b_cold = b0;
        setup(6).mix(&mut b_cold, 4, &mut CommStats::default());

        assert_eq!(a_warm, a_cold, "warm buffers changed the arithmetic");
        assert_eq!(b_warm, b_cold, "shape-changed buffers leaked state");
        assert_eq!(a_again, a_cold, "second rebuild leaked state");
    }

    #[test]
    fn pooled_mix_bit_identical_to_sequential() {
        // The executor only changes which thread computes an agent's row
        // block; the per-agent arithmetic (and its accumulation order)
        // is the shared `chebyshev_row_update` — exact equality.
        let topo = Topology::ring(9);
        let g = GossipMatrix::from_laplacian(&topo);
        let stack0 = random_stack(9, 5, 2, 111);
        let mut want = stack0.clone();
        FastMix::new(g.clone(), topo.num_edges()).mix(&mut want, 6, &mut CommStats::default());
        for threads in [2usize, 4, 8] {
            let fm = FastMix::new(g.clone(), topo.num_edges())
                .with_executor(Arc::new(Executor::new(threads)));
            let mut got = stack0.clone();
            fm.mix(&mut got, 6, &mut CommStats::default());
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn eta_in_unit_interval() {
        for n in [4usize, 9, 16, 30] {
            let fm = setup(n);
            assert!(fm.eta >= 0.0 && fm.eta < 1.0, "eta={}", fm.eta);
        }
    }
}
