//! Communication substrate: gossip averaging over the agent network.
//!
//! - [`stack`] — `AgentStack`, the aggregate variable `W ∈ R^{d×k×m}` of
//!   §4.1 (one d×k slice per agent) plus the mean/deviation operators the
//!   analysis uses (`W̄`, `‖W − W̄⊗1‖`).
//! - [`fastmix`] — Algorithm 3 (Chebyshev-accelerated gossip, Liu & Morse
//!   2011) with the Proposition-1 contraction guarantee.
//! - [`comm`] — the [`comm::Communicator`] abstraction: a dense
//!   single-process engine for fast experiment sweeps, and a threaded
//!   message-passing runtime (one thread per agent, channels per edge)
//!   that exercises real concurrency and counts every byte on the wire.
//! - [`metrics`] — communication accounting shared by both engines.

pub mod stack;
pub mod fastmix;
pub mod comm;
pub mod metrics;

pub use fastmix::FastMix;
pub use stack::AgentStack;
