//! Communication substrate: gossip averaging over the agent network.
//!
//! - [`stack`] — `AgentStack`, the aggregate variable `W ∈ R^{d×k×m}` of
//!   §4.1 (one d×k slice per agent) plus the mean/deviation operators the
//!   analysis uses (`W̄`, `‖W − W̄⊗1‖`).
//! - [`fastmix`] — Algorithm 3 (Chebyshev-accelerated gossip, Liu & Morse
//!   2011) with the Proposition-1 contraction guarantee.
//! - [`comm`] — the [`comm::Communicator`] abstraction: a dense
//!   single-process engine for fast experiment sweeps, and a threaded
//!   message-passing runtime (one thread per agent, channels per edge)
//!   that exercises real concurrency and counts every byte on the wire.
//! - [`simnet`] — a deterministic discrete-event simulator of
//!   *unreliable* networks (seeded packet drops, per-link latency on a
//!   virtual clock, payload noise, time-varying topologies) for
//!   reproducible fault scenarios.
//! - [`metrics`] — communication accounting shared by all engines.

pub mod stack;
pub mod fastmix;
pub mod comm;
pub mod simnet;
pub mod metrics;

pub use fastmix::FastMix;
pub use stack::AgentStack;
