//! Spectral norms and extreme singular values.
//!
//! The Lemma 4–7 quantities are spectral norms (`‖·‖₂`) and
//! pseudo-inverse norms (`‖S†‖ = 1/σ_min(S)`). For a d×k matrix with
//! k ≤ 16 the cheap, robust route is through the k×k Gram matrix
//! `GᵀG`, whose eigenvalues (Jacobi, exact) are the squared singular
//! values — no iterative tolerance tuning needed.

use super::eig::eig_sym;
use super::matrix::Mat;

/// All singular values of `a`, descending (via eig of the small Gram side).
pub fn singular_values(a: &Mat) -> Vec<f64> {
    let (m, n) = a.shape();
    let gram = if n <= m {
        a.t_matmul(a) // n×n
    } else {
        a.matmul(&a.t()) // m×m
    };
    let mut g = gram;
    g.symmetrize();
    eig_sym(&g)
        .values
        .iter()
        .map(|&v| v.max(0.0).sqrt())
        .collect()
}

/// Spectral norm `‖A‖₂` (largest singular value).
pub fn spectral_norm(a: &Mat) -> f64 {
    *singular_values(a)
        .first()
        .expect("spectral_norm of empty matrix")
}

/// Smallest singular value σ_min(A) (of the thin dimension).
pub fn sigma_min(a: &Mat) -> f64 {
    *singular_values(a)
        .last()
        .expect("sigma_min of empty matrix")
}

/// Pseudo-inverse norm `‖A†‖₂ = 1/σ_min(A)` (∞ if singular).
pub fn pinv_norm(a: &Mat) -> f64 {
    let s = sigma_min(a);
    if s == 0.0 {
        f64::INFINITY
    } else {
        1.0 / s
    }
}

/// Spectral norm via power iteration on `AᵀA` — used on the large d×d
/// aggregate where Jacobi on the full matrix would be wasteful.
/// `iters`=100 gives ~1e-10 relative accuracy for gapped spectra.
pub fn spectral_norm_power(a: &Mat, iters: usize) -> f64 {
    let n = a.cols();
    if n == 0 || a.rows() == 0 {
        return 0.0;
    }
    // Deterministic start vector that is unlikely to be orthogonal to the
    // top singular vector: ones + small index-dependent perturbation.
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + 0.01 * (i as f64 + 1.0).sin()).collect();
    let mut norm_est = 0.0;
    for _ in 0..iters {
        let av = a.matvec(&v);
        let atav = a.t().matvec(&av);
        let nrm = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm == 0.0 {
            return 0.0;
        }
        for (vi, &ai) in v.iter_mut().zip(&atav) {
            *vi = ai / nrm;
        }
        norm_est = nrm.sqrt();
    }
    norm_est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn singular_values_of_diag() {
        let a = Mat::diag(&[3.0, -5.0, 1.0]);
        let s = singular_values(&a);
        assert!((s[0] - 5.0).abs() < 1e-10);
        assert!((s[1] - 3.0).abs() < 1e-10);
        assert!((s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn spectral_norm_of_orthonormal_is_one() {
        let mut rng = Rng::seed_from(41);
        let q = Mat::rand_orthonormal(30, 5, &mut rng);
        assert!((spectral_norm(&q) - 1.0).abs() < 1e-10);
        assert!((sigma_min(&q) - 1.0).abs() < 1e-10);
        assert!((pinv_norm(&q) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn wide_and_tall_agree() {
        let mut rng = Rng::seed_from(42);
        let a = Mat::randn(10, 4, &mut rng);
        let st = singular_values(&a);
        let sw = singular_values(&a.t());
        for (x, y) in st.iter().zip(&sw) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn pinv_norm_singular_is_inf() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(pinv_norm(&a).is_infinite());
    }

    #[test]
    fn power_iteration_matches_jacobi() {
        let mut rng = Rng::seed_from(43);
        let a = Mat::randn(25, 25, &mut rng);
        let exact = spectral_norm(&a);
        let power = spectral_norm_power(&a, 200);
        assert!(
            (exact - power).abs() < 1e-6 * exact,
            "exact={exact} power={power}"
        );
    }

    #[test]
    fn norm_scales_linearly() {
        let mut rng = Rng::seed_from(44);
        let a = Mat::randn(12, 5, &mut rng);
        let n1 = spectral_norm(&a);
        let n3 = spectral_norm(&a.scaled(3.0));
        assert!((n3 - 3.0 * n1).abs() < 1e-9 * n1);
    }
}
