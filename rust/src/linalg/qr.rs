//! Householder thin QR with the positive-diagonal-R convention.
//!
//! Algorithm 1 of the paper orthonormalizes the tracked subspace every
//! power iteration (`W = QR(S)`). For full-rank `S`, the thin QR with
//! `R_ii > 0` is *unique*, which gives two properties the system relies on:
//!
//! 1. The Rust backend and the JAX/PJRT backend (modified Gram–Schmidt,
//!    positive-diagonal by construction) produce the same `Q` up to fp
//!    precision, so they are interchangeable and cross-checkable.
//! 2. `SignAdjust` (paper Algorithm 2) only has to repair genuine sign
//!    flips caused by the *subspace* rotating, not factorization noise.

use super::matrix::Mat;

/// Reusable scratch for [`qr_into`]: the compact Householder working
/// matrix (R in the upper triangle, reflector vectors below) and the
/// reflector scalars β.
///
/// One workspace serves any number of sequential factorizations; the
/// buffers are (re)allocated only when the input shape changes, so a
/// solver factoring the same d×k iterate every power iteration performs
/// zero heap allocation after the first call.
#[derive(Clone, Debug)]
pub struct QrWorkspace {
    h: Mat,
    betas: Vec<f64>,
}

impl QrWorkspace {
    /// Workspace pre-sized for `rows × cols` inputs.
    pub fn new(rows: usize, cols: usize) -> Self {
        QrWorkspace { h: Mat::zeros(rows, cols), betas: vec![0.0; cols] }
    }

    /// Grow/shrink to fit an `rows × cols` factorization (no-op when the
    /// shape already matches — the steady-state path).
    fn ensure(&mut self, rows: usize, cols: usize) {
        if self.h.shape() != (rows, cols) {
            self.h = Mat::zeros(rows, cols);
        }
        if self.betas.len() != cols {
            self.betas = vec![0.0; cols];
        }
    }
}

/// Thin QR: returns (Q: m×n with orthonormal columns, R: n×n upper
/// triangular with non-negative diagonal) such that `A = Q·R`.
///
/// Panics if `A.rows() < A.cols()`.
pub fn thin_qr(a: &Mat) -> (Mat, Mat) {
    thin_qr_with(a, true)
}

/// Thin QR with a choice of sign convention.
///
/// `canonical = true`: flip so `R_ii ≥ 0` (unique factorization — the
/// crate default). `canonical = false`: keep the raw Householder signs,
/// i.e. `sign(R_ii) = −sign` of the leading pivot element — what
/// LAPACK's `geqrf` produces. The raw convention flips a column whenever
/// that element crosses zero between iterations, and *differently on
/// different agents* whose `S_j` straddle the boundary — exactly the
/// instability paper Algorithm 2 (SignAdjust) exists to repair. The
/// `abl_sign` experiment runs the 2×2 of {raw, canonical} × {adjust on,
/// off}.
pub fn thin_qr_with(a: &Mat, canonical: bool) -> (Mat, Mat) {
    let (m, n) = a.shape();
    let mut q = Mat::zeros(m, n);
    let mut r = Mat::zeros(n, n);
    let mut ws = QrWorkspace::new(m, n);
    qr_into(a, canonical, &mut q, &mut r, &mut ws);
    (q, r)
}

/// Thin QR into caller-owned buffers: `q` (m×n) and `r` (n×n) are fully
/// overwritten, `ws` holds the Householder scratch. No allocation when
/// the workspace already fits the input shape — the form every solver
/// iteration runs on. Bit-identical to [`thin_qr_with`] (which is a thin
/// wrapper over this).
pub fn qr_into(a: &Mat, canonical: bool, q: &mut Mat, r: &mut Mat, ws: &mut QrWorkspace) {
    let (m, n) = a.shape();
    assert!(m >= n, "thin_qr needs rows >= cols, got {m}x{n}");
    assert_eq!(q.shape(), (m, n), "qr_into Q output shape mismatch");
    assert_eq!(r.shape(), (n, n), "qr_into R output shape mismatch");
    ws.ensure(m, n);

    // Working copy that becomes R in its upper triangle; Householder
    // vectors are stored below the diagonal (classic compact form).
    let h = &mut ws.h;
    h.copy_from(a);
    let betas = &mut ws.betas;

    for j in 0..n {
        // Householder vector for column j, rows j..m.
        let mut norm2 = 0.0;
        for i in j..m {
            norm2 += h[(i, j)] * h[(i, j)];
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let alpha = if h[(j, j)] >= 0.0 { -norm } else { norm };
        let v0 = h[(j, j)] - alpha;
        // v = x - alpha*e1; normalize so v[0] = 1 (stored implicitly).
        let mut vnorm2 = v0 * v0;
        for i in (j + 1)..m {
            vnorm2 += h[(i, j)] * h[(i, j)];
        }
        if vnorm2 == 0.0 {
            betas[j] = 0.0;
            h[(j, j)] = alpha;
            continue;
        }
        betas[j] = 2.0 * v0 * v0 / vnorm2;
        // Store normalized v below diagonal: v / v0 (so v[j] = 1).
        for i in (j + 1)..m {
            h[(i, j)] /= v0;
        }
        h[(j, j)] = alpha;

        // Apply reflector to remaining columns: A := (I - beta v vᵀ) A.
        for c in (j + 1)..n {
            let mut dot = h[(j, c)]; // v[j] = 1
            for i in (j + 1)..m {
                dot += h[(i, j)] * h[(i, c)];
            }
            let s = betas[j] * dot;
            h[(j, c)] -= s;
            for i in (j + 1)..m {
                let vij = h[(i, j)];
                h[(i, c)] -= s * vij;
            }
        }
    }

    // Extract R (upper triangle).
    r.data_mut().fill(0.0);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = h[(i, j)];
        }
    }

    // Form thin Q by applying reflectors to the first n columns of I,
    // in reverse order.
    q.data_mut().fill(0.0);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for j in (0..n).rev() {
        if betas[j] == 0.0 {
            continue;
        }
        for c in 0..n {
            let mut dot = q[(j, c)];
            for i in (j + 1)..m {
                dot += h[(i, j)] * q[(i, c)];
            }
            let s = betas[j] * dot;
            q[(j, c)] -= s;
            for i in (j + 1)..m {
                let vij = h[(i, j)];
                q[(i, c)] -= s * vij;
            }
        }
    }

    // Positive-diagonal convention: flip columns of Q / rows of R so
    // R_ii >= 0 (unique thin QR for full-rank A).
    if canonical {
        for i in 0..n {
            if r[(i, i)] < 0.0 {
                for j in i..n {
                    r[(i, j)] = -r[(i, j)];
                }
                for row in 0..m {
                    q[(row, i)] = -q[(row, i)];
                }
            }
        }
    }
}

/// Orthonormal basis of the columns of `A` (the Q factor, canonical signs).
pub fn orth(a: &Mat) -> Mat {
    thin_qr(a).0
}

/// Q factor with raw Householder (LAPACK-style) signs — see
/// [`thin_qr_with`].
pub fn orth_raw(a: &Mat) -> Mat {
    thin_qr_with(a, false).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_qr(a: &Mat, tol: f64) {
        let (q, r) = thin_qr(a);
        let (m, n) = a.shape();
        assert_eq!(q.shape(), (m, n));
        assert_eq!(r.shape(), (n, n));
        // Reconstruction.
        assert!((&q.matmul(&r) - a).fro_norm() < tol, "A != QR");
        // Orthonormal columns.
        let g = q.t_matmul(&q);
        assert!((&g - &Mat::eye(n)).fro_norm() < tol, "QᵀQ != I");
        // Upper triangular with non-negative diagonal.
        for i in 0..n {
            assert!(r[(i, i)] >= 0.0, "R diag negative");
            for j in 0..i {
                assert!(r[(i, j)].abs() < tol, "R not upper triangular");
            }
        }
    }

    #[test]
    fn qr_random_tall() {
        let mut rng = Rng::seed_from(10);
        for &(m, n) in &[(5, 3), (20, 5), (100, 8), (300, 5)] {
            let a = Mat::randn(m, n, &mut rng);
            check_qr(&a, 1e-10);
        }
    }

    #[test]
    fn qr_square() {
        let mut rng = Rng::seed_from(11);
        let a = Mat::randn(6, 6, &mut rng);
        check_qr(&a, 1e-10);
    }

    #[test]
    fn qr_of_orthonormal_is_identityish() {
        let mut rng = Rng::seed_from(12);
        let q0 = Mat::rand_orthonormal(30, 4, &mut rng);
        let (q, r) = thin_qr(&q0);
        assert!((&q - &q0).fro_norm() < 1e-10);
        assert!((&r - &Mat::eye(4)).fro_norm() < 1e-10);
    }

    #[test]
    fn qr_unique_positive_diagonal() {
        // Same column space, scaled by a positive-diagonal upper triangular
        // matrix on the right => identical Q.
        let mut rng = Rng::seed_from(13);
        let a = Mat::randn(15, 3, &mut rng);
        let t = Mat::from_rows(3, 3, &[2.0, 1.0, -0.5, 0.0, 3.0, 0.7, 0.0, 0.0, 1.5]);
        let b = a.matmul(&t);
        let (qa, _) = thin_qr(&a);
        let (qb, _) = thin_qr(&b);
        assert!((&qa - &qb).fro_norm() < 1e-9);
    }

    #[test]
    fn qr_sign_flip_of_input_flips_q_column() {
        let mut rng = Rng::seed_from(14);
        let a = Mat::randn(10, 2, &mut rng);
        let mut b = a.clone();
        // Negate column 0 of the input.
        let c0: Vec<f64> = a.col(0).iter().map(|v| -v).collect();
        b.set_col(0, &c0);
        let (qa, _) = thin_qr(&a);
        let (qb, _) = thin_qr(&b);
        let qa0 = qa.col(0);
        let qb0 = qb.col(0);
        let dot: f64 = qa0.iter().zip(&qb0).map(|(x, y)| x * y).sum();
        assert!(dot < -0.999, "column sign should flip with input");
    }

    #[test]
    fn qr_near_rank_deficient_stays_finite() {
        let mut rng = Rng::seed_from(15);
        let a = Mat::randn(20, 3, &mut rng);
        let mut b = a.clone();
        // Make column 2 almost a copy of column 0.
        let c0 = a.col(0);
        let c2: Vec<f64> = c0.iter().map(|v| v * (1.0 + 1e-13)).collect();
        b.set_col(2, &c2);
        let (q, r) = thin_qr(&b);
        assert!(q.is_finite());
        assert!(r.is_finite());
    }

    #[test]
    fn qr_into_bit_identical_and_workspace_reusable() {
        // One workspace across shrinking/growing shapes and dirty output
        // buffers: every factorization must agree bit-for-bit with the
        // allocating path.
        let mut rng = Rng::seed_from(17);
        let mut ws = QrWorkspace::new(1, 1);
        for &(m, n) in &[(8, 3), (30, 5), (4, 4), (30, 5), (12, 2)] {
            let a = Mat::randn(m, n, &mut rng);
            for canonical in [true, false] {
                let (wq, wr) = thin_qr_with(&a, canonical);
                let mut q = Mat::from_fn(m, n, |_, _| f64::NAN);
                let mut r = Mat::from_fn(n, n, |_, _| f64::NAN);
                qr_into(&a, canonical, &mut q, &mut r, &mut ws);
                assert_eq!(wq, q, "{m}x{n} canonical={canonical}");
                assert_eq!(wr, r, "{m}x{n} canonical={canonical}");
            }
        }
    }

    #[test]
    fn orth_returns_q() {
        let mut rng = Rng::seed_from(16);
        let a = Mat::randn(12, 4, &mut rng);
        let q = orth(&a);
        let g = q.t_matmul(&q);
        assert!((&g - &Mat::eye(4)).fro_norm() < 1e-10);
    }
}
