//! Principal angles between subspaces — paper Definition 1.
//!
//! For orthonormal `U` (ground truth, d×k) and full-column-rank `X` (d×k):
//!
//! - `cos θ_k(U, X) = σ_min(Uᵀ Q)`
//! - `sin θ_k(U, X) = ‖(I − UUᵀ) Q‖₂`
//! - `tan θ_k(U, X) = ‖(I − UUᵀ) Q (Uᵀ Q)^{-1}‖₂`
//!
//! where `Q = orth(X)`; all three are invariant to right-multiplication of
//! `X` by an invertible matrix, so orthonormalizing first is exact and
//! avoids forming the d×(d−k) complement `V` explicitly: we use the
//! projector `(I − UUᵀ)X = X − U(UᵀX)`, an O(dk²) computation.

use super::matrix::Mat;
use super::norms::{sigma_min, spectral_norm};
use super::qr::orth;
use super::solve::lu;

/// All three principal-angle statistics of Definition 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Angles {
    /// cos θ_k — smallest cosine over the subspace pair.
    pub cos: f64,
    /// sin θ_k — largest sine.
    pub sin: f64,
    /// tan θ_k — the paper's convergence measure (∞ if UᵀX is singular).
    pub tan: f64,
}

/// Compute the Definition-1 angles between `span(u)` and `span(x)`.
///
/// `u` must have orthonormal columns; `x` must have full column rank and
/// the same column count. Returns `tan = ∞` when the subspaces contain
/// orthogonal directions (UᵀQ singular).
pub fn subspace_angles(u: &Mat, x: &Mat) -> Angles {
    assert_eq!(u.cols(), x.cols(), "subspace dimension mismatch");
    assert_eq!(u.rows(), x.rows(), "ambient dimension mismatch");
    let q = orth(x);
    subspace_angles_orthonormal(u, &q)
}

/// [`subspace_angles`] when `q` is already orthonormal (skips the QR —
/// the per-agent metrics path calls this on the W iterates, which are
/// orthonormal by construction; §Perf).
pub fn subspace_angles_orthonormal(u: &Mat, q: &Mat) -> Angles {
    debug_assert!(
        (&q.t_matmul(q) - &Mat::eye(q.cols())).fro_norm() < 1e-6,
        "q not orthonormal"
    );
    // B = UᵀQ (k×k), P = Q − U·B = (I − UUᵀ)Q (d×k).
    let b = u.t_matmul(&q);
    let mut p = q.clone();
    let ub = u.matmul(&b);
    p.axpy(-1.0, &ub);

    let cos = sigma_min(&b);
    let sin = spectral_norm(&p).min(1.0);

    // tan = ‖P B^{-1}‖₂ = √λ_max(B^{-T} (PᵀP) B^{-1}): form the k×k Gram
    // G = PᵀP once (O(dk²)) and run two k×k solves — avoids the d-column
    // triangular solve of the naive formulation (§Perf: ~4× on the
    // per-iteration metrics path).
    let ft = lu(&b.t());
    let tan = if ft.is_singular() {
        f64::INFINITY
    } else {
        let g = p.t_matmul(&p); // k×k PSD
        let y = ft.solve_mat(&g); // Y = B^{-T} G
        let mt = ft.solve_mat(&y.t()); // M = Y·B^{-1} ⇔ Bᵀ·Mᵀ = Yᵀ
        let mut m_sym = mt.t();
        m_sym.axpy(1.0, &mt);
        m_sym.scale(0.5); // symmetrize fp noise; M is PSD in exact arithmetic
        let lam = crate::linalg::eig::eig_sym(&m_sym).values[0].max(0.0);
        lam.sqrt()
    };

    Angles { cos, sin, tan }
}

/// Just tan θ_k(U, X) — the quantity tracked in the paper's figures.
pub fn tan_theta(u: &Mat, x: &Mat) -> f64 {
    subspace_angles(u, x).tan
}

/// tan θ_k(U, Q) for already-orthonormal Q (fast metrics path).
pub fn tan_theta_orthonormal(u: &Mat, q: &Mat) -> f64 {
    subspace_angles_orthonormal(u, q).tan
}

/// Just sin θ_k(U, X).
pub fn sin_theta(u: &Mat, x: &Mat) -> f64 {
    subspace_angles(u, x).sin
}

/// Projector distance `‖UUᵀ − QQᵀ‖_F / √2` — an angle-free sanity metric
/// used in tests (equals `‖sin Θ‖_F` over all principal angles).
pub fn projector_distance(u: &Mat, x: &Mat) -> f64 {
    let q = orth(x);
    let pu = u.matmul(&u.t());
    let pq = q.matmul(&q.t());
    (&pu - &pq).fro_norm() / std::f64::consts::SQRT_2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_subspace_zero_angle() {
        let mut rng = Rng::seed_from(51);
        let u = Mat::rand_orthonormal(20, 4, &mut rng);
        // X = U * (random invertible) spans the same subspace.
        let t = Mat::randn(4, 4, &mut rng);
        let x = u.matmul(&t);
        let a = subspace_angles(&u, &x);
        assert!((a.cos - 1.0).abs() < 1e-10);
        assert!(a.sin < 1e-10);
        assert!(a.tan < 1e-10);
    }

    #[test]
    fn orthogonal_subspaces_tan_infinite() {
        // U = first two coordinates, X = last two: orthogonal.
        let mut u = Mat::zeros(4, 2);
        u[(0, 0)] = 1.0;
        u[(1, 1)] = 1.0;
        let mut x = Mat::zeros(4, 2);
        x[(2, 0)] = 1.0;
        x[(3, 1)] = 1.0;
        let a = subspace_angles(&u, &x);
        assert!(a.cos < 1e-12);
        assert!((a.sin - 1.0).abs() < 1e-12);
        assert!(a.tan.is_infinite());
    }

    #[test]
    fn known_angle_k1() {
        // 2-D: U = e1, X = (cos φ, sin φ).
        let phi = 0.3f64;
        let u = Mat::from_rows(2, 1, &[1.0, 0.0]);
        let x = Mat::from_rows(2, 1, &[phi.cos(), phi.sin()]);
        let a = subspace_angles(&u, &x);
        assert!((a.cos - phi.cos()).abs() < 1e-12);
        assert!((a.sin - phi.sin()).abs() < 1e-12);
        assert!((a.tan - phi.tan()).abs() < 1e-12);
    }

    #[test]
    fn tan_invariant_to_right_multiplication() {
        let mut rng = Rng::seed_from(52);
        let u = Mat::rand_orthonormal(30, 3, &mut rng);
        let x = Mat::randn(30, 3, &mut rng);
        let t = Mat::randn(3, 3, &mut rng); // a.s. invertible
        let t1 = tan_theta(&u, &x);
        let t2 = tan_theta(&u, &x.matmul(&t));
        assert!((t1 - t2).abs() < 1e-8 * (1.0 + t1));
    }

    #[test]
    fn pythagorean_identity() {
        let mut rng = Rng::seed_from(53);
        let u = Mat::rand_orthonormal(25, 2, &mut rng);
        let x = Mat::randn(25, 2, &mut rng);
        let a = subspace_angles(&u, &x);
        // For the *largest* principal angle: sin² + cos'² where cos' is the
        // cosine of that same angle. We only check consistency bounds here:
        assert!(a.cos >= 0.0 && a.cos <= 1.0 + 1e-12);
        assert!(a.sin >= 0.0 && a.sin <= 1.0 + 1e-12);
        // tan >= sin/1 and tan >= sin/cos relationship for extreme angles:
        assert!(a.tan + 1e-12 >= a.sin, "tan {} < sin {}", a.tan, a.sin);
        // tan θ_max = sin θ_max / cos θ_max and cos here is the min cosine,
        // matching the same (largest) angle:
        let expect = a.sin / a.cos;
        assert!((a.tan - expect).abs() < 0.2 * expect.max(1e-12) + 1e-9,
            "tan {} vs sin/cos {}", a.tan, expect);
    }

    #[test]
    fn small_perturbation_small_angle() {
        let mut rng = Rng::seed_from(54);
        let u = Mat::rand_orthonormal(40, 5, &mut rng);
        let mut x = u.clone();
        let noise = Mat::randn(40, 5, &mut rng);
        x.axpy(1e-6, &noise);
        let t = tan_theta(&u, &x);
        assert!(t < 1e-4, "tan={t}");
        assert!(t > 0.0);
    }

    #[test]
    fn projector_distance_consistent_with_sin() {
        let mut rng = Rng::seed_from(55);
        let u = Mat::rand_orthonormal(20, 1, &mut rng);
        let x = Mat::randn(20, 1, &mut rng);
        // For k=1, projector distance equals |sin θ|.
        let a = subspace_angles(&u, &x);
        let pd = projector_distance(&u, &x);
        assert!((pd - a.sin).abs() < 1e-9, "pd={pd} sin={}", a.sin);
    }

    #[test]
    fn angles_symmetric_between_orthonormal_bases() {
        let mut rng = Rng::seed_from(56);
        let u = Mat::rand_orthonormal(15, 3, &mut rng);
        let q = Mat::rand_orthonormal(15, 3, &mut rng);
        let a1 = subspace_angles(&u, &q);
        let a2 = subspace_angles(&q, &u);
        assert!((a1.cos - a2.cos).abs() < 1e-9);
        assert!((a1.sin - a2.sin).abs() < 1e-9);
    }
}
