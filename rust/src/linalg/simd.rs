//! Runtime-dispatched SIMD microkernels for the dense hot paths.
//!
//! Everything numerical the solvers do per iteration bottoms out in a
//! handful of primitive loops: the ≤8-wide matmul panel kernel (power
//! products), elementwise axpy/scale/add-scaled (tracking updates,
//! Chebyshev gossip rounds), and strided column dots (SignAdjust). This
//! module owns those loops behind one [`KernelDispatch`], selected
//! **once per process** from `DEEPCA_SIMD=auto|scalar|avx2|neon`:
//!
//! - `scalar` — the exact loops the crate has always run: plain `f64`
//!   mul-then-add, bit-identical to every pre-SIMD release.
//! - `avx2` — 4-lane `core::arch::x86_64` AVX2+FMA kernels.
//! - `neon` — 2-lane `core::arch::aarch64` NEON FMA kernels.
//! - `auto` (default) — the best mode the running CPU supports.
//!
//! ## Determinism contract
//!
//! Mode selection is a pure function of the environment variable and
//! the ISA — never of thread count, data, or timing. Within a mode,
//! every output element is produced by a **fixed sequence of
//! identically-rounded operations**: the scalar mode applies an
//! unfused multiply-then-add per update, and the vector modes apply
//! one correctly-rounded fused multiply-add per update — in the vector
//! body via FMA lanes and in ragged tails via [`f64::mul_add`], which
//! is the *same* correctly-rounded operation. Consequences, all pinned
//! by tests (`tests/simd_kernels.rs`, the suites under both CI modes):
//!
//! - results are bit-identical across thread counts in every mode
//!   (chunking never changes any element's update sequence);
//! - the packed-B kernel is bit-identical to the unpacked panel kernel
//!   within a mode (packing relocates bytes, never reorders math);
//! - `DEEPCA_SIMD=scalar` is bit-identical to the pre-SIMD kernels;
//! - scalar vs. vector modes differ only by FMA fusion — one rounding
//!   per update instead of two, within ~`k·ε` relative error;
//! - multiply-only primitives ([`KernelDispatch::fill_scaled`],
//!   [`KernelDispatch::scale`]) are bit-identical across **all** modes.
//!
//! ## Packed-B layout
//!
//! The wide-product hot path (`Mat::matmul_packed_into`) packs each
//! ≤8-wide B panel into a [`PackBuf`]: a cache-line-aligned,
//! stride-8, zero-padded scratch owned by the caller's workspace
//! (`SolverWorkspace`, the backend's per-chunk scratch pool). The
//! microkernel then streams the panel as contiguous full-width rows —
//! no per-`p` bounds checks, no strided-row cache splits — and the
//! grow-only buffer keeps steady state at zero heap allocations
//! (audited by `tests/alloc_free.rs`).
//!
//! This file is the only place `core::arch`/feature detection may
//! appear — `cargo xtask lint` enforces the boundary (rule `arch`).

use std::sync::OnceLock;

/// Which kernel family a [`KernelDispatch`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Unfused scalar loops — bit-identical to the pre-SIMD kernels.
    Scalar,
    /// x86_64 AVX2+FMA, 4 × f64 lanes.
    Avx2,
    /// aarch64 NEON FMA, 2 × f64 lanes.
    Neon,
}

impl SimdMode {
    /// Stable lowercase name (the `DEEPCA_SIMD` vocabulary; recorded in
    /// BENCH JSON metadata).
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Scalar => "scalar",
            SimdMode::Avx2 => "avx2",
            SimdMode::Neon => "neon",
        }
    }
}

#[cfg(all(target_arch = "x86_64", not(miri)))]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(all(target_arch = "x86_64", not(miri))))]
fn avx2_available() -> bool {
    false
}

#[cfg(all(target_arch = "aarch64", not(miri)))]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(all(target_arch = "aarch64", not(miri))))]
fn neon_available() -> bool {
    false
}

/// Best mode the running target supports — a pure function of the ISA.
/// Under Miri no vendor intrinsics are interpretable, so the
/// "ISA" the interpreter presents is scalar-only.
fn detect() -> SimdMode {
    if avx2_available() {
        SimdMode::Avx2
    } else if neon_available() {
        SimdMode::Neon
    } else {
        SimdMode::Scalar
    }
}

/// Resolve a `DEEPCA_SIMD` value (`None` = unset) to a mode. Pure —
/// the testable core of [`dispatch`]. An explicitly requested vector
/// mode that the CPU cannot run is a hard error, not a silent
/// fallback: silently degrading would make "same env, same bits"
/// unverifiable across machines.
fn mode_from_env(var: Option<&str>) -> SimdMode {
    match var {
        None | Some("auto") | Some("") => detect(),
        Some("scalar") => SimdMode::Scalar,
        Some("avx2") => {
            assert!(
                avx2_available(),
                "DEEPCA_SIMD=avx2 requested but AVX2+FMA are not available on this CPU"
            );
            SimdMode::Avx2
        }
        Some("neon") => {
            assert!(
                neon_available(),
                "DEEPCA_SIMD=neon requested but NEON is not available on this target"
            );
            SimdMode::Neon
        }
        Some(other) => {
            panic!("DEEPCA_SIMD={other:?}: expected auto|scalar|avx2|neon")
        }
    }
}

static DISPATCH: OnceLock<KernelDispatch> = OnceLock::new();

/// The process-wide kernel dispatch, selected once from `DEEPCA_SIMD`
/// on first use. Every `Mat` kernel routes through this.
pub fn dispatch() -> &'static KernelDispatch {
    DISPATCH.get_or_init(|| {
        let var = std::env::var("DEEPCA_SIMD").ok();
        KernelDispatch { mode: mode_from_env(var.as_deref()) }
    })
}

/// A resolved kernel family. Copyable and constructible per-mode
/// ([`KernelDispatch::for_mode`]) so benches and parity tests can run
/// scalar and vector kernels side by side in one process; production
/// code uses the process-wide [`dispatch`].
#[derive(Clone, Copy, Debug)]
pub struct KernelDispatch {
    mode: SimdMode,
}

/// Grow-only, cache-line-aligned packing scratch for the packed-B
/// matmul path. One lives in each `SolverWorkspace` and in each of the
/// backend's per-chunk scratch slots; `ensure` reallocates only when a
/// request exceeds every previous one, so steady-state solver steps
/// (repeating shapes) allocate nothing.
#[derive(Debug)]
pub struct PackBuf {
    buf: Vec<f64>,
    /// Element offset of the first 64-byte-aligned slot, recomputed on
    /// every (re)allocation.
    off: usize,
}

impl PackBuf {
    pub fn new() -> Self {
        PackBuf { buf: Vec::new(), off: 0 }
    }

    /// Borrow `len` f64s of scratch starting on a cache-line boundary.
    fn ensure(&mut self, len: usize) -> &mut [f64] {
        if self.buf.len() < len + 8 {
            // Grow-only (+8 slack f64s so a 64-byte-aligned start always
            // fits); reached only when the request exceeds every
            // previous one — never in steady state.
            self.buf.resize(len + 8, 0.0);
            let addr = self.buf.as_ptr() as usize;
            // Vec<f64> storage is 8-aligned, so the byte distance to
            // the next 64-boundary is a whole number of elements.
            self.off = (addr.wrapping_neg() & 63) / 8;
        }
        &mut self.buf[self.off..self.off + len]
    }

    /// Current backing capacity in elements (diagnostics/tests).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
}

impl Default for PackBuf {
    fn default() -> Self {
        PackBuf::new()
    }
}

impl Clone for PackBuf {
    /// Scratch contents are not part of any value — a clone starts
    /// empty (and re-aligns against its own allocation on first use).
    fn clone(&self) -> Self {
        PackBuf::new()
    }
}

impl KernelDispatch {
    /// Dispatch for an explicit mode. Panics if the running CPU cannot
    /// execute it (same contract as `DEEPCA_SIMD=<mode>`).
    pub fn for_mode(mode: SimdMode) -> KernelDispatch {
        match mode {
            SimdMode::Scalar => {}
            SimdMode::Avx2 => assert!(
                avx2_available(),
                "KernelDispatch::for_mode(Avx2): AVX2+FMA not available on this CPU"
            ),
            SimdMode::Neon => assert!(
                neon_available(),
                "KernelDispatch::for_mode(Neon): NEON not available on this target"
            ),
        }
        KernelDispatch { mode }
    }

    /// Dispatch for the best mode this CPU supports (what
    /// `DEEPCA_SIMD=auto` resolves to).
    pub fn auto() -> KernelDispatch {
        KernelDispatch { mode: detect() }
    }

    /// The resolved mode.
    pub fn mode(&self) -> SimdMode {
        self.mode
    }

    /// Unpacked ≤8-wide matmul panel kernel over inner rows `p0..p1`:
    /// `out[i, col0..col0+width] (+)= a[i, p0..p1] · b[p0..p1, col0..col0+width]`
    /// for row-major `a` (n×k), `b` (k×bn), `out` (n×on). With
    /// `accumulate` the register accumulators seed from `out` (later
    /// inner blocks of the wide tiled path) instead of zero; without
    /// it, `out` is never read (dirty buffers allowed). Per output
    /// element the updates run in ascending `p`, one per inner row —
    /// so inner-dimension splits are bit-invisible in every mode.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_panel_block(
        &self,
        a: &[f64],
        n: usize,
        k: usize,
        b: &[f64],
        bn: usize,
        col0: usize,
        width: usize,
        p0: usize,
        p1: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
    ) {
        assert!((1..=8).contains(&width), "panel width must be 1..=8");
        assert!(p0 <= p1 && p1 <= k, "inner block out of range");
        assert!(col0 + width <= bn && col0 + width <= on, "panel out of range");
        assert!(a.len() == n * k && b.len() == k * bn && out.len() == n * on);
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: an `Avx2` dispatch is only constructed after
            // `avx2_available` confirmed AVX2+FMA on this CPU, so the
            // target-feature call is sound; the asserts above establish
            // the slice-extent invariants the kernel's raw-pointer
            // arithmetic relies on.
            SimdMode::Avx2 => unsafe {
                avx2::matmul_panel_block(a, n, k, b, bn, col0, width, p0, p1, accumulate, out, on)
            },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: a `Neon` dispatch is only constructed after
            // `neon_available` confirmed NEON, and the asserts above
            // establish the extent invariants.
            SimdMode::Neon => unsafe {
                neon::matmul_panel_block(a, n, k, b, bn, col0, width, p0, p1, accumulate, out, on)
            },
            _ => scalar::matmul_panel_block(
                a, n, k, b, bn, col0, width, p0, p1, accumulate, out, on, col0,
            ),
        }
    }

    /// Pack B columns `col0..col0+width` over all `k` inner rows into
    /// `pack` as a stride-8, zero-padded, cache-line-aligned panel and
    /// return it. Pure data movement — identical in every mode — so no
    /// per-ISA variants exist.
    pub fn pack_panel<'p>(
        &self,
        b: &[f64],
        bn: usize,
        col0: usize,
        width: usize,
        k: usize,
        pack: &'p mut PackBuf,
    ) -> &'p [f64] {
        assert!((1..=8).contains(&width), "panel width must be 1..=8");
        assert!(col0 + width <= bn && b.len() == k * bn);
        let buf = pack.ensure(k * 8);
        for p in 0..k {
            let dst = &mut buf[p * 8..p * 8 + 8];
            dst[..width].copy_from_slice(&b[p * bn + col0..p * bn + col0 + width]);
            dst[width..].fill(0.0);
        }
        buf
    }

    /// Packed-panel matmul over the full inner dimension:
    /// `out[i, col0..col0+width] = a[i, :] · panel`, where `packed` is a
    /// stride-8 panel from [`KernelDispatch::pack_panel`]. Bit-identical
    /// to the unpacked kernel within a mode: packing changes where the
    /// B values live, never the per-element update sequence.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_panel_packed(
        &self,
        a: &[f64],
        n: usize,
        k: usize,
        packed: &[f64],
        col0: usize,
        width: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
    ) {
        assert!((1..=8).contains(&width), "panel width must be 1..=8");
        assert!(packed.len() >= k * 8, "packed panel shorter than the inner dimension");
        assert!(col0 + width <= on, "panel out of range");
        assert!(a.len() == n * k && out.len() == n * on);
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2+FMA confirmed at dispatch construction; the
            // asserts above establish the extent invariants (including
            // the full stride-8 panel the aligned full-width loads
            // rely on).
            SimdMode::Avx2 => unsafe {
                avx2::matmul_panel_packed(a, n, k, packed, col0, width, accumulate, out, on)
            },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON confirmed at dispatch construction; extents
            // established by the asserts above.
            SimdMode::Neon => unsafe {
                neon::matmul_panel_packed(a, n, k, packed, col0, width, accumulate, out, on)
            },
            // The scalar path reuses the generic panel kernel with the
            // packed layout as an 8-stride B starting at column 0,
            // writing the output window at `col0` — by construction the
            // same arithmetic as the unpacked scalar kernel.
            _ => scalar::matmul_panel_block(
                a, n, k, packed, 8, 0, width, 0, k, accumulate, out, on, col0,
            ),
        }
    }

    /// `dst += alpha · src`, elementwise. One update per element:
    /// unfused in scalar mode, one fused multiply-add in vector modes.
    #[inline]
    pub fn axpy(&self, dst: &mut [f64], alpha: f64, src: &[f64]) {
        assert_eq!(dst.len(), src.len(), "axpy length mismatch");
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2+FMA confirmed at dispatch construction;
            // equal lengths asserted above.
            SimdMode::Avx2 => unsafe { avx2::axpy(dst, alpha, src) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON confirmed at dispatch construction; equal
            // lengths asserted above.
            SimdMode::Neon => unsafe { neon::axpy(dst, alpha, src) },
            _ => scalar::axpy(dst, alpha, src),
        }
    }

    /// `dst = alpha · src`, elementwise — the fused form of copy +
    /// scale. A single correctly-rounded multiply per element in every
    /// mode, so results are bit-identical across **all** modes (and to
    /// the unfused copy-then-scale sequence it replaces).
    #[inline]
    pub fn fill_scaled(&self, dst: &mut [f64], src: &[f64], alpha: f64) {
        assert_eq!(dst.len(), src.len(), "fill_scaled length mismatch");
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2+FMA confirmed at dispatch construction;
            // equal lengths asserted above.
            SimdMode::Avx2 => unsafe { avx2::fill_scaled(dst, src, alpha) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON confirmed at dispatch construction; equal
            // lengths asserted above.
            SimdMode::Neon => unsafe { neon::fill_scaled(dst, src, alpha) },
            _ => scalar::fill_scaled(dst, src, alpha),
        }
    }

    /// `dst *= alpha`, elementwise. A single multiply per element in
    /// every mode — bit-identical across all modes.
    #[inline]
    pub fn scale(&self, dst: &mut [f64], alpha: f64) {
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2+FMA confirmed at dispatch construction; the
            // kernel stays within `dst`'s bounds.
            SimdMode::Avx2 => unsafe { avx2::scale(dst, alpha) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON confirmed at dispatch construction; the
            // kernel stays within `dst`'s bounds.
            SimdMode::Neon => unsafe { neon::scale(dst, alpha) },
            _ => scalar::scale(dst, alpha),
        }
    }

    /// `out = a + alpha · b`, elementwise. One update per element, same
    /// rounding profile as [`KernelDispatch::axpy`] — so
    /// `out.copy_from(a); axpy(out, alpha, b)` and `add_scaled(out, a,
    /// alpha, b)` are bit-identical within every mode.
    #[inline]
    pub fn add_scaled(&self, out: &mut [f64], a: &[f64], alpha: f64, b: &[f64]) {
        assert!(out.len() == a.len() && out.len() == b.len(), "add_scaled length mismatch");
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2+FMA confirmed at dispatch construction;
            // equal lengths asserted above.
            SimdMode::Avx2 => unsafe { avx2::add_scaled(out, a, alpha, b) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON confirmed at dispatch construction; equal
            // lengths asserted above.
            SimdMode::Neon => unsafe { neon::add_scaled(out, a, alpha, b) },
            _ => scalar::add_scaled(out, a, alpha, b),
        }
    }

    /// `dots[j] += w[j] · r[j]`, elementwise — one row's contribution
    /// to a block of per-column dot products (SignAdjust's column-dot
    /// pass restructured row-major). Per column the accumulation chain
    /// runs in ascending row order, exactly the pre-SIMD column loop.
    #[inline]
    pub fn col_dots(&self, w: &[f64], r: &[f64], dots: &mut [f64]) {
        assert!(w.len() == r.len() && w.len() == dots.len(), "col_dots length mismatch");
        match self.mode {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2+FMA confirmed at dispatch construction;
            // equal lengths asserted above.
            SimdMode::Avx2 => unsafe { avx2::col_dots(w, r, dots) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON confirmed at dispatch construction; equal
            // lengths asserted above.
            SimdMode::Neon => unsafe { neon::col_dots(w, r, dots) },
            _ => scalar::col_dots(w, r, dots),
        }
    }
}

/// The pre-SIMD loops, verbatim: plain unfused multiply-then-add.
/// `DEEPCA_SIMD=scalar` runs exactly these, which is how the
/// "bit-identical to every pre-SIMD release" leg of the contract holds.
mod scalar {
    /// Generic panel kernel: B columns `bcol0..bcol0+width` with row
    /// stride `bstride` into output columns `ocol0..ocol0+width`. The
    /// unpacked entry uses `bstride = bn, bcol0 = ocol0`; the packed
    /// entry uses `bstride = 8, bcol0 = 0` — same arithmetic, shifted
    /// addressing.
    #[allow(clippy::too_many_arguments)]
    fn panel<const M: usize>(
        a: &[f64],
        n: usize,
        k: usize,
        b: &[f64],
        bstride: usize,
        bcol0: usize,
        p0: usize,
        p1: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
        ocol0: usize,
    ) {
        // Two A-rows per pass: 2·M independent accumulator chains hide
        // FP-add latency, and each B row is loaded once for both
        // outputs.
        let mut i = 0;
        while i + 1 < n {
            let arow0 = &a[i * k..(i + 1) * k];
            let arow1 = &a[(i + 1) * k..(i + 2) * k];
            let mut acc0 = [0.0f64; M];
            let mut acc1 = [0.0f64; M];
            if accumulate {
                acc0.copy_from_slice(&out[i * on + ocol0..i * on + ocol0 + M]);
                acc1.copy_from_slice(&out[(i + 1) * on + ocol0..(i + 1) * on + ocol0 + M]);
            }
            for p in p0..p1 {
                let a0 = arow0[p];
                let a1 = arow1[p];
                let brow = &b[p * bstride + bcol0..p * bstride + bcol0 + M];
                for j in 0..M {
                    acc0[j] += a0 * brow[j];
                    acc1[j] += a1 * brow[j];
                }
            }
            out[i * on + ocol0..i * on + ocol0 + M].copy_from_slice(&acc0);
            out[(i + 1) * on + ocol0..(i + 1) * on + ocol0 + M].copy_from_slice(&acc1);
            i += 2;
        }
        if i < n {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = [0.0f64; M];
            if accumulate {
                acc.copy_from_slice(&out[i * on + ocol0..i * on + ocol0 + M]);
            }
            for p in p0..p1 {
                let av = arow[p];
                let brow = &b[p * bstride + bcol0..p * bstride + bcol0 + M];
                for j in 0..M {
                    acc[j] += av * brow[j];
                }
            }
            out[i * on + ocol0..i * on + ocol0 + M].copy_from_slice(&acc);
        }
    }

    /// Width → monomorphized kernel dispatch (register-resident
    /// accumulator arrays need a compile-time width).
    #[allow(clippy::too_many_arguments)]
    pub(super) fn matmul_panel_block(
        a: &[f64],
        n: usize,
        k: usize,
        b: &[f64],
        bstride: usize,
        bcol0: usize,
        width: usize,
        p0: usize,
        p1: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
        ocol0: usize,
    ) {
        match width {
            1 => panel::<1>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            2 => panel::<2>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            3 => panel::<3>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            4 => panel::<4>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            5 => panel::<5>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            6 => panel::<6>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            7 => panel::<7>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            8 => panel::<8>(a, n, k, b, bstride, bcol0, p0, p1, accumulate, out, on, ocol0),
            _ => unreachable!("thin panels are 1..=8 wide"),
        }
    }

    pub(super) fn axpy(dst: &mut [f64], alpha: f64, src: &[f64]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d += alpha * s;
        }
    }

    pub(super) fn fill_scaled(dst: &mut [f64], src: &[f64], alpha: f64) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = alpha * s;
        }
    }

    pub(super) fn scale(dst: &mut [f64], alpha: f64) {
        for d in dst.iter_mut() {
            *d *= alpha;
        }
    }

    pub(super) fn add_scaled(out: &mut [f64], a: &[f64], alpha: f64, b: &[f64]) {
        for ((o, &av), &bv) in out.iter_mut().zip(a).zip(b) {
            *o = av + alpha * bv;
        }
    }

    pub(super) fn col_dots(w: &[f64], r: &[f64], dots: &mut [f64]) {
        for ((d, &wv), &rv) in dots.iter_mut().zip(w).zip(r) {
            *d += wv * rv;
        }
    }
}

/// AVX2+FMA kernels: 4 × f64 ymm lanes, `f64::mul_add` ragged tails
/// (the same correctly-rounded fused op as an FMA lane, so tail
/// elements match their packed-lane counterparts bitwise).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Unpacked ≤8-wide panel kernel. Full 4-lane groups run as FMA
    /// vectors; the `width % 4` tail runs as scalar `mul_add` chains so
    /// no load ever touches B or `out` past `col0 + width`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn matmul_panel_block(
        a: &[f64],
        n: usize,
        k: usize,
        b: &[f64],
        bn: usize,
        col0: usize,
        width: usize,
        p0: usize,
        p1: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
    ) {
        let vw = width / 4;
        let tail = width % 4;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let op = out.as_mut_ptr();
        let mut i = 0;
        while i + 1 < n {
            // SAFETY: the dispatch wrapper asserted `a.len() == n·k`,
            // `b.len() == k·bn`, `out.len() == n·on`, `p1 ≤ k`, and
            // `col0 + width ≤ min(bn, on)`; all offsets below stay
            // inside those extents (`4·vw + tail == width`), and `out`
            // does not alias `a`/`b` (distinct slices).
            unsafe {
                let o0 = op.add(i * on + col0);
                let o1 = op.add((i + 1) * on + col0);
                let mut acc0 = [_mm256_setzero_pd(); 2];
                let mut acc1 = [_mm256_setzero_pd(); 2];
                let mut t0 = [0.0f64; 4];
                let mut t1 = [0.0f64; 4];
                if accumulate {
                    for g in 0..vw {
                        acc0[g] = _mm256_loadu_pd(o0.add(4 * g));
                        acc1[g] = _mm256_loadu_pd(o1.add(4 * g));
                    }
                    for j in 0..tail {
                        t0[j] = *o0.add(4 * vw + j);
                        t1[j] = *o1.add(4 * vw + j);
                    }
                }
                let ar0 = ap.add(i * k);
                let ar1 = ap.add((i + 1) * k);
                for p in p0..p1 {
                    let s0 = *ar0.add(p);
                    let s1 = *ar1.add(p);
                    let a0 = _mm256_set1_pd(s0);
                    let a1 = _mm256_set1_pd(s1);
                    let br = bp.add(p * bn + col0);
                    for g in 0..vw {
                        let bv = _mm256_loadu_pd(br.add(4 * g));
                        acc0[g] = _mm256_fmadd_pd(a0, bv, acc0[g]);
                        acc1[g] = _mm256_fmadd_pd(a1, bv, acc1[g]);
                    }
                    for j in 0..tail {
                        let bj = *br.add(4 * vw + j);
                        t0[j] = s0.mul_add(bj, t0[j]);
                        t1[j] = s1.mul_add(bj, t1[j]);
                    }
                }
                for g in 0..vw {
                    _mm256_storeu_pd(o0.add(4 * g), acc0[g]);
                    _mm256_storeu_pd(o1.add(4 * g), acc1[g]);
                }
                for j in 0..tail {
                    *o0.add(4 * vw + j) = t0[j];
                    *o1.add(4 * vw + j) = t1[j];
                }
            }
            i += 2;
        }
        if i < n {
            // SAFETY: same extents as above for the single remaining
            // row `i == n - 1`.
            unsafe {
                let o0 = op.add(i * on + col0);
                let mut acc = [_mm256_setzero_pd(); 2];
                let mut t = [0.0f64; 4];
                if accumulate {
                    for g in 0..vw {
                        acc[g] = _mm256_loadu_pd(o0.add(4 * g));
                    }
                    for j in 0..tail {
                        t[j] = *o0.add(4 * vw + j);
                    }
                }
                let ar = ap.add(i * k);
                for p in p0..p1 {
                    let s = *ar.add(p);
                    let av = _mm256_set1_pd(s);
                    let br = bp.add(p * bn + col0);
                    for g in 0..vw {
                        let bv = _mm256_loadu_pd(br.add(4 * g));
                        acc[g] = _mm256_fmadd_pd(av, bv, acc[g]);
                    }
                    for j in 0..tail {
                        t[j] = s.mul_add(*br.add(4 * vw + j), t[j]);
                    }
                }
                for g in 0..vw {
                    _mm256_storeu_pd(o0.add(4 * g), acc[g]);
                }
                for j in 0..tail {
                    *o0.add(4 * vw + j) = t[j];
                }
            }
        }
    }

    /// Packed-panel kernel: the stride-8 zero-padded panel always
    /// supports full 8-lane loads, so every element — ragged widths
    /// included — runs as an FMA lane; seeds and stores stage through
    /// an 8-wide stack buffer so only `width` output columns are ever
    /// read or written.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn matmul_panel_packed(
        a: &[f64],
        n: usize,
        k: usize,
        packed: &[f64],
        col0: usize,
        width: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
    ) {
        let ap = a.as_ptr();
        let pp = packed.as_ptr();
        let op = out.as_mut_ptr();
        let mut i = 0;
        while i + 1 < n {
            // SAFETY: the dispatch wrapper asserted `a.len() == n·k`,
            // `packed.len() ≥ k·8`, `out.len() == n·on`, and
            // `col0 + width ≤ on`; panel loads are full stride-8 rows
            // inside `packed`, output access stages through `width`
            // elements of 8-wide stack buffers, and `out` does not
            // alias `a`/`packed` (distinct slices).
            unsafe {
                let o0 = op.add(i * on + col0);
                let o1 = op.add((i + 1) * on + col0);
                let mut s0 = [0.0f64; 8];
                let mut s1 = [0.0f64; 8];
                if accumulate {
                    core::ptr::copy_nonoverlapping(o0, s0.as_mut_ptr(), width);
                    core::ptr::copy_nonoverlapping(o1, s1.as_mut_ptr(), width);
                }
                let mut acc00 = _mm256_loadu_pd(s0.as_ptr());
                let mut acc01 = _mm256_loadu_pd(s0.as_ptr().add(4));
                let mut acc10 = _mm256_loadu_pd(s1.as_ptr());
                let mut acc11 = _mm256_loadu_pd(s1.as_ptr().add(4));
                let ar0 = ap.add(i * k);
                let ar1 = ap.add((i + 1) * k);
                for p in 0..k {
                    let b0 = _mm256_loadu_pd(pp.add(8 * p));
                    let b1 = _mm256_loadu_pd(pp.add(8 * p + 4));
                    let a0 = _mm256_set1_pd(*ar0.add(p));
                    let a1 = _mm256_set1_pd(*ar1.add(p));
                    acc00 = _mm256_fmadd_pd(a0, b0, acc00);
                    acc01 = _mm256_fmadd_pd(a0, b1, acc01);
                    acc10 = _mm256_fmadd_pd(a1, b0, acc10);
                    acc11 = _mm256_fmadd_pd(a1, b1, acc11);
                }
                _mm256_storeu_pd(s0.as_mut_ptr(), acc00);
                _mm256_storeu_pd(s0.as_mut_ptr().add(4), acc01);
                _mm256_storeu_pd(s1.as_mut_ptr(), acc10);
                _mm256_storeu_pd(s1.as_mut_ptr().add(4), acc11);
                core::ptr::copy_nonoverlapping(s0.as_ptr(), o0, width);
                core::ptr::copy_nonoverlapping(s1.as_ptr(), o1, width);
            }
            i += 2;
        }
        if i < n {
            // SAFETY: same extents as above for the single remaining
            // row `i == n - 1`.
            unsafe {
                let o0 = op.add(i * on + col0);
                let mut s0 = [0.0f64; 8];
                if accumulate {
                    core::ptr::copy_nonoverlapping(o0, s0.as_mut_ptr(), width);
                }
                let mut acc0 = _mm256_loadu_pd(s0.as_ptr());
                let mut acc1 = _mm256_loadu_pd(s0.as_ptr().add(4));
                let ar = ap.add(i * k);
                for p in 0..k {
                    let b0 = _mm256_loadu_pd(pp.add(8 * p));
                    let b1 = _mm256_loadu_pd(pp.add(8 * p + 4));
                    let av = _mm256_set1_pd(*ar.add(p));
                    acc0 = _mm256_fmadd_pd(av, b0, acc0);
                    acc1 = _mm256_fmadd_pd(av, b1, acc1);
                }
                _mm256_storeu_pd(s0.as_mut_ptr(), acc0);
                _mm256_storeu_pd(s0.as_mut_ptr().add(4), acc1);
                core::ptr::copy_nonoverlapping(s0.as_ptr(), o0, width);
            }
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn axpy(dst: &mut [f64], alpha: f64, src: &[f64]) {
        // SAFETY: the dispatch wrapper asserted equal lengths; the
        // vector loop stops at `len/4*4` and the tail is scalar, so
        // every access is in bounds (`dst`/`src` are distinct slices).
        unsafe {
            let n = dst.len();
            let n4 = n / 4 * 4;
            let av = _mm256_set1_pd(alpha);
            let dp = dst.as_mut_ptr();
            let sp = src.as_ptr();
            let mut i = 0;
            while i < n4 {
                let d = _mm256_loadu_pd(dp.add(i));
                let s = _mm256_loadu_pd(sp.add(i));
                _mm256_storeu_pd(dp.add(i), _mm256_fmadd_pd(av, s, d));
                i += 4;
            }
            while i < n {
                *dp.add(i) = alpha.mul_add(*sp.add(i), *dp.add(i));
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn fill_scaled(dst: &mut [f64], src: &[f64], alpha: f64) {
        // SAFETY: equal lengths asserted by the wrapper; bounds as in
        // `axpy` above.
        unsafe {
            let n = dst.len();
            let n4 = n / 4 * 4;
            let av = _mm256_set1_pd(alpha);
            let dp = dst.as_mut_ptr();
            let sp = src.as_ptr();
            let mut i = 0;
            while i < n4 {
                let s = _mm256_loadu_pd(sp.add(i));
                _mm256_storeu_pd(dp.add(i), _mm256_mul_pd(av, s));
                i += 4;
            }
            while i < n {
                *dp.add(i) = alpha * *sp.add(i);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn scale(dst: &mut [f64], alpha: f64) {
        // SAFETY: the vector loop stops at `len/4*4` and the tail is
        // scalar, so every access stays inside `dst`.
        unsafe {
            let n = dst.len();
            let n4 = n / 4 * 4;
            let av = _mm256_set1_pd(alpha);
            let dp = dst.as_mut_ptr();
            let mut i = 0;
            while i < n4 {
                let d = _mm256_loadu_pd(dp.add(i));
                _mm256_storeu_pd(dp.add(i), _mm256_mul_pd(av, d));
                i += 4;
            }
            while i < n {
                *dp.add(i) *= alpha;
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn add_scaled(out: &mut [f64], a: &[f64], alpha: f64, b: &[f64]) {
        // SAFETY: equal lengths asserted by the wrapper; bounds as in
        // `axpy` above (`out` distinct from `a`/`b`).
        unsafe {
            let n = out.len();
            let n4 = n / 4 * 4;
            let av = _mm256_set1_pd(alpha);
            let op = out.as_mut_ptr();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < n4 {
                let va = _mm256_loadu_pd(ap.add(i));
                let vb = _mm256_loadu_pd(bp.add(i));
                _mm256_storeu_pd(op.add(i), _mm256_fmadd_pd(av, vb, va));
                i += 4;
            }
            while i < n {
                *op.add(i) = alpha.mul_add(*bp.add(i), *ap.add(i));
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callers guarantee AVX2+FMA availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn col_dots(w: &[f64], r: &[f64], dots: &mut [f64]) {
        // SAFETY: equal lengths asserted by the wrapper; bounds as in
        // `axpy` above.
        unsafe {
            let n = dots.len();
            let n4 = n / 4 * 4;
            let dp = dots.as_mut_ptr();
            let wp = w.as_ptr();
            let rp = r.as_ptr();
            let mut i = 0;
            while i < n4 {
                let d = _mm256_loadu_pd(dp.add(i));
                let wv = _mm256_loadu_pd(wp.add(i));
                let rv = _mm256_loadu_pd(rp.add(i));
                _mm256_storeu_pd(dp.add(i), _mm256_fmadd_pd(wv, rv, d));
                i += 4;
            }
            while i < n {
                *dp.add(i) = (*wp.add(i)).mul_add(*rp.add(i), *dp.add(i));
                i += 1;
            }
        }
    }
}

/// NEON kernels: 2 × f64 lanes (`vfmaq_f64` is a correctly-rounded
/// fused multiply-add, like the AVX2 lanes and `f64::mul_add` tails).
#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    /// Unpacked ≤8-wide panel kernel: full 2-lane groups as FMA
    /// vectors, `width % 2` tail as a scalar `mul_add` chain.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn matmul_panel_block(
        a: &[f64],
        n: usize,
        k: usize,
        b: &[f64],
        bn: usize,
        col0: usize,
        width: usize,
        p0: usize,
        p1: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
    ) {
        let vw = width / 2;
        let tail = width % 2;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let op = out.as_mut_ptr();
        for i in 0..n {
            // SAFETY: the dispatch wrapper asserted `a.len() == n·k`,
            // `b.len() == k·bn`, `out.len() == n·on`, `p1 ≤ k`, and
            // `col0 + width ≤ min(bn, on)`; `2·vw + tail == width`
            // keeps every offset inside those extents, and `out` does
            // not alias `a`/`b`.
            unsafe {
                let o0 = op.add(i * on + col0);
                let mut acc = [vdupq_n_f64(0.0); 4];
                let mut t = 0.0f64;
                if accumulate {
                    for g in 0..vw {
                        acc[g] = vld1q_f64(o0.add(2 * g));
                    }
                    if tail == 1 {
                        t = *o0.add(2 * vw);
                    }
                }
                let ar = ap.add(i * k);
                for p in p0..p1 {
                    let s = *ar.add(p);
                    let av = vdupq_n_f64(s);
                    let br = bp.add(p * bn + col0);
                    for g in 0..vw {
                        let bv = vld1q_f64(br.add(2 * g));
                        acc[g] = vfmaq_f64(acc[g], av, bv);
                    }
                    if tail == 1 {
                        t = s.mul_add(*br.add(2 * vw), t);
                    }
                }
                for g in 0..vw {
                    vst1q_f64(o0.add(2 * g), acc[g]);
                }
                if tail == 1 {
                    *o0.add(2 * vw) = t;
                }
            }
        }
    }

    /// Packed-panel kernel: full 8-lane (4 × 2-lane) compute over the
    /// stride-8 zero-padded panel; output access stages through an
    /// 8-wide stack buffer so only `width` columns are read or written.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn matmul_panel_packed(
        a: &[f64],
        n: usize,
        k: usize,
        packed: &[f64],
        col0: usize,
        width: usize,
        accumulate: bool,
        out: &mut [f64],
        on: usize,
    ) {
        let ap = a.as_ptr();
        let pp = packed.as_ptr();
        let op = out.as_mut_ptr();
        for i in 0..n {
            // SAFETY: the dispatch wrapper asserted `a.len() == n·k`,
            // `packed.len() ≥ k·8`, `out.len() == n·on`, and
            // `col0 + width ≤ on`; panel loads are full stride-8 rows,
            // and output access stages through `width` elements of an
            // 8-wide stack buffer (`out` distinct from `a`/`packed`).
            unsafe {
                let o0 = op.add(i * on + col0);
                let mut s = [0.0f64; 8];
                if accumulate {
                    core::ptr::copy_nonoverlapping(o0, s.as_mut_ptr(), width);
                }
                let mut acc = [
                    vld1q_f64(s.as_ptr()),
                    vld1q_f64(s.as_ptr().add(2)),
                    vld1q_f64(s.as_ptr().add(4)),
                    vld1q_f64(s.as_ptr().add(6)),
                ];
                let ar = ap.add(i * k);
                for p in 0..k {
                    let av = vdupq_n_f64(*ar.add(p));
                    let pr = pp.add(8 * p);
                    for (g, slot) in acc.iter_mut().enumerate() {
                        let bv = vld1q_f64(pr.add(2 * g));
                        *slot = vfmaq_f64(*slot, av, bv);
                    }
                }
                for (g, slot) in acc.iter().enumerate() {
                    vst1q_f64(s.as_mut_ptr().add(2 * g), *slot);
                }
                core::ptr::copy_nonoverlapping(s.as_ptr(), o0, width);
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn axpy(dst: &mut [f64], alpha: f64, src: &[f64]) {
        // SAFETY: equal lengths asserted by the wrapper; the vector
        // loop stops at `len/2*2` and the tail is scalar.
        unsafe {
            let n = dst.len();
            let n2 = n / 2 * 2;
            let av = vdupq_n_f64(alpha);
            let dp = dst.as_mut_ptr();
            let sp = src.as_ptr();
            let mut i = 0;
            while i < n2 {
                let d = vld1q_f64(dp.add(i));
                let s = vld1q_f64(sp.add(i));
                vst1q_f64(dp.add(i), vfmaq_f64(d, av, s));
                i += 2;
            }
            if i < n {
                *dp.add(i) = alpha.mul_add(*sp.add(i), *dp.add(i));
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn fill_scaled(dst: &mut [f64], src: &[f64], alpha: f64) {
        // SAFETY: equal lengths asserted by the wrapper; bounds as in
        // `axpy` above.
        unsafe {
            let n = dst.len();
            let n2 = n / 2 * 2;
            let av = vdupq_n_f64(alpha);
            let dp = dst.as_mut_ptr();
            let sp = src.as_ptr();
            let mut i = 0;
            while i < n2 {
                let s = vld1q_f64(sp.add(i));
                vst1q_f64(dp.add(i), vmulq_f64(av, s));
                i += 2;
            }
            if i < n {
                *dp.add(i) = alpha * *sp.add(i);
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn scale(dst: &mut [f64], alpha: f64) {
        // SAFETY: the vector loop stops at `len/2*2` and the tail is
        // scalar, so every access stays inside `dst`.
        unsafe {
            let n = dst.len();
            let n2 = n / 2 * 2;
            let av = vdupq_n_f64(alpha);
            let dp = dst.as_mut_ptr();
            let mut i = 0;
            while i < n2 {
                let d = vld1q_f64(dp.add(i));
                vst1q_f64(dp.add(i), vmulq_f64(av, d));
                i += 2;
            }
            if i < n {
                *dp.add(i) *= alpha;
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn add_scaled(out: &mut [f64], a: &[f64], alpha: f64, b: &[f64]) {
        // SAFETY: equal lengths asserted by the wrapper; bounds as in
        // `axpy` above (`out` distinct from `a`/`b`).
        unsafe {
            let n = out.len();
            let n2 = n / 2 * 2;
            let av = vdupq_n_f64(alpha);
            let op = out.as_mut_ptr();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < n2 {
                let va = vld1q_f64(ap.add(i));
                let vb = vld1q_f64(bp.add(i));
                vst1q_f64(op.add(i), vfmaq_f64(va, av, vb));
                i += 2;
            }
            if i < n {
                *op.add(i) = alpha.mul_add(*bp.add(i), *ap.add(i));
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callers guarantee NEON availability (checked once at
    // dispatch construction) and the slice-extent invariants asserted
    // by the dispatch wrapper.
    pub(super) unsafe fn col_dots(w: &[f64], r: &[f64], dots: &mut [f64]) {
        // SAFETY: equal lengths asserted by the wrapper; bounds as in
        // `axpy` above.
        unsafe {
            let n = dots.len();
            let n2 = n / 2 * 2;
            let dp = dots.as_mut_ptr();
            let wp = w.as_ptr();
            let rp = r.as_ptr();
            let mut i = 0;
            while i < n2 {
                let d = vld1q_f64(dp.add(i));
                let wv = vld1q_f64(wp.add(i));
                let rv = vld1q_f64(rp.add(i));
                vst1q_f64(dp.add(i), vfmaq_f64(d, wv, rv));
                i += 2;
            }
            if i < n {
                *dp.add(i) = (*wp.add(i)).mul_add(*rp.add(i), *dp.add(i));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn env_parsing_is_a_pure_function() {
        assert_eq!(mode_from_env(Some("scalar")), SimdMode::Scalar);
        assert_eq!(mode_from_env(None), detect());
        assert_eq!(mode_from_env(Some("auto")), detect());
        assert_eq!(mode_from_env(Some("")), detect());
        // Repeat calls agree — selection depends on nothing mutable.
        assert_eq!(mode_from_env(None), mode_from_env(None));
    }

    #[test]
    #[should_panic(expected = "expected auto|scalar|avx2|neon")]
    fn unknown_mode_is_rejected() {
        mode_from_env(Some("sse9"));
    }

    #[test]
    fn global_dispatch_is_stable_and_env_consistent() {
        let first = dispatch().mode();
        assert_eq!(dispatch().mode(), first);
        match std::env::var("DEEPCA_SIMD").ok().as_deref() {
            Some("scalar") => assert_eq!(first, SimdMode::Scalar),
            Some("avx2") => assert_eq!(first, SimdMode::Avx2),
            Some("neon") => assert_eq!(first, SimdMode::Neon),
            _ => assert_eq!(first, detect()),
        }
    }

    #[test]
    fn packbuf_is_cache_line_aligned_and_grow_only() {
        let mut pack = PackBuf::new();
        for len in [8usize, 64, 64, 640, 640, 16] {
            let buf = pack.ensure(len);
            assert_eq!(buf.len(), len);
            assert_eq!(buf.as_ptr() as usize % 64, 0, "len={len}");
        }
        let cap = pack.capacity();
        pack.ensure(640);
        assert_eq!(pack.capacity(), cap, "shrinking request must not reallocate");
    }

    #[test]
    fn pack_panel_layout_and_zero_padding() {
        let kd = KernelDispatch::for_mode(SimdMode::Scalar);
        let mut rng = Rng::seed_from(41);
        let (k, bn) = (5usize, 7usize);
        let b = randv(k * bn, &mut rng);
        let mut pack = PackBuf::new();
        let panel = kd.pack_panel(&b, bn, 4, 3, k, &mut pack);
        assert_eq!(panel.len(), k * 8);
        for p in 0..k {
            for j in 0..3 {
                assert_eq!(panel[p * 8 + j].to_bits(), b[p * bn + 4 + j].to_bits());
            }
            for j in 3..8 {
                assert_eq!(panel[p * 8 + j], 0.0, "padding must be exact zero");
            }
        }
    }

    /// The scalar elementwise primitives are the pre-SIMD loops,
    /// verbatim — pinned here so a refactor cannot silently change
    /// the `DEEPCA_SIMD=scalar` bit contract.
    #[test]
    fn scalar_primitives_match_the_reference_loops_bitwise() {
        let kd = KernelDispatch::for_mode(SimdMode::Scalar);
        let mut rng = Rng::seed_from(42);
        let n = 37;
        let a = randv(n, &mut rng);
        let b = randv(n, &mut rng);
        let alpha = rng.normal();

        let mut got = a.clone();
        kd.axpy(&mut got, alpha, &b);
        let want: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + alpha * y).collect();
        assert!(got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));

        let mut got = vec![f64::NAN; n];
        kd.fill_scaled(&mut got, &b, alpha);
        assert!(got.iter().zip(&b).all(|(x, y)| x.to_bits() == (alpha * y).to_bits()));

        let mut got = a.clone();
        kd.scale(&mut got, alpha);
        assert!(got.iter().zip(&a).all(|(x, y)| x.to_bits() == (y * alpha).to_bits()));

        let mut got = vec![f64::NAN; n];
        kd.add_scaled(&mut got, &a, alpha, &b);
        assert!(got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));

        let mut dots = vec![0.25f64; n];
        kd.col_dots(&a, &b, &mut dots);
        let want: Vec<f64> =
            a.iter().zip(&b).map(|(x, y)| 0.25 + x * y).collect();
        assert!(dots.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    /// Packed and unpacked panel kernels agree bitwise in whatever mode
    /// this process runs (the full cross-mode matrix lives in
    /// `tests/simd_kernels.rs`).
    #[test]
    fn packed_panel_bit_matches_unpacked_panel() {
        let kd = *dispatch();
        let mut rng = Rng::seed_from(43);
        let mut pack = PackBuf::new();
        for (n, k, bn, col0, width) in
            [(9usize, 30usize, 8usize, 0usize, 8usize), (7, 13, 7, 2, 5), (1, 20, 3, 0, 3)]
        {
            let a = randv(n * k, &mut rng);
            let b = randv(k * bn, &mut rng);
            let mut unpacked = vec![f64::NAN; n * bn];
            kd.matmul_panel_block(&a, n, k, &b, bn, col0, width, 0, k, false, &mut unpacked, bn);
            let panel = kd.pack_panel(&b, bn, col0, width, k, &mut pack);
            // Borrow gymnastics: the panel borrow ends before the
            // packed kernel writes the output.
            let panel: Vec<f64> = panel.to_vec();
            let mut packed_out = vec![f64::NAN; n * bn];
            kd.matmul_panel_packed(&a, n, k, &panel, col0, width, false, &mut packed_out, bn);
            for (i, (x, y)) in unpacked.iter().zip(&packed_out).enumerate() {
                let col = i % bn;
                if col >= col0 && col < col0 + width {
                    assert_eq!(x.to_bits(), y.to_bits(), "n={n} k={k} width={width} i={i}");
                }
            }
        }
    }

    /// The fixed mode's vector kernels are within FMA-fusion distance
    /// of scalar: one rounding per update instead of two.
    #[test]
    fn native_mode_is_within_fusion_tolerance_of_scalar() {
        let scalar = KernelDispatch::for_mode(SimdMode::Scalar);
        let native = KernelDispatch::auto();
        let mut rng = Rng::seed_from(44);
        let (n, k, bn) = (11usize, 64usize, 6usize);
        let a = randv(n * k, &mut rng);
        let b = randv(k * bn, &mut rng);
        let mut want = vec![f64::NAN; n * bn];
        scalar.matmul_panel_block(&a, n, k, &b, bn, 0, bn, 0, k, false, &mut want, bn);
        let mut got = vec![f64::NAN; n * bn];
        native.matmul_panel_block(&a, n, k, &b, bn, 0, bn, 0, k, false, &mut got, bn);
        let scale = want.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() <= 1e-13 * scale, "{x} vs {y}");
        }
    }
}
