//! Dense linear algebra substrate.
//!
//! The paper's analysis lives in plain dense linear algebra: QR
//! factorizations (Eqn. 3.3), symmetric eigendecompositions (ground-truth
//! top-k subspace U), spectral norms and pseudo-inverse norms (the Lemma 4–7
//! quantities), and principal angles between subspaces (Definition 1).
//! No BLAS/LAPACK is available in the offline image, so this module
//! implements the needed kernels from scratch with care for the sizes the
//! paper uses (d ≤ 300, k ≤ 16, m = 50):
//!
//! - [`Mat`] — row-major `f64` matrix with cache-blocked matmul. Every
//!   hot-path kernel has a buffer-reusing `_into` form (`matmul_into`,
//!   `t_matmul_into`, `transpose_into`, `add_scaled_into`, `copy_from`)
//!   that writes into a caller-owned output; the allocating methods are
//!   thin wrappers over them, bit-identical by construction.
//! - [`simd`] — runtime-dispatched SIMD microkernels (AVX2+FMA / NEON /
//!   scalar, `DEEPCA_SIMD` knob) plus the packed-B panel layout; every
//!   `Mat` hot loop and the Chebyshev/SignAdjust cores route through its
//!   [`simd::KernelDispatch`].
//! - [`qr`] — Householder thin QR with the positive-diagonal-R
//!   convention; `qr_into` + [`qr::QrWorkspace`] is the allocation-free
//!   form the solver loops run on.
//! - [`eig`] — cyclic Jacobi eigensolver for symmetric matrices.
//! - [`solve`] — LU with partial pivoting; triangular and general solves.
//! - [`norms`] — spectral norm / σ_min via power iteration + Jacobi.
//! - [`angles`] — cos/sin/tan θ_k between subspaces (paper Definition 1).

pub mod matrix;
pub mod simd;
pub mod qr;
pub mod eig;
pub mod solve;
pub mod norms;
pub mod angles;

pub use matrix::Mat;
pub use qr::QrWorkspace;
