//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Used for the *ground truth* the paper's metrics need: the exact top-k
//! principal subspace `U` of the aggregate `A = (1/m) Σ A_j` (Definition 1
//! angles are always measured against this U), as well as λ_k / λ_{k+1}
//! gap diagnostics and λ₂ of the gossip matrix.
//!
//! Jacobi is O(d³) per sweep and converges quadratically; at the paper's
//! d ≤ 300 a full decomposition takes well under a second and is accurate
//! to fp precision — exactly what a ground-truth oracle should be.

use super::matrix::Mat;

/// Result of a symmetric eigendecomposition, eigenvalues sorted descending.
#[derive(Clone, Debug)]
pub struct EigSym {
    /// Eigenvalues in descending order.
    pub values: Vec<f64>,
    /// Column `i` of `vectors` is the eigenvector for `values[i]`.
    pub vectors: Mat,
}

impl EigSym {
    /// The top-k eigenvector block (d×k), the paper's `U`.
    pub fn top_k(&self, k: usize) -> Mat {
        self.vectors.cols_range(0, k)
    }

    /// Relative spectral gap `(λ_k − λ_{k+1}) / λ_k` used in Theorem 1.
    pub fn relative_gap(&self, k: usize) -> f64 {
        (self.values[k - 1] - self.values[k]) / self.values[k - 1]
    }
}

/// Eigendecomposition of a symmetric matrix by cyclic Jacobi rotations.
///
/// `a` must be symmetric (asserted up to 1e-8 relative). Converges when the
/// off-diagonal Frobenius mass falls below `1e-14 * ||A||_F` or after 50
/// sweeps (never observed to need more than ~12 at d=300).
pub fn eig_sym(a: &Mat) -> EigSym {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "eig_sym needs a square matrix");
    let scale = a.max_abs().max(1e-300);
    for i in 0..n {
        for j in (i + 1)..n {
            assert!(
                (a[(i, j)] - a[(j, i)]).abs() <= 1e-8 * scale,
                "eig_sym: matrix not symmetric at ({i},{j})"
            );
        }
    }

    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);
    let fro = m.fro_norm().max(1e-300);
    let tol = 1e-14 * fro;

    for _sweep in 0..50 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if (2.0 * off).sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.5).
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // M := Jᵀ M J, applied to rows/cols p and q.
                for i in 0..n {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = c * mip - s * miq;
                    m[(i, q)] = s * mip + c * miq;
                }
                for i in 0..n {
                    let mpi = m[(p, i)];
                    let mqi = m[(q, i)];
                    m[(p, i)] = c * mpi - s * mqi;
                    m[(q, i)] = s * mpi + c * mqi;
                }
                // Accumulate eigenvectors.
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
    }

    // Collect and sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vectors = Mat::zeros(n, n);
    for (newj, &(_, oldj)) in pairs.iter().enumerate() {
        for i in 0..n {
            vectors[(i, newj)] = v[(i, oldj)];
        }
    }
    EigSym { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym_with_spectrum(evals: &[f64], rng: &mut Rng) -> (Mat, Mat) {
        let n = evals.len();
        let q = Mat::rand_orthonormal(n, n, rng);
        let d = Mat::diag(evals);
        let a = q.matmul(&d).matmul(&q.t());
        (a, q)
    }

    #[test]
    fn eig_diagonal() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let e = eig_sym(&a);
        assert_eq!(e.values.len(), 3);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eig_2x2_analytic() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let e = eig_sym(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
        // Eigenvector of 3 is (1,1)/sqrt(2) up to sign.
        let v0 = e.vectors.col(0);
        assert!((v0[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        assert!((v0[0] - v0[1]).abs() < 1e-10);
    }

    #[test]
    fn eig_recovers_planted_spectrum() {
        let mut rng = Rng::seed_from(21);
        let evals = [10.0, 7.0, 5.5, 2.0, 1.0, 0.5, 0.1, 0.0];
        let (a, _q) = random_sym_with_spectrum(&evals, &mut rng);
        let e = eig_sym(&a);
        for (got, want) in e.values.iter().zip(&evals) {
            assert!((got - want).abs() < 1e-10, "got {got}, want {want}");
        }
    }

    #[test]
    fn eig_residual_small() {
        let mut rng = Rng::seed_from(22);
        let g = Mat::randn(40, 40, &mut rng);
        let mut a = g.t_matmul(&g); // PSD
        a.symmetrize();
        let e = eig_sym(&a);
        // ||A V - V D|| small
        let d = Mat::diag(&e.values);
        let lhs = a.matmul(&e.vectors);
        let rhs = e.vectors.matmul(&d);
        assert!((&lhs - &rhs).fro_norm() < 1e-9 * a.fro_norm().max(1.0));
        // V orthonormal
        let gvv = e.vectors.t_matmul(&e.vectors);
        assert!((&gvv - &Mat::eye(40)).fro_norm() < 1e-10);
    }

    #[test]
    fn top_k_spans_planted_subspace() {
        let mut rng = Rng::seed_from(23);
        let evals = [9.0, 8.0, 7.0, 0.3, 0.2, 0.1];
        let (a, q) = random_sym_with_spectrum(&evals, &mut rng);
        let e = eig_sym(&a);
        let u = e.top_k(3);
        let planted = q.cols_range(0, 3);
        // Projector distance: ||UUᵀ − PPᵀ|| should vanish.
        let pu = u.matmul(&u.t());
        let pp = planted.matmul(&planted.t());
        assert!((&pu - &pp).fro_norm() < 1e-9);
    }

    #[test]
    fn relative_gap_matches() {
        let e = EigSym { values: vec![4.0, 2.0, 1.0], vectors: Mat::eye(3) };
        assert!((e.relative_gap(1) - 0.5).abs() < 1e-15);
        assert!((e.relative_gap(2) - 0.5).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "not symmetric")]
    fn eig_rejects_asymmetric() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let _ = eig_sym(&a);
    }

    #[test]
    fn eig_handles_repeated_eigenvalues() {
        let mut rng = Rng::seed_from(24);
        let evals = [5.0, 5.0, 1.0, 1.0];
        let (a, _q) = random_sym_with_spectrum(&evals, &mut rng);
        let e = eig_sym(&a);
        for (got, want) in e.values.iter().zip(&evals) {
            assert!((got - want).abs() < 1e-10);
        }
    }
}
