//! Linear solves: LU with partial pivoting, triangular solves, inverse.
//!
//! Needed for the tan-θ computation (`V̂ = V̂ (UᵀQ)^{-1}` in
//! [`super::angles`]) and for small k×k systems throughout the metrics
//! layer. Sizes here are k×k (k ≤ 16), so simplicity beats blocking.

use super::matrix::Mat;

/// LU factorization with partial pivoting: `P·A = L·U` stored compactly.
#[derive(Clone, Debug)]
pub struct Lu {
    lu: Mat,
    /// Row permutation: `perm[i]` is the original row now at position i.
    perm: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
    singular: bool,
}

/// Factor a square matrix.
pub fn lu(a: &Mat) -> Lu {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "lu needs a square matrix");
    let mut m = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;
    let mut singular = false;

    for col in 0..n {
        // Pivot: largest |entry| in column `col`, rows col..n.
        let mut piv = col;
        let mut best = m[(col, col)].abs();
        for r in (col + 1)..n {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best == 0.0 {
            singular = true;
            continue;
        }
        if piv != col {
            for j in 0..n {
                let tmp = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = tmp;
            }
            perm.swap(col, piv);
            sign = -sign;
        }
        let d = m[(col, col)];
        for r in (col + 1)..n {
            let f = m[(r, col)] / d;
            m[(r, col)] = f;
            for j in (col + 1)..n {
                let mcj = m[(col, j)];
                m[(r, j)] -= f * mcj;
            }
        }
    }
    Lu { lu: m, perm, sign, singular }
}

impl Lu {
    /// Whether a zero pivot was hit (matrix numerically singular).
    pub fn is_singular(&self) -> bool {
        self.singular
    }

    /// Determinant of the original matrix.
    pub fn det(&self) -> f64 {
        if self.singular {
            return 0.0;
        }
        let n = self.lu.rows();
        let mut d = self.sign;
        for i in 0..n {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// Solve `A x = b` for a single right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        assert!(!self.singular, "solve on singular matrix");
        // Apply permutation.
        let mut x: Vec<f64> = (0..n).map(|i| b[self.perm[i]]).collect();
        // Forward substitution (L has unit diagonal).
        for i in 1..n {
            let mut s = x[i];
            for j in 0..i {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in (i + 1)..n {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s / self.lu[(i, i)];
        }
        x
    }

    /// Solve `A X = B` column by column.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.lu.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        for j in 0..b.cols() {
            let col = b.col(j);
            let x = self.solve_vec(&col);
            out.set_col(j, &x);
        }
        out
    }
}

/// Inverse of a square matrix (via LU). Panics if singular.
pub fn inverse(a: &Mat) -> Mat {
    let f = lu(a);
    assert!(!f.is_singular(), "inverse of singular matrix");
    f.solve_mat(&Mat::eye(a.rows()))
}

/// Solve `X R = B` for upper-triangular `R` (right division), i.e.
/// `X = B R^{-1}`. Used to form `Q = S R^{-1}` style products cheaply.
pub fn solve_upper_right(b: &Mat, r: &Mat) -> Mat {
    let (m, n) = b.shape();
    assert_eq!(r.shape(), (n, n));
    let mut x = b.clone();
    // Column j of X: (B[:,j] - sum_{i<j} X[:,i] R[i,j]) / R[j,j]
    for j in 0..n {
        for i in 0..j {
            let rij = r[(i, j)];
            if rij != 0.0 {
                for row in 0..m {
                    let xi = x[(row, i)];
                    x[(row, j)] -= xi * rij;
                }
            }
        }
        let d = r[(j, j)];
        assert!(d != 0.0, "singular triangular factor");
        for row in 0..m {
            x[(row, j)] /= d;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lu_solve_matches_direct() {
        let mut rng = Rng::seed_from(31);
        let a = Mat::randn(8, 8, &mut rng);
        let xtrue: Vec<f64> = (0..8).map(|i| i as f64 - 3.5).collect();
        let b = a.matvec(&xtrue);
        let f = lu(&a);
        let x = f.solve_vec(&b);
        for (got, want) in x.iter().zip(&xtrue) {
            assert!((got - want).abs() < 1e-10);
        }
    }

    #[test]
    fn lu_det_2x2() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert!((lu(&a).det() - (-2.0)).abs() < 1e-12);
    }

    #[test]
    fn lu_detects_singular() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(lu(&a).is_singular());
        assert_eq!(lu(&a).det(), 0.0);
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::seed_from(32);
        let a = Mat::randn(6, 6, &mut rng);
        let ainv = inverse(&a);
        let prod = a.matmul(&ainv);
        assert!((&prod - &Mat::eye(6)).fro_norm() < 1e-9);
    }

    #[test]
    fn solve_mat_multi_rhs() {
        let mut rng = Rng::seed_from(33);
        let a = Mat::randn(5, 5, &mut rng);
        let x = Mat::randn(5, 3, &mut rng);
        let b = a.matmul(&x);
        let f = lu(&a);
        let got = f.solve_mat(&b);
        assert!((&got - &x).fro_norm() < 1e-9);
    }

    #[test]
    fn solve_upper_right_matches_inverse() {
        let mut rng = Rng::seed_from(34);
        let b = Mat::randn(7, 4, &mut rng);
        // Random well-conditioned upper triangular with positive diagonal.
        let mut r = Mat::zeros(4, 4);
        for i in 0..4 {
            r[(i, i)] = 1.0 + rng.uniform();
            for j in (i + 1)..4 {
                r[(i, j)] = rng.normal() * 0.3;
            }
        }
        let fast = solve_upper_right(&b, &r);
        let slow = b.matmul(&inverse(&r));
        assert!((&fast - &slow).fro_norm() < 1e-10);
    }

    #[test]
    fn permutation_needed_case() {
        // Zero on the first pivot forces a row swap.
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let f = lu(&a);
        assert!(!f.is_singular());
        let x = f.solve_vec(&[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }
}
