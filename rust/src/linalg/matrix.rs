//! Row-major dense `f64` matrix with the operations the DeEPCA stack needs.
//!
//! Kernel family (see EXPERIMENTS.md §Perf for the measured history):
//! ≤8 output columns run a register-blocked panel kernel (the DeEPCA
//! power-step shape `A(d×d) @ W(d×k)`), 9–16 as two panels, and wider
//! outputs — Gram/covariance products, Rayleigh blocks — run the same
//! panel kernel under a cache-blocked `k × j` tiling: 8-wide column
//! panels × inner-dimension blocks sized so the streamed B panel stays
//! in cache, with the panel accumulator re-seeded from the output
//! between blocks (bit-identical to a single full-depth pass, because
//! each output element still accumulates in ascending inner order).
//! `t_matmul_into` tiles wide outputs by column block for the same
//! reason, keeping its sparse-operand zero skip.
//!
//! All scalar inner loops live in [`super::simd`] behind the
//! process-wide [`simd::dispatch`] (AVX2+FMA / NEON / scalar, selected
//! once from `DEEPCA_SIMD`); `matmul_packed_into` additionally packs
//! each B panel into a [`PackBuf`] for contiguous full-width streaming
//! on the wide-product hot paths. See `linalg/simd.rs` for the
//! per-mode determinism contract.

use super::simd::{self, KernelDispatch, PackBuf};
use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Add, Mul, Sub};

/// Inner-dimension block for the wide (>16 column) matmul path: the
/// panel kernel streams `WIDE_K_BLOCK` B-rows per pass, so the live
/// B panel is `256 × 8 × 8 B = 16 KiB` — resident in L1 while the
/// accumulators sit in registers. Chosen once; the blocked result is
/// bit-identical for *any* block size (ascending-`p` accumulation),
/// so this is purely a cache knob.
const WIDE_K_BLOCK: usize = 256;

/// Column tile for wide `t_matmul_into` outputs: bounds the output
/// working set touched per input row to `d × 64 × 8 B`, so the Gram
/// accumulation (`CovTracker`'s `XᵀX` at d up to a few hundred) stays
/// in L2 instead of sweeping the whole `d × m` output every row.
const TM_COL_BLOCK: usize = 64;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    // ---------------------------------------------------------------- ctors

    /// Zero matrix of shape (rows, cols).
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Take ownership of a row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    /// Random matrix with orthonormal columns (QR of a Gaussian).
    pub fn rand_orthonormal(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        assert!(cols <= rows);
        let g = Mat::randn(rows, cols, rng);
        let (q, _r) = super::qr::thin_qr(&g);
        q
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    // ------------------------------------------------------------ accessors

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Underlying row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable underlying row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Overwrite column `j`.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Columns `j0..j1` as a new matrix.
    pub fn cols_range(&self, j0: usize, j1: usize) -> Mat {
        assert!(j0 <= j1 && j1 <= self.cols);
        Mat::from_fn(self.rows, j1 - j0, |i, j| self[(i, j0 + j)])
    }

    // ----------------------------------------------------------- arithmetic

    /// Overwrite `self` with `other`'s contents (shapes must match).
    /// Never reallocates — the workhorse of the `_into` hot paths.
    #[inline]
    pub fn copy_from(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "copy_from shape mismatch");
        self.data.copy_from_slice(&other.data);
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a caller-owned buffer (`out` must be cols×rows).
    pub fn transpose_into(&self, out: &mut Mat) {
        assert_eq!(
            out.shape(),
            (self.cols, self.rows),
            "transpose_into output shape mismatch"
        );
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
    }

    /// Matrix product `self * other`.
    ///
    /// Thin wrapper over [`Mat::matmul_into`] (allocates the output);
    /// the two are bit-identical by construction.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// Matrix product into a caller-owned buffer: `out = self * other`.
    /// `out` is fully overwritten (no need to zero it first) and never
    /// reallocated — this is the zero-allocation hot path every solver
    /// iteration runs on.
    ///
    /// The DeEPCA hot path is `A(d×d) @ W(d×k)` with k ≤ 16: that case
    /// dispatches to a register-blocked kernel (`M` output accumulators
    /// live in registers, one streaming pass over the A row and the B
    /// panel — ~8× the naive i-k-j loop, see EXPERIMENTS.md §Perf);
    /// 9–16 columns run as two ≤8-wide panels directly into the output
    /// (no column-slice materialization). Wider outputs — Gram and
    /// covariance products — auto-detect by shape and run the same
    /// panel kernel under a cache-blocked `k × j` tiling: 8-wide column
    /// panels × [`WIDE_K_BLOCK`]-deep inner blocks, with the panel
    /// accumulators re-seeded from `out` between blocks. Each output
    /// element still accumulates in ascending inner order, so the
    /// blocked result is bit-identical to a single full-depth panel
    /// pass (pinned by a unit test below).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(
            out.shape(),
            (self.rows, other.cols),
            "matmul_into output shape mismatch"
        );
        let m = other.cols;
        match m {
            0 => {}
            1..=8 => self.matmul_thin_panel_into(other, 0, m, out),
            9..=16 => {
                let half = m / 2;
                self.matmul_thin_panel_into(other, 0, half, out);
                self.matmul_thin_panel_into(other, half, m - half, out);
            }
            _ => self.matmul_wide_blocked_into(other, out),
        }
    }

    /// Dispatch one ≤8-wide panel to the monomorphized thin kernel over
    /// the full inner dimension: B columns `col0 .. col0+width` into the
    /// same output columns.
    fn matmul_thin_panel_into(&self, other: &Mat, col0: usize, width: usize, out: &mut Mat) {
        self.matmul_panel_block_into(other, col0, width, 0, self.cols, false, out);
    }

    /// Dispatch one ≤8-wide panel restricted to inner rows `p0..p1` to
    /// the process-wide SIMD kernel dispatch (`simd::dispatch()`).
    /// `accumulate` seeds the register accumulators from `out` (for the
    /// second and later inner blocks of the wide tiled path) instead of
    /// zero.
    #[allow(clippy::too_many_arguments)]
    fn matmul_panel_block_into(
        &self,
        other: &Mat,
        col0: usize,
        width: usize,
        p0: usize,
        p1: usize,
        accumulate: bool,
        out: &mut Mat,
    ) {
        simd::dispatch().matmul_panel_block(
            &self.data,
            self.rows,
            self.cols,
            &other.data,
            other.cols,
            col0,
            width,
            p0,
            p1,
            accumulate,
            &mut out.data,
            out.cols,
        );
    }

    /// Cache-blocked product for wide outputs (> 16 columns): iterate
    /// 8-wide column panels, and within each panel sweep the inner
    /// dimension in [`WIDE_K_BLOCK`]-deep blocks so the streamed B
    /// panel stays L1-resident. The first block overwrites `out`
    /// (dirty buffers allowed, same contract as the thin path), later
    /// blocks re-seed the register accumulators from `out` — per
    /// output element that is the same ascending-`p` addition sequence
    /// as one full-depth pass, so the split is bit-invisible.
    fn matmul_wide_blocked_into(&self, other: &Mat, out: &mut Mat) {
        let (k, m) = (self.cols, other.cols);
        if k == 0 {
            out.data.fill(0.0);
            return;
        }
        let mut col0 = 0;
        while col0 < m {
            let width = (m - col0).min(8);
            let mut p0 = 0;
            while p0 < k {
                let p1 = (p0 + WIDE_K_BLOCK).min(k);
                self.matmul_panel_block_into(other, col0, width, p0, p1, p0 > 0, out);
                p0 = p1;
            }
            col0 += width;
        }
    }

    /// Packed-B product into a caller-owned buffer: like
    /// [`Mat::matmul_into`], but each ≤8-wide B panel is first packed
    /// into `pack` (stride-8, zero-padded, cache-line-aligned scratch —
    /// see [`simd::PackBuf`]) and the microkernel streams the panel as
    /// contiguous rows over the **full** inner dimension in one pass.
    /// Bit-identical to [`Mat::matmul_into`] in every SIMD mode
    /// (packing relocates B values, never reorders any element's
    /// update sequence; pinned by unit tests below). The scratch is
    /// grow-only, so repeated products at steady-state shapes allocate
    /// nothing — this is the backend/centralized hot path for wide
    /// products.
    pub fn matmul_packed_into(&self, other: &Mat, pack: &mut PackBuf, out: &mut Mat) {
        self.matmul_packed_with(simd::dispatch(), other, pack, out);
    }

    /// [`Mat::matmul_packed_into`] with an explicit kernel dispatch
    /// (benches and parity tests run scalar and vector side by side;
    /// production code uses the process-wide dispatch).
    pub fn matmul_packed_with(
        &self,
        kd: &KernelDispatch,
        other: &Mat,
        pack: &mut PackBuf,
        out: &mut Mat,
    ) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(
            out.shape(),
            (self.rows, other.cols),
            "matmul_packed_into output shape mismatch"
        );
        let (k, m) = (self.cols, other.cols);
        if k == 0 || m == 0 {
            out.data.fill(0.0);
            return;
        }
        let mut col0 = 0;
        while col0 < m {
            let width = (m - col0).min(8);
            let packed = kd.pack_panel(&other.data, m, col0, width, k, pack);
            kd.matmul_panel_packed(
                &self.data, self.rows, k, packed, col0, width, false, &mut out.data, m,
            );
            col0 += width;
        }
    }

    /// General i-k-j product (contiguous FMA inner loop), allocating.
    /// Test-only reference the blocked wide path is checked against.
    #[cfg(test)]
    fn matmul_wide(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_wide_into(other, &mut out);
        out
    }

    /// General i-k-j product into a caller-owned buffer (test-only
    /// reference; the production wide path is
    /// [`Mat::matmul_wide_blocked_into`]).
    #[cfg(test)]
    fn matmul_wide_into(&self, other: &Mat, out: &mut Mat) {
        let (n, k, m) = (self.rows, self.cols, other.cols);
        out.data.fill(0.0);
        for i in 0..n {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * m..(i + 1) * m];
            for (p, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue; // sparse-ish operands (binary features)
                }
                let brow = &other.data[p * m..(p + 1) * m];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
    }

    /// `selfᵀ * other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols, other.cols);
        self.t_matmul_into(other, &mut out);
        out
    }

    /// `out = selfᵀ * other` into a caller-owned buffer (`out` is fully
    /// overwritten, never reallocated).
    ///
    /// Wide outputs (> 16 columns — the Gram/covariance shape
    /// `Xᵀ(n×d) X(n×d)` with d up to a few hundred) run column-tiled
    /// ([`TM_COL_BLOCK`]) so each input row's outer-product update
    /// touches an L2-resident output panel instead of sweeping the full
    /// `d × m` output. Per output element the accumulation order is
    /// unchanged (ascending input row, same `a == 0` skip), so the
    /// tiled result is bit-identical to the untiled loop (pinned by a
    /// unit test below).
    pub fn t_matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        assert_eq!(
            out.shape(),
            (self.cols, other.cols),
            "t_matmul_into output shape mismatch"
        );
        let (n, m) = (self.rows, other.cols);
        if m > 16 {
            self.t_matmul_blocked_into(other, out);
            return;
        }
        let kd = simd::dispatch();
        out.data.fill(0.0);
        for p in 0..n {
            let arow = &self.data[p * self.cols..(p + 1) * self.cols];
            let brow = &other.data[p * m..(p + 1) * m];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                kd.axpy(&mut out.data[i * m..(i + 1) * m], a, brow);
            }
        }
    }

    /// Column-tiled `selfᵀ * other` for wide outputs: for each
    /// [`TM_COL_BLOCK`]-wide output column tile, sweep all input rows
    /// and accumulate the outer-product contribution restricted to the
    /// tile. Same ascending-row accumulation and `a == 0.0` skip
    /// (sparse-ish binary features) as the untiled loop — the tiling
    /// only reorders *which elements* are updated when, never the order
    /// of additions within one element, so results are bit-identical.
    fn t_matmul_blocked_into(&self, other: &Mat, out: &mut Mat) {
        let (n, d, m) = (self.rows, self.cols, other.cols);
        let kd = simd::dispatch();
        out.data.fill(0.0);
        let mut j0 = 0;
        while j0 < m {
            let j1 = (j0 + TM_COL_BLOCK).min(m);
            for p in 0..n {
                let arow = &self.data[p * d..(p + 1) * d];
                let brow = &other.data[p * m + j0..p * m + j1];
                for (i, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    kd.axpy(&mut out.data[i * m + j0..i * m + j1], a, brow);
                }
            }
            j0 = j1;
        }
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// In-place `self += alpha * other`. One update per element through
    /// the SIMD dispatch (unfused in scalar mode, fused in vector
    /// modes) — the same per-element formula as
    /// [`Mat::add_scaled_into`], so copy-then-axpy and add-scaled are
    /// bit-identical within every mode.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        simd::dispatch().axpy(&mut self.data, alpha, &other.data);
    }

    /// `out = self + alpha · other` into a caller-owned buffer (the
    /// allocation-free form of `&a + &b` / `&a - &b`; `out` is fully
    /// overwritten).
    pub fn add_scaled_into(&self, alpha: f64, other: &Mat, out: &mut Mat) {
        assert_eq!(self.shape(), other.shape(), "add_scaled shape mismatch");
        assert_eq!(self.shape(), out.shape(), "add_scaled_into output shape mismatch");
        simd::dispatch().add_scaled(&mut out.data, &self.data, alpha, &other.data);
    }

    /// In-place scale. A single multiply per element — bit-identical
    /// across all SIMD modes.
    pub fn scale(&mut self, alpha: f64) {
        simd::dispatch().scale(&mut self.data, alpha);
    }

    /// `self = alpha · src`, elementwise — the fused form of
    /// [`Mat::copy_from`] + [`Mat::scale`]. A single correctly-rounded
    /// multiply per element, so it is bit-identical to the
    /// copy-then-scale sequence it replaces in every SIMD mode (and
    /// across modes) while touching each cache line once instead of
    /// twice — the Chebyshev row-update seed path.
    pub fn fill_scaled_from(&mut self, alpha: f64, src: &Mat) {
        assert_eq!(self.shape(), src.shape(), "fill_scaled_from shape mismatch");
        simd::dispatch().fill_scaled(&mut self.data, &src.data, alpha);
    }

    /// `alpha * self` as a new matrix.
    pub fn scaled(&self, alpha: f64) -> Mat {
        let mut out = self.clone();
        out.scale(alpha);
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Frobenius inner product <self, other>.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Symmetrize in place: `(A + Aᵀ)/2` (counters fp drift on PSD matrices).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// True iff all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        self.add_scaled_into(1.0, rhs, &mut out);
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        self.add_scaled_into(-1.0, rhs, &mut out);
        out
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, rhs: &Mat) -> Mat {
        self.matmul(rhs)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for i in 0..show_rows {
            let cells: Vec<String> = self
                .row(i)
                .iter()
                .take(8)
                .map(|v| format!("{v:>10.4}"))
                .collect();
            let ell = if self.cols > 8 { " …" } else { "" };
            writeln!(f, "  [{}{}]", cells.join(", "), ell)?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn eye_matmul_identity() {
        let mut r = Rng::seed_from(1);
        let a = Mat::randn(5, 5, &mut r);
        let i = Mat::eye(5);
        let prod = a.matmul(&i);
        assert!((&prod - &a).fro_norm() < 1e-14);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_rows(3, 2, &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.shape(), (2, 2));
        assert_eq!(c[(0, 0)], 58.0);
        assert_eq!(c[(0, 1)], 64.0);
        assert_eq!(c[(1, 0)], 139.0);
        assert_eq!(c[(1, 1)], 154.0);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut r = Rng::seed_from(2);
        let a = Mat::randn(7, 4, &mut r);
        let b = Mat::randn(7, 3, &mut r);
        let fast = a.t_matmul(&b);
        let slow = a.t().matmul(&b);
        assert!((&fast - &slow).fro_norm() < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::seed_from(3);
        let a = Mat::randn(6, 4, &mut r);
        assert!((&a.t().t() - &a).fro_norm() == 0.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut r = Rng::seed_from(4);
        let a = Mat::randn(5, 3, &mut r);
        let x = vec![1.0, -2.0, 0.5];
        let xm = Mat::from_vec(3, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        for i in 0..5 {
            assert!(approx(via_mm[(i, 0)], via_mv[i], 1e-14));
        }
    }

    #[test]
    fn axpy_and_ops() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[10.0, 20.0, 30.0, 40.0]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c[(1, 1)], 24.0);
        let s = &a + &b;
        assert_eq!(s[(0, 0)], 11.0);
        let d = &b - &a;
        assert_eq!(d[(0, 1)], 18.0);
    }

    #[test]
    fn fro_norm_and_dot() {
        let a = Mat::from_rows(1, 3, &[3.0, 4.0, 0.0]);
        assert!(approx(a.fro_norm(), 5.0, 1e-15));
        let b = Mat::from_rows(1, 3, &[1.0, 1.0, 1.0]);
        assert!(approx(a.dot(&b), 7.0, 1e-15));
    }

    #[test]
    fn col_roundtrip() {
        let mut r = Rng::seed_from(6);
        let mut a = Mat::randn(4, 3, &mut r);
        let c = a.col(1);
        a.set_col(1, &c);
        assert_eq!(a.col(1), c);
    }

    #[test]
    fn cols_range_slices() {
        let a = Mat::from_rows(2, 4, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let s = a.cols_range(1, 3);
        assert_eq!(s.shape(), (2, 2));
        assert_eq!(s[(0, 0)], 1.0);
        assert_eq!(s[(1, 1)], 6.0);
    }

    #[test]
    fn rand_orthonormal_is_orthonormal() {
        let mut r = Rng::seed_from(8);
        let q = Mat::rand_orthonormal(20, 5, &mut r);
        let g = q.t_matmul(&q);
        assert!((&g - &Mat::eye(5)).fro_norm() < 1e-12);
    }

    #[test]
    fn symmetrize_fixes_drift() {
        let mut a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0 + 1e-10, 3.0]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], a[(1, 0)]);
    }

    #[test]
    fn diag_builds() {
        let d = Mat::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(d[(1, 1)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn thin_and_wide_matmul_agree() {
        let mut r = Rng::seed_from(60);
        for m in [1usize, 2, 5, 8, 9, 12, 16, 17, 40] {
            let a = Mat::randn(23, 31, &mut r);
            let b = Mat::randn(31, m, &mut r);
            let fast = a.matmul(&b);
            let slow = a.matmul_wide(&b);
            assert!(
                (&fast - &slow).fro_norm() < 1e-12 * (1.0 + slow.fro_norm()),
                "cols={m}"
            );
        }
    }

    #[test]
    fn wide_blocked_matches_naive_reference_past_one_k_block() {
        // Inner dimension 700 spans three WIDE_K_BLOCK blocks; widths
        // cover full panels, a ragged tail panel, and both sides of the
        // 16/17 dispatch boundary.
        let mut r = Rng::seed_from(64);
        for m in [17usize, 33, 40, 64, 100] {
            let a = Mat::randn(9, 700, &mut r);
            let b = Mat::randn(700, m, &mut r);
            let fast = a.matmul(&b);
            let slow = a.matmul_wide(&b);
            assert!(
                (&fast - &slow).fro_norm() < 1e-11 * (1.0 + slow.fro_norm()),
                "cols={m}"
            );
        }
    }

    #[test]
    fn wide_blocked_k_split_bit_identical_to_single_pass() {
        // The inner-dimension split must be bit-invisible: seeding the
        // panel accumulators from the previous block's partial sums and
        // continuing in ascending p is the same addition sequence as one
        // full-depth panel pass. 700 inner rows → 3 blocks vs 1 pass.
        let mut r = Rng::seed_from(65);
        let a = Mat::randn(11, 700, &mut r);
        let b = Mat::randn(700, 20, &mut r);
        let mut blocked = Mat::from_fn(11, 20, |_, _| f64::NAN);
        a.matmul_into(&b, &mut blocked);
        let mut single = Mat::from_fn(11, 20, |_, _| f64::NAN);
        let mut col0 = 0;
        while col0 < 20 {
            let width = (20 - col0).min(8);
            a.matmul_thin_panel_into(&b, col0, width, &mut single);
            col0 += width;
        }
        assert!(blocked
            .data()
            .iter()
            .zip(single.data())
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn t_matmul_wide_tiling_bit_identical_to_untiled_loop() {
        // Column tiling must not change per-element accumulation order
        // or the a == 0.0 skip; widths cover one tile, a tile boundary,
        // and a ragged tail tile.
        let mut r = Rng::seed_from(66);
        for m in [17usize, 64, 70, 150] {
            let mut a = Mat::randn(40, 23, &mut r);
            // Inject exact zeros so the sparse skip is exercised.
            for i in 0..40 {
                a[(i, i % 23)] = 0.0;
            }
            let b = Mat::randn(40, m, &mut r);
            let mut tiled = Mat::from_fn(23, m, |_, _| f64::NAN);
            a.t_matmul_into(&b, &mut tiled);
            // Untiled reference: the narrow-path loop, verbatim — over
            // the same dispatched axpy rows so the comparison stays
            // within whatever SIMD mode this process runs.
            let kd = simd::dispatch();
            let mut want = Mat::zeros(23, m);
            for p in 0..40 {
                let arow = a.row(p).to_vec();
                let brow = b.row(p).to_vec();
                for (i, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    kd.axpy(want.row_mut(i), av, &brow);
                }
            }
            assert!(
                want.data().iter().zip(tiled.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "cols={m}"
            );
        }
    }

    #[test]
    fn matmul_packed_bit_identical_to_matmul_into() {
        // Packing B panels into the stride-8 scratch must be
        // bit-invisible: same per-element update sequence, relocated
        // operand bytes. Shapes cover thin, split-panel, full-8, and
        // wide/ragged panels; the PackBuf is shared across shapes to
        // prove stale scratch contents never leak.
        let mut r = Rng::seed_from(67);
        let mut pack = PackBuf::new();
        for (n, k, m) in
            [(9usize, 30usize, 8usize), (19, 27, 3), (11, 700, 20), (7, 64, 33), (1, 5, 17)]
        {
            let a = Mat::randn(n, k, &mut r);
            let b = Mat::randn(k, m, &mut r);
            let mut want = Mat::from_fn(n, m, |_, _| f64::NAN);
            a.matmul_into(&b, &mut want);
            let mut got = Mat::from_fn(n, m, |_, _| f64::NAN);
            a.matmul_packed_into(&b, &mut pack, &mut got);
            assert!(
                want.data().iter().zip(got.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "n={n} k={k} m={m}"
            );
        }
    }

    #[test]
    fn matmul_packed_handles_degenerate_shapes() {
        let mut pack = PackBuf::new();
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let mut out = Mat::from_fn(3, 4, |_, _| f64::NAN);
        a.matmul_packed_into(&b, &mut pack, &mut out);
        assert!(out.data().iter().all(|&x| x == 0.0), "k=0 must zero the output");
        let a = Mat::zeros(3, 5);
        let b = Mat::zeros(5, 0);
        let mut out = Mat::zeros(3, 0);
        a.matmul_packed_into(&b, &mut pack, &mut out);
    }

    #[test]
    fn fill_scaled_from_bit_identical_to_copy_then_scale() {
        let mut r = Rng::seed_from(68);
        let src = Mat::randn(6, 9, &mut r);
        let mut want = Mat::zeros(6, 9);
        want.copy_from(&src);
        want.scale(-0.75);
        let mut got = Mat::from_fn(6, 9, |_, _| f64::NAN);
        got.fill_scaled_from(-0.75, &src);
        assert!(want.data().iter().zip(got.data()).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn matmul_into_bit_identical_with_dirty_buffer() {
        // The `_into` form must fully overwrite a garbage-filled output
        // and agree bit-for-bit with the allocating form, across every
        // kernel dispatch band (thin, split-panel, wide).
        let mut r = Rng::seed_from(61);
        for m in [1usize, 3, 8, 9, 11, 16, 17, 33] {
            let a = Mat::randn(19, 27, &mut r);
            let b = Mat::randn(27, m, &mut r);
            let want = a.matmul(&b);
            let mut out = Mat::from_fn(19, m, |_, _| f64::NAN);
            a.matmul_into(&b, &mut out);
            assert!(
                want.data().iter().zip(out.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "cols={m}"
            );
        }
    }

    #[test]
    fn t_matmul_and_transpose_into_bit_identical() {
        let mut r = Rng::seed_from(62);
        let a = Mat::randn(13, 7, &mut r);
        let b = Mat::randn(13, 4, &mut r);
        let want = a.t_matmul(&b);
        let mut out = Mat::from_fn(7, 4, |_, _| f64::NAN);
        a.t_matmul_into(&b, &mut out);
        assert_eq!(want, out);

        let want_t = a.t();
        let mut tout = Mat::from_fn(7, 13, |_, _| f64::NAN);
        a.transpose_into(&mut tout);
        assert_eq!(want_t, tout);
    }

    #[test]
    fn add_scaled_into_and_copy_from() {
        let mut r = Rng::seed_from(63);
        let a = Mat::randn(5, 4, &mut r);
        let b = Mat::randn(5, 4, &mut r);
        let mut out = Mat::from_fn(5, 4, |_, _| f64::NAN);
        a.add_scaled_into(-2.5, &b, &mut out);
        let want = {
            let mut w = a.clone();
            w.axpy(-2.5, &b);
            w
        };
        assert_eq!(want, out);

        let mut dst = Mat::zeros(5, 4);
        dst.copy_from(&a);
        assert_eq!(dst, a);
    }

    #[test]
    #[should_panic(expected = "output shape mismatch")]
    fn matmul_into_rejects_wrong_output_shape() {
        let a = Mat::zeros(3, 2);
        let b = Mat::zeros(2, 4);
        let mut out = Mat::zeros(3, 3);
        a.matmul_into(&b, &mut out);
    }

    #[test]
    fn is_finite_detects_nan() {
        let mut a = Mat::zeros(2, 2);
        assert!(a.is_finite());
        a[(0, 1)] = f64::NAN;
        assert!(!a.is_finite());
    }
}
