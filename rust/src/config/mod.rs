//! Experiment configuration: a TOML-subset parser + typed configs.
//!
//! No `serde`/`toml` offline, so [`ConfigMap`] parses the subset the
//! launcher needs: `key = value` lines, `[section]` headers (flattened to
//! `section.key`), `#` comments, strings/numbers/bools. Typed accessors
//! carry defaults so config files only state what they override.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Flat key → raw-string-value map with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct ConfigMap {
    entries: BTreeMap<String, String>,
}

impl ConfigMap {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            entries.insert(key, val);
        }
        Ok(ConfigMap { entries })
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Insert/override a key programmatically (CLI overrides).
    pub fn set(&mut self, key: &str, value: &str) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// usize with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config `{key}` = `{v}`: not a usize")),
        }
    }

    /// f64 with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config `{key}` = `{v}`: not a number")),
        }
    }

    /// bool with default (`true/false/1/0/yes/no`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => bail!("config `{key}` = `{other}`: not a bool"),
            },
        }
    }

    /// Comma-separated usize list with default.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .with_context(|| format!("config `{key}`: bad element `{s}`"))
                })
                .collect(),
        }
    }

    /// All keys (for validation / debugging).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig1"
seed = 7

[deepca]
consensus_rounds = 8
tol = 1e-9
sign_adjust = true

[sweep]
ks = 1, 3, 5, 8
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", "x"), "fig1");
        assert_eq!(c.usize_or("seed", 0).unwrap(), 7);
        assert_eq!(c.usize_or("deepca.consensus_rounds", 0).unwrap(), 8);
        assert!((c.f64_or("deepca.tol", 0.0).unwrap() - 1e-9).abs() < 1e-24);
        assert!(c.bool_or("deepca.sign_adjust", false).unwrap());
        assert_eq!(c.usize_list_or("sweep.ks", &[]).unwrap(), vec![1, 3, 5, 8]);
    }

    #[test]
    fn defaults_apply() {
        let c = ConfigMap::parse("").unwrap();
        assert_eq!(c.usize_or("missing", 42).unwrap(), 42);
        assert_eq!(c.str_or("missing", "d"), "d");
        assert!(!c.bool_or("missing", false).unwrap());
        assert_eq!(c.usize_list_or("missing", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn cli_override() {
        let mut c = ConfigMap::parse(SAMPLE).unwrap();
        c.set("deepca.consensus_rounds", "12");
        assert_eq!(c.usize_or("deepca.consensus_rounds", 0).unwrap(), 12);
    }

    #[test]
    fn bad_types_error() {
        let c = ConfigMap::parse("x = notanumber").unwrap();
        assert!(c.usize_or("x", 0).is_err());
        assert!(c.f64_or("x", 0.0).is_err());
        assert!(c.bool_or("x", false).is_err());
    }

    #[test]
    fn bad_lines_error() {
        assert!(ConfigMap::parse("just a line without equals").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = ConfigMap::parse("# only a comment\n\n  \n").unwrap();
        assert_eq!(c.keys().count(), 0);
    }
}
