//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** (Blackman & Vigna) seeded through SplitMix64 — the standard
//! pairing recommended by the xoshiro authors. Deterministic across
//! platforms, which the experiment harness relies on for reproducibility:
//! every figure in EXPERIMENTS.md records its seed.

/// xoshiro256** PRNG with Box–Muller Gaussian sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the most recent Box–Muller pair.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits of the top of the stream.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Guard against log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with given mean/stddev.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (for per-agent streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed_from(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::seed_from(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(5);
        let idx = r.sample_indices(100, 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::seed_from(77);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
