//! Small shared utilities: PRNG, timing, and formatting helpers.
//!
//! The offline build image ships no `rand`/`criterion`/`log` stack, so the
//! pieces we need are implemented here (see DESIGN.md §8).

pub mod rng;
pub mod timer;
pub mod format;
