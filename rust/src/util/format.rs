//! Human-readable number / table formatting for experiment reports.

/// Format seconds adaptively (ns/µs/ms/s).
pub fn secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Format a byte count adaptively.
pub fn bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b < KIB {
        format!("{b:.0}B")
    } else if b < KIB * KIB {
        format!("{:.1}KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.1}MiB", b / KIB / KIB)
    } else {
        format!("{:.2}GiB", b / KIB / KIB / KIB)
    }
}

/// Format a float in scientific notation with 3 significant digits.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else {
        format!("{x:.2e}")
    }
}

/// Render a simple aligned text table: `header` then `rows`.
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_units() {
        assert!(secs(5e-9).ends_with("ns"));
        assert!(secs(5e-6).ends_with("µs"));
        assert!(secs(5e-3).ends_with("ms"));
        assert!(secs(5.0).ends_with('s'));
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512B");
        assert!(bytes(2048).contains("KiB"));
        assert!(bytes(3 * 1024 * 1024).contains("MiB"));
    }

    #[test]
    fn sci_zero_and_value() {
        assert_eq!(sci(0.0), "0");
        assert!(sci(1234.0).contains('e'));
    }

    #[test]
    fn table_aligns() {
        let t = table(
            &["name", "val"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer".into(), "22".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
