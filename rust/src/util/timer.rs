//! Wall-clock timing helpers used by the bench harness and experiments.

use std::time::Instant;

/// Measure the wall time of `f` in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A started wall-clock timer.
///
/// This (plus [`time_it`]/[`Stopwatch`]) is the crate's only sanctioned
/// way to read the wall clock: `cargo xtask lint`'s `timing` rule bans
/// `Instant::now`/`SystemTime` everywhere except this module and the
/// bench harness, so elapsed-time plumbing stays behind one auditable
/// seam.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    t0: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { t0: Instant::now() }
    }

    /// Seconds elapsed since [`Timer::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Whole nanoseconds elapsed since [`Timer::start`].
    ///
    /// This is the flight recorder's timestamp source
    /// ([`crate::obs::trace`] reads a process-epoch `Timer` through it) —
    /// trace timestamps stay behind the same auditable seam as every
    /// other wall-clock read.
    #[inline]
    pub fn elapsed_nanos(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }
}

/// Simple accumulating stopwatch for profiling sections of a hot loop.
#[derive(Default, Debug, Clone)]
pub struct Stopwatch {
    total: f64,
    count: u64,
}

impl Stopwatch {
    /// Time one invocation of `f`, accumulating into this stopwatch.
    pub fn measure<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total += t0.elapsed().as_secs_f64();
        self.count += 1;
        out
    }

    /// Total accumulated seconds.
    pub fn total_secs(&self) -> f64 {
        self.total
    }

    /// Number of measured invocations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean seconds per invocation (0 if never used).
    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(secs >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::default();
        for _ in 0..3 {
            sw.measure(|| std::hint::black_box(1 + 1));
        }
        assert_eq!(sw.count(), 3);
        assert!(sw.total_secs() >= 0.0);
        assert!(sw.mean_secs() <= sw.total_secs() + 1e-12);
    }
}
