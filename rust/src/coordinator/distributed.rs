//! Fully-distributed DeEPCA: one thread per agent, channels per edge.
//!
//! Each agent thread executes the complete Algorithm-1 loop on its
//! private state — tracking update, K channel-level gossip exchanges,
//! QR + SignAdjust — with *no shared memory* between agents. A telemetry
//! channel streams per-iteration `(S_j, W_j)` snapshots to the leader,
//! which computes the Figure 1–2 metrics offline. Message payloads are
//! byte-counted per agent and merged at join time.
//!
//! Integration tests pin this engine's output to the leader-driven
//! dense engine (via the `Session` builder) to ~1e-9 (the engines
//! accumulate neighbor contributions in different orders, so agreement
//! is to fp round-off, not bit-for-bit).

use super::agent::AgentState;
use crate::algo::deepca::DeepcaConfig;
use crate::algo::metrics::{RunOutput, RunRecorder};
use crate::algo::problem::Problem;
use crate::consensus::metrics::CommStats;
use crate::consensus::AgentStack;
use crate::exec::Executor;
use crate::graph::gossip::GossipMatrix;
use crate::graph::topology::Topology;
use crate::linalg::Mat;
use crate::util::timer::Timer;
use std::sync::mpsc;

/// Telemetry sample sent by an agent each iteration.
struct Telemetry {
    agent: usize,
    iter: usize,
    s: Mat,
    w: Mat,
}

/// Run DeEPCA with every agent in its own thread.
///
/// Returns the usual [`RunOutput`] plus a populated recorder. `tol`-based
/// early stopping is not available in this engine (there is no global
/// barrier to broadcast a stop decision through); use `max_iters`.
pub fn run_deepca_distributed(
    problem: &Problem,
    topo: &Topology,
    cfg: &DeepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let m = problem.m();
    assert_eq!(topo.n(), m, "topology/problem size mismatch");
    let gossip = GossipMatrix::from_laplacian(topo);
    let eta = gossip.chebyshev_eta();

    let w0 = problem.initial_w(cfg.init_seed);
    let (d, k) = w0.shape();
    let u = problem.u();
    let rounds = cfg.consensus_rounds;
    let iters = cfg.max_iters;

    // Edge channels: senders[i] -> (dest j, tx), receivers[j] -> (src i, rx).
    // One channel per directed edge for the entire run; mpsc ordering
    // makes rounds and iterations self-synchronizing.
    let mut senders: Vec<Vec<(usize, mpsc::Sender<Vec<f64>>)>> =
        (0..m).map(|_| Vec::new()).collect();
    let mut receivers: Vec<Vec<(usize, mpsc::Receiver<Vec<f64>>)>> =
        (0..m).map(|_| Vec::new()).collect();
    for i in 0..m {
        for &j in topo.neighbors(i) {
            let (tx, rx) = mpsc::channel();
            senders[i].push((j, tx));
            receivers[j].push((i, rx));
        }
    }
    let (tele_tx, tele_rx) = mpsc::channel::<Telemetry>();

    let weights = &gossip.weights;
    let t0 = Timer::start();

    // Agent threads come from the executor's blocking tier — one
    // dedicated persistent thread per task (agents park on channel
    // `recv` mid-round, so they need real threads, not pool slots).
    // The leader's telemetry loop rides along as one more blocking
    // task; `scoped_blocking` returns once every agent *and* the
    // leader have finished, which is what keeps the `'env` borrows
    // (recorder, result slots) sound.
    let exec = Executor::sequential();
    let mut agent_results: Vec<Option<(Mat, u64)>> = (0..m).map(|_| None).collect();
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(m + 1);
        for ((j, (outs, ins)), slot) in senders
            .drain(..)
            .zip(receivers.drain(..))
            .enumerate()
            .zip(agent_results.iter_mut())
        {
            let local = problem.locals[j].clone();
            let w0j = w0.clone();
            let wrow: Vec<f64> = weights.row(j).to_vec();
            let tele = tele_tx.clone();
            let use_sign = cfg.sign_adjust;
            tasks.push(Box::new(move || {
                let mut st = AgentState::init(j, local, w0j);
                let mut scalars: u64 = 0;
                // Per-thread recursion buffers, reused across all
                // iterations (payload Vecs per message remain — they
                // model real serialization).
                let mut prev = st.s.clone();
                let mut cur = st.s.clone();
                let mut next = Mat::zeros(d, k);
                for t in 0..iters {
                    // (3.1) local tracking update.
                    st.tracking_update();
                    // (3.2) K gossip rounds on S_j (FastMix recursion).
                    prev.copy_from(&st.s);
                    cur.copy_from(&st.s);
                    for _r in 0..rounds {
                        let payload = cur.data().to_vec();
                        for (_to, tx) in &outs {
                            tx.send(payload.clone()).expect("peer alive");
                            scalars += (d * k) as u64;
                        }
                        next.copy_from(&cur);
                        next.scale(wrow[j]);
                        for (from, rx) in &ins {
                            let data = rx.recv().expect("peer alive");
                            next.axpy(wrow[*from], &Mat::from_vec(d, k, data));
                        }
                        next.scale(1.0 + eta);
                        next.axpy(-eta, &prev);
                        std::mem::swap(&mut prev, &mut cur);
                        std::mem::swap(&mut cur, &mut next);
                    }
                    st.s.copy_from(&cur);
                    // (3.3) orthonormalize + sign adjust.
                    st.orthonormalize(use_sign);
                    // Telemetry (leader-side metrics only; not part of the
                    // algorithm's communication budget).
                    tele.send(Telemetry { agent: j, iter: t, s: st.s.clone(), w: st.w.clone() })
                        .ok();
                }
                *slot = Some((st.w, scalars));
            }));
        }
        drop(tele_tx);

        // Leader task: assemble per-iteration snapshots as they stream
        // in; `tele_rx.iter()` ends once every agent has dropped its
        // telemetry sender.
        let rec = &mut *recorder;
        let u_ref = &u;
        tasks.push(Box::new(move || {
            let mut pending: Vec<Vec<Option<(Mat, Mat)>>> =
                (0..iters).map(|_| (0..m).map(|_| None).collect()).collect();
            let mut complete = vec![0usize; iters];
            for tele in tele_rx.iter() {
                let Telemetry { agent, iter, s, w } = tele;
                pending[iter][agent] = Some((s, w));
                complete[iter] += 1;
                if complete[iter] == m {
                    // Communication to date: (iter+1) mixes of `rounds` rounds.
                    let mut stats_for_record = CommStats::default();
                    stats_for_record.mixes = (iter + 1) as u64;
                    stats_for_record.rounds = ((iter + 1) * rounds) as u64;
                    if rec.should_record(iter) {
                        let ss = AgentStack::new(
                            pending[iter].iter().map(|p| p.as_ref().unwrap().0.clone()).collect(),
                        );
                        let ws = AgentStack::new(
                            pending[iter].iter().map(|p| p.as_ref().unwrap().1.clone()).collect(),
                        );
                        rec.record(iter, u_ref, &ws, Some(&ss), &stats_for_record, t0.elapsed_secs());
                    } else {
                        rec.record_cheap(iter, &stats_for_record, t0.elapsed_secs());
                    }
                    pending[iter].iter_mut().for_each(|p| *p = None); // free
                }
            }
        }));

        // Blocks until agents and leader all finish; an agent panic
        // drops its channel endpoints, unwinding its peers, and is
        // re-raised here after every task has ended.
        exec.scoped_blocking(tasks);
    }

    // Records may arrive out of iteration order; sort.
    recorder.records.sort_by_key(|r| r.iter);

    let mut total_scalars = 0u64;
    let mut final_slices = Vec::with_capacity(m);
    for res in agent_results {
        let (wj, scalars) = res.expect("agent task completed");
        final_slices.push(wj);
        total_scalars += scalars;
    }
    let final_w = AgentStack::new(final_slices);
    let mut comm = CommStats::default();
    comm.mixes = iters as u64;
    comm.rounds = (iters * rounds) as u64;
    comm.messages = (iters * rounds * 2 * topo.num_edges()) as u64;
    // Scalar counts were measured per agent thread; route them through
    // the measured-bytes accessor (one accounting path, no hard-coded
    // payload width).
    comm.record_measured(total_scalars, total_scalars * std::mem::size_of::<f64>() as u64);

    let diverged = !final_w.is_finite();
    RunOutput {
        iters,
        final_tan_theta: recorder.final_tan_theta(),
        comm,
        final_w,
        elapsed_secs: t0.elapsed_secs(),
        diverged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::solver::Algo;
    use crate::coordinator::session::Session;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Problem, Topology) {
        let ds = synthetic::spiked_covariance(
            300,
            12,
            &[9.0, 6.0],
            0.3,
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 6, 2);
        let topo = Topology::erdos_renyi(6, 0.6, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn distributed_converges() {
        let (p, topo) = setup(211);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 80, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_deepca_distributed(&p, &topo, &cfg, &mut rec);
        assert!(!out.diverged);
        assert!(out.final_tan_theta < 1e-9, "tanθ={}", out.final_tan_theta);
        assert_eq!(rec.records.len(), 80);
    }

    #[test]
    fn matches_leader_driven_engine() {
        let (p, topo) = setup(212);
        let cfg = DeepcaConfig { consensus_rounds: 6, max_iters: 25, ..Default::default() };
        let mut rec_a = RunRecorder::every_iteration();
        let dist = run_deepca_distributed(&p, &topo, &cfg, &mut rec_a);
        let dense = Session::on(&p, &topo).algo(Algo::Deepca(cfg)).solve();
        assert!(
            dist.final_w.distance(&dense.final_w) < 1e-9,
            "engines disagree by {}",
            dist.final_w.distance(&dense.final_w)
        );
        // Metric traces agree too.
        for (a, b) in rec_a.records.iter().zip(&dense.trace.records) {
            assert!((a.mean_tan_theta - b.mean_tan_theta).abs() < 1e-9 * (1.0 + a.mean_tan_theta));
            assert!((a.s_deviation - b.s_deviation).abs() < 1e-9 * (1.0 + a.s_deviation));
        }
    }

    #[test]
    fn byte_accounting_consistent() {
        let (p, topo) = setup(213);
        let cfg = DeepcaConfig { consensus_rounds: 4, max_iters: 7, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_deepca_distributed(&p, &topo, &cfg, &mut rec);
        let expect = (7 * 4 * 2 * topo.num_edges() * 12 * 2) as u64;
        assert_eq!(out.comm.scalars_sent, expect);
        assert_eq!(out.comm.bytes_sent, expect * 8);
    }

    #[test]
    fn records_sorted_by_iter() {
        let (p, topo) = setup(214);
        let cfg = DeepcaConfig { consensus_rounds: 5, max_iters: 12, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let _ = run_deepca_distributed(&p, &topo, &cfg, &mut rec);
        for win in rec.records.windows(2) {
            assert!(win[0].iter < win[1].iter);
        }
    }
}
