//! Per-agent private state for the distributed runtime.

use crate::algo::sign_adjust::sign_adjust;
use crate::linalg::qr::orth;
use crate::linalg::Mat;

/// Everything agent j owns in Algorithm 1.
#[derive(Clone, Debug)]
pub struct AgentState {
    /// This agent's id.
    pub id: usize,
    /// Local matrix `A_j` (private to the agent — never transmitted).
    pub local: Mat,
    /// Tracked variable `S_j`.
    pub s: Mat,
    /// Current orthonormal iterate `W_j`.
    pub w: Mat,
    /// Cached previous product `G_j = A_j W_j^{t−1}`.
    pub g_prev: Mat,
    /// The shared reference `W⁰` for SignAdjust.
    pub w0: Mat,
}

impl AgentState {
    /// Algorithm-1 initialization: `S_j = W_j = W⁰`, `A_j W^{-1} := W⁰`.
    pub fn init(id: usize, local: Mat, w0: Mat) -> Self {
        AgentState {
            id,
            local,
            s: w0.clone(),
            w: w0.clone(),
            g_prev: w0.clone(),
            w0,
        }
    }

    /// Eqn. 3.1: the local tracking update (one `A_j·W` product).
    /// Returns nothing; mutates `s` and refreshes the cached product.
    pub fn tracking_update(&mut self) {
        let g = self.local.matmul(&self.w);
        self.s.axpy(1.0, &g);
        self.s.axpy(-1.0, &self.g_prev);
        self.g_prev = g;
    }

    /// Eqn. 3.3: orthonormalize the (post-mix) `S_j` into `W_j`.
    pub fn orthonormalize(&mut self, use_sign_adjust: bool) {
        let q = orth(&self.s);
        self.w = if use_sign_adjust {
            sign_adjust(&q, &self.w0)
        } else {
            q
        };
    }

    /// DePCA's local step (no tracking): `S_j ← A_j W_j`.
    pub fn power_step(&mut self) {
        self.s = self.local.matmul(&self.w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn state(seed: u64) -> AgentState {
        let mut rng = Rng::seed_from(seed);
        let g = Mat::randn(8, 8, &mut rng);
        let mut a = g.t_matmul(&g);
        a.symmetrize();
        let w0 = Mat::rand_orthonormal(8, 2, &mut rng);
        AgentState::init(0, a, w0)
    }

    #[test]
    fn init_replicates_w0() {
        let st = state(201);
        assert_eq!(st.s.data(), st.w0.data());
        assert_eq!(st.w.data(), st.w0.data());
        assert_eq!(st.g_prev.data(), st.w0.data());
    }

    #[test]
    fn first_tracking_update_matches_formula() {
        let mut st = state(202);
        let expect = {
            // S¹ = W⁰ + A W⁰ − W⁰ = A W⁰.
            st.local.matmul(&st.w0)
        };
        st.tracking_update();
        assert!((&st.s - &expect).fro_norm() < 1e-12);
        assert!((&st.g_prev - &expect).fro_norm() < 1e-12);
    }

    #[test]
    fn orthonormalize_produces_orthonormal_w() {
        let mut st = state(203);
        st.tracking_update();
        st.orthonormalize(true);
        let g = st.w.t_matmul(&st.w);
        assert!((&g - &Mat::eye(2)).fro_norm() < 1e-10);
    }

    #[test]
    fn power_step_overwrites_s() {
        let mut st = state(204);
        st.power_step();
        let expect = st.local.matmul(&st.w);
        assert!((&st.s - &expect).fro_norm() < 1e-12);
    }

    #[test]
    fn tracking_telescopes() {
        // After two updates with unchanged W, S gains A·W − A·W = 0 net
        // beyond the first injection.
        let mut st = state(205);
        st.tracking_update();
        let s1 = st.s.clone();
        st.tracking_update(); // W unchanged → G == G_prev → S unchanged
        assert!((&st.s - &s1).fro_norm() < 1e-12);
    }
}
