//! `OnlineSession` — warm-started DeEPCA over live data streams.
//!
//! The paper's core trick is *subspace tracking*: because each power
//! iteration warm-starts from the previous subspace, a fixed,
//! precision-independent number of FastMix rounds per iteration suffices
//! (Theorem 1). This driver makes that claim operational on *drifting*
//! data: per stream epoch each agent ingests a fresh batch into its
//! [`CovTracker`], and one short warm-started DeEPCA run (a small
//! constant `power_iters × consensus_rounds` budget, reusing the
//! previous epoch's `W`) re-tracks the moving subspace. A cold-start
//! baseline with the *same* per-epoch budget cannot hold the tracking
//! error down — the contrast `experiment tracking` tabulates.
//!
//! The driver is engine-agnostic: each epoch's inner run goes through
//! the ordinary [`Session`] builder, so the same stream scenario runs on
//! [`Engine::Dense`], [`Engine::Threaded`], [`Engine::Sim`] (drift plus
//! packet drops/latency/noise together), or [`Engine::Sparse`]
//! (fleet-scale CSR gossip — the epoch loop rebuilds the Metropolis
//! weights from each epoch's topology, so it composes with a
//! [`TopologySchedule`] like every other engine). An optional
//! [`TopologySchedule`] additionally re-draws the network once per
//! stream epoch — unlike [`Session::schedule`] this works on *every*
//! engine, because the epoch topology is materialized before the inner
//! run starts.
//!
//! Per epoch the driver records the tracking metrics the streaming
//! evaluation needs: mean principal angle against the **oracle**
//! drifting subspace (when the source knows it), the angle against the
//! current empirical aggregate's top-k, and the communication spent
//! (gossip rounds, virtual time, drops).

use crate::algo::deepca::DeepcaConfig;
use crate::algo::problem::Problem;
use crate::algo::solver::{mean_tan_theta, Algo, Engine};
use crate::consensus::metrics::CommStats;
use crate::consensus::simnet::SimConfig;
use crate::consensus::AgentStack;
use crate::coordinator::session::Session;
use crate::exec::Executor;
use std::sync::Arc;
use crate::graph::dynamic::TopologySchedule;
use crate::graph::topology::Topology;
use crate::linalg::Mat;
use crate::stream::cov::{CovTracker, Forgetting};
use crate::stream::source::StreamSource;

/// Knobs for an online run.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Stream epochs to run.
    pub epochs: usize,
    /// FastMix rounds K per power iteration (constant — the headline
    /// knob stays precision-independent in the streaming setting too).
    pub consensus_rounds: usize,
    /// Power iterations per epoch (the whole point of warm-starting is
    /// that a small constant suffices).
    pub power_iters: usize,
    /// Reuse the previous epoch's `W` (true) or restart every epoch from
    /// a fresh random iterate with the same budget (the baseline).
    pub warm_start: bool,
    /// Per-agent covariance memory policy.
    pub forgetting: Forgetting,
    /// Seed for the (cold) initial iterates; epoch e uses `seed + e` so
    /// the baseline redraws honestly.
    pub init_seed: u64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            epochs: 40,
            consensus_rounds: 8,
            power_iters: 2,
            warm_start: true,
            forgetting: Forgetting::Exponential(0.7),
            init_seed: 2021,
        }
    }
}

/// Tracking metrics for one stream epoch.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    /// Stream epoch (0-based).
    pub epoch: u64,
    /// Mean `tan θ_k` of the per-agent iterates against the **oracle**
    /// drifting subspace (NaN when the source has no oracle).
    pub oracle_tan_theta: f64,
    /// Mean `tan θ_k` against the current empirical aggregate's top-k
    /// (what the inner solver can actually reach).
    pub empirical_tan_theta: f64,
    /// Gossip rounds spent this epoch.
    pub rounds: u64,
    /// Virtual clock ticks this epoch (SimNet engine; 0 elsewhere).
    pub virtual_time: u64,
    /// Messages dropped this epoch (SimNet engine; 0 elsewhere).
    pub dropped: u64,
    /// Whether the inner run tripped the divergence guard.
    pub diverged: bool,
    /// Wall seconds inside the inner solver.
    pub elapsed_secs: f64,
}

/// Result of an online run.
#[derive(Clone, Debug)]
pub struct OnlineReport {
    /// Source label (scenario + shape).
    pub scenario: String,
    /// Per-epoch tracking metrics.
    pub records: Vec<EpochRecord>,
    /// Communication totals across all epochs (`epochs` counted).
    pub comm: CommStats,
    /// Final per-agent iterates.
    pub final_w: AgentStack,
}

impl OnlineReport {
    /// Largest oracle tracking error over epochs `burn_in..` (NaN when
    /// the tail is empty or the source had no oracle, matching
    /// [`OnlineReport::mean_oracle_after`] — `f64::max` would silently
    /// drop the NaN records and report a fabricated 0.0).
    pub fn max_oracle_after(&self, burn_in: usize) -> f64 {
        let mut max = f64::NEG_INFINITY;
        let mut any = false;
        for r in self.records.iter().skip(burn_in) {
            if r.oracle_tan_theta.is_nan() {
                return f64::NAN;
            }
            any = true;
            max = max.max(r.oracle_tan_theta);
        }
        if any {
            max
        } else {
            f64::NAN
        }
    }

    /// Mean oracle tracking error over epochs `burn_in..`.
    pub fn mean_oracle_after(&self, burn_in: usize) -> f64 {
        let tail: Vec<f64> = self
            .records
            .iter()
            .skip(burn_in)
            .map(|r| r.oracle_tan_theta)
            .collect();
        if tail.is_empty() {
            f64::NAN
        } else {
            tail.iter().sum::<f64>() / tail.len() as f64
        }
    }

    /// Per-epoch CSV (the streaming analogue of
    /// [`crate::algo::metrics::RunRecorder::to_csv`]).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "epoch,oracle_tan_theta,empirical_tan_theta,rounds,virtual_time,dropped,elapsed_secs\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6e},{:.6e},{},{},{},{:.6e}\n",
                r.epoch,
                r.oracle_tan_theta,
                r.empirical_tan_theta,
                r.rounds,
                r.virtual_time,
                r.dropped,
                r.elapsed_secs
            ));
        }
        out
    }
}

/// Fluent builder for one online run over a stream source.
pub struct OnlineSession<'a> {
    topo: &'a Topology,
    engine: Engine,
    cfg: OnlineConfig,
    schedule: Option<TopologySchedule>,
    threads: Option<usize>,
    exec: Option<Arc<Executor>>,
    trace: Option<std::path::PathBuf>,
}

impl<'a> OnlineSession<'a> {
    /// Start an online session over a base network.
    pub fn on(topo: &'a Topology) -> Self {
        OnlineSession {
            topo,
            engine: Engine::Dense,
            cfg: OnlineConfig::default(),
            schedule: None,
            threads: None,
            exec: None,
            trace: None,
        }
    }

    /// Capture a flight-recorder trace of the whole stream run —
    /// per-epoch ingest/refresh/solve spans plus everything the inner
    /// sessions record — and write it to `path` when the run finishes
    /// (`.json` → Chrome Trace Format, else JSONL). Mirror of
    /// [`Session::trace`].
    pub fn trace(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Size the worker pool shared across every epoch: the per-agent
    /// covariance refreshes and all inner solves run on one persistent
    /// executor (passthrough of [`Session::threads`] — same defaults,
    /// same bit-identical-for-any-thread-count guarantee).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Share an existing executor (e.g. one pool across a whole sweep
    /// of online runs) instead of building one per run. Overrides
    /// [`OnlineSession::threads`] — mirror of [`Session::executor`].
    pub fn executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = Some(exec);
        self
    }

    /// Select the execution engine for the inner per-epoch runs.
    ///
    /// [`Engine::Distributed`] is rejected: it would drive only the
    /// first (cold) epoch, while every warm-started epoch silently
    /// falls back to [`Engine::Threaded`] inside [`Session`] — one run
    /// mixing two runtimes. Use [`Engine::Threaded`] directly.
    pub fn engine(mut self, engine: Engine) -> Self {
        assert!(
            engine != Engine::Distributed,
            "Engine::Distributed cannot drive online epochs (warm-started \
             epochs would silently fall back to Threaded) — use Engine::Threaded"
        );
        self.engine = engine;
        self
    }

    /// Set the online configuration.
    pub fn config(mut self, cfg: OnlineConfig) -> Self {
        assert!(cfg.epochs >= 1, "need at least one epoch");
        assert!(cfg.power_iters >= 1, "need at least one power iteration");
        self.cfg = cfg;
        self
    }

    /// Re-draw the network once per stream epoch from a schedule
    /// (honored on every engine: the epoch's topology is materialized
    /// before the inner run starts).
    pub fn schedule(mut self, schedule: TopologySchedule) -> Self {
        assert_eq!(
            schedule.n(),
            self.topo.n(),
            "schedule/topology node count mismatch"
        );
        self.schedule = Some(schedule);
        self
    }

    /// Drive the stream: per epoch, ingest one batch per agent, rebuild
    /// the local covariances, run a short (warm-started) DeEPCA session,
    /// and record tracking metrics.
    pub fn run(mut self, source: &mut dyn StreamSource) -> OnlineReport {
        let trace_path = self.trace.take();
        if trace_path.is_some() {
            crate::obs::trace::enable(crate::obs::trace::DEFAULT_CAPACITY);
        }
        let m = source.m();
        let d = source.dim();
        let k = source.k();
        assert_eq!(m, self.topo.n(), "stream/topology agent count mismatch");

        let mut trackers: Vec<CovTracker> =
            (0..m).map(|_| CovTracker::new(d, self.cfg.forgetting)).collect();
        let scenario = source.label();
        let mut records = Vec::with_capacity(self.cfg.epochs);
        let mut comm = CommStats::default();
        let mut prev_w: Option<AgentStack> = None;
        let mut final_w: Option<AgentStack> = None;
        // Epoch-persistent covariance buffers: refreshed in place each
        // epoch (`covariance_into`), lent to the epoch's `Problem`, and
        // reclaimed after the inner run — the refresh itself allocates
        // nothing (the `Problem`'s ground-truth eigensolve still does).
        let mut locals: Vec<Mat> = (0..m).map(|_| Mat::zeros(d, d)).collect();
        // One persistent pool for the whole run (or for a whole sweep,
        // when the caller shares one): per-agent covariance refreshes
        // and every epoch's inner solve share it.
        let exec = match &self.exec {
            Some(e) => Arc::clone(e),
            None => Arc::new(Executor::new(self.threads.unwrap_or(0))),
        };

        for e in 0..self.cfg.epochs {
            let _span_epoch = crate::trace_span!(Epoch, e as u64);
            {
                let _span = crate::trace_span!(Ingest, e as u64, m as u64);
                for (j, tracker) in trackers.iter_mut().enumerate() {
                    tracker.observe(&source.next_batch(j));
                }
            }
            {
                // Each agent's tracker writes only its own buffer —
                // deterministic under the fixed per-agent partitioning.
                let _span = crate::trace_span!(Refresh, e as u64, m as u64);
                let trackers = &trackers;
                exec.par_for_each_agent(&mut locals, |j, local| {
                    trackers[j].covariance_into(local)
                });
            }
            let problem = Problem::new(std::mem::take(&mut locals), k, &scenario);

            let epoch_topo = match self.schedule.as_mut() {
                Some(s) => s.topology_at_epoch(e as u64),
                None => self.topo.clone(),
            };
            // Sim engine: re-derive the fault seed per epoch so drops and
            // noise vary across epochs while staying replayable.
            let engine = match self.engine {
                Engine::Sim(c) => {
                    Engine::Sim(SimConfig { seed: c.seed.wrapping_add(e as u64), ..c })
                }
                other => other,
            };
            let deepca_cfg = DeepcaConfig {
                consensus_rounds: self.cfg.consensus_rounds,
                max_iters: self.cfg.power_iters,
                tol: 0.0,
                init_seed: self.cfg.init_seed.wrapping_add(e as u64),
                ..Default::default()
            };
            let mut session = Session::on(&problem, &epoch_topo)
                .engine(engine)
                .algo(Algo::Deepca(deepca_cfg))
                .executor(Arc::clone(&exec));
            if self.cfg.warm_start {
                if let Some(w) = &prev_w {
                    session = session.warm_start_from(w);
                }
            }
            let rep = {
                let _span = crate::trace_span!(EpochSolve, e as u64);
                session.solve()
            };

            let oracle_tan_theta = match source.oracle() {
                Some(u) => mean_tan_theta(&u, &rep.final_w),
                None => f64::NAN,
            };
            records.push(EpochRecord {
                epoch: source.epoch(),
                oracle_tan_theta,
                empirical_tan_theta: rep.final_tan_theta,
                rounds: rep.comm.rounds,
                virtual_time: rep.comm.virtual_time,
                dropped: rep.comm.dropped,
                diverged: rep.diverged,
                elapsed_secs: rep.elapsed_secs,
            });
            comm.merge(&rep.comm);
            comm.record_epoch();

            // Carry the subspace forward only while it is healthy; a
            // diverged epoch falls back to a cold restart.
            if rep.final_w.is_finite() {
                prev_w = Some(rep.final_w.clone());
            } else {
                prev_w = None;
            }
            final_w = Some(rep.final_w);
            // Reclaim the covariance buffers for the next epoch.
            locals = problem.locals;
            source.advance();
        }

        if let Some(path) = trace_path {
            crate::obs::trace::disable();
            let snap = crate::obs::trace::snapshot();
            if let Err(e) = crate::obs::export::write_auto(&path, &snap) {
                eprintln!("warning: could not write trace {}: {e}", path.display());
            }
        }
        OnlineReport {
            scenario,
            records,
            comm,
            final_w: final_w.expect("at least one epoch ran"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::source::{Drift, StreamParams, SyntheticStream};

    fn stream(drift: Drift, seed: u64) -> SyntheticStream {
        SyntheticStream::new(StreamParams {
            m: 6,
            dim: 12,
            batch: 60,
            spikes: vec![8.0, 4.0],
            noise: 0.3,
            drift,
            seed,
        })
    }

    #[test]
    fn stationary_online_converges_with_constant_budget() {
        let topo = Topology::ring(6);
        let mut src = stream(Drift::Stationary, 31);
        let report = OnlineSession::on(&topo)
            .config(OnlineConfig {
                epochs: 15,
                consensus_rounds: 8,
                power_iters: 3,
                warm_start: true,
                forgetting: Forgetting::Exponential(1.0),
                init_seed: 5,
            })
            .run(&mut src);
        assert_eq!(report.records.len(), 15);
        // Constant per-epoch round budget.
        for r in &report.records {
            assert_eq!(r.rounds, 8 * 3, "epoch {} spent {} rounds", r.epoch, r.rounds);
            assert!(!r.diverged);
        }
        assert_eq!(report.comm.rounds, 15 * 8 * 3);
        assert_eq!(report.comm.epochs, 15);
        // The iterate locks onto the empirical subspace…
        let last = report.records.last().unwrap();
        assert!(
            last.empirical_tan_theta < 1e-4,
            "empirical error: {:.3e}",
            last.empirical_tan_theta
        );
        // …and (with β=1 accumulating all data) approaches the oracle.
        assert!(
            last.oracle_tan_theta < 0.2,
            "oracle error: {:.3e}",
            last.oracle_tan_theta
        );
    }

    #[test]
    fn schedule_redraws_topology_per_epoch() {
        let topo = Topology::erdos_renyi(6, 0.6, &mut crate::util::rng::Rng::seed_from(77));
        let sched = TopologySchedule::markov(topo.clone(), 0.3, 0.5, 9, 1);
        let mut src = stream(Drift::Stationary, 33);
        let report = OnlineSession::on(&topo)
            .config(OnlineConfig {
                epochs: 8,
                consensus_rounds: 10,
                power_iters: 2,
                warm_start: true,
                forgetting: Forgetting::Exponential(1.0),
                init_seed: 5,
            })
            .schedule(sched)
            .run(&mut src);
        assert!(!report.records.iter().any(|r| r.diverged));
        assert!(report.records.last().unwrap().empirical_tan_theta < 1e-2);
    }

    #[test]
    fn online_run_is_thread_count_invariant() {
        let topo = Topology::ring(6);
        let run = |threads: usize| {
            let mut src = stream(Drift::Rotation { rate: 0.05 }, 37);
            OnlineSession::on(&topo)
                .threads(threads)
                .config(OnlineConfig {
                    epochs: 6,
                    consensus_rounds: 6,
                    power_iters: 2,
                    warm_start: true,
                    forgetting: Forgetting::Exponential(0.8),
                    init_seed: 5,
                })
                .run(&mut src)
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(
            a.final_w.distance(&b.final_w),
            0.0,
            "online runs must be bit-identical across thread counts"
        );
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.oracle_tan_theta.to_bits(), rb.oracle_tan_theta.to_bits());
            assert_eq!(ra.empirical_tan_theta.to_bits(), rb.empirical_tan_theta.to_bits());
        }
    }

    #[test]
    fn sparse_engine_tracks_like_dense_on_the_same_stream() {
        // Engine::Sparse (CSR Metropolis weights, Lanczos λ₂) is not
        // bit-identical to Dense (exact-spectrum weights), so parity is
        // subspace-level: the same drifting stream, topology, and
        // per-epoch budget must land both engines on the same empirical
        // subspace — and the sparse epoch loop must itself stay
        // bit-identical across thread counts.
        let topo =
            Topology::erdos_renyi(6, 0.6, &mut crate::util::rng::Rng::seed_from(91));
        let run = |engine: Engine, threads: usize| {
            let mut src = stream(Drift::Rotation { rate: 0.02 }, 41);
            OnlineSession::on(&topo)
                .engine(engine)
                .threads(threads)
                .config(OnlineConfig {
                    epochs: 8,
                    consensus_rounds: 12,
                    power_iters: 2,
                    warm_start: true,
                    forgetting: Forgetting::Exponential(0.8),
                    init_seed: 5,
                })
                .run(&mut src)
        };
        let dense = run(Engine::Dense, 1);
        let sparse = run(Engine::Sparse, 1);
        assert!(!sparse.records.iter().any(|r| r.diverged));
        // Identical round accounting: the engines differ in weights, not
        // in how many gossip rounds the budget buys.
        assert_eq!(dense.comm.rounds, sparse.comm.rounds);
        let dl = dense.records.last().unwrap().empirical_tan_theta;
        let sl = sparse.records.last().unwrap().empirical_tan_theta;
        assert!(dl < 5e-2, "dense tracking error: {dl:.3e}");
        assert!(sl < 5e-2, "sparse tracking error: {sl:.3e}");
        assert!(
            (dl - sl).abs() < 5e-2,
            "engines disagree on the tracked subspace: dense {dl:.3e} vs sparse {sl:.3e}"
        );
        let pooled = run(Engine::Sparse, 4);
        assert_eq!(
            sparse.final_w.distance(&pooled.final_w),
            0.0,
            "sparse epoch loop must be bit-identical across thread counts"
        );
    }

    #[test]
    #[should_panic(expected = "agent count mismatch")]
    fn rejects_topology_mismatch() {
        let topo = Topology::ring(4);
        let mut src = stream(Drift::Stationary, 35);
        let _ = OnlineSession::on(&topo).run(&mut src);
    }

    #[test]
    #[should_panic(expected = "cannot drive online epochs")]
    fn rejects_distributed_engine() {
        let topo = Topology::ring(6);
        let _ = OnlineSession::on(&topo).engine(Engine::Distributed);
    }
}
