//! Legacy leader-driven orchestration — superseded by
//! [`crate::coordinator::session::Session`] (the `SolverBuilder`).
//!
//! [`Leader`] and [`Algorithm`] are kept for one release as thin
//! deprecated wrappers that delegate to a `Session`, so downstream code
//! migrates on its own schedule while running on the new step-wise
//! driver (and therefore already gets the fresh-error stop criteria).

#![allow(deprecated)] // this module *is* the deprecated surface.

use crate::algo::deepca::DeepcaConfig;
use crate::algo::depca::DepcaConfig;
use crate::algo::metrics::{RunOutput, RunRecorder};
use crate::algo::problem::Problem;
use crate::algo::solver::Algo;
use crate::coordinator::session::Session;
use crate::graph::topology::Topology;

/// Re-export of the unified engine enum under its historical name.
pub use crate::algo::solver::Engine as EngineKind;

/// Which algorithm to run (legacy subset of [`Algo`]).
#[derive(Clone, Debug)]
#[deprecated(note = "use `algo::solver::Algo` with the `Session` builder")]
pub enum Algorithm {
    /// Paper Algorithm 1.
    Deepca(DeepcaConfig),
    /// Eqn. 3.4 baseline.
    Depca(DepcaConfig),
}

/// Leader: owns the problem/topology pair and dispatches runs.
#[deprecated(note = "use `Session::on(problem, topo)` (the SolverBuilder API)")]
pub struct Leader<'a> {
    /// Problem instance.
    pub problem: &'a Problem,
    /// Agent network.
    pub topo: &'a Topology,
    /// Engine selection.
    pub engine: EngineKind,
}

impl<'a> Leader<'a> {
    /// New leader with the default dense engine.
    pub fn new(problem: &'a Problem, topo: &'a Topology) -> Self {
        Leader { problem, topo, engine: EngineKind::Dense }
    }

    /// Select an engine.
    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Execute `algo`, filling `recorder`.
    pub fn run(&self, algo: &Algorithm, recorder: &mut RunRecorder) -> RunOutput {
        let unified = match algo {
            Algorithm::Deepca(cfg) => Algo::Deepca(cfg.clone()),
            Algorithm::Depca(cfg) => Algo::Depca(cfg.clone()),
        };
        let report = Session::on(self.problem, self.topo)
            .engine(self.engine)
            .algo(unified)
            .record(std::mem::take(recorder))
            .solve();
        let out = report.to_run_output();
        *recorder = report.trace;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Problem, Topology) {
        let ds = synthetic::spiked_covariance(
            300,
            10,
            &[8.0, 5.0],
            0.3,
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 5, 1);
        let topo = Topology::erdos_renyi(5, 0.7, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn all_engines_agree_deepca() {
        let (p, topo) = setup(221);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 30, ..Default::default() };
        let algo = Algorithm::Deepca(cfg);
        let mut outs = Vec::new();
        for engine in [
            EngineKind::Dense,
            EngineKind::DenseParallel,
            EngineKind::Threaded,
            EngineKind::Distributed,
        ] {
            let mut rec = RunRecorder::every_iteration();
            let out = Leader::new(&p, &topo).with_engine(engine).run(&algo, &mut rec);
            outs.push((engine, out));
        }
        let base = &outs[0].1;
        for (engine, out) in &outs[1..] {
            assert!(
                base.final_w.distance(&out.final_w) < 1e-8,
                "{engine:?} disagrees with Dense by {}",
                base.final_w.distance(&out.final_w)
            );
        }
    }

    #[test]
    fn depca_through_leader() {
        let (p, topo) = setup(222);
        let cfg = DepcaConfig::default();
        let mut rec = RunRecorder::every_iteration();
        let out = Leader::new(&p, &topo).run(&Algorithm::Depca(cfg), &mut rec);
        assert!(out.iters > 0);
        assert!(out.final_tan_theta.is_finite());
    }

    #[test]
    fn depca_distributed_falls_back() {
        let (p, topo) = setup(223);
        let cfg = DepcaConfig { max_iters: 10, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = Leader::new(&p, &topo)
            .with_engine(EngineKind::Distributed)
            .run(&Algorithm::Depca(cfg), &mut rec);
        assert_eq!(out.iters, 10);
    }

    #[test]
    fn leader_fills_external_recorder() {
        let (p, topo) = setup(224);
        let cfg = DeepcaConfig { consensus_rounds: 6, max_iters: 20, ..Default::default() };
        let mut rec = RunRecorder::with_stride(5);
        let _ = Leader::new(&p, &topo).run(&Algorithm::Deepca(cfg), &mut rec);
        let iters: Vec<usize> = rec.records.iter().map(|r| r.iter).collect();
        assert_eq!(iters, vec![0, 5, 10, 15]);
    }
}
