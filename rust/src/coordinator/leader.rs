//! Leader-driven orchestration: config → engine selection → run.
//!
//! The `Leader` is the programmatic entry point `main.rs`, the examples,
//! and the experiment harness share: pick an algorithm, an execution
//! engine, and get back a `RunOutput` plus the metric trace.

use crate::algo::deepca::{self, DeepcaConfig};
use crate::algo::depca::{self, DepcaConfig};
use crate::algo::metrics::{RunOutput, RunRecorder};
use crate::algo::problem::Problem;
use crate::algo::backend::{ParallelBackend, PowerBackend, RustBackend};
use crate::consensus::comm::{Communicator, DenseComm, ThreadedNetwork};
use crate::graph::topology::Topology;

/// Which algorithm to run.
#[derive(Clone, Debug)]
pub enum Algorithm {
    /// Paper Algorithm 1.
    Deepca(DeepcaConfig),
    /// Eqn. 3.4 baseline.
    Depca(DepcaConfig),
}

/// Which execution engine carries the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Single-process dense gossip, sequential products.
    Dense,
    /// Dense gossip, thread-parallel local products.
    DenseParallel,
    /// Real message-passing gossip (threads + channels).
    Threaded,
    /// Fully distributed: the whole loop inside per-agent threads
    /// (DeEPCA only; DePCA falls back to `Threaded`).
    Distributed,
}

/// Leader: owns the problem/topology pair and dispatches runs.
pub struct Leader<'a> {
    /// Problem instance.
    pub problem: &'a Problem,
    /// Agent network.
    pub topo: &'a Topology,
    /// Engine selection.
    pub engine: EngineKind,
}

impl<'a> Leader<'a> {
    /// New leader with the default dense engine.
    pub fn new(problem: &'a Problem, topo: &'a Topology) -> Self {
        Leader { problem, topo, engine: EngineKind::Dense }
    }

    /// Select an engine.
    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Execute `algo`, filling `recorder`.
    pub fn run(&self, algo: &Algorithm, recorder: &mut RunRecorder) -> RunOutput {
        match (algo, self.engine) {
            (Algorithm::Deepca(cfg), EngineKind::Distributed) => {
                crate::coordinator::distributed::run_deepca_distributed(
                    self.problem,
                    self.topo,
                    cfg,
                    recorder,
                )
            }
            (Algorithm::Deepca(cfg), engine) => {
                let (backend, comm) = self.make_parts(engine);
                deepca::run_with(self.problem, backend.as_ref(), comm.as_ref(), cfg, recorder)
            }
            (Algorithm::Depca(cfg), engine) => {
                let engine = if engine == EngineKind::Distributed {
                    EngineKind::Threaded
                } else {
                    engine
                };
                let (backend, comm) = self.make_parts(engine);
                depca::run_with(self.problem, backend.as_ref(), comm.as_ref(), cfg, recorder)
            }
        }
    }

    fn make_parts(
        &self,
        engine: EngineKind,
    ) -> (Box<dyn PowerBackend + 'a>, Box<dyn Communicator + 'a>) {
        let backend: Box<dyn PowerBackend + 'a> = match engine {
            EngineKind::DenseParallel => Box::new(ParallelBackend::new(&self.problem.locals, 0)),
            _ => Box::new(RustBackend::new(&self.problem.locals)),
        };
        let comm: Box<dyn Communicator + 'a> = match engine {
            EngineKind::Threaded => Box::new(ThreadedNetwork::from_topology(self.topo)),
            _ => Box::new(DenseComm::from_topology(self.topo)),
        };
        (backend, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Problem, Topology) {
        let ds = synthetic::spiked_covariance(
            300,
            10,
            &[8.0, 5.0],
            0.3,
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 5, 1);
        let topo = Topology::erdos_renyi(5, 0.7, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn all_engines_agree_deepca() {
        let (p, topo) = setup(221);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 30, ..Default::default() };
        let algo = Algorithm::Deepca(cfg);
        let mut outs = Vec::new();
        for engine in [
            EngineKind::Dense,
            EngineKind::DenseParallel,
            EngineKind::Threaded,
            EngineKind::Distributed,
        ] {
            let mut rec = RunRecorder::every_iteration();
            let out = Leader::new(&p, &topo).with_engine(engine).run(&algo, &mut rec);
            outs.push((engine, out));
        }
        let base = &outs[0].1;
        for (engine, out) in &outs[1..] {
            assert!(
                base.final_w.distance(&out.final_w) < 1e-8,
                "{engine:?} disagrees with Dense by {}",
                base.final_w.distance(&out.final_w)
            );
        }
    }

    #[test]
    fn depca_through_leader() {
        let (p, topo) = setup(222);
        let cfg = DepcaConfig::default();
        let mut rec = RunRecorder::every_iteration();
        let out = Leader::new(&p, &topo).run(&Algorithm::Depca(cfg), &mut rec);
        assert!(out.iters > 0);
        assert!(out.final_tan_theta.is_finite());
    }

    #[test]
    fn depca_distributed_falls_back() {
        let (p, topo) = setup(223);
        let cfg = DepcaConfig { max_iters: 10, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = Leader::new(&p, &topo)
            .with_engine(EngineKind::Distributed)
            .run(&Algorithm::Depca(cfg), &mut rec);
        assert_eq!(out.iters, 10);
    }
}
