//! `Session` — the fluent `SolverBuilder` over the step-wise solver API.
//!
//! One entry point for every algorithm × engine combination:
//!
//! ```no_run
//! use deepca::prelude::*;
//!
//! # let data = deepca::data::synthetic::w8a_like_scaled(10, 80, &mut Rng::seed_from(7));
//! # let problem = Problem::from_dataset(&data, 10, 5);
//! # let topo = Topology::erdos_renyi(10, 0.5, &mut Rng::seed_from(13));
//! let report = Session::on(&problem, &topo)
//!     .algo(Algo::Deepca(DeepcaConfig { consensus_rounds: 8, ..Default::default() }))
//!     .engine(Engine::Threaded)
//!     .stop(StopCriteria::max_iters(200).with_tol(1e-9))
//!     .observe(|step| {
//!         if let Some(err) = step.mean_tan_theta {
//!             eprintln!("iter {}: tanθ = {err:.3e}", step.iter);
//!         }
//!     })
//!     .eigenvalues(20) // Remark-4 Rayleigh post-step
//!     .solve();
//! println!("{}: tanθ = {:.3e} ({})", report.algo, report.final_tan_theta, report.comm);
//! ```
//!
//! The session owns the plumbing the experiments, benches, and CLI used
//! to re-wire by hand: engine selection (backends +
//! communicators), the shared driver loop with fresh-error
//! [`StopCriteria`], recording, observers, warm starts from a prior
//! [`SolveReport`], and the Rayleigh eigenvalue post-step.
//!
//! Engine notes:
//!
//! - [`Engine::Distributed`] runs DeEPCA with one OS thread per agent
//!   ([`crate::coordinator::distributed`]). That engine drives itself
//!   and honors only an iteration budget (there is no global barrier to
//!   evaluate stop criteria through); a session asking for more —
//!   tolerance/stall stopping, observers, or a warm start — falls back
//!   to [`Engine::Threaded`], where those features are honored (the
//!   report's `engine` field says which engine actually ran).
//!   Algorithms other than DeEPCA fall back to [`Engine::Threaded`] as
//!   well.
//! - [`Engine::Sim`] runs gossip through the deterministic
//!   unreliable-network simulator ([`crate::consensus::simnet::SimNet`]):
//!   seeded packet drops, virtual-clock latency, payload noise, and —
//!   via [`Session::schedule`] — time-varying topologies. With an ideal
//!   config it reproduces [`Engine::Dense`] bit-for-bit.
//! - [`Engine::Sparse`] gossips through CSR Metropolis weights
//!   ([`crate::consensus::comm::SparseComm`]) with a Lanczos λ₂
//!   estimate — O(edges) per round and nothing dense in the agent
//!   count, for fleet-scale topologies the dense engines cannot hold.
//! - The centralized reference ignores the engine (no communication).

use crate::algo::backend::{PowerBackend, RustBackend};
use crate::algo::centralized::CentralizedSolver;
use crate::algo::deepca::DeepcaSolver;
use crate::algo::depca::DepcaSolver;
use crate::algo::local_power::LocalPowerSolver;
use crate::algo::metrics::RunRecorder;
use crate::algo::problem::Problem;
use crate::algo::rayleigh::estimate_eigenvalues_from;
use crate::algo::solver::{
    drive, mean_tan_theta, Algo, Engine, SolveReport, Solver, StepReport, StopCriteria,
    StopReason,
};
use crate::consensus::comm::{Communicator, DenseComm, SparseComm, ThreadedNetwork};
use crate::consensus::simnet::SimNet;
use crate::consensus::AgentStack;
use crate::exec::Executor;
use crate::graph::dynamic::TopologySchedule;
use crate::graph::topology::Topology;
use std::sync::Arc;

/// Fluent builder for one solver run. See the module docs for a tour.
pub struct Session<'a> {
    problem: &'a Problem,
    topo: &'a Topology,
    engine: Engine,
    algo: Algo,
    stop: Option<StopCriteria>,
    recorder: Option<RunRecorder>,
    observer: Option<Box<dyn FnMut(&StepReport) + 'a>>,
    warm: Option<AgentStack>,
    eig_rounds: Option<usize>,
    schedule: Option<TopologySchedule>,
    threads: Option<usize>,
    exec: Option<Arc<Executor>>,
    trace: Option<std::path::PathBuf>,
}

/// The issue-tracker name for [`Session`] — same type.
pub type SolverBuilder<'a> = Session<'a>;

impl<'a> Session<'a> {
    /// Start a session on a problem/topology pair (defaults: DeEPCA with
    /// its default config, dense engine, every-iteration recorder).
    pub fn on(problem: &'a Problem, topo: &'a Topology) -> Self {
        Session {
            problem,
            topo,
            engine: Engine::Dense,
            algo: Algo::Deepca(Default::default()),
            stop: None,
            recorder: None,
            observer: None,
            warm: None,
            eig_rounds: None,
            schedule: None,
            threads: None,
            exec: None,
            trace: None,
        }
    }

    /// Capture a flight-recorder trace of this solve and write it to
    /// `path` when the run finishes (`.json` → Chrome Trace Format for
    /// Perfetto/`chrome://tracing`, anything else → JSONL for `deepca
    /// trace`). Enables [`crate::obs::trace`] for the duration of
    /// [`Session::solve`]; an export failure is reported on stderr, not
    /// panicked on — the solve result is never sacrificed to a full
    /// disk.
    pub fn trace(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Size the deterministic worker pool shared by the power-step
    /// backend, the communication engine, and the solver's per-agent
    /// loops. `0` (and never calling this) resolves to `DEEPCA_THREADS`
    /// or `available_parallelism`; `1` is the sequential fallback.
    /// Results are **bit-identical for every value** — the pool only
    /// changes which thread computes each agent's work (see
    /// [`crate::exec`]).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Share an existing executor (e.g. across the epochs of an online
    /// run) instead of building one per solve. Overrides
    /// [`Session::threads`].
    pub fn executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = Some(exec);
        self
    }

    /// Select the algorithm.
    pub fn algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    /// Select the execution engine.
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Override the stop criteria (default: derived from the algorithm
    /// config's `max_iters`/`tol`).
    pub fn stop(mut self, stop: StopCriteria) -> Self {
        self.stop = Some(stop);
        self
    }

    /// Use a custom recorder (e.g. [`RunRecorder::with_stride`] to make
    /// long sweeps cheap — stop criteria stay exact regardless).
    pub fn record(mut self, recorder: RunRecorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Observe every step (called after recording; `mean_tan_theta` is
    /// filled on iterations where the driver evaluated the error).
    pub fn observe(mut self, f: impl FnMut(&StepReport) + 'a) -> Self {
        self.observer = Some(Box::new(f));
        self
    }

    /// Warm-start from a prior run's final iterate.
    pub fn warm_start(self, prior: &SolveReport) -> Self {
        self.warm_start_from(&prior.final_w)
    }

    /// Warm-start from an explicit per-agent iterate.
    pub fn warm_start_from(mut self, w: &AgentStack) -> Self {
        self.warm = Some(w.clone());
        self
    }

    /// Run the Remark-4 Rayleigh eigenvalue estimation as a post-step
    /// (`rounds` FastMix rounds over the k×k Rayleigh blocks).
    pub fn eigenvalues(mut self, rounds: usize) -> Self {
        self.eig_rounds = Some(rounds);
        self
    }

    /// Time-varying topology for the [`Engine::Sim`] engine (static /
    /// periodic / Markov churn — see [`TopologySchedule`]). Only
    /// `Engine::Sim` can honor it, so solving any other engine with a
    /// schedule set panics (rather than silently running the ideal
    /// static network); the session's base `topo` is still used for the
    /// metrics/eigenvalue post-steps. The schedule's node count must
    /// match the problem's agent count.
    pub fn schedule(mut self, schedule: TopologySchedule) -> Self {
        assert_eq!(
            schedule.n(),
            self.problem.m(),
            "schedule/problem agent count mismatch"
        );
        self.schedule = Some(schedule);
        self
    }

    /// A schedule without the sim engine would be silently meaningless —
    /// mirror the CLI and refuse.
    fn check_schedule_engine(&self) {
        assert!(
            self.schedule.is_none() || matches!(self.engine, Engine::Sim(_)),
            "a TopologySchedule is only honored by Engine::Sim (got {:?})",
            self.engine
        );
    }

    /// Build the step-wise solver for manual driving ([`Solver::step`]).
    /// Uses the leader-driven engines; [`Engine::Distributed`] falls
    /// back to [`Engine::Threaded`] here. A configured warm start is
    /// applied, same as in [`Session::solve`].
    pub fn build_solver(&self) -> Box<dyn Solver + 'a> {
        self.check_schedule_engine();
        let engine = match self.engine {
            Engine::Distributed => Engine::Threaded,
            e => e,
        };
        let mut solver = self.build_solver_for(engine);
        if let Some(w) = &self.warm {
            solver.warm_start(w);
        }
        solver
    }

    /// Execute the session and collect the unified report.
    pub fn solve(mut self) -> SolveReport {
        self.check_schedule_engine();
        let trace_path = self.trace.take();
        if trace_path.is_some() {
            crate::obs::trace::enable(crate::obs::trace::DEFAULT_CAPACITY);
        }
        let stop = self
            .stop
            .clone()
            .unwrap_or_else(|| self.algo.default_stop());
        let mut recorder = self
            .recorder
            .take()
            .unwrap_or_else(RunRecorder::every_iteration);
        let algo_name = self.algo.name();

        // The per-agent-thread engine has no global barrier to evaluate
        // stop criteria through, so anything beyond an iteration budget
        // (tol/stall, observers, warm starts) falls back to the
        // leader-driven Threaded engine where those features are honored.
        let distributed_ok = matches!(self.algo, Algo::Deepca(_))
            && self.observer.is_none()
            && self.warm.is_none()
            && !stop.needs_error();

        let mut report = if self.engine == Engine::Distributed && distributed_ok {
            let Algo::Deepca(cfg) = &self.algo else { unreachable!() };
            let mut cfg = cfg.clone();
            cfg.max_iters = stop.max_iters;
            let out = crate::coordinator::distributed::run_deepca_distributed(
                self.problem,
                self.topo,
                &cfg,
                &mut recorder,
            );
            let final_tan_theta = if out.final_w.is_finite() {
                mean_tan_theta(&self.problem.u(), &out.final_w)
            } else {
                recorder.final_tan_theta()
            };
            SolveReport {
                algo: algo_name,
                engine: Engine::Distributed,
                iters: out.iters,
                reason: if out.diverged {
                    StopReason::Diverged
                } else {
                    StopReason::MaxIters
                },
                diverged: out.diverged,
                final_tan_theta,
                comm: out.comm,
                final_w: out.final_w,
                trace: recorder,
                elapsed_secs: out.elapsed_secs,
                eigenvalues: None,
            }
        } else {
            let engine = if self.engine == Engine::Distributed {
                // Non-DeEPCA algorithms, observers, and warm starts need
                // the leader-driven step loop.
                Engine::Threaded
            } else {
                self.engine
            };
            let mut solver = self.build_solver_for(engine);
            if let Some(w) = &self.warm {
                solver.warm_start(w);
            }
            let outcome = drive(
                &mut *solver,
                &stop,
                &mut recorder,
                self.observer.as_deref_mut(),
            );
            SolveReport {
                algo: algo_name,
                engine,
                iters: outcome.iters,
                reason: outcome.reason,
                diverged: outcome.reason == StopReason::Diverged,
                final_tan_theta: outcome.final_tan_theta,
                comm: solver.state().stats.clone(),
                final_w: solver.state().w.clone(),
                trace: recorder,
                elapsed_secs: outcome.elapsed_secs,
                eigenvalues: None,
            }
        };

        if let Some(rounds) = self.eig_rounds {
            let comm = DenseComm::from_topology(self.topo);
            let stack = if report.final_w.m() == self.problem.m() {
                report.final_w.clone()
            } else {
                // Centralized runs hold a single shared iterate; every
                // "agent" starts the Rayleigh pass from the same W.
                AgentStack::replicate(self.problem.m(), report.final_w.slice(0))
            };
            report.eigenvalues =
                Some(estimate_eigenvalues_from(self.problem, &stack, &comm, rounds));
        }
        if let Some(path) = trace_path {
            crate::obs::trace::disable();
            let snap = crate::obs::trace::snapshot();
            if let Err(e) = crate::obs::export::write_auto(&path, &snap) {
                eprintln!("warning: could not write trace {}: {e}", path.display());
            }
        }
        report
    }

    /// The session-wide executor: an explicitly shared one, or a fresh
    /// pool sized by [`Session::threads`] (default: `DEEPCA_THREADS` /
    /// `available_parallelism`). One pool serves the backend, the
    /// communication engine, and the solver's per-agent loops.
    fn make_executor(&self) -> Arc<Executor> {
        match &self.exec {
            Some(e) => Arc::clone(e),
            None => Arc::new(Executor::new(self.threads.unwrap_or(0))),
        }
    }

    fn build_solver_for(&self, engine: Engine) -> Box<dyn Solver + 'a> {
        match &self.algo {
            Algo::Deepca(cfg) => {
                let exec = self.make_executor();
                let (backend, comm) = self.parts(engine, &exec);
                Box::new(
                    DeepcaSolver::new(self.problem, backend, comm, cfg.clone())
                        .with_executor(exec),
                )
            }
            Algo::Depca(cfg) => {
                let exec = self.make_executor();
                let (backend, comm) = self.parts(engine, &exec);
                Box::new(
                    DepcaSolver::new(self.problem, backend, comm, cfg.clone())
                        .with_executor(exec),
                )
            }
            Algo::LocalPower(cfg) => {
                // No communication: build only the backend (skip the
                // communicator's gossip-matrix spectral computation).
                let exec = self.make_executor();
                Box::new(
                    LocalPowerSolver::new(self.problem, self.backend(&exec), cfg.clone())
                        .with_executor(exec),
                )
            }
            // The centralized solver has a single-slice iterate — no
            // per-agent loop to fan out — so it takes no executor and no
            // pool is spun up for it.
            Algo::Centralized(cfg) => Box::new(CentralizedSolver::new(self.problem, cfg.clone())),
        }
    }

    fn backend(&self, exec: &Arc<Executor>) -> Box<dyn PowerBackend + 'a> {
        // Every engine composes the same in-process backend with the
        // session executor ([`Engine::DenseParallel`] is a legacy alias
        // for Dense now that parallelism is the executor's job).
        Box::new(RustBackend::with_executor(&self.problem.locals, Arc::clone(exec)))
    }

    fn parts(
        &self,
        engine: Engine,
        exec: &Arc<Executor>,
    ) -> (Box<dyn PowerBackend + 'a>, Box<dyn Communicator + 'a>) {
        let comm: Box<dyn Communicator + 'a> = match engine {
            Engine::Threaded => Box::new(
                ThreadedNetwork::from_topology(self.topo).with_executor(Arc::clone(exec)),
            ),
            Engine::Sim(cfg) => {
                let sched = self
                    .schedule
                    .clone()
                    .unwrap_or_else(|| TopologySchedule::fixed(self.topo.clone()));
                Box::new(SimNet::new(sched, cfg).with_executor(Arc::clone(exec)))
            }
            // Fleet-scale CSR gossip: Metropolis weights + Lanczos λ₂,
            // nothing dense in the agent count.
            Engine::Sparse => Box::new(
                SparseComm::metropolis(self.topo).with_executor(Arc::clone(exec)),
            ),
            _ => Box::new(DenseComm::from_topology(self.topo).with_executor(Arc::clone(exec))),
        };
        (self.backend(exec), comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::centralized::CentralizedConfig;
    use crate::algo::deepca::DeepcaConfig;
    use crate::algo::depca::{DepcaConfig, KPolicy};
    use crate::algo::local_power::LocalPowerConfig;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Problem, Topology) {
        let ds = synthetic::spiked_covariance(
            300,
            10,
            &[8.0, 5.0],
            0.3,
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 5, 1);
        let topo = Topology::erdos_renyi(5, 0.7, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn all_four_algorithms_solve() {
        let (p, topo) = setup(611);
        for algo in [
            Algo::Deepca(DeepcaConfig { consensus_rounds: 8, max_iters: 40, ..Default::default() }),
            Algo::Depca(DepcaConfig {
                k_policy: KPolicy::Fixed(8),
                max_iters: 40,
                ..Default::default()
            }),
            Algo::LocalPower(LocalPowerConfig { max_iters: 40, ..Default::default() }),
            Algo::Centralized(CentralizedConfig { max_iters: 40, ..Default::default() }),
        ] {
            let name = algo.name();
            let report = Session::on(&p, &topo).algo(algo).solve();
            assert_eq!(report.algo, name);
            assert_eq!(report.iters, 40, "{name}");
            assert!(report.final_tan_theta.is_finite(), "{name}");
            assert_eq!(report.trace.records.len(), 40, "{name}");
            assert!(!report.diverged, "{name}");
        }
    }

    #[test]
    fn observer_sees_every_step() {
        let (p, topo) = setup(612);
        let mut calls = 0usize;
        let mut evaluated = 0usize;
        let report = {
            let counter = &mut calls;
            let eval = &mut evaluated;
            Session::on(&p, &topo)
                .algo(Algo::Deepca(DeepcaConfig {
                    consensus_rounds: 8,
                    max_iters: 12,
                    ..Default::default()
                }))
                .observe(move |step| {
                    *counter += 1;
                    if step.mean_tan_theta.is_some() {
                        *eval += 1;
                    }
                })
                .solve()
        };
        assert_eq!(report.iters, 12);
        assert_eq!(calls, 12);
        // Every-iteration recorder → error evaluated every step.
        assert_eq!(evaluated, 12);
    }

    #[test]
    fn warm_start_via_builder_continues() {
        let (p, topo) = setup(613);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 25, ..Default::default() };
        let first = Session::on(&p, &topo).algo(Algo::Deepca(cfg.clone())).solve();
        let resumed = Session::on(&p, &topo)
            .algo(Algo::Deepca(cfg))
            .warm_start(&first)
            .solve();
        assert!(
            resumed.final_tan_theta < first.final_tan_theta.max(1e-13)
                || resumed.final_tan_theta < 1e-12,
            "resume should not regress: {:.3e} -> {:.3e}",
            first.final_tan_theta,
            resumed.final_tan_theta
        );
    }

    #[test]
    fn centralized_eigenvalue_post_step() {
        let (p, topo) = setup(614);
        let report = Session::on(&p, &topo)
            .algo(Algo::Centralized(CentralizedConfig {
                max_iters: 120,
                ..Default::default()
            }))
            .eigenvalues(25)
            .solve();
        let est = report.eigenvalues.as_ref().unwrap();
        assert!(
            (est.values()[0] - p.truth.values[0]).abs() < 1e-6 * p.truth.values[0],
            "λ₁ estimate {} vs truth {}",
            est.values()[0],
            p.truth.values[0]
        );
    }

    #[test]
    fn sparse_engine_solves_deepca() {
        // The fleet-scale CSR engine: different weights than Dense (so
        // no bit parity expected), but DeEPCA still converges to the
        // same subspace on a small graph.
        let (p, topo) = setup(621);
        let report = Session::on(&p, &topo)
            .algo(Algo::Deepca(DeepcaConfig {
                consensus_rounds: 10,
                max_iters: 40,
                ..Default::default()
            }))
            .engine(Engine::Sparse)
            .solve();
        assert_eq!(report.engine, Engine::Sparse);
        assert!(!report.diverged);
        assert!(
            report.final_tan_theta < 1e-6,
            "sparse engine failed to converge: {:.3e}",
            report.final_tan_theta
        );
    }

    #[test]
    fn non_deepca_distributed_falls_back_to_threaded() {
        // Only DeEPCA has a per-agent-thread engine; other algorithms
        // asked to run distributed must fall back to Threaded and say so
        // in the report (coverage inherited from the removed Leader).
        let (p, topo) = setup(620);
        let report = Session::on(&p, &topo)
            .algo(Algo::Depca(DepcaConfig { max_iters: 10, ..Default::default() }))
            .engine(Engine::Distributed)
            .solve();
        assert_eq!(report.engine, Engine::Threaded);
        assert_eq!(report.iters, 10);
        assert!(report.final_tan_theta.is_finite());
    }

    #[test]
    fn sim_engine_through_builder() {
        use crate::consensus::simnet::SimConfig;
        let (p, topo) = setup(616);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 25, ..Default::default() };

        // Ideal SimNet must reproduce the dense engine.
        let dense = Session::on(&p, &topo).algo(Algo::Deepca(cfg.clone())).solve();
        let sim = Session::on(&p, &topo)
            .algo(Algo::Deepca(cfg.clone()))
            .engine(Engine::Sim(SimConfig::ideal(0)))
            .solve();
        assert!(
            dense.final_w.distance(&sim.final_w) < 1e-12,
            "ideal sim vs dense: {}",
            dense.final_w.distance(&sim.final_w)
        );
        // Virtual time: one tick per gossip round at zero latency.
        assert_eq!(sim.virtual_time(), sim.comm.rounds);
        assert_eq!(dense.virtual_time(), 0);

        // Faulty SimNet still runs and drops messages.
        let faulty = Session::on(&p, &topo)
            .algo(Algo::Deepca(cfg))
            .engine(Engine::Sim(SimConfig {
                drop_prob: 0.1,
                max_latency: 2,
                ..SimConfig::ideal(9)
            }))
            .solve();
        assert!(!faulty.diverged);
        assert!(faulty.comm.dropped > 0, "10% drops must fire");
        assert!(faulty.virtual_time() >= faulty.comm.rounds);
    }

    #[test]
    fn sim_engine_with_churn_schedule() {
        use crate::consensus::simnet::SimConfig;
        use crate::graph::dynamic::TopologySchedule;
        let (p, topo) = setup(617);
        let sched = TopologySchedule::markov(topo.clone(), 0.3, 0.5, 77, 4);
        let report = Session::on(&p, &topo)
            .algo(Algo::Deepca(DeepcaConfig {
                consensus_rounds: 12,
                max_iters: 60,
                ..Default::default()
            }))
            .engine(Engine::Sim(SimConfig { drop_prob: 0.02, ..SimConfig::ideal(5) }))
            .schedule(sched)
            .solve();
        assert!(!report.diverged);
        assert!(
            report.final_tan_theta < 1e-6,
            "churned network should still converge: {:.3e}",
            report.final_tan_theta
        );
    }

    #[test]
    #[should_panic(expected = "only honored by Engine::Sim")]
    fn schedule_without_sim_engine_panics() {
        use crate::graph::dynamic::TopologySchedule;
        let (p, topo) = setup(619);
        // Default engine is Dense: a schedule there would silently run
        // the ideal static network, so the builder must refuse.
        let _ = Session::on(&p, &topo)
            .schedule(TopologySchedule::fixed(topo.clone()))
            .solve();
    }

    #[test]
    fn build_solver_applies_warm_start() {
        let (p, topo) = setup(618);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 20, ..Default::default() };
        let first = Session::on(&p, &topo).algo(Algo::Deepca(cfg.clone())).solve();
        let solver = Session::on(&p, &topo)
            .algo(Algo::Deepca(cfg))
            .warm_start(&first)
            .build_solver();
        assert!(
            solver.state().w == first.final_w,
            "manual solver must start from the warm iterate"
        );
    }

    #[test]
    fn manual_stepping_through_build_solver() {
        let (p, topo) = setup(615);
        let session = Session::on(&p, &topo).algo(Algo::Deepca(DeepcaConfig {
            consensus_rounds: 8,
            max_iters: 10,
            ..Default::default()
        }));
        let mut solver = session.build_solver();
        for t in 0..10 {
            let rep = solver.step();
            assert_eq!(rep.iter, t);
        }
        assert_eq!(solver.state().iter, 10);
    }
}
