//! L3 coordination runtime: leader/agent process topology.
//!
//! Two execution styles:
//!
//! - **Leader-driven** ([`leader`]) — the leader owns the loop and calls
//!   into pluggable backends/communicators ([`crate::algo`]); the natural
//!   mode for experiment sweeps and the PJRT artifact backend.
//! - **Fully distributed** ([`distributed`]) — one OS thread per agent
//!   owning its private `A_j, S_j, W_j, G_j` state end-to-end; gossip
//!   rounds are real channel exchanges; the leader thread only receives
//!   per-iteration telemetry. This is the deployment-shaped runtime the
//!   end-to-end example runs, and integration tests pin it numerically to
//!   the leader-driven engine.

pub mod agent;
pub mod leader;
pub mod distributed;
