//! L3 coordination runtime: sessions, engines, and process topology.
//!
//! - **Session builder** ([`session`]) — the fluent `SolverBuilder`
//!   entry point: pick an algorithm ([`crate::algo::solver::Algo`]), an
//!   execution engine, observers, stop criteria, warm starts, and the
//!   Rayleigh post-step; get one unified
//!   [`crate::algo::solver::SolveReport`]. This is what `main.rs`, the
//!   experiments, benches, and examples drive.
//! - **Fully distributed** ([`distributed`]) — one OS thread per agent
//!   owning its private `A_j, S_j, W_j, G_j` state end-to-end; gossip
//!   rounds are real channel exchanges; the leader thread only receives
//!   per-iteration telemetry. This is the deployment-shaped runtime the
//!   end-to-end example runs, and integration tests pin it numerically to
//!   the leader-driven engines.
//! - **Online driver** ([`online`]) — `OnlineSession`, warm-started
//!   DeEPCA epochs over live data streams ([`crate::stream`]): per-epoch
//!   covariance refresh, constant round budget, tracking metrics against
//!   the drifting oracle subspace.
//!
//! (The legacy `Leader`/`Algorithm` wrappers and the per-algorithm
//! `run_dense`/`run_with` shims were removed once everything routed
//! through [`session::Session`].)

pub mod agent;
pub mod session;
pub mod online;
pub mod distributed;
