//! Per-agent stream sources with a drifting ground-truth subspace.
//!
//! A [`StreamSource`] hands every agent a fresh batch of sample rows per
//! epoch and exposes the *oracle*: the true top-k subspace of the
//! current population covariance, against which tracking error is
//! measured. [`SyntheticStream`] layers the drift scenarios on the same
//! spiked-covariance machinery as [`crate::data::synthetic`]: samples
//! are `x = B(t) · (√vals(t) ⊙ z)` with `z ~ N(0, I)`, so the population
//! covariance is exactly `B(t) diag(vals(t)) B(t)ᵀ` and the oracle is
//! known in closed form at every epoch.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// How the population covariance evolves across epochs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Drift {
    /// Fixed covariance — the batch setting fed incrementally.
    Stationary,
    /// Slow subspace rotation: signal direction `i` rotates into the
    /// paired bulk direction `k + i` by `rate` radians per epoch.
    Rotation {
        /// Radians per epoch.
        rate: f64,
    },
    /// Abrupt change-point: at epoch `at` the signal subspace jumps to
    /// an independent random frame.
    ChangePoint {
        /// First epoch with the new subspace.
        at: u64,
    },
    /// Spike-strength fade: the k-th spike decays while a challenger
    /// direction rises; they cross at epoch `ln 2 / rate`, flipping the
    /// identity of the oracle's k-th direction.
    SpikeFade {
        /// Exponential fade rate per epoch.
        rate: f64,
    },
}

/// Parameters for [`SyntheticStream`].
#[derive(Clone, Debug)]
pub struct StreamParams {
    /// Number of agents m.
    pub m: usize,
    /// Ambient dimension d.
    pub dim: usize,
    /// Rows each agent draws per epoch.
    pub batch: usize,
    /// Signal variances (strictly decreasing, all above `noise`); the
    /// target rank is `spikes.len()`.
    pub spikes: Vec<f64>,
    /// Bulk variance of the non-signal directions.
    pub noise: f64,
    /// Drift scenario.
    pub drift: Drift,
    /// Master seed (basis, change-point frame, per-agent sample streams).
    pub seed: u64,
}

/// A live data stream over m agents.
///
/// Protocol per epoch: call [`StreamSource::next_batch`] once for every
/// agent, then [`StreamSource::advance`]. Implementations must be
/// deterministic per seed so runs replay exactly.
pub trait StreamSource {
    /// Number of agents.
    fn m(&self) -> usize;
    /// Ambient dimension d.
    fn dim(&self) -> usize;
    /// Target subspace rank k.
    fn k(&self) -> usize;
    /// Current epoch (0-based).
    fn epoch(&self) -> u64;
    /// Agent `agent`'s fresh rows for the current epoch (`batch × d`).
    fn next_batch(&mut self, agent: usize) -> Mat;
    /// Advance the environment to the next epoch.
    fn advance(&mut self);
    /// The true top-k subspace of the current population covariance
    /// (`d × k`, orthonormal), when the source knows it.
    fn oracle(&self) -> Option<Mat>;
    /// Human label for reports.
    fn label(&self) -> String;
}

/// Per-epoch sampling state, rebuilt once per epoch rather than once
/// per agent call (`m` agents draw from the same Σ(t)).
struct EpochCache {
    epoch: u64,
    /// √vals(t), one scale per population direction.
    scales: Vec<f64>,
    /// `B(t)ᵀ` so a batch is one `Z · B(t)ᵀ` matmul.
    basis_t: Mat,
}

/// Drifting spiked-covariance stream — the synthetic reference source.
pub struct SyntheticStream {
    p: StreamParams,
    /// Epoch-0 orthonormal frame (d × d).
    basis: Mat,
    /// Independent frame the change-point scenario jumps to.
    alt_basis: Mat,
    /// Per-agent sample generators (forked from the master seed).
    agent_rngs: Vec<Rng>,
    epoch: u64,
    cache: Option<EpochCache>,
}

impl SyntheticStream {
    /// Build a stream from parameters (validates shapes and spectra).
    pub fn new(p: StreamParams) -> Self {
        let k = p.spikes.len();
        assert!(p.m > 0, "need at least one agent");
        assert!(p.batch > 0, "need at least one row per epoch");
        assert!(k >= 1 && k < p.dim, "need 1 <= k < d");
        assert!(p.noise >= 0.0, "bulk variance must be >= 0");
        for w in p.spikes.windows(2) {
            assert!(w[0] > w[1], "spikes must be strictly decreasing");
        }
        assert!(
            p.spikes[k - 1] > p.noise,
            "smallest spike must exceed the bulk variance"
        );
        if let Drift::Rotation { .. } = p.drift {
            assert!(2 * k <= p.dim, "rotation pairs need d >= 2k");
        }
        let mut master = Rng::seed_from(p.seed);
        let basis = Mat::rand_orthonormal(p.dim, p.dim, &mut master);
        let alt_basis = Mat::rand_orthonormal(p.dim, p.dim, &mut master);
        let agent_rngs = (0..p.m).map(|_| master.fork()).collect();
        SyntheticStream { p, basis, alt_basis, agent_rngs, epoch: 0, cache: None }
    }

    /// Ensure `cache` describes the current epoch.
    fn refresh_cache(&mut self) {
        let stale = self.cache.as_ref().map(|c| c.epoch != self.epoch).unwrap_or(true);
        if stale {
            self.cache = Some(EpochCache {
                epoch: self.epoch,
                scales: self.values_at(self.epoch).iter().map(|v| v.sqrt()).collect(),
                basis_t: self.basis_at(self.epoch).t(),
            });
        }
    }

    /// The population eigenvalues at epoch `t` (length d: signal spikes
    /// first, then the bulk; the fade scenario reshuffles two of them).
    pub fn values_at(&self, t: u64) -> Vec<f64> {
        let k = self.p.spikes.len();
        let mut vals = vec![self.p.noise; self.p.dim];
        vals[..k].copy_from_slice(&self.p.spikes);
        if let Drift::SpikeFade { rate } = self.p.drift {
            let span = self.p.spikes[k - 1] - self.p.noise;
            let f = (-(rate * t as f64)).exp();
            vals[k - 1] = self.p.noise + span * f;
            vals[k] = self.p.noise + span * (1.0 - f);
        }
        vals
    }

    /// The population eigenbasis at epoch `t` (d × d orthonormal; column
    /// `i` carries variance `values_at(t)[i]`).
    pub fn basis_at(&self, t: u64) -> Mat {
        match self.p.drift {
            Drift::Stationary | Drift::SpikeFade { .. } => self.basis.clone(),
            Drift::ChangePoint { at } => {
                if t < at {
                    self.basis.clone()
                } else {
                    self.alt_basis.clone()
                }
            }
            Drift::Rotation { rate } => {
                let k = self.p.spikes.len();
                let a = rate * t as f64;
                let (sin, cos) = a.sin_cos();
                let mut out = self.basis.clone();
                for i in 0..k {
                    for r in 0..self.p.dim {
                        let b1 = self.basis[(r, i)];
                        let b2 = self.basis[(r, k + i)];
                        out[(r, i)] = cos * b1 + sin * b2;
                        out[(r, k + i)] = cos * b2 - sin * b1;
                    }
                }
                out
            }
        }
    }

    /// Exact population covariance at the current epoch,
    /// `B(t) diag(vals(t)) B(t)ᵀ` (tests and diagnostics).
    pub fn population_covariance(&self) -> Mat {
        let d = self.p.dim;
        let vals = self.values_at(self.epoch);
        let b = self.basis_at(self.epoch);
        let mut cov = Mat::zeros(d, d);
        for i in 0..d {
            if vals[i] == 0.0 {
                continue;
            }
            for r in 0..d {
                let vr = vals[i] * b[(r, i)];
                for c in 0..d {
                    cov[(r, c)] += vr * b[(c, i)];
                }
            }
        }
        cov.symmetrize();
        cov
    }

    fn oracle_at(&self, t: u64) -> Mat {
        let d = self.p.dim;
        let k = self.p.spikes.len();
        let vals = self.values_at(t);
        let b = self.basis_at(t);
        // Top-k columns by current variance (stable on ties).
        let mut idx: Vec<usize> = (0..d).collect();
        idx.sort_by(|&x, &y| vals[y].partial_cmp(&vals[x]).unwrap().then(x.cmp(&y)));
        Mat::from_fn(d, k, |r, c| b[(r, idx[c])])
    }
}

impl StreamSource for SyntheticStream {
    fn m(&self) -> usize {
        self.p.m
    }

    fn dim(&self) -> usize {
        self.p.dim
    }

    fn k(&self) -> usize {
        self.p.spikes.len()
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn next_batch(&mut self, agent: usize) -> Mat {
        assert!(agent < self.p.m, "agent index out of range");
        self.refresh_cache();
        let d = self.p.dim;
        let cache = self.cache.as_ref().expect("cache refreshed above");
        let rng = &mut self.agent_rngs[agent];
        // x = B · (scales ⊙ z), z ~ N(0, I) — the same construction as
        // `data::synthetic::spiked_covariance`, batched as Z·Bᵀ.
        let mut z = Mat::zeros(self.p.batch, d);
        for r in 0..self.p.batch {
            for i in 0..d {
                z[(r, i)] = rng.normal() * cache.scales[i];
            }
        }
        z.matmul(&cache.basis_t)
    }

    fn advance(&mut self) {
        self.epoch += 1;
    }

    fn oracle(&self) -> Option<Mat> {
        Some(self.oracle_at(self.epoch))
    }

    fn label(&self) -> String {
        let drift = match self.p.drift {
            Drift::Stationary => "stationary".to_string(),
            Drift::Rotation { rate } => format!("rotate{rate}"),
            Drift::ChangePoint { at } => format!("change{at}"),
            Drift::SpikeFade { rate } => format!("fade{rate}"),
        };
        format!(
            "stream-{drift}(m={},d={},k={})",
            self.p.m,
            self.p.dim,
            self.p.spikes.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::angles::tan_theta;
    use crate::linalg::eig::eig_sym;

    fn params(drift: Drift) -> StreamParams {
        StreamParams {
            m: 3,
            dim: 10,
            batch: 20,
            spikes: vec![8.0, 4.0],
            noise: 0.5,
            drift,
            seed: 41,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SyntheticStream::new(params(Drift::Rotation { rate: 0.02 }));
        let mut b = SyntheticStream::new(params(Drift::Rotation { rate: 0.02 }));
        for _ in 0..3 {
            for j in 0..3 {
                assert_eq!(a.next_batch(j).data(), b.next_batch(j).data());
            }
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn oracle_is_orthonormal_top_k() {
        let s = SyntheticStream::new(params(Drift::Stationary));
        let u = s.oracle().unwrap();
        assert_eq!(u.shape(), (10, 2));
        let g = u.t_matmul(&u);
        assert!((&g - &Mat::eye(2)).fro_norm() < 1e-10);
        // Stationary oracle = first k basis columns.
        let expect = Mat::from_fn(10, 2, |r, c| s.basis[(r, c)]);
        assert!(tan_theta(&u, &expect) < 1e-12);
    }

    #[test]
    fn rotation_moves_the_oracle_at_the_configured_rate() {
        let mut s = SyntheticStream::new(params(Drift::Rotation { rate: 0.02 }));
        let u0 = s.oracle().unwrap();
        for _ in 0..10 {
            s.advance();
        }
        let u10 = s.oracle().unwrap();
        let angle = tan_theta(&u0, &u10);
        // Each of the two planes rotated 0.2 rad: largest principal
        // angle is 0.2, so tan θ ≈ tan(0.2).
        assert!(
            (angle - (0.2f64).tan()).abs() < 1e-9,
            "tan θ after 10 epochs: {angle}"
        );
        // Basis stays orthonormal under rotation.
        let b = s.basis_at(10);
        assert!((&b.t_matmul(&b) - &Mat::eye(10)).fro_norm() < 1e-9);
    }

    #[test]
    fn change_point_jumps_and_preserves_prefix() {
        let mut a = SyntheticStream::new(params(Drift::ChangePoint { at: 3 }));
        let mut b = SyntheticStream::new(params(Drift::Stationary));
        // Before the change the two scenarios generate identical rows.
        for _ in 0..3 {
            assert_eq!(a.next_batch(0).data(), b.next_batch(0).data());
            a.advance();
            b.advance();
        }
        let before = a.oracle_at(2);
        let after = a.oracle_at(3);
        assert!(
            tan_theta(&before, &after) > 0.5,
            "change-point should swap the subspace"
        );
    }

    #[test]
    fn spike_fade_crosses_and_swaps_direction() {
        let s = SyntheticStream::new(params(Drift::SpikeFade { rate: 0.2 }));
        // ln 2 / 0.2 ≈ 3.5: by epoch 20 the challenger dominates.
        let v0 = s.values_at(0);
        assert!((v0[1] - 4.0).abs() < 1e-12 && (v0[2] - 0.5).abs() < 1e-12);
        let v20 = s.values_at(20);
        assert!(v20[2] > v20[1], "challenger must overtake the faded spike");
        let early = s.oracle_at(0);
        let late = s.oracle_at(20);
        let expect_late = Mat::from_fn(10, 2, |r, c| s.basis[(r, if c == 0 { 0 } else { 2 })]);
        assert!(tan_theta(&late, &expect_late) < 1e-12);
        assert!(tan_theta(&early, &late) > 0.5);
    }

    #[test]
    fn population_covariance_has_the_planted_spectrum() {
        let s = SyntheticStream::new(params(Drift::Stationary));
        let e = eig_sym(&s.population_covariance());
        assert!((e.values[0] - 8.0).abs() < 1e-9);
        assert!((e.values[1] - 4.0).abs() < 1e-9);
        assert!((e.values[2] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn sample_second_moment_approaches_population() {
        let mut p = params(Drift::Stationary);
        p.batch = 4000;
        let mut s = SyntheticStream::new(p);
        let rows = s.next_batch(0);
        let mut emp = rows.t_matmul(&rows);
        emp.scale(1.0 / 4000.0);
        let pop = s.population_covariance();
        let rel = (&emp - &pop).fro_norm() / pop.fro_norm();
        assert!(rel < 0.15, "empirical vs population covariance: {rel}");
    }
}
