//! `CovTracker` — incremental per-agent covariance maintenance.
//!
//! Each agent summarizes its row stream as a (weighted) second-moment
//! matrix `C = (1/W) Σ w_i v_i v_iᵀ`, the streaming analogue of the
//! Eqn.-5.1 local Gram `A_j = (1/n) Σ v vᵀ` built by
//! [`crate::data::partition::partition_gram`]. Two memory policies:
//!
//! - [`Forgetting::Exponential`]`(β)` — every `observe` call decays the
//!   accumulated mass by β before adding the new batch, so the tracker
//!   follows drift with an effective memory of `β/(1−β)` batches. With
//!   `β = 1` it is *exactly* the batch per-row covariance (the
//!   equivalence the streaming tests pin to 1e-12).
//! - [`Forgetting::SlidingWindow`]`(n)` — keep the most recent `n` rows:
//!   each arriving row is a rank-1 update, each expiring row a rank-1
//!   downdate. A window covering the whole history is again the batch
//!   covariance.

use crate::linalg::Mat;
use std::collections::VecDeque;

/// Memory policy for a [`CovTracker`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Forgetting {
    /// Decay factor β ∈ (0, 1] applied once per `observe` call;
    /// β = 1 keeps everything (batch covariance).
    Exponential(f64),
    /// Keep exactly the most recent `n` rows (rank-1 update/downdate).
    SlidingWindow(usize),
}

/// Incremental local covariance (uncentered second moment, matching the
/// repo-wide Gram convention).
///
/// Steady-state updates are allocation-free: exponential-mode batches
/// accumulate through a persistent d×d Gram scratch, and a full sliding
/// window recycles the expired row's buffer for the arriving row.
#[derive(Clone, Debug)]
pub struct CovTracker {
    d: usize,
    mode: Forgetting,
    /// Unnormalized weighted sum `Σ w_i v_i v_iᵀ`.
    raw: Mat,
    /// Total weight `Σ w_i` (exponential mode).
    weight: f64,
    /// Retained rows (sliding-window mode only).
    window: VecDeque<Vec<f64>>,
    /// Total rows ever observed.
    seen: u64,
    /// Batch-Gram scratch (exponential mode; empty in window mode).
    gram: Mat,
}

/// `acc += sign · v vᵀ`.
fn rank_one(acc: &mut Mat, v: &[f64], sign: f64) {
    for i in 0..v.len() {
        let vi = sign * v[i];
        if vi == 0.0 {
            continue;
        }
        let row = acc.row_mut(i);
        for (j, &vj) in v.iter().enumerate() {
            row[j] += vi * vj;
        }
    }
}

impl CovTracker {
    /// Empty tracker over dimension `d`.
    pub fn new(d: usize, mode: Forgetting) -> Self {
        match mode {
            Forgetting::Exponential(beta) => {
                assert!(
                    beta > 0.0 && beta <= 1.0,
                    "forgetting factor must be in (0, 1], got {beta}"
                );
            }
            Forgetting::SlidingWindow(n) => assert!(n >= 1, "window must hold at least one row"),
        }
        let gram = match mode {
            Forgetting::Exponential(_) => Mat::zeros(d, d),
            Forgetting::SlidingWindow(_) => Mat::zeros(0, 0),
        };
        CovTracker {
            d,
            mode,
            raw: Mat::zeros(d, d),
            weight: 0.0,
            window: VecDeque::new(),
            seen: 0,
            gram,
        }
    }

    /// Ambient dimension d.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The memory policy.
    pub fn mode(&self) -> Forgetting {
        self.mode
    }

    /// Total rows ever observed.
    pub fn rows_seen(&self) -> u64 {
        self.seen
    }

    /// Current normalization mass (rows in exponential mode are decayed;
    /// in window mode this is the retained row count).
    pub fn weight(&self) -> f64 {
        match self.mode {
            Forgetting::Exponential(_) => self.weight,
            Forgetting::SlidingWindow(_) => self.window.len() as f64,
        }
    }

    /// Whether any data has been observed.
    pub fn is_warm(&self) -> bool {
        self.weight() > 0.0
    }

    /// Ingest one batch of rows (`n × d`).
    pub fn observe(&mut self, rows: &Mat) {
        assert_eq!(rows.cols(), self.d, "row dimension mismatch");
        let n = rows.rows();
        if n == 0 {
            return;
        }
        self.seen += n as u64;
        match self.mode {
            Forgetting::Exponential(beta) => {
                if beta < 1.0 {
                    self.raw.scale(beta);
                    self.weight *= beta;
                }
                // Batch Gram through the persistent scratch (no temp).
                rows.t_matmul_into(rows, &mut self.gram);
                self.raw.axpy(1.0, &self.gram);
                self.weight += n as f64;
            }
            Forgetting::SlidingWindow(cap) => {
                for r in 0..n {
                    let row = rows.row(r);
                    // Recycle the expired row's buffer for the arriving
                    // row — a full window updates with zero allocation.
                    let v = if self.window.len() == cap {
                        let mut old = self.window.pop_front().expect("window non-empty");
                        rank_one(&mut self.raw, &old, -1.0);
                        old.copy_from_slice(row);
                        old
                    } else {
                        row.to_vec()
                    };
                    rank_one(&mut self.raw, &v, 1.0);
                    self.window.push_back(v);
                }
            }
        }
    }

    /// The current normalized covariance `(1/W) Σ w_i v_i v_iᵀ`
    /// (symmetrized). Panics before any data arrives.
    pub fn covariance(&self) -> Mat {
        let mut c = Mat::zeros(self.d, self.d);
        self.covariance_into(&mut c);
        c
    }

    /// Write the normalized covariance into a caller-owned d×d buffer
    /// (the allocation-free form the per-epoch online refresh uses).
    /// Panics before any data arrives.
    pub fn covariance_into(&self, out: &mut Mat) {
        let w = self.weight();
        assert!(w > 0.0, "covariance requested before any data");
        out.copy_from(&self.raw);
        out.scale(1.0 / w);
        out.symmetrize();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{partition_gram, GramScaling};
    use crate::data::Dataset;
    use crate::testing::{check, PropConfig};
    use crate::util::rng::Rng;

    fn random_rows(n: usize, d: usize, rng: &mut Rng) -> Mat {
        Mat::from_fn(n, d, |_, _| rng.normal())
    }

    fn batch_cov(rows: &Mat) -> Mat {
        let mut c = rows.t_matmul(rows);
        c.scale(1.0 / rows.rows() as f64);
        c.symmetrize();
        c
    }

    #[test]
    fn no_forgetting_equals_batch_partition_covariance() {
        let mut rng = Rng::seed_from(211);
        let all = random_rows(120, 7, &mut rng);
        let ds = Dataset { features: all.clone(), labels: vec![0.0; 120], name: "t".into() };
        let batch = partition_gram(&ds, 1, GramScaling::PerRow);

        let mut tracker = CovTracker::new(7, Forgetting::Exponential(1.0));
        // Feed the same rows in 4 uneven batches.
        for (lo, hi) in [(0usize, 10usize), (10, 50), (50, 51), (51, 120)] {
            let chunk = Mat::from_fn(hi - lo, 7, |r, c| all[(lo + r, c)]);
            tracker.observe(&chunk);
        }
        let diff = (&tracker.covariance() - &batch.locals[0]).max_abs();
        assert!(diff < 1e-12, "exponential β=1 vs batch: {diff:.3e}");
        assert_eq!(tracker.rows_seen(), 120);
        assert!((tracker.weight() - 120.0).abs() < 1e-12);
    }

    #[test]
    fn full_window_equals_batch_partition_covariance() {
        let mut rng = Rng::seed_from(212);
        let all = random_rows(60, 5, &mut rng);
        let ds = Dataset { features: all.clone(), labels: vec![0.0; 60], name: "t".into() };
        let batch = partition_gram(&ds, 1, GramScaling::PerRow);

        let mut tracker = CovTracker::new(5, Forgetting::SlidingWindow(60));
        for (lo, hi) in [(0usize, 25usize), (25, 40), (40, 60)] {
            let chunk = Mat::from_fn(hi - lo, 5, |r, c| all[(lo + r, c)]);
            tracker.observe(&chunk);
        }
        let diff = (&tracker.covariance() - &batch.locals[0]).max_abs();
        assert!(diff < 1e-12, "full window vs batch: {diff:.3e}");
    }

    #[test]
    fn window_downdate_matches_recompute() {
        let mut rng = Rng::seed_from(213);
        let all = random_rows(200, 6, &mut rng);
        let mut tracker = CovTracker::new(6, Forgetting::SlidingWindow(48));
        tracker.observe(&all);
        // Recompute from the last 48 rows directly.
        let tail = Mat::from_fn(48, 6, |r, c| all[(152 + r, c)]);
        let diff = (&tracker.covariance() - &batch_cov(&tail)).max_abs();
        assert!(diff < 1e-9, "window after downdates vs recompute: {diff:.3e}");
        assert!((tracker.weight() - 48.0).abs() < 1e-12);
        assert_eq!(tracker.rows_seen(), 200);
    }

    #[test]
    fn exponential_forgetting_tracks_the_recent_distribution() {
        let mut rng = Rng::seed_from(214);
        // Phase A: variance concentrated on axis 0; phase B: axis 1.
        let a = Mat::from_fn(300, 3, |_, c| if c == 0 { 3.0 * rng.normal() } else { 0.1 * rng.normal() });
        let b = Mat::from_fn(300, 3, |_, c| if c == 1 { 3.0 * rng.normal() } else { 0.1 * rng.normal() });
        let mut fading = CovTracker::new(3, Forgetting::Exponential(0.2));
        let mut keeping = CovTracker::new(3, Forgetting::Exponential(1.0));
        for chunk in 0..3 {
            let sl = Mat::from_fn(100, 3, |r, c| a[(chunk * 100 + r, c)]);
            fading.observe(&sl);
            keeping.observe(&sl);
        }
        for chunk in 0..3 {
            let sl = Mat::from_fn(100, 3, |r, c| b[(chunk * 100 + r, c)]);
            fading.observe(&sl);
            keeping.observe(&sl);
        }
        let cf = fading.covariance();
        let ck = keeping.covariance();
        // The forgetful tracker is dominated by phase B; the keeper
        // still carries half its mass from phase A.
        assert!(cf[(1, 1)] > 20.0 * cf[(0, 0)], "forgetful: {} vs {}", cf[(1, 1)], cf[(0, 0)]);
        assert!(ck[(0, 0)] > 0.25 * ck[(1, 1)], "keeper lost phase A");
    }

    #[test]
    fn property_stationary_stream_equivalence() {
        // For random dims / row counts / batch splits, feeding a row
        // stream through β=1 exponential AND a covering window both
        // reproduce the one-shot batch covariance.
        check(
            "covtracker stationary equivalence",
            PropConfig { cases: 24, seed: 0xC0F },
            |rng| {
                let d = rng.range(2, 9);
                let n = rng.range(4, 80);
                let rows = random_rows(n, d, rng);
                // Random split points.
                let mut cuts: Vec<usize> = (0..rng.range(0, 4)).map(|_| rng.range(1, n)).collect();
                cuts.push(0);
                cuts.push(n);
                cuts.sort_unstable();
                cuts.dedup();
                (rows, cuts)
            },
            |(rows, cuts)| {
                let d = rows.cols();
                let expect = batch_cov(rows);
                let mut exp = CovTracker::new(d, Forgetting::Exponential(1.0));
                let mut win = CovTracker::new(d, Forgetting::SlidingWindow(rows.rows()));
                for w in cuts.windows(2) {
                    let chunk = Mat::from_fn(w[1] - w[0], d, |r, c| rows[(w[0] + r, c)]);
                    exp.observe(&chunk);
                    win.observe(&chunk);
                }
                let de = (&exp.covariance() - &expect).max_abs();
                let dw = (&win.covariance() - &expect).max_abs();
                if de > 1e-12 {
                    return Err(format!("exponential deviates by {de:.3e}"));
                }
                if dw > 1e-12 {
                    return Err(format!("window deviates by {dw:.3e}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn covariance_into_matches_allocating_form() {
        let mut rng = Rng::seed_from(215);
        let rows = random_rows(40, 5, &mut rng);
        for mode in [Forgetting::Exponential(0.8), Forgetting::SlidingWindow(16)] {
            let mut t = CovTracker::new(5, mode);
            t.observe(&rows);
            let want = t.covariance();
            let mut out = Mat::from_fn(5, 5, |_, _| f64::NAN);
            t.covariance_into(&mut out);
            assert_eq!(want, out, "{mode:?}");
        }
    }

    #[test]
    #[should_panic(expected = "before any data")]
    fn covariance_before_data_panics() {
        let t = CovTracker::new(4, Forgetting::Exponential(0.9));
        let _ = t.covariance();
    }

    #[test]
    #[should_panic(expected = "forgetting factor")]
    fn rejects_zero_beta() {
        let _ = CovTracker::new(4, Forgetting::Exponential(0.0));
    }
}
