//! Streaming substrate: live data sources and incremental covariance.
//!
//! The batch pipeline solves one fixed [`crate::algo::problem::Problem`].
//! This module opens the *online* workload class — the setting of
//! decentralized eigendecomposition over graphs with drifting data
//! (PAPERS.md: arXiv 2209.01257) and the noisy power method — where each
//! agent observes a live row stream whose population covariance moves
//! over time:
//!
//! - [`source`] — the [`source::StreamSource`] trait (per-agent batch
//!   generators with an epoch clock and a ground-truth oracle) and
//!   [`source::SyntheticStream`], a drifting spiked-covariance generator
//!   covering four scenarios: stationary, slow subspace rotation, abrupt
//!   change-point, and spike-strength fade.
//! - [`cov`] — [`cov::CovTracker`], the incremental local covariance
//!   maintainer each agent owns: exponential forgetting or a sliding
//!   window with rank-1 update/downdate. With forgetting `1.0` (or a
//!   window covering the whole history) it reproduces the batch
//!   [`crate::data::partition`] covariance exactly.
//!
//! The online driver that runs *warm-started* DeEPCA epochs over these
//! pieces is [`crate::coordinator::online::OnlineSession`]: the paper's
//! subspace-tracking trick (reuse the previous `W`, spend a small
//! constant number of FastMix rounds per epoch) made operational on
//! drifting streams.

pub mod cov;
pub mod source;

pub use cov::{CovTracker, Forgetting};
pub use source::{Drift, StreamParams, StreamSource, SyntheticStream};
