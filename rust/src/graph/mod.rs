//! Network topology substrate.
//!
//! The paper assumes agents on a connected undirected graph with a gossip
//! weight matrix `L` that is symmetric, doubly stochastic, `0 ⪯ L ⪯ I`, and
//! `null(I − L) = span(1)` (§2.2). This module provides:
//!
//! - [`topology`] — graph generators (the paper's Erdős–Rényi p=0.5 setup
//!   plus ring/path/star/grid/complete/barbell for ablations);
//! - [`gossip`] — the paper's weight construction `L = I − M/λ_max(M)`
//!   (M = Laplacian), Metropolis–Hastings weights as an alternative, and
//!   the spectral quantities (λ₂, `1 − λ₂`) driving FastMix;
//! - [`sparse`] — [`sparse::SparseGossip`]: CSR weights with a Lanczos
//!   λ₂ estimate, the fleet-scale representation (nothing dense in the
//!   agent count; O(edges) per FastMix round);
//! - [`dynamic`] — [`dynamic::TopologySchedule`]: time-varying networks
//!   (static / periodic switching / seeded Markov per-link churn with a
//!   connectivity floor) consumed by the `SimNet` engine.

pub mod topology;
pub mod gossip;
pub mod sparse;
pub mod dynamic;
