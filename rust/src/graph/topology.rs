//! Undirected graph generators for the agent network.
//!
//! The paper's experiments use a random (Erdős–Rényi) network with edge
//! probability p = 0.5 over m = 50 agents. The ablation benches sweep the
//! other families to probe how `1 − λ₂(L)` (graph connectivity) drives the
//! required consensus rounds K — Theorem 1's `1/√(1−λ₂)` factor.

use crate::util::rng::Rng;

/// Undirected simple graph on `n` nodes, adjacency stored both as a list
/// and a lookup set.
#[derive(Clone, Debug)]
pub struct Topology {
    n: usize,
    /// Sorted neighbor lists.
    adj: Vec<Vec<usize>>,
    /// Human-readable family name (for reports).
    pub name: String,
}

impl Topology {
    /// Explicit edge-list constructor (used by [`crate::graph::dynamic`]
    /// to materialize churned snapshots). Duplicate edges are collapsed;
    /// self-loops and out-of-range endpoints panic.
    ///
    /// Runs in O(Σ degree · log degree): every endpoint is pushed
    /// unconditionally, then each list is sorted and deduplicated. (The
    /// previous `adj[a].contains(&b)` probe per insertion was O(Σ degree²)
    /// — quadratic on high-degree graphs and on every churn snapshot.)
    pub fn from_edges(n: usize, edges: &[(usize, usize)], name: &str) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n && a != b, "bad edge ({a},{b})");
            adj[a].push(b);
            adj[b].push(a);
        }
        for l in &mut adj {
            l.sort_unstable();
            l.dedup();
        }
        Topology { n, adj, name: name.to_string() }
    }

    /// Erdős–Rényi G(n, p), retried until connected (paper setup: p=0.5).
    pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Self {
        assert!(n >= 2);
        for attempt in 0..1000 {
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.chance(p) {
                        edges.push((i, j));
                    }
                }
            }
            let t = Topology::from_edges(n, &edges, &format!("erdos_renyi(p={p})"));
            if t.is_connected() {
                return t;
            }
            let _ = attempt;
        }
        panic!("erdos_renyi: failed to draw a connected graph (n={n}, p={p})");
    }

    /// Cycle graph.
    pub fn ring(n: usize) -> Self {
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Topology::from_edges(n, &edges, "ring")
    }

    /// Path graph (worst-case diameter).
    pub fn path(n: usize) -> Self {
        let edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1)).collect();
        Topology::from_edges(n, &edges, "path")
    }

    /// Star graph centered at node 0.
    pub fn star(n: usize) -> Self {
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Topology::from_edges(n, &edges, "star")
    }

    /// Complete graph.
    pub fn complete(n: usize) -> Self {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Topology::from_edges(n, &edges, "complete")
    }

    /// `rows × cols` 2-D grid.
    pub fn grid(rows: usize, cols: usize) -> Self {
        let n = rows * cols;
        let mut edges = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let id = r * cols + c;
                if c + 1 < cols {
                    edges.push((id, id + 1));
                }
                if r + 1 < rows {
                    edges.push((id, id + cols));
                }
            }
        }
        Topology::from_edges(n, &edges, &format!("grid({rows}x{cols})"))
    }

    /// Random `degree`-regular graph built as the union of `degree/2`
    /// independent random Hamiltonian cycles (so `degree` must be even and
    /// ≥ 2). Connected by construction — each cycle alone visits every
    /// node — and O(n) per cycle, so it scales to fleet-size n. A cycle
    /// that would duplicate an existing edge is redrawn (collisions are
    /// vanishingly rare at large n; a retry cap guards small n).
    pub fn random_regular(n: usize, degree: usize, rng: &mut Rng) -> Self {
        assert!(degree >= 2 && degree % 2 == 0, "degree must be even and ≥ 2");
        assert!(n > degree, "need n > degree for a simple {degree}-regular graph");
        assert!(n >= 3, "a Hamiltonian cycle needs n ≥ 3");
        let mut perm: Vec<usize> = (0..n).collect();
        let mut seen = std::collections::HashSet::with_capacity(n * degree / 2);
        let mut edges = Vec::with_capacity(n * degree / 2);
        for _cycle in 0..degree / 2 {
            let mut committed = false;
            'attempt: for _attempt in 0..200 {
                rng.shuffle(&mut perm);
                // Check the whole cycle is collision-free before committing.
                for w in 0..n {
                    let (a, b) = (perm[w], perm[(w + 1) % n]);
                    let key = (a.min(b) as u64) * n as u64 + a.max(b) as u64;
                    if seen.contains(&key) {
                        continue 'attempt;
                    }
                }
                for w in 0..n {
                    let (a, b) = (perm[w], perm[(w + 1) % n]);
                    let key = (a.min(b) as u64) * n as u64 + a.max(b) as u64;
                    seen.insert(key);
                    edges.push((a, b));
                }
                committed = true;
                break;
            }
            assert!(
                committed,
                "random_regular: could not place cycle {_cycle} without \
                 duplicate edges (n={n}, degree={degree})"
            );
        }
        Topology::from_edges(n, &edges, &format!("random_regular(d={degree})"))
    }

    /// Two complete cliques of size n/2 joined by a single bridge edge —
    /// pathological connectivity (tiny `1 − λ₂`), stress-tests FastMix.
    pub fn barbell(n: usize) -> Self {
        assert!(n >= 4 && n % 2 == 0);
        let h = n / 2;
        let mut edges = Vec::new();
        for i in 0..h {
            for j in (i + 1)..h {
                edges.push((i, j));
                edges.push((h + i, h + j));
            }
        }
        edges.push((h - 1, h));
        Topology::from_edges(n, &edges, "barbell")
    }

    /// Parse a whitespace-separated edge list (`u v` per line; blank
    /// lines and `#` comment lines skipped) into a topology on
    /// `max node + 1` agents — the `--topology file` loader. Fallible
    /// (malformed input comes from user files, not crate bugs): reports
    /// the offending line for non-numeric tokens, wrong token counts,
    /// and self-loops, and rejects empty inputs.
    pub fn from_edge_list_text(text: &str, name: &str) -> Result<Self, String> {
        let mut edges: Vec<(usize, usize)> = Vec::new();
        let mut max_node = 0usize;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (Some(a), Some(b), None) = (it.next(), it.next(), it.next()) else {
                return Err(format!(
                    "line {}: expected exactly two node ids, got {:?}",
                    lineno + 1,
                    line
                ));
            };
            let parse = |tok: &str| {
                tok.parse::<usize>().map_err(|_| {
                    format!("line {}: {:?} is not a node id", lineno + 1, tok)
                })
            };
            let (u, v) = (parse(a)?, parse(b)?);
            if u == v {
                return Err(format!("line {}: self-loop {u} {v}", lineno + 1));
            }
            max_node = max_node.max(u).max(v);
            edges.push((u, v));
        }
        if edges.is_empty() {
            return Err("edge list has no edges".to_string());
        }
        Ok(Topology::from_edges(max_node + 1, &edges, name))
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Neighbors of node `i` (sorted).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Insert the undirected edge `{a, b}`, keeping both adjacency lists
    /// sorted. Idempotent; O(degree) per endpoint. Used by the churn
    /// machinery to maintain a snapshot incrementally — when edges only
    /// ever toggle within a fixed base set, list capacities warm up to
    /// the base degree and steady-state toggles never reallocate.
    pub fn insert_edge(&mut self, a: usize, b: usize) {
        assert!(a < self.n && b < self.n && a != b, "bad edge ({a},{b})");
        if let Err(pos) = self.adj[a].binary_search(&b) {
            self.adj[a].insert(pos, b);
        }
        if let Err(pos) = self.adj[b].binary_search(&a) {
            self.adj[b].insert(pos, a);
        }
    }

    /// Remove the undirected edge `{a, b}` if present (sorted-list
    /// surgery, O(degree) per endpoint, never reallocates).
    pub fn remove_edge(&mut self, a: usize, b: usize) {
        assert!(a < self.n && b < self.n && a != b, "bad edge ({a},{b})");
        if let Ok(pos) = self.adj[a].binary_search(&b) {
            self.adj[a].remove(pos);
        }
        if let Ok(pos) = self.adj[b].binary_search(&a) {
            self.adj[b].remove(pos);
        }
    }

    /// Total number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    /// All undirected edges (i < j).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for i in 0..self.n {
            for &j in &self.adj[i] {
                if i < j {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Graph diameter via BFS from every node (small n only).
    pub fn diameter(&self) -> usize {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut queue = std::collections::VecDeque::from([s]);
            while let Some(u) = queue.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        queue.push_back(v);
                    }
                }
            }
            diam = diam.max(*dist.iter().max().unwrap());
        }
        diam
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let t = Topology::ring(6);
        assert_eq!(t.n(), 6);
        assert_eq!(t.num_edges(), 6);
        for i in 0..6 {
            assert_eq!(t.degree(i), 2);
        }
        assert!(t.is_connected());
        assert_eq!(t.diameter(), 3);
    }

    #[test]
    fn path_structure() {
        let t = Topology::path(5);
        assert_eq!(t.num_edges(), 4);
        assert_eq!(t.degree(0), 1);
        assert_eq!(t.degree(2), 2);
        assert_eq!(t.diameter(), 4);
    }

    #[test]
    fn star_structure() {
        let t = Topology::star(7);
        assert_eq!(t.degree(0), 6);
        for i in 1..7 {
            assert_eq!(t.degree(i), 1);
        }
        assert_eq!(t.diameter(), 2);
    }

    #[test]
    fn complete_structure() {
        let t = Topology::complete(5);
        assert_eq!(t.num_edges(), 10);
        assert_eq!(t.diameter(), 1);
    }

    #[test]
    fn grid_structure() {
        let t = Topology::grid(3, 4);
        assert_eq!(t.n(), 12);
        assert_eq!(t.num_edges(), 3 * 3 + 2 * 4); // horizontal + vertical
        assert!(t.is_connected());
        assert_eq!(t.diameter(), 2 + 3);
    }

    #[test]
    fn barbell_structure() {
        let t = Topology::barbell(10);
        assert!(t.is_connected());
        // Two K5s (10 edges each) + bridge.
        assert_eq!(t.num_edges(), 21);
    }

    #[test]
    fn erdos_renyi_connected_and_symmetric() {
        let mut rng = Rng::seed_from(61);
        let t = Topology::erdos_renyi(50, 0.5, &mut rng);
        assert!(t.is_connected());
        for i in 0..50 {
            for &j in t.neighbors(i) {
                assert!(t.neighbors(j).contains(&i), "asymmetric adjacency");
                assert_ne!(i, j, "self loop");
            }
        }
        // p=0.5 on 50 nodes: expected degree ≈ 24.5.
        let mean_deg: f64 =
            (0..50).map(|i| t.degree(i) as f64).sum::<f64>() / 50.0;
        assert!((mean_deg - 24.5).abs() < 6.0, "mean degree {mean_deg}");
    }

    #[test]
    fn erdos_renyi_deterministic_per_seed() {
        let t1 = Topology::erdos_renyi(20, 0.3, &mut Rng::seed_from(5));
        let t2 = Topology::erdos_renyi(20, 0.3, &mut Rng::seed_from(5));
        assert_eq!(t1.edges(), t2.edges());
    }

    #[test]
    fn edges_are_canonical() {
        let t = Topology::ring(4);
        for (i, j) in t.edges() {
            assert!(i < j);
        }
        assert_eq!(t.edges().len(), t.num_edges());
    }

    #[test]
    fn disconnected_detected() {
        // Two disjoint edges on 4 nodes.
        let t = Topology::from_edges(4, &[(0, 1), (2, 3)], "manual");
        assert!(!t.is_connected());
    }

    #[test]
    fn from_edges_collapses_duplicates_and_reversals() {
        let t = Topology::from_edges(
            5,
            &[(0, 1), (1, 0), (0, 1), (2, 3), (3, 2), (1, 4)],
            "dups",
        );
        assert_eq!(t.neighbors(0), &[1]);
        assert_eq!(t.neighbors(1), &[0, 4]);
        assert_eq!(t.neighbors(2), &[3]);
        assert_eq!(t.num_edges(), 3);
    }

    #[test]
    fn insert_remove_edge_keeps_sorted_symmetric_adjacency() {
        let mut t = Topology::ring(6);
        t.insert_edge(0, 3);
        t.insert_edge(0, 3); // idempotent
        assert_eq!(t.neighbors(0), &[1, 3, 5]);
        assert_eq!(t.neighbors(3), &[0, 2, 4]);
        t.remove_edge(3, 0);
        t.remove_edge(3, 0); // idempotent
        assert_eq!(t.neighbors(0), &[1, 5]);
        assert_eq!(t.neighbors(3), &[2, 4]);
        assert_eq!(t.edges(), Topology::ring(6).edges());
    }

    #[test]
    fn edge_list_text_round_trips() {
        let t = Topology::from_edge_list_text(
            "# a ring of four with a chord\n0 1\n1 2\n\n2 3\n3 0\n0 2\n",
            "file",
        )
        .expect("well-formed edge list");
        assert_eq!(t.n(), 4);
        assert_eq!(t.num_edges(), 5);
        assert_eq!(t.neighbors(0), &[1, 2, 3]);
        assert!(t.is_connected());
    }

    #[test]
    fn edge_list_text_rejects_malformed_input() {
        for (text, needle) in [
            ("0 1\n2\n", "exactly two"),
            ("0 1 2\n", "exactly two"),
            ("0 x\n", "not a node id"),
            ("3 3\n", "self-loop"),
            ("# only comments\n\n", "no edges"),
        ] {
            let err = Topology::from_edge_list_text(text, "bad")
                .expect_err(&format!("{text:?} must be rejected"));
            assert!(err.contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn random_regular_structure() {
        let mut rng = Rng::seed_from(17);
        let t = Topology::random_regular(40, 4, &mut rng);
        assert_eq!(t.n(), 40);
        assert!(t.is_connected());
        for i in 0..40 {
            assert_eq!(t.degree(i), 4, "node {i}");
            for &j in t.neighbors(i) {
                assert!(t.neighbors(j).contains(&i), "asymmetric adjacency");
                assert_ne!(i, j, "self loop");
            }
        }
        // Deterministic per seed.
        let t2 = Topology::random_regular(40, 4, &mut Rng::seed_from(17));
        assert_eq!(t.edges(), t2.edges());
    }
}
