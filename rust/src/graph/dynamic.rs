//! Time-varying topologies: the network a gossip round actually sees.
//!
//! The paper's analysis assumes one fixed connected graph, but the
//! regimes studied by the related work — decentralized eigendecomposition
//! over *time-varying* graphs and power iterations under lossy links —
//! need the network itself to evolve while the algorithm runs. This
//! module provides [`TopologySchedule`], a deterministic map from the
//! global gossip-round counter to the topology in force during that
//! round:
//!
//! - **static** — one graph forever (degenerates to the paper's setup);
//! - **periodic** — cycle through a fixed list of graphs, switching every
//!   `rounds_per_epoch` gossip rounds;
//! - **Markov churn** — every non-protected link of a base graph is an
//!   independent two-state Markov chain (up → down with `p_drop`, down →
//!   up with `p_revive` per epoch), driven by a seeded [`Rng`] so the
//!   whole sample path replays bit-for-bit from the seed.
//!
//! Churn can be configured with a **connectivity floor**: a BFS spanning
//! tree of the base graph whose edges are immune to churn, so every
//! epoch's snapshot stays connected (gossip matrices remain well-defined;
//! `prop_gossip.rs` asserts this property). Without the floor, epochs may
//! disconnect — fine for studying failure, but
//! [`crate::consensus::simnet::SimNet`] requires connected epochs to
//! build its gossip weights.
//!
//! Time is counted in *gossip rounds*, not power iterations: an epoch of
//! `rounds_per_epoch = K` with DeEPCA's `consensus_rounds = K` changes
//! the network once per power iteration; `rounds_per_epoch = 1` churns on
//! every single exchange.

use super::topology::Topology;
use crate::util::rng::Rng;

/// Per-link Markov churn state over a base graph.
#[derive(Clone, Debug)]
struct MarkovChurn {
    base: Topology,
    /// Canonical (i < j) edges of the base graph.
    edges: Vec<(usize, usize)>,
    /// Edges in the connectivity floor (immune to churn), if enabled.
    protected: Vec<bool>,
    /// Current up/down state per base edge.
    up: Vec<bool>,
    p_drop: f64,
    p_revive: f64,
    rng: Rng,
    /// Epoch the `up` vector corresponds to.
    epoch: u64,
    /// Snapshot for `epoch`, maintained incrementally: each link toggle
    /// is O(degree) sorted-list surgery on this topology instead of a
    /// full O(edges) rebuild per epoch. Because live edges are always a
    /// subset of the base graph and the initial snapshot is the full
    /// base, adjacency capacities are at their high-water mark from the
    /// start — steady-state toggles never allocate.
    snapshot: Topology,
    /// Toggles `(a, b, now_up)` accumulated since the last
    /// [`TopologySchedule::advance_to`] call, in chain order. Applying
    /// them in order to the previous snapshot's edge set reproduces the
    /// current snapshot. Cleared at the start of each batch so it never
    /// grows beyond one batch's churn.
    deltas: Vec<(usize, usize, bool)>,
}

impl MarkovChurn {
    fn new(base: Topology, p_drop: f64, p_revive: f64, seed: u64, floor: bool) -> Self {
        assert!((0.0..=1.0).contains(&p_drop), "p_drop out of [0,1]");
        assert!((0.0..=1.0).contains(&p_revive), "p_revive out of [0,1]");
        assert!(base.is_connected(), "churn base graph must be connected");
        let edges = base.edges();
        let protected = if floor {
            spanning_tree_mask(&base, &edges)
        } else {
            vec![false; edges.len()]
        };
        let up = vec![true; edges.len()];
        let mut snapshot = base.clone();
        snapshot.name = "markov-churn".to_string();
        MarkovChurn {
            base,
            edges,
            protected,
            up,
            p_drop,
            p_revive,
            rng: Rng::seed_from(seed),
            epoch: 0,
            snapshot,
            deltas: Vec::new(),
        }
    }

    /// Advance the per-link chains by one epoch, applying each toggle to
    /// the persistent snapshot in place and recording it in `deltas` —
    /// O(changed edges · degree) per epoch with zero steady-state
    /// allocation. (The previous version collected a fresh live-edge
    /// `Vec` and rebuilt a full `Topology` every epoch: with
    /// `rounds_per_epoch = 1` that was a per-gossip-round allocation
    /// inside `SimNet::fastmix`.) The `rng` consumption order and the
    /// resulting adjacency are bit-identical to the rebuild path, so
    /// seeded sample paths replay unchanged.
    fn advance_one(&mut self) {
        for (idx, state) in self.up.iter_mut().enumerate() {
            if self.protected[idx] {
                continue; // floor edges never churn
            }
            let was = *state;
            *state = if was {
                !self.rng.chance(self.p_drop)
            } else {
                self.rng.chance(self.p_revive)
            };
            if *state != was {
                let (a, b) = self.edges[idx];
                if *state {
                    self.snapshot.insert_edge(a, b);
                } else {
                    self.snapshot.remove_edge(a, b);
                }
                self.deltas.push((a, b, *state));
            }
        }
        self.epoch += 1;
    }
}

/// Mark a BFS spanning tree of `base` inside its canonical edge list.
fn spanning_tree_mask(base: &Topology, edges: &[(usize, usize)]) -> Vec<bool> {
    let n = base.n();
    let mut parent: Vec<Option<usize>> = vec![None; n];
    let mut seen = vec![false; n];
    seen[0] = true;
    let mut queue = std::collections::VecDeque::from([0usize]);
    while let Some(u) = queue.pop_front() {
        for &v in base.neighbors(u) {
            if !seen[v] {
                seen[v] = true;
                parent[v] = Some(u);
                queue.push_back(v);
            }
        }
    }
    let mut tree: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    for (v, p) in parent.iter().enumerate() {
        if let Some(u) = p {
            tree.insert((v.min(*u), v.max(*u)));
        }
    }
    edges.iter().map(|e| tree.contains(e)).collect()
}

#[derive(Clone, Debug)]
enum Kind {
    Fixed(Topology),
    Periodic(Vec<Topology>),
    Markov(MarkovChurn),
}

/// What changed between two consecutive [`TopologySchedule::advance_to`]
/// calls — the incremental-epoch contract that lets `SimNet` skip
/// gossip-weight rebuilds when nothing moved.
#[derive(Debug)]
pub enum EpochStep<'a> {
    /// Identical topology to the previous `advance_to` result: the
    /// consumer can keep its weights untouched (the O(1) fast path —
    /// the common case under light churn).
    Unchanged(&'a Topology),
    /// A structurally new topology (first query, or a periodic phase
    /// switch): full rebuild required.
    Switched(&'a Topology),
    /// The same evolving graph with the listed `(a, b, now_up)` link
    /// toggles applied since the previous result, in chain order —
    /// O(changed edges) information for incremental consumers.
    Deltas(&'a Topology, &'a [(usize, usize, bool)]),
}

impl<'a> EpochStep<'a> {
    /// The topology now in force, whatever the step kind.
    pub fn topology(&self) -> &'a Topology {
        match self {
            EpochStep::Unchanged(t) | EpochStep::Switched(t) => t,
            EpochStep::Deltas(t, _) => t,
        }
    }

    /// Whether the topology differs from the previous `advance_to`
    /// result.
    pub fn changed(&self) -> bool {
        !matches!(self, EpochStep::Unchanged(_))
    }
}

/// Deterministic round → topology map. See the module docs for the
/// three schedule families.
#[derive(Clone, Debug)]
pub struct TopologySchedule {
    rounds_per_epoch: usize,
    kind: Kind,
    /// Epoch of the last `advance_to` call (None before the first).
    last_epoch: Option<u64>,
}

impl TopologySchedule {
    /// The degenerate schedule: one graph for the whole run.
    pub fn fixed(topo: Topology) -> Self {
        assert!(topo.is_connected(), "schedule needs a connected graph");
        TopologySchedule {
            rounds_per_epoch: 1,
            kind: Kind::Fixed(topo),
            last_epoch: None,
        }
    }

    /// Cycle through `phases`, switching every `rounds_per_epoch` gossip
    /// rounds. Every phase must be connected and on the same node set.
    pub fn periodic(phases: Vec<Topology>, rounds_per_epoch: usize) -> Self {
        assert!(!phases.is_empty(), "periodic schedule needs ≥ 1 phase");
        assert!(rounds_per_epoch >= 1, "rounds_per_epoch must be ≥ 1");
        let n = phases[0].n();
        for p in &phases {
            assert_eq!(p.n(), n, "periodic phases must share the node set");
            assert!(p.is_connected(), "periodic phase must be connected");
        }
        TopologySchedule {
            rounds_per_epoch,
            kind: Kind::Periodic(phases),
            last_epoch: None,
        }
    }

    /// Seeded per-link Markov churn over `base` **with** the connectivity
    /// floor (a spanning tree of `base` never churns, so every epoch is
    /// connected).
    pub fn markov(
        base: Topology,
        p_drop: f64,
        p_revive: f64,
        seed: u64,
        rounds_per_epoch: usize,
    ) -> Self {
        Self::markov_with_floor(base, p_drop, p_revive, seed, rounds_per_epoch, true)
    }

    /// Markov churn with the connectivity floor made explicit. With
    /// `floor = false`, epochs may disconnect — usable for studying the
    /// schedule itself, but not by `SimNet` (gossip weights need a
    /// connected graph).
    pub fn markov_with_floor(
        base: Topology,
        p_drop: f64,
        p_revive: f64,
        seed: u64,
        rounds_per_epoch: usize,
        floor: bool,
    ) -> Self {
        assert!(rounds_per_epoch >= 1, "rounds_per_epoch must be ≥ 1");
        TopologySchedule {
            rounds_per_epoch,
            kind: Kind::Markov(MarkovChurn::new(base, p_drop, p_revive, seed, floor)),
            last_epoch: None,
        }
    }

    /// Number of nodes (constant across epochs).
    pub fn n(&self) -> usize {
        match &self.kind {
            Kind::Fixed(t) => t.n(),
            Kind::Periodic(ps) => ps[0].n(),
            Kind::Markov(mc) => mc.base.n(),
        }
    }

    /// Whether the topology ever changes (static schedules let callers
    /// skip per-epoch gossip-weight rebuilds).
    pub fn is_static(&self) -> bool {
        matches!(self.kind, Kind::Fixed(_))
    }

    /// Epoch index in force during gossip round `round` (0-based global
    /// counter). Static schedules live entirely in epoch 0.
    pub fn epoch_of(&self, round: u64) -> u64 {
        match self.kind {
            Kind::Fixed(_) => 0,
            _ => round / self.rounds_per_epoch as u64,
        }
    }

    /// The topology in force during `epoch`.
    ///
    /// Markov churn is a stateful chain: epochs must be queried in
    /// non-decreasing order (the engine's natural access pattern), and
    /// the chain is advanced deterministically from its seed. Panics on
    /// an out-of-order query.
    pub fn topology_at_epoch(&mut self, epoch: u64) -> Topology {
        match &mut self.kind {
            Kind::Fixed(t) => t.clone(),
            Kind::Periodic(ps) => ps[(epoch % ps.len() as u64) as usize].clone(),
            Kind::Markov(mc) => {
                assert!(
                    epoch >= mc.epoch,
                    "markov schedule queried backwards ({} after {})",
                    epoch,
                    mc.epoch
                );
                mc.deltas.clear();
                while mc.epoch < epoch {
                    mc.advance_one();
                }
                mc.snapshot.clone()
            }
        }
    }

    /// Advance the schedule to `epoch` and report *what changed* since
    /// the previous `advance_to` result — the allocation-free engine
    /// path. Unlike [`TopologySchedule::topology_at_epoch`] (which
    /// clones a `Topology` per query) this hands back a borrow plus an
    /// incremental change description, so a `SimNet` epoch tick is O(1)
    /// when nothing churned and O(changed edges) bookkeeping when
    /// something did.
    ///
    /// Markov schedules must be advanced in non-decreasing epoch order
    /// (panics otherwise, like `topology_at_epoch`). A schedule instance
    /// should be driven through *one* of the two access APIs, not both
    /// interleaved: `topology_at_epoch` does not update the step
    /// tracking.
    pub fn advance_to(&mut self, epoch: u64) -> EpochStep<'_> {
        let prev = self.last_epoch;
        self.last_epoch = Some(epoch);
        match &mut self.kind {
            Kind::Fixed(t) => {
                if prev.is_none() {
                    EpochStep::Switched(t)
                } else {
                    EpochStep::Unchanged(t)
                }
            }
            Kind::Periodic(ps) => {
                let len = ps.len() as u64;
                let phase = (epoch % len) as usize;
                match prev {
                    Some(p) if (p % len) as usize == phase => {
                        EpochStep::Unchanged(&ps[phase])
                    }
                    _ => EpochStep::Switched(&ps[phase]),
                }
            }
            Kind::Markov(mc) => {
                assert!(
                    epoch >= mc.epoch,
                    "markov schedule queried backwards ({} after {})",
                    epoch,
                    mc.epoch
                );
                mc.deltas.clear();
                while mc.epoch < epoch {
                    mc.advance_one();
                }
                if prev.is_none() {
                    EpochStep::Switched(&mc.snapshot)
                } else if mc.deltas.is_empty() {
                    EpochStep::Unchanged(&mc.snapshot)
                } else {
                    EpochStep::Deltas(&mc.snapshot, &mc.deltas)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_schedule_never_changes() {
        let mut s = TopologySchedule::fixed(Topology::ring(6));
        assert!(s.is_static());
        assert_eq!(s.epoch_of(0), 0);
        assert_eq!(s.epoch_of(999), 0);
        let a = s.topology_at_epoch(0);
        let b = s.topology_at_epoch(7);
        assert_eq!(a.edges(), b.edges());
    }

    #[test]
    fn periodic_cycles_phases() {
        let mut s = TopologySchedule::periodic(
            vec![Topology::ring(6), Topology::star(6), Topology::complete(6)],
            4,
        );
        assert_eq!(s.epoch_of(0), 0);
        assert_eq!(s.epoch_of(3), 0);
        assert_eq!(s.epoch_of(4), 1);
        assert_eq!(s.epoch_of(11), 2);
        assert_eq!(s.topology_at_epoch(0).edges(), Topology::ring(6).edges());
        assert_eq!(s.topology_at_epoch(1).edges(), Topology::star(6).edges());
        assert_eq!(s.topology_at_epoch(3).edges(), Topology::ring(6).edges());
    }

    #[test]
    fn markov_is_deterministic_per_seed() {
        let base = Topology::complete(8);
        let mut a = TopologySchedule::markov(base.clone(), 0.4, 0.3, 42, 1);
        let mut b = TopologySchedule::markov(base, 0.4, 0.3, 42, 1);
        for epoch in 0..25 {
            assert_eq!(
                a.topology_at_epoch(epoch).edges(),
                b.topology_at_epoch(epoch).edges(),
                "sample paths diverged at epoch {epoch}"
            );
        }
    }

    #[test]
    fn markov_actually_churns() {
        let base = Topology::complete(8);
        let mut s = TopologySchedule::markov(base.clone(), 0.5, 0.5, 7, 1);
        let changed = (1..20)
            .any(|e| s.topology_at_epoch(e).edges() != base.edges());
        assert!(changed, "no epoch differed from the base graph");
    }

    #[test]
    fn floor_keeps_every_epoch_connected() {
        // Aggressive drop on a sparse base: without the floor this would
        // disconnect almost immediately.
        let base = Topology::erdos_renyi(10, 0.3, &mut Rng::seed_from(9));
        let mut s = TopologySchedule::markov(base, 0.7, 0.2, 11, 1);
        for epoch in 0..50 {
            assert!(
                s.topology_at_epoch(epoch).is_connected(),
                "floored churn disconnected at epoch {epoch}"
            );
        }
    }

    #[test]
    fn markov_rejects_backward_queries() {
        let mut s = TopologySchedule::markov(Topology::ring(5), 0.3, 0.3, 1, 1);
        let _ = s.topology_at_epoch(5);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.topology_at_epoch(2)
        }));
        assert!(r.is_err(), "backward query must panic");
    }

    #[test]
    fn incremental_snapshot_matches_from_edges_rebuild() {
        // The persistent snapshot maintained by sorted-list surgery must
        // stay identical to what a full rebuild from the live edge set
        // would produce, at every epoch.
        let base = Topology::erdos_renyi(12, 0.4, &mut Rng::seed_from(21));
        let mut s =
            TopologySchedule::markov_with_floor(base, 0.4, 0.4, 33, 1, false);
        for epoch in 1..40 {
            let snap = s.topology_at_epoch(epoch);
            let Kind::Markov(mc) = &s.kind else { unreachable!() };
            let live: Vec<(usize, usize)> = mc
                .edges
                .iter()
                .zip(mc.up.iter())
                .filter(|p| *p.1)
                .map(|p| *p.0)
                .collect();
            let rebuilt =
                Topology::from_edges(mc.base.n(), &live, "markov-churn");
            assert_eq!(
                snap.edges(),
                rebuilt.edges(),
                "incremental snapshot diverged at epoch {epoch}"
            );
        }
    }

    #[test]
    fn advance_to_reports_exact_deltas() {
        let base = Topology::erdos_renyi(10, 0.5, &mut Rng::seed_from(4));
        let mut s = TopologySchedule::markov(base, 0.3, 0.3, 99, 1);
        let mut edges = s.advance_to(0).topology().edges();
        for epoch in 1..30 {
            let step = s.advance_to(epoch);
            let after = step.topology().edges();
            match step {
                EpochStep::Switched(_) => panic!("markov never switches"),
                EpochStep::Unchanged(_) => {
                    assert_eq!(edges, after, "Unchanged but edges differ")
                }
                EpochStep::Deltas(_, changes) => {
                    assert!(!changes.is_empty());
                    for &(a, b, up) in changes {
                        let e = (a.min(b), a.max(b));
                        match (edges.binary_search(&e), up) {
                            (Err(pos), true) => edges.insert(pos, e),
                            (Ok(pos), false) => {
                                edges.remove(pos);
                            }
                            (found, _) => panic!(
                                "delta ({a},{b},{up}) inconsistent: {found:?}"
                            ),
                        }
                    }
                    assert_eq!(
                        edges, after,
                        "deltas don't reproduce epoch {epoch}"
                    );
                }
            }
        }
    }

    #[test]
    fn advance_to_frozen_chain_is_unchanged() {
        // p_drop = p_revive = 0: every epoch after the first must take
        // the O(1) Unchanged fast path.
        let mut s = TopologySchedule::markov(Topology::ring(8), 0.0, 0.0, 5, 1);
        assert!(matches!(s.advance_to(0), EpochStep::Switched(_)));
        for epoch in 1..10 {
            assert!(
                !s.advance_to(epoch).changed(),
                "frozen chain reported change at epoch {epoch}"
            );
        }
        // Fixed and periodic schedules take the same fast path.
        let mut f = TopologySchedule::fixed(Topology::ring(5));
        assert!(f.advance_to(0).changed());
        assert!(!f.advance_to(3).changed());
        let mut p = TopologySchedule::periodic(
            vec![Topology::ring(6), Topology::star(6)],
            1,
        );
        assert!(p.advance_to(0).changed());
        assert!(!p.advance_to(2).changed(), "same phase: unchanged");
        assert!(p.advance_to(3).changed(), "phase switch");
    }

    #[test]
    fn epoch_of_respects_rounds_per_epoch() {
        let s = TopologySchedule::markov(Topology::ring(5), 0.1, 0.1, 3, 8);
        assert_eq!(s.epoch_of(0), 0);
        assert_eq!(s.epoch_of(7), 0);
        assert_eq!(s.epoch_of(8), 1);
        assert_eq!(s.epoch_of(17), 2);
    }
}
