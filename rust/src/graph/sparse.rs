//! Sparse CSR gossip weights: the fleet-scale representation.
//!
//! The paper's whole point is per-agent locality — each FastMix round
//! touches only a node's neighbors — so at n = 10⁵–10⁶ agents nothing may
//! be dense in n. [`SparseGossip`] stores one CSR row per agent (neighbor
//! indices + weights in ascending column order, diagonal included),
//! builds Metropolis–Hastings weights straight from a [`Topology`]
//! without materializing an n×n matrix, and estimates the spectrum
//! (λ₂, λ_min) with a seeded deterministic Lanczos iteration on the
//! sparse operator instead of a dense `eig_sym`.
//!
//! Determinism and parity contracts:
//! - Rows store exactly the nonzero entries in ascending column order —
//!   the same floating-point accumulation sequence the dense
//!   `chebyshev_row_update` produces by skipping `w == 0.0` while
//!   scanning ascending columns. Compressing a [`GossipMatrix`] with
//!   [`SparseGossip::from_gossip`] therefore yields *bit-identical*
//!   mixing results.
//! - The λ₂ estimator is fully deterministic (fixed seed, sequential
//!   arithmetic). On graphs small enough for a dense cross-check it runs
//!   Lanczos with full reorthogonalization to completion, agreeing with
//!   `eig_sym` to ~1e-12; on large graphs it caps the iteration count and
//!   *underestimates* λ₂ (Rayleigh–Ritz bounds from below), which only
//!   slows the Chebyshev recursion — it never destabilizes it.

use crate::linalg::Mat;
use crate::util::rng::Rng;

use super::gossip::{GossipInfo, GossipMatrix};
use super::topology::Topology;

/// Up to this agent count the spectrum estimator keeps the full Lanczos
/// basis and reorthogonalizes every step — essentially exact (matches
/// `eig_sym` to ~1e-12). Beyond it, storage drops to three vectors.
const FULL_REORTHO_MAX_M: usize = 512;

/// Lanczos iteration cap for large graphs. Extreme Ritz values converge
/// first (Kaniel–Paige), so this is plenty to get a usable λ₂ on
/// fleet-scale rings/grids; any remaining underestimate is benign (see
/// module docs).
const LARGE_GRAPH_MAX_ITERS: usize = 128;

/// Seed for the deterministic Lanczos start vector.
const LANCZOS_SEED: u64 = 0x5EED_CA11;

/// Gossip weights in CSR form plus their estimated spectrum.
///
/// Memory is O(n + nnz) where nnz = n + 2·edges (each row holds its
/// neighbors and its own diagonal).
#[derive(Clone, Debug)]
pub struct SparseGossip {
    m: usize,
    row_ptr: Vec<usize>,
    cols: Vec<usize>,
    vals: Vec<f64>,
    edges: usize,
    /// Second-largest eigenvalue λ₂(L) (estimated; clamped below 1).
    pub lambda2: f64,
    /// Smallest eigenvalue of L, capped at 0 (Metropolis weights can be
    /// indefinite; the Chebyshev step size accounts for it).
    pub lambda_min: f64,
}

/// Reusable scratch for [`SparseGossip::estimate_spectrum`] so churn-epoch
/// re-estimates allocate nothing in steady state (buffers warm up on
/// first use and are reused thereafter).
#[derive(Debug, Default)]
pub struct SpectrumWorkspace {
    v_prev: Vec<f64>,
    v_cur: Vec<f64>,
    w: Vec<f64>,
    alpha: Vec<f64>,
    beta: Vec<f64>,
    /// Full Lanczos basis, allocated only in small-m reortho mode.
    basis: Vec<Vec<f64>>,
}

impl SpectrumWorkspace {
    /// Fresh, empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, m: usize, iters: usize, reortho: bool) {
        self.v_prev.resize(m, 0.0);
        self.v_cur.resize(m, 0.0);
        self.w.resize(m, 0.0);
        self.alpha.reserve(iters);
        self.beta.reserve(iters);
        if reortho {
            for b in &mut self.basis {
                b.resize(m, 0.0);
            }
            while self.basis.len() < iters {
                self.basis.push(vec![0.0; m]);
            }
        }
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Subtract the mean: projects out the all-ones eigenvector of `L`.
fn project_out_mean(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

/// Extreme eigenvalues of the symmetric tridiagonal (alpha; beta) via a
/// Sturm-sequence bisection — deterministic and allocation-free, so
/// churn-epoch spectrum refreshes stay off the allocator.
fn tridiag_extremes(alpha: &[f64], beta: &[f64]) -> (f64, f64) {
    let k = alpha.len();
    assert!(k >= 1 && beta.len() + 1 == k);
    if k == 1 {
        return (alpha[0], alpha[0]);
    }
    // Gershgorin interval containing the whole spectrum.
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..k {
        let mut r = 0.0;
        if i > 0 {
            r += beta[i - 1].abs();
        }
        if i + 1 < k {
            r += beta[i].abs();
        }
        lo = lo.min(alpha[i] - r);
        hi = hi.max(alpha[i] + r);
    }
    // Sturm count: number of eigenvalues strictly below x (LDLᵀ pivots).
    let count_below = |x: f64| -> usize {
        let mut cnt = 0usize;
        let mut d = 1.0f64;
        for i in 0..k {
            let b2 = if i > 0 { beta[i - 1] * beta[i - 1] } else { 0.0 };
            d = (alpha[i] - x) - b2 / d;
            if d == 0.0 {
                d = -1e-300;
            }
            if d < 0.0 {
                cnt += 1;
            }
        }
        cnt
    };
    let bisect = |want_at_least: usize| -> f64 {
        let mut a = lo - 1.0;
        let mut b = hi + 1.0;
        for _ in 0..120 {
            let mid = 0.5 * (a + b);
            if count_below(mid) >= want_at_least {
                b = mid;
            } else {
                a = mid;
            }
        }
        0.5 * (a + b)
    };
    (bisect(1), bisect(k))
}

impl SparseGossip {
    fn empty() -> Self {
        SparseGossip {
            m: 0,
            row_ptr: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
            edges: 0,
            lambda2: 0.0,
            lambda_min: 0.0,
        }
    }

    /// Metropolis–Hastings weights over `topo` with an estimated
    /// spectrum — the cold constructor (checks connectivity, allocates
    /// its own scratch). For churn-epoch rebuilds use
    /// [`SparseGossip::rebuild_metropolis`] +
    /// [`SparseGossip::estimate_spectrum`] with persistent buffers.
    pub fn metropolis(topo: &Topology) -> Self {
        assert!(topo.n() >= 2, "sparse gossip needs ≥ 2 agents");
        assert!(topo.is_connected(), "gossip matrix needs a connected graph");
        let mut sg = Self::empty();
        sg.rebuild_metropolis(topo);
        let mut ws = SpectrumWorkspace::new();
        sg.estimate_spectrum(&mut ws);
        sg
    }

    /// Rebuild the CSR weights for `topo` in place, reusing this struct's
    /// buffers (no allocation once capacities have warmed up — under
    /// Markov churn the live graph is a subgraph of the base graph, so
    /// the epoch-0 build is the capacity high-water mark). Does not touch
    /// the stored spectrum; callers that need a fresh λ₂ follow up with
    /// [`SparseGossip::estimate_spectrum`]. Connectivity is the caller's
    /// contract (churn schedules keep a spanning-tree floor).
    ///
    /// Weight convention matches [`GossipMatrix::metropolis`]:
    /// `L_ij = 1/(1+max(d_i,d_j))` on edges, diagonal fills the row to 1.
    /// Each row stores its entries in ascending column order (diagonal in
    /// place), the same accumulation sequence the dense kernel uses.
    pub fn rebuild_metropolis(&mut self, topo: &Topology) {
        let m = topo.n();
        self.m = m;
        self.row_ptr.clear();
        self.cols.clear();
        self.vals.clear();
        self.row_ptr.push(0);
        let mut deg_sum = 0usize;
        for i in 0..m {
            let di = topo.degree(i);
            let mut off = 0.0;
            let mut diag_idx = usize::MAX;
            for &j in topo.neighbors(i) {
                if diag_idx == usize::MAX && j > i {
                    diag_idx = self.cols.len();
                    self.cols.push(i);
                    self.vals.push(0.0);
                }
                let w = 1.0 / (1.0 + di.max(topo.degree(j)) as f64);
                self.cols.push(j);
                self.vals.push(w);
                off += w;
            }
            if diag_idx == usize::MAX {
                diag_idx = self.cols.len();
                self.cols.push(i);
                self.vals.push(0.0);
            }
            self.vals[diag_idx] = 1.0 - off;
            deg_sum += di;
            self.row_ptr.push(self.cols.len());
        }
        self.edges = deg_sum / 2;
    }

    /// Compress a validated dense [`GossipMatrix`] to CSR, copying its
    /// exact spectrum. Rows keep the nonzeros in ascending column order,
    /// so mixing through the sparse kernel is bit-identical to the dense
    /// kernel (which skips `w == 0.0` while scanning ascending columns).
    pub fn from_gossip(g: &GossipMatrix) -> Self {
        let m = g.m();
        let mut sg = Self::empty();
        sg.m = m;
        sg.row_ptr.reserve(m + 1);
        sg.row_ptr.push(0);
        let mut off_nnz = 0usize;
        for i in 0..m {
            for (j, &w) in g.weights.row(i).iter().enumerate() {
                if w != 0.0 {
                    sg.cols.push(j);
                    sg.vals.push(w);
                    if j != i {
                        off_nnz += 1;
                    }
                }
            }
            sg.row_ptr.push(sg.cols.len());
        }
        sg.edges = off_nnz / 2;
        sg.lambda2 = g.lambda2;
        sg.lambda_min = g.lambda_min;
        sg
    }

    /// `out = L·v − mean(v)·1`: the gossip operator with the all-ones
    /// eigenvector deflated away, so its largest eigenvalue is λ₂(L)
    /// (clamped at 0) and its smallest is min(λ_min(L), 0).
    fn apply_deflated(&self, v: &[f64], out: &mut [f64]) {
        for i in 0..self.m {
            let lo = self.row_ptr[i];
            let hi = self.row_ptr[i + 1];
            let mut acc = 0.0;
            for idx in lo..hi {
                acc += self.vals[idx] * v[self.cols[idx]];
            }
            out[i] = acc;
        }
        let mean = v.iter().sum::<f64>() / self.m as f64;
        for o in out.iter_mut() {
            *o -= mean;
        }
    }

    /// Estimate (λ₂, λ_min) of the current weights with a seeded
    /// deterministic Lanczos iteration on the sparse operator — O(nnz)
    /// per step, never materializing anything dense in n.
    ///
    /// For m ≤ 512 the full basis is kept and reorthogonalized every step
    /// (runs to completion: exact to roundoff). For larger m the
    /// iteration is capped and keeps only three vectors; the resulting
    /// Ritz value can only *under*estimate λ₂, which merely slows the
    /// Chebyshev recursion (its roots stay strictly inside the unit disk
    /// for any |μ| < 1), so the cap is safe.
    pub fn estimate_spectrum(&mut self, ws: &mut SpectrumWorkspace) {
        let m = self.m;
        assert!(m >= 2, "spectrum estimation needs ≥ 2 agents");
        let reortho = m <= FULL_REORTHO_MAX_M;
        let max_iters = if reortho {
            m - 1
        } else {
            LARGE_GRAPH_MAX_ITERS
        };
        ws.ensure(m, max_iters, reortho);
        let mut rng = Rng::seed_from(
            LANCZOS_SEED ^ (m as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        for x in ws.v_cur.iter_mut() {
            *x = rng.uniform() - 0.5;
        }
        project_out_mean(&mut ws.v_cur);
        let nrm = norm2(&ws.v_cur);
        assert!(nrm > 0.0, "degenerate Lanczos start vector");
        for x in ws.v_cur.iter_mut() {
            *x /= nrm;
        }
        ws.v_prev.fill(0.0);
        ws.alpha.clear();
        ws.beta.clear();
        let mut beta_prev = 0.0;
        let mut scale = 1.0f64;
        for k in 0..max_iters {
            if reortho {
                ws.basis[k].copy_from_slice(&ws.v_cur);
            }
            self.apply_deflated(&ws.v_cur, &mut ws.w);
            if beta_prev != 0.0 {
                for (w, &p) in ws.w.iter_mut().zip(ws.v_prev.iter()) {
                    *w -= beta_prev * p;
                }
            }
            let a = dot(&ws.w, &ws.v_cur);
            ws.alpha.push(a);
            for (w, &c) in ws.w.iter_mut().zip(ws.v_cur.iter()) {
                *w -= a * c;
            }
            // Keep the iteration out of span(1) despite rounding drift.
            project_out_mean(&mut ws.w);
            if reortho {
                for q in &ws.basis[..=k] {
                    let c = dot(q, &ws.w);
                    for (w, &qv) in ws.w.iter_mut().zip(q.iter()) {
                        *w -= c * qv;
                    }
                }
            }
            scale = scale.max(a.abs());
            let b = norm2(&ws.w);
            if b <= 1e-12 * scale.max(1.0) {
                break; // invariant subspace found: Ritz values are exact
            }
            ws.beta.push(b);
            scale = scale.max(b);
            std::mem::swap(&mut ws.v_prev, &mut ws.v_cur);
            for (v, &w) in ws.v_cur.iter_mut().zip(ws.w.iter()) {
                *v = w / b;
            }
            beta_prev = b;
        }
        let steps = ws.alpha.len();
        let (lo, hi) = tridiag_extremes(&ws.alpha, &ws.beta[..steps - 1]);
        // λ₂ < 1 is structural for connected graphs; clamp so the
        // Chebyshev step size stays finite even if an estimate grazes 1.
        self.lambda2 = hi.min(1.0 - 1e-12);
        self.lambda_min = lo.min(0.0);
    }

    /// Number of agents.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Undirected edge count of the represented graph.
    pub fn edges(&self) -> usize {
        self.edges
    }

    /// Stored nonzeros (n diagonal entries + 2·edges).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Row `j` as (columns, weights), ascending columns, diagonal
    /// included.
    pub fn row(&self, j: usize) -> (&[usize], &[f64]) {
        let lo = self.row_ptr[j];
        let hi = self.row_ptr[j + 1];
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Half-open range of CSR indices backing row `j` (for parallel
    /// arrays aligned with the nonzero layout, e.g. per-link latency).
    pub fn row_span(&self, j: usize) -> (usize, usize) {
        (self.row_ptr[j], self.row_ptr[j + 1])
    }

    /// The CSR row-pointer array (`m + 1` entries, `row_ptr[0] = 0`).
    /// Doubles as the per-row *cost* prefix the executor's weighted
    /// dispatch wants ([`crate::exec::Executor::par_weighted`]): entry
    /// `j` is the cumulative nonzero count before row `j`, so chunking
    /// by it balances gossip work across hub and leaf agents with zero
    /// extra bookkeeping.
    pub fn row_ptr(&self) -> &[usize] {
        &self.row_ptr
    }

    /// The representation-independent spectral summary.
    pub fn info(&self) -> GossipInfo {
        GossipInfo {
            m: self.m,
            lambda2: self.lambda2,
            lambda_min: self.lambda_min,
        }
    }

    /// The spectral gap `1 − λ₂(L)` (see [`GossipInfo::gap`]).
    pub fn gap(&self) -> f64 {
        self.info().gap()
    }

    /// Chebyshev step size (see [`GossipInfo::chebyshev_eta`]).
    pub fn chebyshev_eta(&self) -> f64 {
        self.info().chebyshev_eta()
    }

    /// Proposition-1 contraction base (see [`GossipInfo::fastmix_base`]).
    pub fn fastmix_base(&self) -> f64 {
        self.info().fastmix_base()
    }

    /// ρ(K) after K rounds (see [`GossipInfo::rho`]).
    pub fn rho(&self, k_rounds: usize) -> f64 {
        self.info().rho(k_rounds)
    }

    /// Minimum K with ρ(K) ≤ target (see [`GossipInfo::rounds_for_rho`]).
    pub fn rounds_for_rho(&self, target: f64) -> usize {
        self.info().rounds_for_rho(target)
    }

    /// Materialize the dense m×m weight matrix — for tests and
    /// small-graph diagnostics only (defeats the point at fleet scale).
    pub fn to_dense(&self) -> Mat {
        let mut w = Mat::zeros(self.m, self.m);
        for i in 0..self.m {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                w[(i, j)] = v;
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::eig_sym;

    fn check_csr(sg: &SparseGossip) {
        for i in 0..sg.m() {
            let (cols, vals) = sg.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {i} not sorted");
            assert!(cols.contains(&i), "row {i} missing diagonal");
            let sum: f64 = vals.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {i} sums to {sum}");
        }
    }

    #[test]
    fn metropolis_csr_structure_matches_dense_construction() {
        for topo in [
            Topology::ring(9),
            Topology::star(8),
            Topology::grid(3, 4),
            Topology::path(7),
        ] {
            let sg = SparseGossip::metropolis(&topo);
            check_csr(&sg);
            assert_eq!(sg.edges(), topo.num_edges());
            assert_eq!(sg.nnz(), topo.n() + 2 * topo.num_edges());
            let dense = GossipMatrix::metropolis(&topo);
            let sd = sg.to_dense();
            for i in 0..topo.n() {
                for j in 0..topo.n() {
                    assert_eq!(
                        sd[(i, j)],
                        dense.weights[(i, j)],
                        "weight mismatch at ({i},{j}) on {}",
                        topo.name
                    );
                }
            }
        }
    }

    #[test]
    fn lanczos_spectrum_matches_eig_sym() {
        use crate::util::rng::Rng;
        for topo in [
            Topology::ring(11),
            Topology::star(9),
            Topology::grid(3, 3),
            Topology::path(8),
            Topology::erdos_renyi(14, 0.5, &mut Rng::seed_from(7)),
        ] {
            let sg = SparseGossip::metropolis(&topo);
            let e = eig_sym(&sg.to_dense());
            let lambda2_ref = e.values[1];
            let lambda_min_ref = e.values.last().unwrap().min(0.0);
            assert!(
                (sg.lambda2 - lambda2_ref).abs() < 1e-8,
                "λ₂ = {} vs eig_sym {} on {}",
                sg.lambda2,
                lambda2_ref,
                topo.name
            );
            assert!(
                (sg.lambda_min - lambda_min_ref).abs() < 1e-8,
                "λ_min = {} vs eig_sym {} on {}",
                sg.lambda_min,
                lambda_min_ref,
                topo.name
            );
        }
    }

    #[test]
    fn from_gossip_roundtrips_and_copies_spectrum() {
        let topo = Topology::grid(3, 4);
        let g = GossipMatrix::from_laplacian(&topo);
        let sg = SparseGossip::from_gossip(&g);
        check_csr(&sg);
        assert_eq!(sg.edges(), topo.num_edges());
        assert_eq!(sg.lambda2, g.lambda2);
        assert_eq!(sg.lambda_min, g.lambda_min);
        let sd = sg.to_dense();
        for i in 0..topo.n() {
            for j in 0..topo.n() {
                assert_eq!(sd[(i, j)], g.weights[(i, j)]);
            }
        }
    }

    #[test]
    fn rebuild_reuses_buffers_and_tracks_topology() {
        let mut sg = SparseGossip::metropolis(&Topology::ring(12));
        let mut ws = SpectrumWorkspace::new();
        let ring_l2 = sg.lambda2;
        sg.rebuild_metropolis(&Topology::complete(12));
        sg.estimate_spectrum(&mut ws);
        check_csr(&sg);
        assert_eq!(sg.edges(), 12 * 11 / 2);
        assert!(sg.lambda2 < ring_l2, "K₁₂ should mix far faster than a ring");
        // And back: identical to a cold build.
        sg.rebuild_metropolis(&Topology::ring(12));
        sg.estimate_spectrum(&mut ws);
        let cold = SparseGossip::metropolis(&Topology::ring(12));
        assert_eq!(sg.lambda2, cold.lambda2);
        assert_eq!(sg.row(3), cold.row(3));
    }

    #[test]
    fn two_agents_degenerate_spectrum() {
        let sg = SparseGossip::metropolis(&Topology::path(2));
        assert!(sg.lambda2.abs() < 1e-12);
        assert!((0.0..=1e-12).contains(&sg.chebyshev_eta()));
    }
}
