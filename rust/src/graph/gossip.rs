//! Gossip weight matrices and their spectral properties.
//!
//! The paper's construction (§5): `L = I − M/λ_max(M)` where `M` is the
//! graph Laplacian. This yields a symmetric doubly-stochastic matrix with
//! `0 ⪯ L ⪯ I` and `null(I − L) = span(1)` for connected graphs — the
//! §2.2 assumptions. `1 − λ₂(L)` is the spectral gap that sets both the
//! plain-gossip rate and FastMix's accelerated rate
//! `ρ = (1 − √(1−λ₂))^K` (Proposition 1).

use crate::linalg::eig::eig_sym;
use crate::linalg::Mat;

use super::topology::Topology;

/// The spectral facts every consensus engine needs, decoupled from any
/// particular weight representation (dense [`GossipMatrix`] or sparse CSR
/// [`crate::graph::sparse::SparseGossip`]). `Copy`, so engines can hand it
/// around without borrowing an n×n matrix.
#[derive(Clone, Copy, Debug)]
pub struct GossipInfo {
    /// Number of agents.
    pub m: usize,
    /// Second-largest eigenvalue λ₂(L) (< 1 for connected graphs).
    pub lambda2: f64,
    /// Smallest eigenvalue of L (≥ 0 for the paper's Laplacian
    /// construction; Metropolis weights can dip negative, e.g. −1/3 on a
    /// small ring).
    pub lambda_min: f64,
}

impl GossipInfo {
    /// The spectral gap `1 − λ₂(L)`.
    pub fn gap(&self) -> f64 {
        1.0 - self.lambda2
    }

    /// Algorithm 3's Chebyshev step size
    /// `η = (1 − √(1−β²)) / (1 + √(1−β²))` with `β = max(λ₂, −λ_min)` —
    /// the single source of truth for every engine (FastMix, threaded,
    /// distributed, SimNet, sparse), so the cross-engine parity tests
    /// can't drift. For the paper's PSD construction `β = λ₂` exactly, so
    /// this is bit-identical to the λ₂-only formula; the `−λ_min` arm
    /// keeps the Chebyshev recursion contracting for non-PSD weights
    /// (Metropolis on small rings).
    pub fn chebyshev_eta(&self) -> f64 {
        let beta = self.lambda2.max(-self.lambda_min).max(0.0);
        assert!(beta < 1.0, "spectral radius β = {beta} ≥ 1: disconnected?");
        let root = (1.0 - beta * beta).sqrt();
        (1.0 - root) / (1.0 + root)
    }

    /// FastMix per-round contraction base `1 − √(1−λ₂)` (Proposition 1).
    ///
    /// Lies in `[0, 1)` whenever `0 ≤ λ₂ < 1`; λ₂ < 1 is guaranteed by
    /// construction for connected graphs ([`GossipMatrix::from_weights`]
    /// asserts it, the sparse estimator clamps to it), so the base can
    /// never reach 1 and `ln(base)` below is always finite and negative.
    /// λ₂ < 0 (complete graph) gives a negative base: one round is exact.
    pub fn fastmix_base(&self) -> f64 {
        1.0 - self.gap().sqrt()
    }

    /// ρ(K) = (1 − √(1−λ₂))^K — consensus error contraction after K
    /// rounds. Uses `powf` on the clamped base, so huge K is fine (a
    /// previous `powi(k as i32)` cast silently wrapped for K ≥ 2³¹ and
    /// could report ρ = 1 for K = 2³²).
    pub fn rho(&self, k_rounds: usize) -> f64 {
        if k_rounds == 0 {
            return 1.0;
        }
        // Negative base means better-than-one-shot (complete graph);
        // clamp to 0 so the bound stays a probability-like factor.
        self.fastmix_base().max(0.0).powf(k_rounds as f64)
    }

    /// Minimum K with ρ(K) ≤ target (Theorem-1 style bound inversion).
    /// Saturates at `usize::MAX` instead of performing an unbounded
    /// `f64 as usize` cast when the gap is vanishingly small.
    pub fn rounds_for_rho(&self, target: f64) -> usize {
        assert!(target > 0.0 && target < 1.0);
        let base = self.fastmix_base();
        if base <= 0.0 {
            return 1; // complete graph: one round suffices
        }
        // base == 1.0 requires λ₂ == 1, which every constructor rejects
        // (from_weights asserts λ₂ < 1 − 1e-12, the sparse estimator
        // clamps below 1). Saturate defensively for hand-built infos
        // instead of dividing by ln(1) = 0 below.
        if base >= 1.0 {
            return usize::MAX;
        }
        let k = (target.ln() / base.ln()).ceil().max(1.0);
        if !k.is_finite() || k >= usize::MAX as f64 {
            usize::MAX
        } else {
            k as usize
        }
    }
}

/// A gossip weight matrix together with its relevant spectrum.
#[derive(Clone, Debug)]
pub struct GossipMatrix {
    /// The m×m weight matrix `L`.
    pub weights: Mat,
    /// Second-largest eigenvalue λ₂(L) ∈ [0, 1).
    pub lambda2: f64,
    /// Smallest eigenvalue (≥ 0 for the paper's construction).
    pub lambda_min: f64,
}

impl GossipMatrix {
    /// Paper construction: `L = I − M/λ_max(M)` with `M` the Laplacian.
    pub fn from_laplacian(topo: &Topology) -> Self {
        let m = topo.n();
        assert!(topo.is_connected(), "gossip matrix needs a connected graph");
        let mut lap = Mat::zeros(m, m);
        for i in 0..m {
            lap[(i, i)] = topo.degree(i) as f64;
            for &j in topo.neighbors(i) {
                lap[(i, j)] = -1.0;
            }
        }
        let eig_l = eig_sym(&lap);
        let lmax = eig_l.values[0];
        assert!(lmax > 0.0);
        let mut w = Mat::eye(m);
        w.axpy(-1.0 / lmax, &lap);
        Self::from_weights(w)
    }

    /// Metropolis–Hastings weights: `L_ij = 1/(1+max(d_i,d_j))` for edges,
    /// diagonal fills the remainder. Also symmetric & doubly stochastic;
    /// often a larger spectral gap than the Laplacian construction.
    pub fn metropolis(topo: &Topology) -> Self {
        let m = topo.n();
        assert!(topo.is_connected(), "gossip matrix needs a connected graph");
        let mut w = Mat::zeros(m, m);
        for i in 0..m {
            for &j in topo.neighbors(i) {
                w[(i, j)] = 1.0 / (1.0 + topo.degree(i).max(topo.degree(j)) as f64);
            }
        }
        for i in 0..m {
            let off: f64 = (0..m).filter(|&j| j != i).map(|j| w[(i, j)]).sum();
            w[(i, i)] = 1.0 - off;
        }
        Self::from_weights(w)
    }

    /// Wrap an explicit weight matrix, validating the §2.2 assumptions.
    pub fn from_weights(w: Mat) -> Self {
        let m = w.rows();
        assert_eq!(w.rows(), w.cols());
        // Symmetry + row stochasticity.
        for i in 0..m {
            let row_sum: f64 = w.row(i).iter().sum();
            assert!(
                (row_sum - 1.0).abs() < 1e-9,
                "gossip row {i} sums to {row_sum}, want 1"
            );
            for j in 0..m {
                assert!(
                    (w[(i, j)] - w[(j, i)]).abs() < 1e-9,
                    "gossip matrix not symmetric"
                );
            }
        }
        let e = eig_sym(&w);
        let lambda1 = e.values[0];
        assert!(
            (lambda1 - 1.0).abs() < 1e-8,
            "top eigenvalue should be 1, got {lambda1}"
        );
        let lambda2 = e.values[1];
        assert!(lambda2 < 1.0 - 1e-12, "λ₂ = {lambda2}: graph disconnected?");
        let lambda_min = *e.values.last().unwrap();
        assert!(lambda_min > -1e-9, "L not PSD (λ_min = {lambda_min})");
        GossipMatrix { weights: w, lambda2, lambda_min }
    }

    /// Number of agents.
    pub fn m(&self) -> usize {
        self.weights.rows()
    }

    /// The representation-independent spectral summary (what the
    /// consensus engines actually consume).
    pub fn info(&self) -> GossipInfo {
        GossipInfo {
            m: self.m(),
            lambda2: self.lambda2,
            lambda_min: self.lambda_min,
        }
    }

    /// The spectral gap `1 − λ₂(L)`.
    pub fn gap(&self) -> f64 {
        self.info().gap()
    }

    /// Algorithm 3's Chebyshev step size (see [`GossipInfo::chebyshev_eta`]).
    pub fn chebyshev_eta(&self) -> f64 {
        self.info().chebyshev_eta()
    }

    /// FastMix per-round contraction base `1 − √(1−λ₂)` (Proposition 1).
    pub fn fastmix_base(&self) -> f64 {
        self.info().fastmix_base()
    }

    /// ρ(K) = (1 − √(1−λ₂))^K — consensus error contraction after K rounds.
    pub fn rho(&self, k_rounds: usize) -> f64 {
        self.info().rho(k_rounds)
    }

    /// Minimum K with ρ(K) ≤ target (Theorem-1 style bound inversion).
    pub fn rounds_for_rho(&self, target: f64) -> usize {
        self.info().rounds_for_rho(target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_doubly_stochastic(w: &Mat) {
        let m = w.rows();
        for i in 0..m {
            let rs: f64 = w.row(i).iter().sum();
            assert!((rs - 1.0).abs() < 1e-9);
            let cs: f64 = (0..m).map(|r| w[(r, i)]).sum();
            assert!((cs - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn laplacian_gossip_on_ring() {
        let g = GossipMatrix::from_laplacian(&Topology::ring(8));
        check_doubly_stochastic(&g.weights);
        assert!(g.lambda2 > 0.0 && g.lambda2 < 1.0);
        assert!(g.lambda_min >= -1e-9);
    }

    #[test]
    fn metropolis_gossip_on_star() {
        let g = GossipMatrix::metropolis(&Topology::star(9));
        check_doubly_stochastic(&g.weights);
        assert!(g.lambda2 < 1.0);
    }

    #[test]
    fn respects_sparsity_pattern() {
        let topo = Topology::ring(6);
        let g = GossipMatrix::from_laplacian(&topo);
        for i in 0..6 {
            for j in 0..6 {
                if i != j && !topo.neighbors(i).contains(&j) {
                    assert_eq!(g.weights[(i, j)], 0.0, "weight on non-edge");
                }
            }
        }
    }

    #[test]
    fn paper_setup_gap_magnitude() {
        // Paper §5: m=50, ER(p=0.5) gives 1−λ₂ ≈ 0.4563. Our generator uses
        // a different stream so we check the ballpark (same family).
        let mut rng = Rng::seed_from(62);
        let topo = Topology::erdos_renyi(50, 0.5, &mut rng);
        let g = GossipMatrix::from_laplacian(&topo);
        assert!(
            g.gap() > 0.25 && g.gap() < 0.7,
            "gap {} not in the expected ER(0.5) range",
            g.gap()
        );
    }

    #[test]
    fn complete_graph_good_gap() {
        // L = I − M/λmax = (1/n) 1 1ᵀ for K_n: λ₂ = 0, one-shot averaging.
        let g = GossipMatrix::from_laplacian(&Topology::complete(6));
        assert!(g.lambda2.abs() < 1e-9, "λ₂ = {}", g.lambda2);
        assert_eq!(g.rounds_for_rho(1e-9), 1);
    }

    #[test]
    fn barbell_has_tiny_gap() {
        let g_bar = GossipMatrix::from_laplacian(&Topology::barbell(20));
        let g_er = GossipMatrix::from_laplacian(&Topology::erdos_renyi(
            20,
            0.5,
            &mut Rng::seed_from(63),
        ));
        assert!(g_bar.gap() < 0.2 * g_er.gap(), "barbell should be much worse");
    }

    #[test]
    fn rho_and_rounds_consistent() {
        let g = GossipMatrix::from_laplacian(&Topology::ring(12));
        let k = g.rounds_for_rho(1e-6);
        assert!(g.rho(k) <= 1e-6);
        assert!(g.rho(k.saturating_sub(1)) > 1e-6 || k == 1);
    }

    #[test]
    fn averaging_fixed_point() {
        // L·1 = 1 exactly (within fp): constant vectors are fixed points.
        let g = GossipMatrix::from_laplacian(&Topology::grid(3, 3));
        let ones = vec![1.0; 9];
        let out = g.weights.matvec(&ones);
        for v in out {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn rho_survives_huge_round_counts() {
        // The old `powi(k_rounds as i32)` wrapped: K = 2³³ truncated to 0
        // and reported ρ = 1. The powf path must stay monotone.
        let g = GossipMatrix::from_laplacian(&Topology::ring(12));
        let huge = 1usize << 33;
        assert_eq!(g.rho(0), 1.0);
        let r = g.rho(huge);
        assert!((0.0..=1.0).contains(&r), "rho({huge}) = {r}");
        assert!(r <= g.rho(8), "rho must be non-increasing in K");
    }

    #[test]
    fn rounds_for_rho_saturates_instead_of_wrapping() {
        // λ₂ == 1 can't come out of a validated constructor; a hand-built
        // info must saturate instead of dividing by ln(1) = 0 (the old
        // code's unbounded `as usize` made this UB-adjacent).
        let info = GossipInfo { m: 4, lambda2: 1.0, lambda_min: 0.0 };
        assert_eq!(info.rounds_for_rho(1e-9), usize::MAX);
        // A representable-but-huge count still converts exactly.
        let info = GossipInfo { m: 4, lambda2: 1.0 - 1e-12, lambda_min: 0.0 };
        let k = info.rounds_for_rho(1e-9);
        assert!(k > 1_000_000 && k < usize::MAX, "k = {k}");
        assert!(info.rho(k) <= 1e-9 * (1.0 + 1e-9), "rho = {}", info.rho(k));
    }

    #[test]
    #[should_panic(expected = "disconnected")]
    fn rejects_disconnected_weights() {
        // Block-diagonal averaging matrix of a 2+2 split: λ₂ = 1.
        let w = Mat::from_rows(
            4,
            4,
            &[
                0.5, 0.5, 0.0, 0.0, //
                0.5, 0.5, 0.0, 0.0, //
                0.0, 0.0, 0.5, 0.5, //
                0.0, 0.0, 0.5, 0.5,
            ],
        );
        let _ = GossipMatrix::from_weights(w);
    }
}
