//! # DeEPCA — Decentralized Exact PCA with Linear Convergence Rate
//!
//! Production-quality reproduction of *Ye & Zhang, "DeEPCA: Decentralized
//! Exact PCA with Linear Convergence Rate" (2021)* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the decentralized coordinator: agents,
//!   gossip communication (FastMix), the DeEPCA algorithm and its baselines
//!   (DePCA, local power method, centralized PCA), metrics, experiments.
//! - **Layer 2** — the per-agent compute graph authored in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! - **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the
//!   tracking-update / power-step hot paths, lowered into the same HLO.
//!
//! Python never runs at request time: [`runtime`] loads the pre-built
//! artifacts through the PJRT C API (the `xla` crate) and executes them
//! from the Rust hot path. A pure-Rust [`linalg`] backend implements the
//! identical local step, so everything also runs without artifacts and the
//! two backends are cross-checked in integration tests.
//!
//! ## Quick start
//!
//! Every algorithm runs through the step-wise [`algo::solver::Solver`]
//! API, built and driven by the [`coordinator::session::Session`]
//! builder:
//!
//! ```no_run
//! use deepca::prelude::*;
//!
//! // Synthetic 'w8a'-like dataset split across 10 agents (paper Eqn. 5.1).
//! let data = deepca::data::synthetic::w8a_like_scaled(10, 80, &mut Rng::seed_from(7));
//! let problem = Problem::from_dataset(&data, 10, 5);
//! let net = Topology::erdos_renyi(10, 0.5, &mut Rng::seed_from(13));
//!
//! let report = Session::on(&problem, &net)
//!     .algo(Algo::Deepca(DeepcaConfig { consensus_rounds: 8, ..Default::default() }))
//!     .stop(StopCriteria::max_iters(60).with_tol(1e-9))
//!     .eigenvalues(20) // Remark-4 Rayleigh post-step
//!     .solve();
//! println!(
//!     "tan(theta) after {} iters: {:.3e} ({})",
//!     report.iters, report.final_tan_theta, report.comm
//! );
//! ```
//!
//! Swap `.algo(...)` for `Algo::Depca`, `Algo::LocalPower`, or
//! `Algo::Centralized` to run the baselines through the identical
//! driver, recorder, and report; swap `.engine(...)` across
//! `Engine::Dense`, `Engine::DenseParallel`, `Engine::Threaded`,
//! `Engine::Distributed`, `Engine::Sim` (deterministic
//! unreliable-network simulation: seeded drops/latency/noise and
//! time-varying topologies), and `Engine::Sparse` (fleet-scale CSR
//! gossip — O(edges) rounds, nothing dense in the agent count) to
//! change how the same math executes.
//! Per-agent work (products, gossip row blocks, QR loops) runs on a
//! persistent deterministic worker pool ([`exec::Executor`]), sized by
//! `Session::threads` / `DEEPCA_THREADS` — results are bit-identical
//! for every thread count.
//!
//! For *live* data whose covariance drifts over time, the [`stream`]
//! subsystem ([`stream::source::StreamSource`] scenarios +
//! [`stream::cov::CovTracker`]) and the
//! [`coordinator::online::OnlineSession`] driver run warm-started DeEPCA
//! epochs with a constant per-epoch round budget — the paper's
//! subspace-tracking claim made operational on drifting subspaces.
//!
//! See `examples/` for runnable end-to-end drivers and `DESIGN.md` for the
//! full system inventory.

// Every unsafe operation must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` justification (the latter enforced by
// `cargo xtask lint`), even inside `unsafe fn`.
#![deny(unsafe_op_in_unsafe_fn)]
// Deliberate house style, allowed crate-wide so `clippy -D warnings`
// (blocking in CI) polices real defects instead:
// - indexed `for j in 0..m` loops mirror the paper's per-agent index
//   notation and frequently index several stacks at once;
// - stats structs are built as `default()` + field assignments because
//   most call sites set a different sparse subset of counters.
#![allow(clippy::needless_range_loop, clippy::field_reassign_with_default)]

pub mod util;
pub mod obs;
pub mod exec;
pub mod linalg;
pub mod graph;
pub mod data;
pub mod stream;
pub mod consensus;
pub mod algo;
pub mod coordinator;
pub mod runtime;
pub mod config;
pub mod cli;
pub mod experiments;
pub mod testing;
pub mod benchkit;

/// Convenience re-exports for examples and downstream users.
///
/// Algorithm *modules* are aliased (`deepca_algo`, `depca_algo`,
/// `centralized`) so a glob import never shadows the crate name.
pub mod prelude {
    pub use crate::algo::centralized;
    pub use crate::algo::centralized::{CentralizedConfig, CentralizedOutput, CentralizedSolver};
    pub use crate::algo::deepca as deepca_algo;
    pub use crate::algo::deepca::{DeepcaConfig, DeepcaSolver};
    pub use crate::algo::depca as depca_algo;
    pub use crate::algo::depca::{DepcaConfig, DepcaSolver, KPolicy};
    pub use crate::algo::local_power::{LocalPowerConfig, LocalPowerSolver};
    pub use crate::algo::metrics::{IterationRecord, RunOutput, RunRecorder};
    pub use crate::algo::problem::Problem;
    pub use crate::algo::rayleigh::EigenEstimate;
    pub use crate::algo::solver::{
        Algo, Engine, SolveReport, Solver, SolverState, StepReport, StopCriteria, StopReason,
    };
    pub use crate::algo::workspace::SolverWorkspace;
    pub use crate::consensus::comm::{Communicator, DenseComm, SparseComm};
    pub use crate::consensus::fastmix::FastMix;
    pub use crate::exec::Executor;
    pub use crate::consensus::simnet::{SimConfig, SimNet};
    pub use crate::graph::sparse::{SparseGossip, SpectrumWorkspace};
    pub use crate::coordinator::online::{EpochRecord, OnlineConfig, OnlineReport, OnlineSession};
    pub use crate::coordinator::session::{Session, SolverBuilder};
    pub use crate::graph::dynamic::TopologySchedule;
    pub use crate::stream::cov::{CovTracker, Forgetting};
    pub use crate::stream::source::{Drift, StreamParams, StreamSource, SyntheticStream};
    pub use crate::graph::gossip::GossipMatrix;
    pub use crate::graph::topology::Topology;
    pub use crate::linalg::qr::QrWorkspace;
    pub use crate::linalg::Mat;
    pub use crate::util::rng::Rng;
}
