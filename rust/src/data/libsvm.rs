//! Parser for the libsvm sparse text format.
//!
//! Lines look like `label idx:val idx:val ...` with 1-based feature
//! indices. This lets the genuine 'w8a'/'a9a' files be dropped into the
//! repo and used for the figure benches in place of the synthetic
//! stand-ins (`deepca experiment fig1 --data path/to/w8a`).

use super::Dataset;
use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Parse libsvm-format text into a dense dataset.
///
/// `dim`: if `Some(d)`, features are truncated/zero-padded to `d` columns
/// (the paper fixes d=300 for w8a, d=123 for a9a); if `None`, the max seen
/// index defines the width. `max_rows` truncates the file (paper uses the
/// first `m*n` rows).
pub fn parse_str(text: &str, dim: Option<usize>, max_rows: Option<usize>) -> Result<Dataset> {
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut labels = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(cap) = max_rows {
            if rows.len() >= cap {
                break;
            }
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .context("empty line slipped through")?
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .with_context(|| format!("line {}: token `{tok}` missing ':'", lineno + 1))?;
            let idx: usize = i
                .parse()
                .with_context(|| format!("line {}: bad index `{i}`", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
            }
            let val: f64 = v
                .parse()
                .with_context(|| format!("line {}: bad value `{v}`", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        labels.push(label);
        rows.push(feats);
    }

    if rows.is_empty() {
        bail!("no samples parsed");
    }
    let d = dim.unwrap_or(max_idx);
    let mut features = Mat::zeros(rows.len(), d);
    for (r, feats) in rows.iter().enumerate() {
        for &(c, v) in feats {
            if c < d {
                features[(r, c)] = v;
            }
        }
    }
    Ok(Dataset { features, labels, name: "libsvm".into() })
}

/// Parse a libsvm file from disk.
pub fn load(path: &Path, dim: Option<usize>, max_rows: Option<usize>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut ds = parse_str(&text, dim, max_rows)?;
    ds.name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 3:1 7:1 11:0.5
-1 1:2.0 3:1
# comment line
+1 2:1
";

    #[test]
    fn parses_basic() {
        let ds = parse_str(SAMPLE, None, None).unwrap();
        assert_eq!(ds.num_rows(), 3);
        assert_eq!(ds.dim(), 11);
        assert_eq!(ds.labels, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.features[(0, 2)], 1.0);
        assert_eq!(ds.features[(0, 10)], 0.5);
        assert_eq!(ds.features[(1, 0)], 2.0);
        assert_eq!(ds.features[(2, 1)], 1.0);
    }

    #[test]
    fn fixed_dim_pads_and_truncates() {
        let ds = parse_str(SAMPLE, Some(5), None).unwrap();
        assert_eq!(ds.dim(), 5);
        // Index 7 and 11 (0-based 6, 10) fall outside and are dropped.
        assert_eq!(ds.features.row(0).iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn max_rows_truncates() {
        let ds = parse_str(SAMPLE, None, Some(2)).unwrap();
        assert_eq!(ds.num_rows(), 2);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_str("+1 0:1\n", None, None).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_str("+1 3=1\n", None, None).is_err());
        assert!(parse_str("notalabel 3:1\n", None, None).is_err());
        assert!(parse_str("", None, None).is_err());
    }

    #[test]
    fn density_reasonable() {
        let ds = parse_str(SAMPLE, None, None).unwrap();
        let nnz = 3 + 2 + 1;
        assert!((ds.density() - nnz as f64 / 33.0).abs() < 1e-12);
    }
}
