//! Eqn.-5.1 data placement: sequential row blocks → local Gram matrices.
//!
//! The paper assigns agent `j` the rows `(j−1)·n+1 .. j·n` and forms
//! `A_j = Σ_i v_i v_iᵀ` over its block; the global matrix is
//! `A = (1/m) Σ_j A_j`. We optionally normalize by the per-agent row
//! count so eigenvalues stay O(feature-norm²) regardless of n — a pure
//! rescaling that leaves every convergence ratio in Theorem 1 unchanged.

use super::Dataset;
use crate::linalg::Mat;

/// How to scale each local Gram matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GramScaling {
    /// Paper-literal `A_j = Σ v vᵀ`.
    RawSum,
    /// `A_j = (1/n) Σ v vᵀ` — same dynamics, tamer magnitudes (default).
    PerRow,
}

/// The decentralized problem data: one PSD (or, for the Remark-1
/// robustness ablation, merely symmetric) matrix per agent.
#[derive(Clone, Debug)]
pub struct PartitionedGram {
    /// Local matrices `A_j`, all d×d.
    pub locals: Vec<Mat>,
    /// Aggregate `A = (1/m) Σ_j A_j`.
    pub aggregate: Mat,
    /// Max spectral norm bound `L ≥ max_j ‖A_j‖₂` (paper's L).
    pub spectral_bound: f64,
}

/// Split `ds` into `m` sequential blocks and build the local Grams.
///
/// Panics unless `ds.num_rows()` is divisible by `m` (the paper's setup
/// always is; trim the dataset first otherwise).
pub fn partition_gram(ds: &Dataset, m: usize, scaling: GramScaling) -> PartitionedGram {
    let rows = ds.num_rows();
    assert!(m > 0 && rows % m == 0, "rows {rows} not divisible by m {m}");
    let n = rows / m;
    let d = ds.dim();

    let mut locals = Vec::with_capacity(m);
    for j in 0..m {
        // Block view as its own matrix, then A_j = Bᵀ B.
        let block = Mat::from_fn(n, d, |i, c| ds.features[(j * n + i, c)]);
        let mut a_j = block.t_matmul(&block);
        if scaling == GramScaling::PerRow {
            a_j.scale(1.0 / n as f64);
        }
        a_j.symmetrize();
        locals.push(a_j);
    }

    let mut aggregate = Mat::zeros(d, d);
    for a_j in &locals {
        aggregate.axpy(1.0 / m as f64, a_j);
    }
    aggregate.symmetrize();

    let spectral_bound = locals
        .iter()
        .map(|a| crate::linalg::norms::spectral_norm_power(a, 60))
        .fold(0.0f64, f64::max);

    PartitionedGram { locals, aggregate, spectral_bound }
}

/// Heterogeneity diagnostic `L² / (λ_k λ_{k+1})` from Remark 2 — the
/// quantity that sets the minimum viable consensus rounds K.
pub fn heterogeneity(p: &PartitionedGram, lambda_k: f64, lambda_k1: f64) -> f64 {
    p.spectral_bound * p.spectral_bound / (lambda_k * lambda_k1)
}

/// Mean-shift each local matrix (keeping the aggregate fixed) so some
/// `A_j` are *not* PSD — the Remark-1 robustness setting. `strength`
/// scales the alternating ±shift added to agent j and removed from j+1.
pub fn make_non_psd(p: &mut PartitionedGram, strength: f64) {
    let m = p.locals.len();
    if m < 2 {
        return;
    }
    let d = p.locals[0].rows();
    let shift = Mat::from_fn(d, d, |i, j| if i == j { strength } else { 0.0 });
    // Pairwise: add to even agents, subtract from their odd partner —
    // the aggregate (1/m)ΣA_j is untouched.
    for pair in 0..m / 2 {
        p.locals[2 * pair].axpy(1.0, &shift);
        p.locals[2 * pair + 1].axpy(-1.0, &shift);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::eig::eig_sym;
    use crate::util::rng::Rng;

    fn small_ds() -> Dataset {
        synthetic::spiked_covariance(120, 10, &[8.0, 4.0], 0.3, &mut Rng::seed_from(81))
    }

    #[test]
    fn partition_shapes() {
        let ds = small_ds();
        let p = partition_gram(&ds, 6, GramScaling::PerRow);
        assert_eq!(p.locals.len(), 6);
        for a in &p.locals {
            assert_eq!(a.shape(), (10, 10));
        }
        assert_eq!(p.aggregate.shape(), (10, 10));
    }

    #[test]
    fn aggregate_is_mean_of_locals() {
        let ds = small_ds();
        let p = partition_gram(&ds, 4, GramScaling::PerRow);
        let mut mean = Mat::zeros(10, 10);
        for a in &p.locals {
            mean.axpy(0.25, a);
        }
        assert!((&mean - &p.aggregate).fro_norm() < 1e-10);
    }

    #[test]
    fn aggregate_matches_full_gram() {
        let ds = small_ds();
        let p = partition_gram(&ds, 4, GramScaling::PerRow);
        // (1/m) Σ (1/n) B_jᵀB_j = (1/rows) XᵀX.
        let mut full = ds.features.t_matmul(&ds.features);
        full.scale(1.0 / ds.num_rows() as f64);
        assert!((&full - &p.aggregate).fro_norm() < 1e-9);
    }

    #[test]
    fn raw_sum_scaling() {
        let ds = small_ds();
        let p_raw = partition_gram(&ds, 4, GramScaling::RawSum);
        let p_row = partition_gram(&ds, 4, GramScaling::PerRow);
        let n = ds.num_rows() / 4;
        let diff = (&p_raw.locals[0].scaled(1.0 / n as f64) - &p_row.locals[0]).fro_norm();
        assert!(diff < 1e-10);
    }

    #[test]
    fn locals_are_psd() {
        let ds = small_ds();
        let p = partition_gram(&ds, 6, GramScaling::PerRow);
        for a in &p.locals {
            let e = eig_sym(a);
            assert!(*e.values.last().unwrap() > -1e-9);
        }
    }

    #[test]
    fn spectral_bound_dominates() {
        let ds = small_ds();
        let p = partition_gram(&ds, 6, GramScaling::PerRow);
        for a in &p.locals {
            let n2 = crate::linalg::norms::spectral_norm(a);
            assert!(n2 <= p.spectral_bound * (1.0 + 1e-6), "{n2} > bound");
        }
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn rejects_indivisible() {
        let ds = small_ds();
        let _ = partition_gram(&ds, 7, GramScaling::PerRow);
    }

    #[test]
    fn non_psd_preserves_aggregate() {
        let ds = small_ds();
        let mut p = partition_gram(&ds, 6, GramScaling::PerRow);
        let before = p.aggregate.clone();
        make_non_psd(&mut p, 5.0);
        let mut mean = Mat::zeros(10, 10);
        for a in &p.locals {
            mean.axpy(1.0 / 6.0, a);
        }
        assert!((&mean - &before).fro_norm() < 1e-9);
        // At least one local is now non-PSD.
        let any_negative = p.locals.iter().any(|a| {
            let e = eig_sym(a);
            *e.values.last().unwrap() < -0.1
        });
        assert!(any_negative);
    }

    #[test]
    fn heterogeneity_positive() {
        let ds = small_ds();
        let p = partition_gram(&ds, 6, GramScaling::PerRow);
        let e = eig_sym(&p.aggregate);
        let h = heterogeneity(&p, e.values[1], e.values[2]);
        assert!(h >= 1.0, "heterogeneity {h} should exceed 1");
    }
}
