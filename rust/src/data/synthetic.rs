//! Synthetic dataset generators.
//!
//! Stand-ins for the paper's libsvm 'w8a' / 'a9a' downloads (unavailable
//! offline — DESIGN.md §8). The figures measure convergence *dynamics*,
//! which are governed by (i) the aggregate spectrum λ₁.., λ_k, λ_{k+1} and
//! (ii) cross-agent heterogeneity `L²/(λ_kλ_{k+1})` (paper Remark 2).
//! These generators reproduce both knobs:
//!
//! - [`sparse_binary`] mimics libsvm's binary bag-of-features rows with a
//!   power-law feature popularity profile (a few very common features →
//!   dominant principal directions, long tail → decaying spectrum) and a
//!   *block drift*: consecutive row blocks prefer different feature
//!   clusters, so the sequential Eqn.-5.1 partition yields genuinely
//!   heterogeneous `A_j` — exactly what makes small-K DeEPCA fail in the
//!   paper's Figure 1.
//! - [`spiked_covariance`] plants an exact eigengap for controlled tests.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Parameters for the sparse binary generator.
#[derive(Clone, Debug)]
pub struct SparseBinaryParams {
    /// Total number of rows (= m agents × n rows/agent in the paper).
    pub rows: usize,
    /// Feature dimension d.
    pub dim: usize,
    /// Target overall nonzero density (libsvm w8a ≈ 0.039, a9a ≈ 0.11).
    pub density: f64,
    /// Power-law exponent for feature popularity (larger → steeper
    /// spectrum decay). ~1.1 reproduces w8a-like spectra.
    pub popularity_exponent: f64,
    /// Number of row blocks with drifted feature preferences; the paper's
    /// partition assigns one block per agent.
    pub blocks: usize,
    /// Drift strength in [0,1]: 0 = homogeneous blocks, 1 = disjoint
    /// feature clusters per block (maximum heterogeneity).
    pub drift: f64,
}

/// Generate a sparse binary dataset per [`SparseBinaryParams`].
pub fn sparse_binary(p: &SparseBinaryParams, rng: &mut Rng) -> Dataset {
    assert!(p.rows > 0 && p.dim > 0 && p.blocks > 0);
    assert!((0.0..=1.0).contains(&p.drift));

    // Base popularity: power law over a random permutation of features so
    // popular features are spread across coordinates.
    let mut order: Vec<usize> = (0..p.dim).collect();
    rng.shuffle(&mut order);
    let mut base = vec![0.0f64; p.dim];
    let mut sum = 0.0;
    for (rank, &f) in order.iter().enumerate() {
        let w = 1.0 / (1.0 + rank as f64).powf(p.popularity_exponent);
        base[f] = w;
        sum += w;
    }
    // Normalize so the expected density matches.
    let target_nnz_per_row = p.density * p.dim as f64;
    for b in &mut base {
        *b *= target_nnz_per_row / sum;
    }

    // Block drift: block `b` boosts a contiguous (wrapping) cluster of
    // features and damps the rest.
    let cluster = (p.dim / p.blocks).max(1);
    let rows_per_block = p.rows.div_ceil(p.blocks);

    let mut features = Mat::zeros(p.rows, p.dim);
    let mut labels = Vec::with_capacity(p.rows);
    for r in 0..p.rows {
        let block = (r / rows_per_block).min(p.blocks - 1);
        let start = (block * cluster) % p.dim;
        let row = features.row_mut(r);
        for (f, &pf) in base.iter().enumerate() {
            let in_cluster = {
                let off = (f + p.dim - start) % p.dim;
                off < cluster * 2 // cluster + its right neighbor
            };
            let boost = if in_cluster {
                1.0 + 3.0 * p.drift
            } else {
                1.0 - 0.8 * p.drift
            };
            let prob = (pf * boost).min(0.95);
            if rng.chance(prob) {
                row[f] = 1.0;
            }
        }
        labels.push(if rng.chance(0.5) { 1.0 } else { -1.0 });
    }
    Dataset { features, labels, name: "sparse_binary".into() }
}

/// w8a-like dataset at the paper's scale: 50 agents × 800 rows, d = 300.
pub fn w8a_like(rng: &mut Rng) -> Dataset {
    w8a_like_scaled(50, 800, rng)
}

/// w8a-like with custom (agents, rows-per-agent) for fast tests.
pub fn w8a_like_scaled(m: usize, n: usize, rng: &mut Rng) -> Dataset {
    let mut ds = sparse_binary(
        &SparseBinaryParams {
            rows: m * n,
            dim: 300,
            density: 0.039,
            popularity_exponent: 1.1,
            blocks: m,
            drift: 0.6,
        },
        rng,
    );
    ds.name = format!("w8a-like(m={m},n={n})");
    ds
}

/// a9a-like dataset at the paper's scale: 50 agents × 600 rows, d = 123.
pub fn a9a_like(rng: &mut Rng) -> Dataset {
    a9a_like_scaled(50, 600, rng)
}

/// a9a-like with custom (agents, rows-per-agent).
pub fn a9a_like_scaled(m: usize, n: usize, rng: &mut Rng) -> Dataset {
    let mut ds = sparse_binary(
        &SparseBinaryParams {
            rows: m * n,
            dim: 123,
            density: 0.11,
            popularity_exponent: 0.9,
            blocks: m,
            drift: 0.6,
        },
        rng,
    );
    ds.name = format!("a9a-like(m={m},n={n})");
    ds
}

/// Gaussian rows with a planted covariance spectrum: the first
/// `spikes.len()` directions have variance `spikes[i]`, the remaining
/// directions variance `noise`. Gives an exactly known eigengap.
pub fn spiked_covariance(
    rows: usize,
    dim: usize,
    spikes: &[f64],
    noise: f64,
    rng: &mut Rng,
) -> Dataset {
    assert!(spikes.len() <= dim);
    let basis = Mat::rand_orthonormal(dim, dim, rng);
    let mut scales = vec![noise.sqrt(); dim];
    for (i, &s) in spikes.iter().enumerate() {
        scales[i] = s.sqrt();
    }
    let mut features = Mat::zeros(rows, dim);
    for r in 0..rows {
        // x = B · diag(scales) · z, z ~ N(0, I).
        let z: Vec<f64> = (0..dim).map(|i| rng.normal() * scales[i]).collect();
        for c in 0..dim {
            let mut acc = 0.0;
            for (i, &zi) in z.iter().enumerate() {
                acc += basis[(c, i)] * zi;
            }
            features[(r, c)] = acc;
        }
    }
    Dataset {
        features,
        labels: vec![0.0; rows],
        name: format!("spiked(d={dim},k={})", spikes.len()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::eig_sym;

    #[test]
    fn sparse_binary_shape_and_density() {
        let mut rng = Rng::seed_from(71);
        let p = SparseBinaryParams {
            rows: 2000,
            dim: 100,
            density: 0.05,
            popularity_exponent: 1.0,
            blocks: 10,
            drift: 0.5,
        };
        let ds = sparse_binary(&p, &mut rng);
        assert_eq!(ds.num_rows(), 2000);
        assert_eq!(ds.dim(), 100);
        let dens = ds.density();
        assert!(
            (dens - 0.05).abs() < 0.02,
            "density {dens} too far from target"
        );
        // Binary entries only.
        assert!(ds.features.data().iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn sparse_binary_blocks_are_heterogeneous() {
        let mut rng = Rng::seed_from(72);
        let p = SparseBinaryParams {
            rows: 1000,
            dim: 60,
            density: 0.1,
            popularity_exponent: 0.8,
            blocks: 5,
            drift: 0.9,
        };
        let ds = sparse_binary(&p, &mut rng);
        // Mean feature vector of block 0 vs block 2 should differ clearly.
        let block = |b: usize| -> Vec<f64> {
            let mut mean = vec![0.0; 60];
            for r in b * 200..(b + 1) * 200 {
                for (f, m) in mean.iter_mut().enumerate() {
                    *m += ds.features[(r, f)];
                }
            }
            mean.iter().map(|x| x / 200.0).collect()
        };
        let m0 = block(0);
        let m2 = block(2);
        let dist: f64 = m0
            .iter()
            .zip(&m2)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(dist > 0.3, "blocks too similar: {dist}");
    }

    #[test]
    fn drift_zero_is_homogeneous() {
        let mut rng = Rng::seed_from(73);
        let mk = |drift: f64, rng: &mut Rng| {
            sparse_binary(
                &SparseBinaryParams {
                    rows: 1500,
                    dim: 50,
                    density: 0.1,
                    popularity_exponent: 0.8,
                    blocks: 3,
                    drift,
                },
                rng,
            )
        };
        let homo = mk(0.0, &mut rng);
        let hetero = mk(0.9, &mut rng);
        let block_dist = |ds: &Dataset| {
            let rows = ds.num_rows() / 3;
            let mean = |b: usize| -> Vec<f64> {
                let mut m = vec![0.0; ds.dim()];
                for r in b * rows..(b + 1) * rows {
                    for (f, mm) in m.iter_mut().enumerate() {
                        *mm += ds.features[(r, f)] / rows as f64;
                    }
                }
                m
            };
            let (a, b) = (mean(0), mean(2));
            a.iter()
                .zip(&b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt()
        };
        assert!(block_dist(&hetero) > 3.0 * block_dist(&homo));
    }

    #[test]
    fn w8a_like_scaled_shapes() {
        let mut rng = Rng::seed_from(74);
        let ds = w8a_like_scaled(4, 50, &mut rng);
        assert_eq!(ds.num_rows(), 200);
        assert_eq!(ds.dim(), 300);
        assert!(ds.name.contains("w8a"));
    }

    #[test]
    fn a9a_like_scaled_shapes() {
        let mut rng = Rng::seed_from(75);
        let ds = a9a_like_scaled(4, 30, &mut rng);
        assert_eq!(ds.num_rows(), 120);
        assert_eq!(ds.dim(), 123);
    }

    #[test]
    fn spiked_covariance_recovers_spectrum() {
        let mut rng = Rng::seed_from(76);
        let spikes = [20.0, 10.0];
        let ds = spiked_covariance(4000, 12, &spikes, 0.5, &mut rng);
        // Sample covariance ≈ planted spectrum.
        let mut cov = ds.features.t_matmul(&ds.features);
        cov.scale(1.0 / 4000.0);
        cov.symmetrize();
        let e = eig_sym(&cov);
        assert!((e.values[0] - 20.0).abs() < 2.5, "λ1={}", e.values[0]);
        assert!((e.values[1] - 10.0).abs() < 1.5, "λ2={}", e.values[1]);
        assert!(e.values[2] < 1.0, "bulk should be ≈0.5, got {}", e.values[2]);
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let a = w8a_like_scaled(2, 20, &mut Rng::seed_from(9));
        let b = w8a_like_scaled(2, 20, &mut Rng::seed_from(9));
        assert_eq!(a.features.data(), b.features.data());
    }
}
