//! Dataset substrate: loading, synthesizing, and partitioning data.
//!
//! The paper evaluates on libsvm's 'w8a' (d=300) and 'a9a' (d=123) with
//! rows distributed across m=50 agents per Eqn. 5.1:
//! `A_j = Σ_{i=1..n} v_i v_iᵀ` over the j-th sequential block of n rows.
//!
//! The offline image cannot download libsvm files, so [`synthetic`]
//! generates datasets matching their shapes and sparsity statistics (see
//! DESIGN.md §8); [`libsvm`] parses the real format so genuine files can
//! be dropped in and used unchanged.

pub mod libsvm;
pub mod synthetic;
pub mod partition;

use crate::linalg::Mat;

/// A dense row-sample dataset: `rows × dim` feature matrix.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature rows (one sample per row).
    pub features: Mat,
    /// Optional labels (unused by PCA, kept for provenance).
    pub labels: Vec<f64>,
    /// Provenance string for reports.
    pub name: String,
}

impl Dataset {
    /// Number of samples.
    pub fn num_rows(&self) -> usize {
        self.features.rows()
    }

    /// Feature dimension d.
    pub fn dim(&self) -> usize {
        self.features.cols()
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        let nnz = self
            .features
            .data()
            .iter()
            .filter(|&&x| x != 0.0)
            .count();
        nnz as f64 / (self.num_rows() * self.dim()) as f64
    }
}
