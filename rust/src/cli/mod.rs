//! Hand-rolled CLI argument parser (offline stand-in for `clap`).
//!
//! Grammar: `deepca <subcommand> [positionals] [--flag] [--key value|--key=value]`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positionals: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.options.insert(body.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Option as string with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Option as usize with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} `{v}`: expected an integer")),
        }
    }

    /// Option as f64 with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} `{v}`: expected a number")),
        }
    }

    /// Bare-flag presence (or explicit true/false value).
    pub fn flag(&self, key: &str) -> bool {
        matches!(
            self.options.get(key).map(|s| s.as_str()),
            Some("true") | Some("1") | Some("yes")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("experiment fig1 extra");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positionals, vec!["fig1", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse("run --k 5 --tol=1e-6 --verbose");
        assert_eq!(a.usize_or("k", 0).unwrap(), 5);
        assert!((a.f64_or("tol", 0.0).unwrap() - 1e-6).abs() < 1e-18);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' but not '--' is consumed as a value.
        let a = parse("run --shift -3.5");
        assert!((a.f64_or("shift", 0.0).unwrap() + 3.5).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.str_or("engine", "dense"), "dense");
        assert_eq!(a.usize_or("iters", 7).unwrap(), 7);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("run --k notanum");
        assert!(a.usize_or("k", 0).is_err());
    }

    #[test]
    fn empty_is_ok() {
        let a = parse("");
        assert!(a.command.is_none());
    }
}
