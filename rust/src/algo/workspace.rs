//! Per-agent scratch buffers for the solver hot loops.
//!
//! Every power iteration re-orthonormalizes each agent's d×k slice and
//! (for DeEPCA/DePCA) sign-adjusts it against the shared `W⁰`. Before
//! this module, each of those steps allocated fresh matrices — a QR
//! working copy, a Q factor, an R factor, a sign-adjusted copy — per
//! agent per iteration, thousands of times per solve. A
//! [`SolverWorkspace`] owns those buffers once per solver; the per-agent
//! loop runs entirely through the `_into` kernels
//! ([`crate::linalg::qr::qr_into`],
//! [`crate::algo::sign_adjust::sign_adjust_into`],
//! [`crate::linalg::Mat::copy_from`]) and performs **zero heap
//! allocation after the first iteration** (pinned by the
//! counting-allocator audit in `rust/tests/alloc_free.rs`).
//!
//! The buffers are sized per agent (one d×k slice). A sequential step
//! loop needs a single workspace for all m agents; with the
//! [`crate::exec::Executor`] pool enabled, each decentralized solver
//! holds one workspace **per worker chunk** (`Executor::chunk_count`
//! slots) so
//! parallel chunks never share scratch — workspace contents never
//! influence results (QR recomputes from its input every call), which
//! is one leg of the executor's bit-determinism contract. Stack-shaped
//! buffers (the backend's product stack, the FastMix ping-pong stacks)
//! live with their owners — the solvers and the communication engines
//! respectively.

use crate::linalg::qr::{qr_into, QrWorkspace};
use crate::linalg::simd::PackBuf;
use crate::linalg::Mat;

/// Scratch buffers for one solver's per-iteration linalg: the
/// Householder workspace plus landing pads for the Q and R factors.
#[derive(Clone, Debug)]
pub struct SolverWorkspace {
    qr: QrWorkspace,
    /// d×k orthonormal-factor landing buffer.
    q: Mat,
    /// k×k triangular factor (computed by QR, discarded by the solvers).
    r: Mat,
    /// Packed-B scratch for [`crate::linalg::Mat::matmul_packed_into`]
    /// in the solver's product step (grow-only; cloning a workspace
    /// yields a fresh empty scratch — see [`PackBuf`]).
    pack: PackBuf,
}

impl SolverWorkspace {
    /// Workspace for d×k iterates.
    pub fn new(d: usize, k: usize) -> Self {
        SolverWorkspace {
            qr: QrWorkspace::new(d, k),
            q: Mat::zeros(d, k),
            r: Mat::zeros(k, k),
            pack: PackBuf::new(),
        }
    }

    /// The workspace-owned packed-B scratch (the solvers thread it into
    /// `matmul_packed_into` so the product step stays allocation-free
    /// once the scratch has grown to the steady-state panel size).
    pub fn pack_buf(&mut self) -> &mut PackBuf {
        &mut self.pack
    }

    /// QR-orthonormalize `a` into the workspace's Q buffer and return
    /// it. `canonical` selects the sign convention (see
    /// [`crate::linalg::qr::thin_qr_with`]). The buffers refit
    /// themselves on a shape change (e.g. a warm start with a different
    /// k), so this is allocation-free exactly when the shape repeats —
    /// the steady-state solver path.
    pub fn orth_into(&mut self, a: &Mat, canonical: bool) -> &Mat {
        let (d, k) = a.shape();
        if self.q.shape() != (d, k) {
            self.q = Mat::zeros(d, k);
            self.r = Mat::zeros(k, k);
        }
        qr_into(a, canonical, &mut self.q, &mut self.r, &mut self.qr);
        &self.q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::{orth, orth_raw};
    use crate::util::rng::Rng;

    #[test]
    fn orth_into_matches_allocating_orth() {
        let mut rng = Rng::seed_from(971);
        let mut ws = SolverWorkspace::new(12, 3);
        for _ in 0..4 {
            let a = Mat::randn(12, 3, &mut rng);
            assert_eq!(ws.orth_into(&a, true), &orth(&a));
            assert_eq!(ws.orth_into(&a, false), &orth_raw(&a));
        }
    }

    #[test]
    fn orth_into_refits_on_shape_change() {
        // A warm start may hand the solver a different shape than the
        // workspace was built for; the buffers must refit, not panic.
        let mut rng = Rng::seed_from(972);
        let mut ws = SolverWorkspace::new(12, 3);
        for (d, k) in [(12, 3), (12, 2), (20, 5), (12, 3)] {
            let a = Mat::randn(d, k, &mut rng);
            assert_eq!(ws.orth_into(&a, true), &orth(&a), "{d}x{k}");
        }
    }
}
