//! DeEPCA — paper Algorithm 1: subspace tracking + FastMix + SignAdjust.
//!
//! Per power iteration t (Eqns. 3.1–3.3):
//!
//! ```text
//! S_j ← S_j + A_j W_j^t − A_j W_j^{t−1}        # subspace tracking
//! S   ← FastMix(S, K)                          # K gossip rounds
//! W_j ← SignAdjust(QR(S_j), W⁰)                # local orthonormalize
//! ```
//!
//! The cached `G_j = A_j W_j^{t−1}` means exactly one `A_j·W` product per
//! agent per iteration — the same arithmetic cost as a centralized power
//! step, with K (constant, ε-independent — Theorem 1) gossip rounds of
//! communication.

use super::backend::{PowerBackend, RustBackend};
use super::metrics::{RunOutput, RunRecorder};
use super::problem::Problem;
use super::sign_adjust::sign_adjust;
use crate::consensus::comm::{Communicator, DenseComm};
use crate::consensus::metrics::CommStats;
use crate::consensus::AgentStack;
use crate::graph::topology::Topology;
use crate::linalg::qr::orth;
use std::time::Instant;

/// DeEPCA hyperparameters.
#[derive(Clone, Debug)]
pub struct DeepcaConfig {
    /// FastMix rounds K per power iteration (the paper's headline knob —
    /// constant, independent of target precision).
    pub consensus_rounds: usize,
    /// Maximum power iterations T.
    pub max_iters: usize,
    /// Early-stop once mean tan θ ≤ tol (0 disables; metrics must be on).
    pub tol: f64,
    /// Seed for the shared initial `W⁰`.
    pub init_seed: u64,
    /// Apply Algorithm-2 sign adjustment (true per the paper; the
    /// ablation bench turns it off to demonstrate the failure mode).
    pub sign_adjust: bool,
    /// QR sign convention: `true` = canonical positive-diagonal R (this
    /// crate's default, already sign-stable across agents); `false` =
    /// raw Householder / LAPACK-style signs, which flip with the data and
    /// *require* SignAdjust for DeEPCA to converge (the paper's setting —
    /// see the `abl_sign` experiment).
    pub qr_canonical: bool,
}

impl Default for DeepcaConfig {
    fn default() -> Self {
        DeepcaConfig {
            consensus_rounds: 8,
            max_iters: 100,
            tol: 0.0,
            init_seed: 2021,
            sign_adjust: true,
            qr_canonical: true,
        }
    }
}

/// Run DeEPCA with explicit backend and communicator.
pub fn run_with(
    problem: &Problem,
    backend: &dyn PowerBackend,
    comm: &dyn Communicator,
    cfg: &DeepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let m = problem.m();
    assert_eq!(backend.m(), m, "backend/problem agent count mismatch");
    assert_eq!(comm.m(), m, "communicator/problem agent count mismatch");
    let u = problem.u();
    let w0 = problem.initial_w(cfg.init_seed);

    // Initialization (Algorithm 1 line 2): S_j⁰ = W⁰, W_j⁰ = W⁰, and the
    // virtual product A_j W^{-1} := W⁰ so the first tracking difference
    // injects A_j W⁰ − W⁰.
    let mut s = AgentStack::replicate(m, &w0);
    let mut w = AgentStack::replicate(m, &w0);
    let mut g_prev = AgentStack::replicate(m, &w0);

    let mut stats = CommStats::default();
    let t0 = Instant::now();
    let mut iters = 0;
    let mut diverged = false;

    for t in 0..cfg.max_iters {
        // (3.1) tracking update: S_j += A_j W_j^t − G_j^{t}.
        let g = backend.local_products(&w);
        for j in 0..m {
            let sj = s.slice_mut(j);
            sj.axpy(1.0, g.slice(j));
            sj.axpy(-1.0, g_prev.slice(j));
        }
        g_prev = g;

        // (3.2) multi-consensus on the tracked variable.
        comm.fastmix(&mut s, cfg.consensus_rounds, &mut stats);

        // (3.3) local orthonormalization + sign adjustment.
        for j in 0..m {
            let q = if cfg.qr_canonical {
                orth(s.slice(j))
            } else {
                crate::linalg::qr::orth_raw(s.slice(j))
            };
            *w.slice_mut(j) = if cfg.sign_adjust {
                sign_adjust(&q, &w0)
            } else {
                q
            };
        }

        iters = t + 1;
        if !s.is_finite() || !w.is_finite() {
            diverged = true;
            break;
        }
        if recorder.should_record(t) {
            recorder.record(t, &u, &w, Some(&s), &stats, t0.elapsed().as_secs_f64());
        }
        if cfg.tol > 0.0 && recorder.final_tan_theta() <= cfg.tol {
            break;
        }
    }

    RunOutput {
        iters,
        final_tan_theta: recorder.final_tan_theta(),
        comm: stats,
        final_w: w,
        elapsed_secs: t0.elapsed().as_secs_f64(),
        diverged,
    }
}

/// Convenience runner: Rust backend + dense FastMix over `topo`.
pub fn run_dense(
    problem: &Problem,
    topo: &Topology,
    cfg: &DeepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let backend = RustBackend::new(&problem.locals);
    let comm = DenseComm::from_topology(topo);
    run_with(problem, &backend, &comm, cfg, recorder)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn small_problem(seed: u64) -> (Problem, Topology) {
        let ds = synthetic::spiked_covariance(
            400,
            16,
            &[12.0, 8.0, 5.0],
            0.3,
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn converges_linearly_with_enough_k() {
        let (p, topo) = small_problem(161);
        let cfg = DeepcaConfig { consensus_rounds: 10, max_iters: 120, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(!out.diverged);
        assert!(
            out.final_tan_theta < 1e-9,
            "tanθ = {:.3e} after {} iters",
            out.final_tan_theta,
            out.iters
        );
        // Consensus errors must also vanish (Lemma 1 second claim).
        let last = rec.records.last().unwrap();
        assert!(last.s_deviation < 1e-8, "S dev {}", last.s_deviation);
        assert!(last.w_deviation < 1e-8, "W dev {}", last.w_deviation);
    }

    #[test]
    fn rate_tracks_gamma() {
        // Error after t iters should decay roughly like γ^t (Lemma 1).
        let (p, topo) = small_problem(162);
        let cfg = DeepcaConfig { consensus_rounds: 12, max_iters: 60, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let _ = run_dense(&p, &topo, &cfg, &mut rec);
        let gamma = p.gamma();
        // Measure the empirical decay over a mid-run window.
        let e10 = rec.records[10].mean_tan_theta;
        let e30 = rec.records[30].mean_tan_theta;
        let empirical = (e30 / e10).powf(1.0 / 20.0);
        // Power method converges at (λ_{k+1}/λ_k); γ is the paper's looser
        // bound — empirical rate must be at least as fast.
        assert!(
            empirical <= gamma + 0.05,
            "empirical rate {empirical} slower than γ={gamma}"
        );
    }

    #[test]
    fn too_few_consensus_rounds_stalls() {
        // K=1 on *heterogeneous* data (block-drifted, the paper's regime):
        // DeEPCA must fail to reach high precision (Figure 1, K too small).
        // Note a spiked-covariance split is nearly homogeneous and K=1
        // converges fine there — heterogeneity is what makes K matter.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1600,
                dim: 40,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 8,
                drift: 0.8,
            },
            &mut Rng::seed_from(163),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.4, &mut Rng::seed_from(164));
        let cfg = DeepcaConfig { consensus_rounds: 1, max_iters: 120, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(
            out.diverged || out.final_tan_theta > 1e-6,
            "K=1 unexpectedly reached {:.3e}",
            out.final_tan_theta
        );
        // And with a healthy K the same instance converges deep.
        let cfg_ok = DeepcaConfig { consensus_rounds: 12, max_iters: 120, ..Default::default() };
        let mut rec_ok = RunRecorder::every_iteration();
        let out_ok = run_dense(&p, &topo, &cfg_ok, &mut rec_ok);
        assert!(out_ok.final_tan_theta < 1e-9, "K=12: {:.3e}", out_ok.final_tan_theta);
    }

    #[test]
    fn early_stop_respects_tol() {
        let (p, topo) = small_problem(164);
        let cfg = DeepcaConfig {
            consensus_rounds: 10,
            max_iters: 200,
            tol: 1e-6,
            ..Default::default()
        };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(out.final_tan_theta <= 1e-6);
        assert!(out.iters < 200, "early stop did not fire");
    }

    #[test]
    fn communication_accounting() {
        let (p, topo) = small_problem(165);
        let cfg = DeepcaConfig { consensus_rounds: 5, max_iters: 10, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert_eq!(out.comm.mixes, 10);
        assert_eq!(out.comm.rounds, 50);
    }

    #[test]
    fn tracking_invariant_mean_s_equals_mean_g() {
        // Lemma 2: S̄ᵗ = Ḡᵗ for every t (FastMix preserves means and the
        // update telescopes). Verify on a short run by recomputing Ḡ.
        let (p, topo) = small_problem(166);
        let cfg = DeepcaConfig { consensus_rounds: 6, max_iters: 12, ..Default::default() };
        // Re-run manually to have access to internals.
        let m = p.m();
        let w0 = p.initial_w(cfg.init_seed);
        let backend = RustBackend::new(&p.locals);
        let comm = DenseComm::from_topology(&topo);
        let mut s = AgentStack::replicate(m, &w0);
        let mut w = AgentStack::replicate(m, &w0);
        let mut g_prev = AgentStack::replicate(m, &w0);
        let mut stats = CommStats::default();
        for _t in 0..cfg.max_iters {
            let g = backend.local_products(&w);
            for j in 0..m {
                let sj = s.slice_mut(j);
                sj.axpy(1.0, g.slice(j));
                sj.axpy(-1.0, g_prev.slice(j));
            }
            g_prev = g.clone();
            comm.fastmix(&mut s, cfg.consensus_rounds, &mut stats);
            for j in 0..m {
                *w.slice_mut(j) = sign_adjust(&orth(s.slice(j)), &w0);
            }
            // Invariant check: S̄ = Ḡ.
            assert!(
                (&s.mean() - &g.mean()).fro_norm() < 1e-9,
                "Lemma-2 invariant violated"
            );
        }
    }

    #[test]
    fn works_without_sign_adjust_on_easy_instance() {
        // With a huge gap and homogeneous data the sign never flips, so
        // disabling Algorithm 2 must still converge (the ablation bench
        // covers the failure case on heterogeneous data).
        let mut rng = Rng::seed_from(167);
        let ds = synthetic::spiked_covariance(300, 10, &[50.0], 0.01, &mut rng);
        let p = Problem::from_dataset(&ds, 6, 1);
        let topo = Topology::complete(6);
        let cfg = DeepcaConfig {
            consensus_rounds: 3,
            max_iters: 60,
            sign_adjust: false,
            ..Default::default()
        };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(out.final_tan_theta < 1e-8, "tanθ={}", out.final_tan_theta);
    }

    #[test]
    fn deterministic_given_seeds() {
        let (p, topo) = small_problem(168);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 20, ..Default::default() };
        let mut r1 = RunRecorder::every_iteration();
        let o1 = run_dense(&p, &topo, &cfg, &mut r1);
        let mut r2 = RunRecorder::every_iteration();
        let o2 = run_dense(&p, &topo, &cfg, &mut r2);
        assert_eq!(o1.final_tan_theta.to_bits(), o2.final_tan_theta.to_bits());
    }

    #[test]
    fn non_psd_locals_still_converge() {
        // Remark 1: A_j need not be PSD as long as the aggregate is.
        let ds = synthetic::spiked_covariance(
            400,
            12,
            &[10.0, 6.0],
            0.2,
            &mut Rng::seed_from(169),
        );
        let mut part = crate::data::partition::partition_gram(
            &ds,
            8,
            crate::data::partition::GramScaling::PerRow,
        );
        crate::data::partition::make_non_psd(&mut part, 3.0);
        let p = Problem::from_partition(part, 2, "non-psd");
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(170));
        let cfg = DeepcaConfig { consensus_rounds: 14, max_iters: 150, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(
            out.final_tan_theta < 1e-8,
            "non-PSD locals: tanθ={}",
            out.final_tan_theta
        );
    }
}
