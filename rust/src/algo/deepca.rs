//! DeEPCA — paper Algorithm 1: subspace tracking + FastMix + SignAdjust.
//!
//! Per power iteration t (Eqns. 3.1–3.3):
//!
//! ```text
//! S_j ← S_j + A_j W_j^t − A_j W_j^{t−1}        # subspace tracking
//! S   ← FastMix(S, K)                          # K gossip rounds
//! W_j ← SignAdjust(QR(S_j), W⁰)                # local orthonormalize
//! ```
//!
//! The cached `G_j = A_j W_j^{t−1}` means exactly one `A_j·W` product per
//! agent per iteration — the same arithmetic cost as a centralized power
//! step, with K (constant, ε-independent — Theorem 1) gossip rounds of
//! communication.
//!
//! [`DeepcaSolver`] implements the step-wise [`Solver`] API; iteration
//! control (stopping, recording, observers) lives in the shared
//! [`crate::algo::solver::drive`] loop or the
//! [`crate::coordinator::session::Session`] builder. The step hot path
//! runs entirely through the `_into` kernels and the solver's persistent
//! buffers ([`crate::algo::workspace::SolverWorkspace`] + the product
//! stack), so it performs **zero heap allocation after the first
//! iteration** (audited by `rust/tests/alloc_free.rs`).

use super::backend::{PowerBackend, RustBackend};
use super::problem::Problem;
use super::sign_adjust::sign_adjust_into;
use super::solver::{Solver, SolverState, StepReport};
use super::workspace::SolverWorkspace;
use crate::consensus::comm::{Communicator, DenseComm};
use crate::consensus::AgentStack;
use crate::exec::Executor;
use crate::graph::topology::Topology;
use std::sync::Arc;

/// DeEPCA hyperparameters.
#[derive(Clone, Debug)]
pub struct DeepcaConfig {
    /// FastMix rounds K per power iteration (the paper's headline knob —
    /// constant, independent of target precision).
    pub consensus_rounds: usize,
    /// Maximum power iterations T.
    pub max_iters: usize,
    /// Early-stop once mean tan θ ≤ tol (0 disables). Evaluated freshly
    /// by the driver loop every iteration, independent of the recorder.
    pub tol: f64,
    /// Seed for the shared initial `W⁰`.
    pub init_seed: u64,
    /// Apply Algorithm-2 sign adjustment (true per the paper; the
    /// ablation bench turns it off to demonstrate the failure mode).
    pub sign_adjust: bool,
    /// QR sign convention: `true` = canonical positive-diagonal R (this
    /// crate's default, already sign-stable across agents); `false` =
    /// raw Householder / LAPACK-style signs, which flip with the data and
    /// *require* SignAdjust for DeEPCA to converge (the paper's setting —
    /// see the `abl_sign` experiment).
    pub qr_canonical: bool,
}

impl Default for DeepcaConfig {
    fn default() -> Self {
        DeepcaConfig {
            consensus_rounds: 8,
            max_iters: 100,
            tol: 0.0,
            init_seed: 2021,
            sign_adjust: true,
            qr_canonical: true,
        }
    }
}

/// Step-wise DeEPCA: owns `S`, `W`, the cached products `G_prev`, and
/// the communication stack for one run.
pub struct DeepcaSolver<'a> {
    problem: &'a Problem,
    backend: Box<dyn PowerBackend + 'a>,
    comm: Box<dyn Communicator + 'a>,
    cfg: DeepcaConfig,
    /// Sign-adjust anchor (Algorithm 2's `W⁰`; re-anchored on warm start).
    w0: crate::linalg::Mat,
    /// Cached `G_j = A_j W_j^{t−1}` (initialized to the virtual product
    /// `A_j W^{-1} := W⁰` so the first tracking difference injects
    /// `A_j W⁰ − W⁰` — Algorithm 1 line 2).
    g_prev: crate::consensus::AgentStack,
    /// Landing buffer for this iteration's products `A_j W_j^t`; swapped
    /// with `g_prev` after the tracking update (never reallocated).
    g_next: crate::consensus::AgentStack,
    /// Worker pool for the per-agent loops (tracking update and
    /// QR/sign-adjust); the sequential executor runs them inline.
    exec: Arc<Executor>,
    /// Per-worker QR / sign-adjust scratch: one [`SolverWorkspace`] per
    /// executor chunk, so parallel chunks never share buffers and the
    /// steady-state step stays allocation-free.
    workspaces: Vec<SolverWorkspace>,
    state: SolverState,
}

impl<'a> DeepcaSolver<'a> {
    /// Solver over an explicit backend and communicator.
    pub fn new(
        problem: &'a Problem,
        backend: Box<dyn PowerBackend + 'a>,
        comm: Box<dyn Communicator + 'a>,
        cfg: DeepcaConfig,
    ) -> Self {
        let m = problem.m();
        assert_eq!(backend.m(), m, "backend/problem agent count mismatch");
        assert_eq!(comm.m(), m, "communicator/problem agent count mismatch");
        let w0 = problem.initial_w(cfg.init_seed);
        let (d, k) = w0.shape();
        let w = crate::consensus::AgentStack::replicate(m, &w0);
        DeepcaSolver {
            problem,
            backend,
            comm,
            cfg,
            g_prev: crate::consensus::AgentStack::replicate(m, &w0),
            g_next: crate::consensus::AgentStack::replicate(m, &w0),
            exec: Arc::new(Executor::sequential()),
            workspaces: vec![SolverWorkspace::new(d, k)],
            state: SolverState::init(w, true),
            w0,
        }
    }

    /// Run the per-agent hot loops on `exec`'s worker pool (fixed
    /// partitioning by agent index, one workspace slot per chunk —
    /// results bit-identical to the sequential path for any thread
    /// count).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        let (d, k) = self.w0.shape();
        self.workspaces = (0..exec.chunk_count(self.problem.m()))
            .map(|_| SolverWorkspace::new(d, k))
            .collect();
        self.exec = exec;
        self
    }

    /// Convenience: Rust backend + dense FastMix over `topo`.
    pub fn dense(problem: &'a Problem, topo: &Topology, cfg: DeepcaConfig) -> Self {
        let backend = Box::new(RustBackend::new(&problem.locals));
        let comm = Box::new(DenseComm::from_topology(topo));
        Self::new(problem, backend, comm, cfg)
    }

    /// The configuration this solver runs.
    pub fn config(&self) -> &DeepcaConfig {
        &self.cfg
    }
}

impl Solver for DeepcaSolver<'_> {
    fn name(&self) -> &'static str {
        "deepca"
    }

    fn problem(&self) -> &Problem {
        self.problem
    }

    fn step(&mut self) -> StepReport {
        let t = self.state.iter;
        let _span_step = crate::trace_span!(Step, t as u64);
        let exec = Arc::clone(&self.exec);
        let SolverState { w, s, stats, .. } = &mut self.state;
        let s = s.as_mut().expect("DeEPCA tracks S");

        // (3.1) tracking update: S_j += A_j W_j^t − G_j^t. The products
        // land in the persistent `g_next` buffer, then the buffers swap —
        // exactly one A_j·W product per agent, zero allocation. Both the
        // product batch and the per-agent update run on the pool.
        {
            let _span = crate::trace_span!(LocalProduct, t as u64);
            self.backend.local_products_into(w, &mut self.g_next);
        }
        {
            let _span = crate::trace_span!(TrackingUpdate, t as u64);
            let g_next = &self.g_next;
            let g_prev = &self.g_prev;
            exec.par_for_each_agent(s.slices_mut(), |j, sj| {
                sj.axpy(1.0, g_next.slice(j));
                sj.axpy(-1.0, g_prev.slice(j));
            });
        }
        std::mem::swap(&mut self.g_prev, &mut self.g_next);

        // (3.2) multi-consensus on the tracked variable (the engine
        // reuses its recursion buffers across mixes). The gossip span is
        // emitted inside the engine's `fastmix`, which also records
        // per-round events.
        self.comm.fastmix(s, self.cfg.consensus_rounds, stats);

        // (3.3) local orthonormalization + sign adjustment, chunked over
        // the pool with one workspace slot per chunk.
        {
            let _span = crate::trace_span!(Qr, t as u64);
            let s: &AgentStack = s;
            let w0 = &self.w0;
            let sign_adjust = self.cfg.sign_adjust;
            let canonical = self.cfg.qr_canonical;
            exec.par_chunks_ctx(w.slices_mut(), &mut self.workspaces, |lo, chunk, ws| {
                for (off, wj) in chunk.iter_mut().enumerate() {
                    let q = ws.orth_into(s.slice(lo + off), canonical);
                    if sign_adjust {
                        sign_adjust_into(q, w0, wj);
                    } else {
                        wj.copy_from(q);
                    }
                }
            });
        }
        if self.cfg.sign_adjust {
            crate::trace_event!(SignAdjust, t as u64);
        }

        self.state.iter = t + 1;
        let finite = self.state.w.is_finite()
            && self.state.s.as_ref().map(|s| s.is_finite()).unwrap_or(true);
        StepReport {
            iter: t,
            // lint: allow(alloc, per-step stats snapshot for the report struct — tiny and off the data path)
            comm: self.state.stats.clone(),
            finite,
            mean_tan_theta: None,
        }
    }

    fn state(&self) -> &SolverState {
        &self.state
    }

    fn warm_start(&mut self, w: &crate::consensus::AgentStack) {
        assert_eq!(w.m(), self.problem.m(), "warm-start agent count mismatch");
        assert_eq!(w.slice_shape(), self.w0.shape(), "warm-start shape mismatch");
        // Re-anchor the sign convention on the warm iterate and rebuild
        // the tracking state so Lemma 2's telescoping (S̄ᵗ = Ḡᵗ) holds
        // from the restart: S_j = W_j, virtual G_j^{-1} = W_j.
        self.w0 = w.slice(0).clone();
        self.g_prev = w.clone();
        self.state = SolverState::init(w.clone(), true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::metrics::{RunOutput, RunRecorder};
    use crate::algo::solver::Algo;
    use crate::coordinator::session::Session;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    /// Test driver with the old shim's shape, routed through the
    /// [`Session`] builder (the only run path since the shims' removal).
    fn run_dense(
        problem: &Problem,
        topo: &Topology,
        cfg: &DeepcaConfig,
        recorder: &mut RunRecorder,
    ) -> RunOutput {
        let report = Session::on(problem, topo)
            .algo(Algo::Deepca(cfg.clone()))
            .record(std::mem::take(recorder))
            .solve();
        let out = report.to_run_output();
        *recorder = report.trace;
        out
    }

    fn small_problem(seed: u64) -> (Problem, Topology) {
        let ds = synthetic::spiked_covariance(
            400,
            16,
            &[12.0, 8.0, 5.0],
            0.3,
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn converges_linearly_with_enough_k() {
        let (p, topo) = small_problem(161);
        let cfg = DeepcaConfig { consensus_rounds: 10, max_iters: 120, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(!out.diverged);
        assert!(
            out.final_tan_theta < 1e-9,
            "tanθ = {:.3e} after {} iters",
            out.final_tan_theta,
            out.iters
        );
        // Consensus errors must also vanish (Lemma 1 second claim).
        let last = rec.records.last().unwrap();
        assert!(last.s_deviation < 1e-8, "S dev {}", last.s_deviation);
        assert!(last.w_deviation < 1e-8, "W dev {}", last.w_deviation);
    }

    #[test]
    fn rate_tracks_gamma() {
        // Error after t iters should decay roughly like γ^t (Lemma 1).
        let (p, topo) = small_problem(162);
        let cfg = DeepcaConfig { consensus_rounds: 12, max_iters: 60, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let _ = run_dense(&p, &topo, &cfg, &mut rec);
        let gamma = p.gamma();
        // Measure the empirical decay over a mid-run window.
        let e10 = rec.records[10].mean_tan_theta;
        let e30 = rec.records[30].mean_tan_theta;
        let empirical = (e30 / e10).powf(1.0 / 20.0);
        // Power method converges at (λ_{k+1}/λ_k); γ is the paper's looser
        // bound — empirical rate must be at least as fast.
        assert!(
            empirical <= gamma + 0.05,
            "empirical rate {empirical} slower than γ={gamma}"
        );
    }

    #[test]
    fn too_few_consensus_rounds_stalls() {
        // K=1 on *heterogeneous* data (block-drifted, the paper's regime):
        // DeEPCA must fail to reach high precision (Figure 1, K too small).
        // Note a spiked-covariance split is nearly homogeneous and K=1
        // converges fine there — heterogeneity is what makes K matter.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1600,
                dim: 40,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 8,
                drift: 0.8,
            },
            &mut Rng::seed_from(163),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.4, &mut Rng::seed_from(164));
        let cfg = DeepcaConfig { consensus_rounds: 1, max_iters: 120, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(
            out.diverged || out.final_tan_theta > 1e-6,
            "K=1 unexpectedly reached {:.3e}",
            out.final_tan_theta
        );
        // And with a healthy K the same instance converges deep.
        let cfg_ok = DeepcaConfig { consensus_rounds: 12, max_iters: 120, ..Default::default() };
        let mut rec_ok = RunRecorder::every_iteration();
        let out_ok = run_dense(&p, &topo, &cfg_ok, &mut rec_ok);
        assert!(out_ok.final_tan_theta < 1e-9, "K=12: {:.3e}", out_ok.final_tan_theta);
    }

    #[test]
    fn early_stop_respects_tol() {
        let (p, topo) = small_problem(164);
        let cfg = DeepcaConfig {
            consensus_rounds: 10,
            max_iters: 200,
            tol: 1e-6,
            ..Default::default()
        };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(out.final_tan_theta <= 1e-6);
        assert!(out.iters < 200, "early stop did not fire");
    }

    #[test]
    fn communication_accounting() {
        let (p, topo) = small_problem(165);
        let cfg = DeepcaConfig { consensus_rounds: 5, max_iters: 10, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert_eq!(out.comm.mixes, 10);
        assert_eq!(out.comm.rounds, 50);
    }

    #[test]
    fn tracking_invariant_mean_s_equals_mean_g() {
        // Lemma 2: S̄ᵗ = Ḡᵗ for every t (FastMix preserves means and the
        // update telescopes). Verified against the step-wise solver's own
        // state after each step: S̄ must equal the mean of the products it
        // just cached.
        let (p, topo) = small_problem(166);
        let cfg = DeepcaConfig { consensus_rounds: 6, max_iters: 12, ..Default::default() };
        let mut solver = DeepcaSolver::dense(&p, &topo, cfg.clone());
        for _t in 0..cfg.max_iters {
            let _ = solver.step();
            // Recompute Ḡᵗ from the post-step iterates' products at t
            // (solver caches exactly A_j W_j^t in g_prev after stepping
            // from W^t; use the pre-step iterate instead): check the
            // invariant via the cached products.
            let s_mean = solver.state().s.as_ref().unwrap().mean();
            let g_mean = solver.g_prev.mean();
            assert!(
                (&s_mean - &g_mean).fro_norm() < 1e-9,
                "Lemma-2 invariant violated"
            );
        }
    }

    #[test]
    fn works_without_sign_adjust_on_easy_instance() {
        // With a huge gap and homogeneous data the sign never flips, so
        // disabling Algorithm 2 must still converge (the ablation bench
        // covers the failure case on heterogeneous data).
        let mut rng = Rng::seed_from(167);
        let ds = synthetic::spiked_covariance(300, 10, &[50.0], 0.01, &mut rng);
        let p = Problem::from_dataset(&ds, 6, 1);
        let topo = Topology::complete(6);
        let cfg = DeepcaConfig {
            consensus_rounds: 3,
            max_iters: 60,
            sign_adjust: false,
            ..Default::default()
        };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(out.final_tan_theta < 1e-8, "tanθ={}", out.final_tan_theta);
    }

    #[test]
    fn deterministic_given_seeds() {
        let (p, topo) = small_problem(168);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 20, ..Default::default() };
        let mut r1 = RunRecorder::every_iteration();
        let o1 = run_dense(&p, &topo, &cfg, &mut r1);
        let mut r2 = RunRecorder::every_iteration();
        let o2 = run_dense(&p, &topo, &cfg, &mut r2);
        assert_eq!(o1.final_tan_theta.to_bits(), o2.final_tan_theta.to_bits());
    }

    #[test]
    fn non_psd_locals_still_converge() {
        // Remark 1: A_j need not be PSD as long as the aggregate is.
        let ds = synthetic::spiked_covariance(
            400,
            12,
            &[10.0, 6.0],
            0.2,
            &mut Rng::seed_from(169),
        );
        let mut part = crate::data::partition::partition_gram(
            &ds,
            8,
            crate::data::partition::GramScaling::PerRow,
        );
        crate::data::partition::make_non_psd(&mut part, 3.0);
        let p = Problem::from_partition(part, 2, "non-psd");
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(170));
        let cfg = DeepcaConfig { consensus_rounds: 14, max_iters: 150, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);
        assert!(
            out.final_tan_theta < 1e-8,
            "non-PSD locals: tanθ={}",
            out.final_tan_theta
        );
    }

    #[test]
    fn solver_steps_match_session() {
        // The step-wise solver driven by hand must equal the driven run.
        let (p, topo) = small_problem(171);
        let cfg = DeepcaConfig { consensus_rounds: 8, max_iters: 15, ..Default::default() };
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(&p, &topo, &cfg, &mut rec);

        let mut solver = DeepcaSolver::dense(&p, &topo, cfg);
        for _ in 0..15 {
            let rep = solver.step();
            assert!(rep.finite);
        }
        assert_eq!(solver.state().iter, 15);
        assert!(
            out.final_w.distance(&solver.state().w) == 0.0,
            "manual steps diverge from the driven run"
        );
    }

    #[test]
    fn warm_start_resumes_convergence() {
        let (p, topo) = small_problem(172);
        let cfg = DeepcaConfig { consensus_rounds: 10, max_iters: 30, ..Default::default() };
        let mut solver = DeepcaSolver::dense(&p, &topo, cfg.clone());
        for _ in 0..30 {
            solver.step();
        }
        let mid = solver.state().w.clone();
        let mid_err = super::super::solver::mean_tan_theta(&p.u(), &mid);

        let mut resumed = DeepcaSolver::dense(&p, &topo, cfg);
        resumed.warm_start(&mid);
        assert_eq!(resumed.state().iter, 0);
        for _ in 0..30 {
            resumed.step();
        }
        let end_err = super::super::solver::mean_tan_theta(&p.u(), &resumed.state().w);
        assert!(
            end_err < 0.5 * mid_err.max(1e-13) || end_err < 1e-12,
            "warm start should keep converging: {mid_err:.3e} -> {end_err:.3e}"
        );
    }
}
