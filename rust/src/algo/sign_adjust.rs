//! SignAdjust — paper Algorithm 2.
//!
//! Column signs of an orthonormal basis are arbitrary: a power iteration
//! can flip them between steps without changing the subspace, but a flip
//! wrecks both the cross-agent average `W̄ = (1/m)ΣW_j` and the tracking
//! difference `A_j(W^t − W^{t−1})`. Algorithm 2 pins every column to the
//! half-space of the corresponding column of the shared `W⁰`: flip
//! column i iff `⟨Wᵗ(:,i), W⁰(:,i)⟩ < 0`.

use crate::linalg::Mat;

/// Flip columns of `w` whose inner product with the same column of
/// `reference` is negative. Returns the adjusted matrix.
pub fn sign_adjust(w: &Mat, reference: &Mat) -> Mat {
    let mut out = Mat::zeros(w.rows(), w.cols());
    sign_adjust_into(w, reference, &mut out);
    out
}

/// Write the sign-adjusted `w` into a caller-owned buffer (the
/// allocation-free form the solver hot loops use; `out` is fully
/// overwritten and never reallocated). Bit-identical to [`sign_adjust`].
pub fn sign_adjust_into(w: &Mat, reference: &Mat, out: &mut Mat) {
    assert_eq!(w.shape(), reference.shape(), "SignAdjust shape mismatch");
    assert_eq!(w.shape(), out.shape(), "SignAdjust output shape mismatch");
    out.copy_from(w);
    sign_adjust_inplace(out, reference);
}

/// In-place variant (column dots are computed before any flip, so the
/// result equals the out-of-place forms exactly).
///
/// Runs row-major in ≤64-column blocks through the SIMD dispatch's
/// [`col_dots`](crate::linalg::simd::KernelDispatch::col_dots) kernel:
/// one streaming pass over `w`/`reference` per block accumulates every
/// column's dot simultaneously instead of striding column-by-column.
/// Per column the accumulation chain still runs in ascending row order
/// (the pre-SIMD sequence — unfused in scalar mode), and flips are
/// exact negations, bit-identical in every mode.
pub fn sign_adjust_inplace(w: &mut Mat, reference: &Mat) {
    assert_eq!(w.shape(), reference.shape(), "SignAdjust shape mismatch");
    let (d, k) = w.shape();
    let kd = crate::linalg::simd::dispatch();
    let mut dots = [0.0f64; 64];
    let mut j0 = 0;
    while j0 < k {
        let jw = (k - j0).min(64);
        dots[..jw].fill(0.0);
        for r in 0..d {
            let row = r * k + j0;
            kd.col_dots(&w.data()[row..row + jw], &reference.data()[row..row + jw], &mut dots[..jw]);
        }
        // Flips only touch their own column, so dots-then-flips equals
        // the old per-column interleaving exactly.
        for j in 0..jw {
            if dots[j] < 0.0 {
                for r in 0..d {
                    let x = &mut w.data_mut()[r * k + j0 + j];
                    *x = -*x;
                }
            }
        }
        j0 += jw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn aligned_input_unchanged() {
        let mut rng = Rng::seed_from(141);
        let w = Mat::rand_orthonormal(10, 3, &mut rng);
        let out = sign_adjust(&w, &w);
        assert_eq!(out.data(), w.data());
    }

    #[test]
    fn flipped_column_restored() {
        let mut rng = Rng::seed_from(142);
        let w = Mat::rand_orthonormal(10, 3, &mut rng);
        let mut flipped = w.clone();
        let c1: Vec<f64> = w.col(1).iter().map(|v| -v).collect();
        flipped.set_col(1, &c1);
        let out = sign_adjust(&flipped, &w);
        assert!((&out - &w).fro_norm() < 1e-15);
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::seed_from(143);
        let w0 = Mat::rand_orthonormal(12, 4, &mut rng);
        let w = Mat::rand_orthonormal(12, 4, &mut rng);
        let once = sign_adjust(&w, &w0);
        let twice = sign_adjust(&once, &w0);
        assert_eq!(once.data(), twice.data());
    }

    #[test]
    fn preserves_column_space() {
        let mut rng = Rng::seed_from(144);
        let w0 = Mat::rand_orthonormal(15, 3, &mut rng);
        let w = Mat::rand_orthonormal(15, 3, &mut rng);
        let out = sign_adjust(&w, &w0);
        // Projectors identical.
        let pw = w.matmul(&w.t());
        let po = out.matmul(&out.t());
        assert!((&pw - &po).fro_norm() < 1e-12);
    }

    #[test]
    fn all_outputs_positively_aligned() {
        let mut rng = Rng::seed_from(145);
        let w0 = Mat::rand_orthonormal(20, 5, &mut rng);
        let w = Mat::rand_orthonormal(20, 5, &mut rng);
        let out = sign_adjust(&w, &w0);
        for i in 0..5 {
            let dot: f64 = out
                .col(i)
                .iter()
                .zip(w0.col(i))
                .map(|(a, b)| a * b)
                .sum();
            assert!(dot >= 0.0, "column {i} still misaligned");
        }
    }

    #[test]
    fn inplace_matches() {
        let mut rng = Rng::seed_from(146);
        let w0 = Mat::rand_orthonormal(8, 2, &mut rng);
        let w = Mat::rand_orthonormal(8, 2, &mut rng);
        let pure = sign_adjust(&w, &w0);
        let mut wm = w.clone();
        sign_adjust_inplace(&mut wm, &w0);
        assert_eq!(pure.data(), wm.data());
    }

    #[test]
    fn into_overwrites_dirty_buffer() {
        let mut rng = Rng::seed_from(147);
        let w0 = Mat::rand_orthonormal(9, 3, &mut rng);
        let w = Mat::rand_orthonormal(9, 3, &mut rng);
        let pure = sign_adjust(&w, &w0);
        let mut out = Mat::from_fn(9, 3, |_, _| f64::NAN);
        sign_adjust_into(&w, &w0, &mut out);
        assert_eq!(pure.data(), out.data());
    }
}
