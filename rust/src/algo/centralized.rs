//! CPCA — the centralized power-method reference.
//!
//! The paper's figures include centralized PCA as the convergence-rate
//! yardstick: DeEPCA with sufficient K should match its linear rate.
//! `W ← QR(A·W)` on the aggregate, with per-iteration tan θ records.
//!
//! [`CentralizedSolver`] implements the step-wise [`Solver`] API over a
//! single-slice iterate stack, so CPCA runs through the same driver,
//! recorder, and builder as the decentralized algorithms.

use super::problem::Problem;
use super::solver::{drive, Solver, SolverState, StepReport, StopCriteria};
use super::workspace::SolverWorkspace;
use crate::algo::metrics::RunRecorder;
use crate::consensus::AgentStack;
use crate::linalg::qr::orth;
use crate::linalg::Mat;
use crate::util::timer::Timer;

/// Centralized power-method knobs.
#[derive(Clone, Debug)]
pub struct CentralizedConfig {
    /// Maximum power iterations.
    pub max_iters: usize,
    /// Early stop once tan θ ≤ tol (0 disables).
    pub tol: f64,
    /// Seed for the initial `W⁰` (same initializer as the decentralized
    /// runs for fair comparison).
    pub init_seed: u64,
}

impl Default for CentralizedConfig {
    fn default() -> Self {
        CentralizedConfig { max_iters: 100, tol: 0.0, init_seed: 2021 }
    }
}

/// Step-wise centralized power method on the aggregate matrix.
pub struct CentralizedSolver<'a> {
    problem: &'a Problem,
    /// Persistent landing buffer for `A·W`.
    prod: Mat,
    /// QR scratch (see [`SolverWorkspace`]). Deliberately no executor
    /// hook: the single-slice iterate has no per-agent loop to fan out
    /// (`chunk_count(1) == 1` would always run inline), so this solver
    /// stays on the caller thread by construction.
    workspace: SolverWorkspace,
    state: SolverState,
}

impl<'a> CentralizedSolver<'a> {
    /// Build from the problem's aggregate.
    pub fn new(problem: &'a Problem, cfg: CentralizedConfig) -> Self {
        let w0 = problem.initial_w(cfg.init_seed);
        let (d, k) = w0.shape();
        CentralizedSolver {
            problem,
            prod: Mat::zeros(d, k),
            workspace: SolverWorkspace::new(d, k),
            state: SolverState::init(AgentStack::replicate(1, &w0), false),
        }
    }
}

impl Solver for CentralizedSolver<'_> {
    fn name(&self) -> &'static str {
        "centralized"
    }

    fn problem(&self) -> &Problem {
        self.problem
    }

    fn step(&mut self) -> StepReport {
        let t = self.state.iter;
        let _span_step = crate::trace_span!(Step, t as u64);
        {
            let _span = crate::trace_span!(LocalProduct, t as u64);
            self.problem.aggregate.matmul_packed_into(
                self.state.w.slice(0),
                self.workspace.pack_buf(),
                &mut self.prod,
            );
        }
        let _span_qr = crate::trace_span!(Qr, t as u64);
        let q = self.workspace.orth_into(&self.prod, true);
        self.state.w.slice_mut(0).copy_from(q);
        self.state.iter = t + 1;
        StepReport {
            iter: t,
            // lint: allow(alloc, per-step stats snapshot for the report struct — tiny and off the data path)
            comm: self.state.stats.clone(),
            finite: self.state.w.is_finite(),
            mean_tan_theta: None,
        }
    }

    fn state(&self) -> &SolverState {
        &self.state
    }

    fn warm_start(&mut self, w: &AgentStack) {
        // Accept any per-agent stack: centralized PCA restarts from the
        // (orthonormalized) mean iterate. Refit the product buffer to
        // the incoming shape (the workspace refits itself on use).
        let mean = orth(&w.mean());
        self.prod = Mat::zeros(mean.rows(), mean.cols());
        self.state = SolverState::init(AgentStack::replicate(1, &mean), false);
    }
}

/// Output of a centralized run (legacy shape).
#[derive(Clone, Debug)]
pub struct CentralizedOutput {
    /// Final orthonormal iterate.
    pub w: Mat,
    /// tan θ_k(U, Wᵗ) per iteration.
    pub tan_trace: Vec<f64>,
    /// Iterations executed.
    pub iters: usize,
    /// Wall time.
    pub elapsed_secs: f64,
}

/// Run `iters` power iterations from the seed-`init_seed` start
/// (same initializer as the decentralized runs for fair comparison).
pub fn run(problem: &Problem, iters: usize, init_seed: u64) -> CentralizedOutput {
    run_with_tol(problem, iters, init_seed, 0.0)
}

/// As [`run`], stopping early once tan θ ≤ tol (if tol > 0).
pub fn run_with_tol(
    problem: &Problem,
    iters: usize,
    init_seed: u64,
    tol: f64,
) -> CentralizedOutput {
    let t0 = Timer::start();
    let cfg = CentralizedConfig { max_iters: iters, tol, init_seed };
    let mut solver = CentralizedSolver::new(problem, cfg);
    let mut rec = RunRecorder::every_iteration();
    let outcome = drive(
        &mut solver,
        &StopCriteria::max_iters(iters).with_tol(tol),
        &mut rec,
        None,
    );
    CentralizedOutput {
        w: solver.state().w.slice(0).clone(),
        tan_trace: rec.records.iter().map(|r| r.mean_tan_theta).collect(),
        iters: outcome.iters,
        elapsed_secs: t0.elapsed_secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn problem(seed: u64) -> Problem {
        let ds = synthetic::spiked_covariance(
            500,
            14,
            &[10.0, 7.0, 4.0],
            0.2,
            &mut Rng::seed_from(seed),
        );
        Problem::from_dataset(&ds, 5, 2)
    }

    #[test]
    fn converges_to_truth() {
        let p = problem(181);
        let out = run(&p, 150, 2021);
        assert!(
            *out.tan_trace.last().unwrap() < 1e-10,
            "tanθ={}",
            out.tan_trace.last().unwrap()
        );
        // Output is orthonormal.
        let g = out.w.t_matmul(&out.w);
        assert!((&g - &Mat::eye(2)).fro_norm() < 1e-10);
    }

    #[test]
    fn monotone_decay_after_burnin() {
        let p = problem(182);
        let out = run(&p, 80, 7);
        for win in out.tan_trace[5..].windows(2) {
            assert!(
                win[1] <= win[0] * 1.01 + 1e-14,
                "tanθ increased: {} -> {}",
                win[0],
                win[1]
            );
        }
    }

    #[test]
    fn rate_close_to_eigen_ratio() {
        let p = problem(183);
        let out = run(&p, 60, 11);
        let lam_ratio = p.lambda_k1() / p.lambda_k();
        let e10 = out.tan_trace[10];
        let e40 = out.tan_trace[40];
        let empirical = (e40 / e10).powf(1.0 / 30.0);
        assert!(
            (empirical - lam_ratio).abs() < 0.1,
            "rate {empirical} vs λ-ratio {lam_ratio}"
        );
    }

    #[test]
    fn tol_stops_early() {
        let p = problem(184);
        let out = run_with_tol(&p, 500, 3, 1e-6);
        assert!(out.iters < 500);
        assert!(*out.tan_trace.last().unwrap() <= 1e-6);
    }

    #[test]
    fn warm_start_with_different_k_refits_buffers() {
        // Centralized accepts any warm-start stack; a k different from
        // the construction-time k must refit the persistent buffers
        // rather than panic in the `_into` kernels.
        let p = problem(186);
        let mut solver = CentralizedSolver::new(&p, CentralizedConfig::default()); // k = 2
        let mut rng = Rng::seed_from(99);
        let w = Mat::rand_orthonormal(p.dim(), 1, &mut rng);
        solver.warm_start(&AgentStack::replicate(3, &w));
        let rep = solver.step();
        assert!(rep.finite);
        assert_eq!(solver.state().w.slice(0).cols(), 1);
    }

    #[test]
    fn solver_single_slice_state() {
        let p = problem(185);
        let mut solver = CentralizedSolver::new(&p, CentralizedConfig::default());
        assert_eq!(solver.state().w.m(), 1);
        let rep = solver.step();
        assert!(rep.finite);
        assert_eq!(rep.comm.rounds, 0, "CPCA never communicates");
    }
}
