//! CPCA — the centralized power-method reference.
//!
//! The paper's figures include centralized PCA as the convergence-rate
//! yardstick: DeEPCA with sufficient K should match its linear rate.
//! `W ← QR(A·W)` on the aggregate, with per-iteration tan θ records.

use super::problem::Problem;
use crate::linalg::angles::tan_theta;
use crate::linalg::qr::orth;
use crate::linalg::Mat;
use std::time::Instant;

/// Output of a centralized run.
#[derive(Clone, Debug)]
pub struct CentralizedOutput {
    /// Final orthonormal iterate.
    pub w: Mat,
    /// tan θ_k(U, Wᵗ) per iteration.
    pub tan_trace: Vec<f64>,
    /// Iterations executed.
    pub iters: usize,
    /// Wall time.
    pub elapsed_secs: f64,
}

/// Run `iters` power iterations from the seed-`init_seed` start
/// (same initializer as the decentralized runs for fair comparison).
pub fn run(problem: &Problem, iters: usize, init_seed: u64) -> CentralizedOutput {
    run_with_tol(problem, iters, init_seed, 0.0)
}

/// As [`run`], stopping early once tan θ ≤ tol (if tol > 0).
pub fn run_with_tol(
    problem: &Problem,
    iters: usize,
    init_seed: u64,
    tol: f64,
) -> CentralizedOutput {
    let u = problem.u();
    let mut w = problem.initial_w(init_seed);
    let t0 = Instant::now();
    let mut tan_trace = Vec::with_capacity(iters);
    let mut done = 0;
    for t in 0..iters {
        w = orth(&problem.aggregate.matmul(&w));
        let tan = tan_theta(&u, &w);
        tan_trace.push(tan);
        done = t + 1;
        if tol > 0.0 && tan <= tol {
            break;
        }
    }
    CentralizedOutput { w, tan_trace, iters: done, elapsed_secs: t0.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn problem(seed: u64) -> Problem {
        let ds = synthetic::spiked_covariance(
            500,
            14,
            &[10.0, 7.0, 4.0],
            0.2,
            &mut Rng::seed_from(seed),
        );
        Problem::from_dataset(&ds, 5, 2)
    }

    #[test]
    fn converges_to_truth() {
        let p = problem(181);
        let out = run(&p, 150, 2021);
        assert!(
            *out.tan_trace.last().unwrap() < 1e-10,
            "tanθ={}",
            out.tan_trace.last().unwrap()
        );
        // Output is orthonormal.
        let g = out.w.t_matmul(&out.w);
        assert!((&g - &Mat::eye(2)).fro_norm() < 1e-10);
    }

    #[test]
    fn monotone_decay_after_burnin() {
        let p = problem(182);
        let out = run(&p, 80, 7);
        for win in out.tan_trace[5..].windows(2) {
            assert!(
                win[1] <= win[0] * 1.01 + 1e-14,
                "tanθ increased: {} -> {}",
                win[0],
                win[1]
            );
        }
    }

    #[test]
    fn rate_close_to_eigen_ratio() {
        let p = problem(183);
        let out = run(&p, 60, 11);
        let lam_ratio = p.lambda_k1() / p.lambda_k();
        let e10 = out.tan_trace[10];
        let e40 = out.tan_trace[40];
        let empirical = (e40 / e10).powf(1.0 / 30.0);
        assert!(
            (empirical - lam_ratio).abs() < 0.1,
            "rate {empirical} vs λ-ratio {lam_ratio}"
        );
    }

    #[test]
    fn tol_stops_early() {
        let p = problem(184);
        let out = run_with_tol(&p, 500, 3, 1e-6);
        assert!(out.iters < 500);
        assert!(*out.tan_trace.last().unwrap() <= 1e-6);
    }
}
