//! DePCA — the Eqn. 3.4 baseline (Wai et al. 2017 style).
//!
//! The conventional decentralized power method: each iteration runs the
//! local power step, then multi-consensus on the *iterate itself* (no
//! tracking variable), then QR:
//!
//! ```text
//! P_j ← A_j W_j ;  P ← FastMix(P, K_t) ;  W_j ← QR(P_j)
//! ```
//!
//! Without tracking, the consensus residue is proportional to the
//! *heterogeneity* of the `A_j W_j` products — which does not shrink as
//! the iterates converge — so a fixed K leaves an error floor ~ρ(K)
//! (paper Figures 1–2, middle series), and reaching precision ε needs
//! `K_t = O(log 1/ε)` rounds per iteration (Eqn. 3.12). Both schedules
//! are implemented so the figure benches can show the contrast.
//!
//! [`DepcaSolver`] implements the step-wise [`Solver`] API; like the
//! other solvers its step hot path runs through the `_into` kernels and
//! persistent buffers (the mixed variable `P` lives in `state.s` and is
//! overwritten in place each iteration), so it allocates nothing after
//! warm-up.

use super::backend::{PowerBackend, RustBackend};
use super::problem::Problem;
use super::sign_adjust::sign_adjust_into;
use super::solver::{Solver, SolverState, StepReport};
use super::workspace::SolverWorkspace;
use crate::consensus::comm::{Communicator, DenseComm};
use crate::consensus::AgentStack;
use crate::exec::Executor;
use crate::graph::topology::Topology;
use crate::linalg::Mat;
use std::sync::Arc;

/// Consensus-rounds schedule for DePCA.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KPolicy {
    /// Constant K every iteration (plateaus at a K-dependent floor).
    Fixed(usize),
    /// `K_t = base + ceil(slope·t)` — the growing schedule the prior art
    /// needs to keep converging (paper Remark 2 / Eqn. 3.12).
    Increasing {
        /// Rounds at t = 0.
        base: usize,
        /// Extra rounds per iteration.
        slope: f64,
    },
}

impl KPolicy {
    /// Rounds for iteration t.
    pub fn rounds(&self, t: usize) -> usize {
        match *self {
            KPolicy::Fixed(k) => k,
            KPolicy::Increasing { base, slope } => base + (slope * t as f64).ceil() as usize,
        }
    }
}

/// DePCA hyperparameters.
#[derive(Clone, Debug)]
pub struct DepcaConfig {
    /// Consensus schedule.
    pub k_policy: KPolicy,
    /// Maximum power iterations.
    pub max_iters: usize,
    /// Early stop on mean tan θ ≤ tol (0 disables).
    pub tol: f64,
    /// Seed for the shared `W⁰`.
    pub init_seed: u64,
    /// Sign-adjust the QR output against `W⁰` (kept on for parity with
    /// DeEPCA so the consensus-error metric is sign-noise free).
    pub sign_adjust: bool,
}

impl Default for DepcaConfig {
    fn default() -> Self {
        DepcaConfig {
            k_policy: KPolicy::Fixed(8),
            max_iters: 100,
            tol: 0.0,
            init_seed: 2021,
            sign_adjust: true,
        }
    }
}

/// Step-wise DePCA: local power step + K_t-round consensus + QR.
pub struct DepcaSolver<'a> {
    problem: &'a Problem,
    backend: Box<dyn PowerBackend + 'a>,
    comm: Box<dyn Communicator + 'a>,
    cfg: DepcaConfig,
    /// Sign-adjust anchor.
    w0: Mat,
    /// Worker pool for the per-agent QR/sign-adjust loop.
    exec: Arc<Executor>,
    /// Per-worker QR / sign-adjust scratch (one slot per executor
    /// chunk; see [`SolverWorkspace`]).
    workspaces: Vec<SolverWorkspace>,
    state: SolverState,
}

impl<'a> DepcaSolver<'a> {
    /// Solver over an explicit backend and communicator.
    pub fn new(
        problem: &'a Problem,
        backend: Box<dyn PowerBackend + 'a>,
        comm: Box<dyn Communicator + 'a>,
        cfg: DepcaConfig,
    ) -> Self {
        let m = problem.m();
        assert_eq!(backend.m(), m, "backend/problem agent count mismatch");
        assert_eq!(comm.m(), m, "communicator/problem agent count mismatch");
        let w0 = problem.initial_w(cfg.init_seed);
        let (d, k) = w0.shape();
        let w = AgentStack::replicate(m, &w0);
        DepcaSolver {
            problem,
            backend,
            comm,
            cfg,
            exec: Arc::new(Executor::sequential()),
            workspaces: vec![SolverWorkspace::new(d, k)],
            // `tracked = true`: `state.s` holds the pre-QR mixed variable
            // `P`, overwritten in place every step (it reads as `W⁰`
            // before the first step).
            state: SolverState::init(w, true),
            w0,
        }
    }

    /// Run the per-agent QR/sign-adjust loop on `exec`'s worker pool
    /// (fixed partitioning, one workspace slot per chunk — bit-identical
    /// results for any thread count).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        let (d, k) = self.w0.shape();
        self.workspaces = (0..exec.chunk_count(self.problem.m()))
            .map(|_| SolverWorkspace::new(d, k))
            .collect();
        self.exec = exec;
        self
    }

    /// Convenience: Rust backend + dense FastMix over `topo`.
    pub fn dense(problem: &'a Problem, topo: &Topology, cfg: DepcaConfig) -> Self {
        let backend = Box::new(RustBackend::new(&problem.locals));
        let comm = Box::new(DenseComm::from_topology(topo));
        Self::new(problem, backend, comm, cfg)
    }
}

impl Solver for DepcaSolver<'_> {
    fn name(&self) -> &'static str {
        "depca"
    }

    fn problem(&self) -> &Problem {
        self.problem
    }

    fn step(&mut self) -> StepReport {
        let t = self.state.iter;
        let _span_step = crate::trace_span!(Step, t as u64);
        let SolverState { w, s, stats, .. } = &mut self.state;
        // The pre-QR mixed variable `P` lives in `state.s` (the
        // recorder's s_deviation analogue; DePCA has no tracked S) and
        // doubles as the persistent product buffer — zero allocation.
        let p = s.as_mut().expect("DePCA mixes P in place");

        // Local power step on the iterate itself (no tracking).
        {
            let _span = crate::trace_span!(LocalProduct, t as u64);
            self.backend.local_products_into(w, p);
        }
        // Multi-consensus with the schedule's rounds for this iteration
        // (the engine's `fastmix` emits the gossip span and round events).
        self.comm.fastmix(p, self.cfg.k_policy.rounds(t), stats);
        // Local orthonormalization, chunked over the pool with one
        // workspace slot per chunk.
        {
            let _span = crate::trace_span!(Qr, t as u64);
            let p: &AgentStack = p;
            let w0 = &self.w0;
            let sign_adjust = self.cfg.sign_adjust;
            self.exec
                .par_chunks_ctx(w.slices_mut(), &mut self.workspaces, |lo, chunk, ws| {
                    for (off, wj) in chunk.iter_mut().enumerate() {
                        let q = ws.orth_into(p.slice(lo + off), true);
                        if sign_adjust {
                            sign_adjust_into(q, w0, wj);
                        } else {
                            wj.copy_from(q);
                        }
                    }
                });
        }

        self.state.iter = t + 1;
        let finite = self.state.w.is_finite();
        StepReport {
            iter: t,
            // lint: allow(alloc, per-step stats snapshot for the report struct — tiny and off the data path)
            comm: self.state.stats.clone(),
            finite,
            mean_tan_theta: None,
        }
    }

    fn state(&self) -> &SolverState {
        &self.state
    }

    fn warm_start(&mut self, w: &AgentStack) {
        assert_eq!(w.m(), self.problem.m(), "warm-start agent count mismatch");
        assert_eq!(w.slice_shape(), self.w0.shape(), "warm-start shape mismatch");
        self.w0 = w.slice(0).clone();
        self.state = SolverState::init(w.clone(), true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::deepca::DeepcaConfig;
    use crate::algo::metrics::{RunOutput, RunRecorder};
    use crate::algo::solver::Algo;
    use crate::coordinator::session::Session;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    /// Test driver with the old shim's shape, routed through the
    /// [`Session`] builder (the only run path since the shims' removal).
    fn run_algo(
        problem: &Problem,
        topo: &Topology,
        algo: Algo,
        recorder: &mut RunRecorder,
    ) -> RunOutput {
        let report = Session::on(problem, topo)
            .algo(algo)
            .record(std::mem::take(recorder))
            .solve();
        let out = report.to_run_output();
        *recorder = report.trace;
        out
    }

    fn run_dense(
        problem: &Problem,
        topo: &Topology,
        cfg: &DepcaConfig,
        recorder: &mut RunRecorder,
    ) -> RunOutput {
        run_algo(problem, topo, Algo::Depca(cfg.clone()), recorder)
    }

    fn heterogeneous_problem(seed: u64) -> (Problem, Topology) {
        // Block-drifted binary data → heterogeneous A_j, the regime where
        // DePCA's floor shows clearly.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1600,
                dim: 40,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 8,
                drift: 0.8,
            },
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn k_policy_schedules() {
        assert_eq!(KPolicy::Fixed(5).rounds(0), 5);
        assert_eq!(KPolicy::Fixed(5).rounds(99), 5);
        let inc = KPolicy::Increasing { base: 3, slope: 0.5 };
        assert_eq!(inc.rounds(0), 3);
        assert_eq!(inc.rounds(4), 5);
        assert!(inc.rounds(40) > inc.rounds(4));
    }

    #[test]
    fn fixed_k_plateaus_above_deepca() {
        let (p, topo) = heterogeneous_problem(171);
        let iters = 80;

        let mut rec_depca = RunRecorder::every_iteration();
        let out_depca = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Fixed(6),
                max_iters: iters,
                ..Default::default()
            },
            &mut rec_depca,
        );

        let mut rec_deepca = RunRecorder::every_iteration();
        let out_deepca = run_algo(
            &p,
            &topo,
            Algo::Deepca(DeepcaConfig { consensus_rounds: 6, max_iters: iters, ..Default::default() }),
            &mut rec_deepca,
        );

        assert!(
            out_deepca.final_tan_theta < 1e-3 * out_depca.final_tan_theta.max(1e-12),
            "DeEPCA {:.3e} should beat DePCA {:.3e} by orders of magnitude",
            out_deepca.final_tan_theta,
            out_depca.final_tan_theta
        );
        // And DePCA's floor is genuinely a plateau: late iterations barely move.
        let mid = rec_depca.records[iters / 2].mean_tan_theta;
        let last = rec_depca.records.last().unwrap().mean_tan_theta;
        assert!(
            last > 0.2 * mid,
            "DePCA kept converging unexpectedly: mid {mid:.3e} last {last:.3e}"
        );
    }

    #[test]
    fn increasing_k_keeps_converging() {
        let (p, topo) = heterogeneous_problem(172);
        let mut rec_fix = RunRecorder::every_iteration();
        let out_fix = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Fixed(4),
                max_iters: 60,
                ..Default::default()
            },
            &mut rec_fix,
        );
        let mut rec_inc = RunRecorder::every_iteration();
        let out_inc = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Increasing { base: 4, slope: 1.0 },
                max_iters: 60,
                ..Default::default()
            },
            &mut rec_inc,
        );
        assert!(
            out_inc.final_tan_theta < 1e-2 * out_fix.final_tan_theta.max(1e-12),
            "increasing K {:.3e} vs fixed {:.3e}",
            out_inc.final_tan_theta,
            out_fix.final_tan_theta
        );
        // But at a much higher communication bill per ε — the paper's point.
        assert!(out_inc.comm.rounds > out_fix.comm.rounds);
    }

    #[test]
    fn depca_converges_on_homogeneous_data() {
        // With identical A_j there is no heterogeneity penalty; DePCA works.
        let mut rng = Rng::seed_from(173);
        let ds = synthetic::spiked_covariance(600, 10, &[8.0, 4.0], 0.1, &mut rng);
        let full = ds.features.t_matmul(&ds.features).scaled(1.0 / 600.0);
        let mut a = full;
        a.symmetrize();
        let p = Problem::new(vec![a; 6], 2, "homogeneous");
        let topo = Topology::ring(6);
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(
            &p,
            &topo,
            &DepcaConfig { k_policy: KPolicy::Fixed(5), max_iters: 120, ..Default::default() },
            &mut rec,
        );
        assert!(out.final_tan_theta < 1e-8, "tanθ={}", out.final_tan_theta);
    }

    #[test]
    fn comm_accounting_with_increasing_schedule() {
        let (p, topo) = heterogeneous_problem(174);
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Increasing { base: 2, slope: 1.0 },
                max_iters: 5,
                ..Default::default()
            },
            &mut rec,
        );
        // K_t = 2+ceil(t): 2,3,4,5,6 → 20 rounds.
        assert_eq!(out.comm.rounds, 20);
        assert_eq!(out.comm.mixes, 5);
    }

    #[test]
    fn solver_schedule_uses_internal_iteration() {
        // The K-schedule must key off the solver's own iteration counter,
        // not an external loop variable.
        let (p, topo) = heterogeneous_problem(175);
        let cfg = DepcaConfig {
            k_policy: KPolicy::Increasing { base: 2, slope: 1.0 },
            max_iters: 5,
            ..Default::default()
        };
        let mut solver = DepcaSolver::dense(&p, &topo, cfg);
        for _ in 0..5 {
            solver.step();
        }
        assert_eq!(solver.state().stats.rounds, 20);
        assert_eq!(solver.state().stats.mixes, 5);
    }
}
