//! DePCA — the Eqn. 3.4 baseline (Wai et al. 2017 style).
//!
//! The conventional decentralized power method: each iteration runs the
//! local power step, then multi-consensus on the *iterate itself* (no
//! tracking variable), then QR:
//!
//! ```text
//! P_j ← A_j W_j ;  P ← FastMix(P, K_t) ;  W_j ← QR(P_j)
//! ```
//!
//! Without tracking, the consensus residue is proportional to the
//! *heterogeneity* of the `A_j W_j` products — which does not shrink as
//! the iterates converge — so a fixed K leaves an error floor ~ρ(K)
//! (paper Figures 1–2, middle series), and reaching precision ε needs
//! `K_t = O(log 1/ε)` rounds per iteration (Eqn. 3.12). Both schedules
//! are implemented so the figure benches can show the contrast.

use super::backend::{PowerBackend, RustBackend};
use super::metrics::{RunOutput, RunRecorder};
use super::problem::Problem;
use super::sign_adjust::sign_adjust;
use crate::consensus::comm::{Communicator, DenseComm};
use crate::consensus::metrics::CommStats;
use crate::consensus::AgentStack;
use crate::graph::topology::Topology;
use crate::linalg::qr::orth;
use std::time::Instant;

/// Consensus-rounds schedule for DePCA.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KPolicy {
    /// Constant K every iteration (plateaus at a K-dependent floor).
    Fixed(usize),
    /// `K_t = base + ceil(slope·t)` — the growing schedule the prior art
    /// needs to keep converging (paper Remark 2 / Eqn. 3.12).
    Increasing {
        /// Rounds at t = 0.
        base: usize,
        /// Extra rounds per iteration.
        slope: f64,
    },
}

impl KPolicy {
    /// Rounds for iteration t.
    pub fn rounds(&self, t: usize) -> usize {
        match *self {
            KPolicy::Fixed(k) => k,
            KPolicy::Increasing { base, slope } => base + (slope * t as f64).ceil() as usize,
        }
    }
}

/// DePCA hyperparameters.
#[derive(Clone, Debug)]
pub struct DepcaConfig {
    /// Consensus schedule.
    pub k_policy: KPolicy,
    /// Maximum power iterations.
    pub max_iters: usize,
    /// Early stop on mean tan θ ≤ tol (0 disables).
    pub tol: f64,
    /// Seed for the shared `W⁰`.
    pub init_seed: u64,
    /// Sign-adjust the QR output against `W⁰` (kept on for parity with
    /// DeEPCA so the consensus-error metric is sign-noise free).
    pub sign_adjust: bool,
}

impl Default for DepcaConfig {
    fn default() -> Self {
        DepcaConfig {
            k_policy: KPolicy::Fixed(8),
            max_iters: 100,
            tol: 0.0,
            init_seed: 2021,
            sign_adjust: true,
        }
    }
}

/// Run DePCA with explicit backend and communicator.
pub fn run_with(
    problem: &Problem,
    backend: &dyn PowerBackend,
    comm: &dyn Communicator,
    cfg: &DepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let m = problem.m();
    assert_eq!(backend.m(), m);
    assert_eq!(comm.m(), m);
    let u = problem.u();
    let w0 = problem.initial_w(cfg.init_seed);

    let mut w = AgentStack::replicate(m, &w0);
    let mut stats = CommStats::default();
    let t0 = Instant::now();
    let mut iters = 0;
    let mut diverged = false;

    for t in 0..cfg.max_iters {
        // Local power step on the iterate itself (no tracking).
        let mut p = backend.local_products(&w);
        // Multi-consensus.
        comm.fastmix(&mut p, cfg.k_policy.rounds(t), &mut stats);
        // Local orthonormalization.
        for j in 0..m {
            let q = orth(p.slice(j));
            *w.slice_mut(j) = if cfg.sign_adjust {
                sign_adjust(&q, &w0)
            } else {
                q
            };
        }

        iters = t + 1;
        if !w.is_finite() {
            diverged = true;
            break;
        }
        if recorder.should_record(t) {
            // DePCA has no tracked S; report the pre-QR consensus variable
            // deviation as its s_deviation analogue (the paper's first
            // column plots ‖S−S̄⊗1‖ for DeEPCA only).
            recorder.record(t, &u, &w, Some(&p), &stats, t0.elapsed().as_secs_f64());
        }
        if cfg.tol > 0.0 && recorder.final_tan_theta() <= cfg.tol {
            break;
        }
    }

    RunOutput {
        iters,
        final_tan_theta: recorder.final_tan_theta(),
        comm: stats,
        final_w: w,
        elapsed_secs: t0.elapsed().as_secs_f64(),
        diverged,
    }
}

/// Convenience runner with Rust backend + dense FastMix.
pub fn run_dense(
    problem: &Problem,
    topo: &Topology,
    cfg: &DepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let backend = RustBackend::new(&problem.locals);
    let comm = DenseComm::from_topology(topo);
    run_with(problem, &backend, &comm, cfg, recorder)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::deepca::{self, DeepcaConfig};
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn heterogeneous_problem(seed: u64) -> (Problem, Topology) {
        // Block-drifted binary data → heterogeneous A_j, the regime where
        // DePCA's floor shows clearly.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1600,
                dim: 40,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 8,
                drift: 0.8,
            },
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn k_policy_schedules() {
        assert_eq!(KPolicy::Fixed(5).rounds(0), 5);
        assert_eq!(KPolicy::Fixed(5).rounds(99), 5);
        let inc = KPolicy::Increasing { base: 3, slope: 0.5 };
        assert_eq!(inc.rounds(0), 3);
        assert_eq!(inc.rounds(4), 5);
        assert!(inc.rounds(40) > inc.rounds(4));
    }

    #[test]
    fn fixed_k_plateaus_above_deepca() {
        let (p, topo) = heterogeneous_problem(171);
        let iters = 80;

        let mut rec_depca = RunRecorder::every_iteration();
        let out_depca = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Fixed(6),
                max_iters: iters,
                ..Default::default()
            },
            &mut rec_depca,
        );

        let mut rec_deepca = RunRecorder::every_iteration();
        let out_deepca = deepca::run_dense(
            &p,
            &topo,
            &DeepcaConfig { consensus_rounds: 6, max_iters: iters, ..Default::default() },
            &mut rec_deepca,
        );

        assert!(
            out_deepca.final_tan_theta < 1e-3 * out_depca.final_tan_theta.max(1e-12),
            "DeEPCA {:.3e} should beat DePCA {:.3e} by orders of magnitude",
            out_deepca.final_tan_theta,
            out_depca.final_tan_theta
        );
        // And DePCA's floor is genuinely a plateau: late iterations barely move.
        let mid = rec_depca.records[iters / 2].mean_tan_theta;
        let last = rec_depca.records.last().unwrap().mean_tan_theta;
        assert!(
            last > 0.2 * mid,
            "DePCA kept converging unexpectedly: mid {mid:.3e} last {last:.3e}"
        );
    }

    #[test]
    fn increasing_k_keeps_converging() {
        let (p, topo) = heterogeneous_problem(172);
        let mut rec_fix = RunRecorder::every_iteration();
        let out_fix = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Fixed(4),
                max_iters: 60,
                ..Default::default()
            },
            &mut rec_fix,
        );
        let mut rec_inc = RunRecorder::every_iteration();
        let out_inc = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Increasing { base: 4, slope: 1.0 },
                max_iters: 60,
                ..Default::default()
            },
            &mut rec_inc,
        );
        assert!(
            out_inc.final_tan_theta < 1e-2 * out_fix.final_tan_theta.max(1e-12),
            "increasing K {:.3e} vs fixed {:.3e}",
            out_inc.final_tan_theta,
            out_fix.final_tan_theta
        );
        // But at a much higher communication bill per ε — the paper's point.
        assert!(out_inc.comm.rounds > out_fix.comm.rounds);
    }

    #[test]
    fn depca_converges_on_homogeneous_data() {
        // With identical A_j there is no heterogeneity penalty; DePCA works.
        let mut rng = Rng::seed_from(173);
        let ds = synthetic::spiked_covariance(600, 10, &[8.0, 4.0], 0.1, &mut rng);
        let full = ds.features.t_matmul(&ds.features).scaled(1.0 / 600.0);
        let mut a = full;
        a.symmetrize();
        let p = Problem::new(vec![a; 6], 2, "homogeneous");
        let topo = Topology::ring(6);
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(
            &p,
            &topo,
            &DepcaConfig { k_policy: KPolicy::Fixed(5), max_iters: 120, ..Default::default() },
            &mut rec,
        );
        assert!(out.final_tan_theta < 1e-8, "tanθ={}", out.final_tan_theta);
    }

    #[test]
    fn comm_accounting_with_increasing_schedule() {
        let (p, topo) = heterogeneous_problem(174);
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Increasing { base: 2, slope: 1.0 },
                max_iters: 5,
                ..Default::default()
            },
            &mut rec,
        );
        // K_t = 2+ceil(t): 2,3,4,5,6 → 20 rounds.
        assert_eq!(out.comm.rounds, 20);
        assert_eq!(out.comm.mixes, 5);
    }
}
