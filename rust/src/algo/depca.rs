//! DePCA — the Eqn. 3.4 baseline (Wai et al. 2017 style).
//!
//! The conventional decentralized power method: each iteration runs the
//! local power step, then multi-consensus on the *iterate itself* (no
//! tracking variable), then QR:
//!
//! ```text
//! P_j ← A_j W_j ;  P ← FastMix(P, K_t) ;  W_j ← QR(P_j)
//! ```
//!
//! Without tracking, the consensus residue is proportional to the
//! *heterogeneity* of the `A_j W_j` products — which does not shrink as
//! the iterates converge — so a fixed K leaves an error floor ~ρ(K)
//! (paper Figures 1–2, middle series), and reaching precision ε needs
//! `K_t = O(log 1/ε)` rounds per iteration (Eqn. 3.12). Both schedules
//! are implemented so the figure benches can show the contrast.
//!
//! [`DepcaSolver`] implements the step-wise [`Solver`] API; the old
//! [`run_with`]/[`run_dense`] free functions remain as deprecated shims.

use super::backend::{PowerBackend, RustBackend};
use super::metrics::{RunOutput, RunRecorder};
use super::problem::Problem;
use super::sign_adjust::sign_adjust;
use super::solver::{drive_to_run_output, Algo, Solver, SolverState, StepReport, StopCriteria};
use crate::consensus::comm::{Communicator, DenseComm};
use crate::consensus::AgentStack;
use crate::coordinator::session::Session;
use crate::graph::topology::Topology;
use crate::linalg::qr::orth;
use crate::linalg::Mat;

/// Consensus-rounds schedule for DePCA.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KPolicy {
    /// Constant K every iteration (plateaus at a K-dependent floor).
    Fixed(usize),
    /// `K_t = base + ceil(slope·t)` — the growing schedule the prior art
    /// needs to keep converging (paper Remark 2 / Eqn. 3.12).
    Increasing {
        /// Rounds at t = 0.
        base: usize,
        /// Extra rounds per iteration.
        slope: f64,
    },
}

impl KPolicy {
    /// Rounds for iteration t.
    pub fn rounds(&self, t: usize) -> usize {
        match *self {
            KPolicy::Fixed(k) => k,
            KPolicy::Increasing { base, slope } => base + (slope * t as f64).ceil() as usize,
        }
    }
}

/// DePCA hyperparameters.
#[derive(Clone, Debug)]
pub struct DepcaConfig {
    /// Consensus schedule.
    pub k_policy: KPolicy,
    /// Maximum power iterations.
    pub max_iters: usize,
    /// Early stop on mean tan θ ≤ tol (0 disables).
    pub tol: f64,
    /// Seed for the shared `W⁰`.
    pub init_seed: u64,
    /// Sign-adjust the QR output against `W⁰` (kept on for parity with
    /// DeEPCA so the consensus-error metric is sign-noise free).
    pub sign_adjust: bool,
}

impl Default for DepcaConfig {
    fn default() -> Self {
        DepcaConfig {
            k_policy: KPolicy::Fixed(8),
            max_iters: 100,
            tol: 0.0,
            init_seed: 2021,
            sign_adjust: true,
        }
    }
}

/// Step-wise DePCA: local power step + K_t-round consensus + QR.
pub struct DepcaSolver<'a> {
    problem: &'a Problem,
    backend: Box<dyn PowerBackend + 'a>,
    comm: Box<dyn Communicator + 'a>,
    cfg: DepcaConfig,
    /// Sign-adjust anchor.
    w0: Mat,
    state: SolverState,
}

impl<'a> DepcaSolver<'a> {
    /// Solver over an explicit backend and communicator.
    pub fn new(
        problem: &'a Problem,
        backend: Box<dyn PowerBackend + 'a>,
        comm: Box<dyn Communicator + 'a>,
        cfg: DepcaConfig,
    ) -> Self {
        let m = problem.m();
        assert_eq!(backend.m(), m, "backend/problem agent count mismatch");
        assert_eq!(comm.m(), m, "communicator/problem agent count mismatch");
        let w0 = problem.initial_w(cfg.init_seed);
        let w = AgentStack::replicate(m, &w0);
        DepcaSolver {
            problem,
            backend,
            comm,
            cfg,
            state: SolverState::init(w, false),
            w0,
        }
    }

    /// Convenience: Rust backend + dense FastMix over `topo`.
    pub fn dense(problem: &'a Problem, topo: &Topology, cfg: DepcaConfig) -> Self {
        let backend = Box::new(RustBackend::new(&problem.locals));
        let comm = Box::new(DenseComm::from_topology(topo));
        Self::new(problem, backend, comm, cfg)
    }
}

impl Solver for DepcaSolver<'_> {
    fn name(&self) -> &'static str {
        "depca"
    }

    fn problem(&self) -> &Problem {
        self.problem
    }

    fn step(&mut self) -> StepReport {
        let t = self.state.iter;
        let m = self.state.w.m();

        // Local power step on the iterate itself (no tracking).
        let mut p = self.backend.local_products(&self.state.w);
        // Multi-consensus with the schedule's rounds for this iteration.
        self.comm
            .fastmix(&mut p, self.cfg.k_policy.rounds(t), &mut self.state.stats);
        // Local orthonormalization.
        for j in 0..m {
            let q = orth(p.slice(j));
            *self.state.w.slice_mut(j) = if self.cfg.sign_adjust {
                sign_adjust(&q, &self.w0)
            } else {
                q
            };
        }
        // Expose the pre-QR mixed variable as this algorithm's consensus
        // state (the recorder's s_deviation analogue; DePCA has no
        // tracked S).
        self.state.s = Some(p);

        self.state.iter = t + 1;
        let finite = self.state.w.is_finite();
        StepReport {
            iter: t,
            comm: self.state.stats.clone(),
            finite,
            mean_tan_theta: None,
        }
    }

    fn state(&self) -> &SolverState {
        &self.state
    }

    fn warm_start(&mut self, w: &AgentStack) {
        assert_eq!(w.m(), self.problem.m(), "warm-start agent count mismatch");
        assert_eq!(w.slice_shape(), self.w0.shape(), "warm-start shape mismatch");
        self.w0 = w.slice(0).clone();
        self.state = SolverState::init(w.clone(), false);
    }
}

/// Run DePCA with explicit backend and communicator.
#[deprecated(note = "use `DepcaSolver` + `algo::solver::drive`, or the `Session` builder")]
pub fn run_with(
    problem: &Problem,
    backend: &dyn PowerBackend,
    comm: &dyn Communicator,
    cfg: &DepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let mut solver = DepcaSolver::new(problem, Box::new(backend), Box::new(comm), cfg.clone());
    let stop = StopCriteria::max_iters(cfg.max_iters).with_tol(cfg.tol);
    drive_to_run_output(&mut solver, &stop, recorder)
}

/// Convenience runner with Rust backend + dense FastMix.
///
/// Delegates straight to the [`Session`] builder (which owns the
/// engine/stop/record plumbing this shim used to duplicate); only the
/// legacy signature survives.
#[deprecated(note = "use `DepcaSolver::dense` + `algo::solver::drive`, or the `Session` builder")]
pub fn run_dense(
    problem: &Problem,
    topo: &Topology,
    cfg: &DepcaConfig,
    recorder: &mut RunRecorder,
) -> RunOutput {
    let report = Session::on(problem, topo)
        .algo(Algo::Depca(cfg.clone()))
        .record(std::mem::take(recorder))
        .solve();
    let out = report.to_run_output();
    *recorder = report.trace;
    out
}

#[cfg(test)]
#[allow(deprecated)] // shim coverage: the unchanged seed tests run
                     // through the deprecated wrappers on purpose.
mod tests {
    use super::*;
    use crate::algo::deepca::{self, DeepcaConfig};
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn heterogeneous_problem(seed: u64) -> (Problem, Topology) {
        // Block-drifted binary data → heterogeneous A_j, the regime where
        // DePCA's floor shows clearly.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1600,
                dim: 40,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 8,
                drift: 0.8,
            },
            &mut Rng::seed_from(seed),
        );
        let p = Problem::from_dataset(&ds, 8, 2);
        let topo = Topology::erdos_renyi(8, 0.5, &mut Rng::seed_from(seed + 1));
        (p, topo)
    }

    #[test]
    fn k_policy_schedules() {
        assert_eq!(KPolicy::Fixed(5).rounds(0), 5);
        assert_eq!(KPolicy::Fixed(5).rounds(99), 5);
        let inc = KPolicy::Increasing { base: 3, slope: 0.5 };
        assert_eq!(inc.rounds(0), 3);
        assert_eq!(inc.rounds(4), 5);
        assert!(inc.rounds(40) > inc.rounds(4));
    }

    #[test]
    fn fixed_k_plateaus_above_deepca() {
        let (p, topo) = heterogeneous_problem(171);
        let iters = 80;

        let mut rec_depca = RunRecorder::every_iteration();
        let out_depca = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Fixed(6),
                max_iters: iters,
                ..Default::default()
            },
            &mut rec_depca,
        );

        let mut rec_deepca = RunRecorder::every_iteration();
        let out_deepca = deepca::run_dense(
            &p,
            &topo,
            &DeepcaConfig { consensus_rounds: 6, max_iters: iters, ..Default::default() },
            &mut rec_deepca,
        );

        assert!(
            out_deepca.final_tan_theta < 1e-3 * out_depca.final_tan_theta.max(1e-12),
            "DeEPCA {:.3e} should beat DePCA {:.3e} by orders of magnitude",
            out_deepca.final_tan_theta,
            out_depca.final_tan_theta
        );
        // And DePCA's floor is genuinely a plateau: late iterations barely move.
        let mid = rec_depca.records[iters / 2].mean_tan_theta;
        let last = rec_depca.records.last().unwrap().mean_tan_theta;
        assert!(
            last > 0.2 * mid,
            "DePCA kept converging unexpectedly: mid {mid:.3e} last {last:.3e}"
        );
    }

    #[test]
    fn increasing_k_keeps_converging() {
        let (p, topo) = heterogeneous_problem(172);
        let mut rec_fix = RunRecorder::every_iteration();
        let out_fix = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Fixed(4),
                max_iters: 60,
                ..Default::default()
            },
            &mut rec_fix,
        );
        let mut rec_inc = RunRecorder::every_iteration();
        let out_inc = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Increasing { base: 4, slope: 1.0 },
                max_iters: 60,
                ..Default::default()
            },
            &mut rec_inc,
        );
        assert!(
            out_inc.final_tan_theta < 1e-2 * out_fix.final_tan_theta.max(1e-12),
            "increasing K {:.3e} vs fixed {:.3e}",
            out_inc.final_tan_theta,
            out_fix.final_tan_theta
        );
        // But at a much higher communication bill per ε — the paper's point.
        assert!(out_inc.comm.rounds > out_fix.comm.rounds);
    }

    #[test]
    fn depca_converges_on_homogeneous_data() {
        // With identical A_j there is no heterogeneity penalty; DePCA works.
        let mut rng = Rng::seed_from(173);
        let ds = synthetic::spiked_covariance(600, 10, &[8.0, 4.0], 0.1, &mut rng);
        let full = ds.features.t_matmul(&ds.features).scaled(1.0 / 600.0);
        let mut a = full;
        a.symmetrize();
        let p = Problem::new(vec![a; 6], 2, "homogeneous");
        let topo = Topology::ring(6);
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(
            &p,
            &topo,
            &DepcaConfig { k_policy: KPolicy::Fixed(5), max_iters: 120, ..Default::default() },
            &mut rec,
        );
        assert!(out.final_tan_theta < 1e-8, "tanθ={}", out.final_tan_theta);
    }

    #[test]
    fn comm_accounting_with_increasing_schedule() {
        let (p, topo) = heterogeneous_problem(174);
        let mut rec = RunRecorder::every_iteration();
        let out = run_dense(
            &p,
            &topo,
            &DepcaConfig {
                k_policy: KPolicy::Increasing { base: 2, slope: 1.0 },
                max_iters: 5,
                ..Default::default()
            },
            &mut rec,
        );
        // K_t = 2+ceil(t): 2,3,4,5,6 → 20 rounds.
        assert_eq!(out.comm.rounds, 20);
        assert_eq!(out.comm.mixes, 5);
    }

    #[test]
    fn solver_schedule_uses_internal_iteration() {
        // The K-schedule must key off the solver's own iteration counter,
        // not an external loop variable.
        let (p, topo) = heterogeneous_problem(175);
        let cfg = DepcaConfig {
            k_policy: KPolicy::Increasing { base: 2, slope: 1.0 },
            max_iters: 5,
            ..Default::default()
        };
        let mut solver = DepcaSolver::dense(&p, &topo, cfg);
        for _ in 0..5 {
            solver.step();
        }
        assert_eq!(solver.state().stats.rounds, 20);
        assert_eq!(solver.state().stats.mixes, 5);
    }
}
