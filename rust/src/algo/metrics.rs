//! Per-iteration convergence records — the Figure 1–2 panels.
//!
//! Each power iteration logs the three quantities the paper plots:
//! `‖Sᵗ − S̄ᵗ⊗1‖` (tracked-variable consensus error),
//! `‖Wᵗ − W̄ᵗ⊗1‖` (iterate consensus error), and
//! `(1/m) Σ_j tan θ_k(U, W_jᵗ)` (mean subspace error), plus cumulative
//! communication so error-vs-communication curves drop out directly.

use crate::consensus::metrics::CommStats;
use crate::consensus::AgentStack;
use crate::linalg::angles::{tan_theta, tan_theta_orthonormal};
use crate::linalg::Mat;

/// One row of a convergence trace.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    /// Power iteration index t (0-based).
    pub iter: usize,
    /// Cumulative gossip rounds after this iteration.
    pub comm_rounds: u64,
    /// `‖Sᵗ − S̄ᵗ⊗1‖` (0 for algorithms without a tracked variable).
    pub s_deviation: f64,
    /// `‖Wᵗ − W̄ᵗ⊗1‖`.
    pub w_deviation: f64,
    /// `(1/m) Σ_j tan θ_k(U, W_jᵗ)`.
    pub mean_tan_theta: f64,
    /// `tan θ_k(U, S̄ᵗ)` — the Lemma-1 mean-variable error.
    pub tan_theta_mean: f64,
    /// Wall-clock seconds spent inside the algorithm so far.
    pub elapsed_secs: f64,
}

/// Collects [`IterationRecord`]s during a run.
///
/// The stride governs only the *expensive* ground-truth metrics
/// (tan-theta angles, deviation norms — each an O(m·d·k²) pass over the
/// stack). Cheap per-iteration facts — iteration index, cumulative
/// communication, elapsed wall time — are recorded **every** iteration
/// via [`RunRecorder::record_cheap`]; on skipped iterations the
/// expensive fields hold NaN sentinels (rendered as `NaN` in the CSV),
/// and the error accessors ([`RunRecorder::final_tan_theta`],
/// [`RunRecorder::first_below`]) skip them.
#[derive(Clone, Debug, Default)]
pub struct RunRecorder {
    /// The trace (one row per iteration; expensive fields are NaN on
    /// iterations the stride skipped).
    pub records: Vec<IterationRecord>,
    /// Evaluate the expensive ground-truth metrics only every `stride`
    /// iterations (1 = evaluate everywhere).
    pub stride: usize,
}

impl RunRecorder {
    /// Recorder that logs every iteration.
    pub fn every_iteration() -> Self {
        RunRecorder { records: Vec::new(), stride: 1 }
    }

    /// Recorder that logs every `stride`-th iteration.
    pub fn with_stride(stride: usize) -> Self {
        RunRecorder { records: Vec::new(), stride: stride.max(1) }
    }

    /// Whether iteration `t` gets the expensive ground-truth metrics
    /// (skipped iterations still get a cheap row via
    /// [`RunRecorder::record_cheap`]).
    pub fn should_record(&self, t: usize) -> bool {
        let stride = self.stride.max(1);
        t % stride == 0
    }

    /// Record the cheap per-iteration facts only (communication,
    /// elapsed time) with NaN sentinels for the expensive metrics — the
    /// stride-skipped complement of [`RunRecorder::record`], so
    /// error-vs-communication traces keep per-iteration x-axes even on
    /// sparse recorders.
    pub fn record_cheap(&mut self, iter: usize, comm: &CommStats, elapsed_secs: f64) {
        self.records.push(IterationRecord {
            iter,
            comm_rounds: comm.rounds,
            s_deviation: f64::NAN,
            w_deviation: f64::NAN,
            mean_tan_theta: f64::NAN,
            tan_theta_mean: f64::NAN,
            elapsed_secs,
        });
    }

    /// Record one iteration given the algorithm state.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        iter: usize,
        u: &Mat,
        ws: &AgentStack,
        ss: Option<&AgentStack>,
        comm: &CommStats,
        elapsed_secs: f64,
    ) {
        let m = ws.m() as f64;
        // W iterates are orthonormal by construction — skip the QR.
        let mean_tan_theta =
            ws.iter().map(|w| tan_theta_orthonormal(u, w)).sum::<f64>() / m;
        let (s_deviation, tan_theta_mean) = match ss {
            Some(s) => (s.deviation_from_mean(), tan_theta(u, &s.mean())),
            None => (0.0, tan_theta(u, &ws.mean())),
        };
        self.records.push(IterationRecord {
            iter,
            comm_rounds: comm.rounds,
            s_deviation,
            w_deviation: ws.deviation_from_mean(),
            mean_tan_theta,
            tan_theta_mean,
            elapsed_secs,
        });
    }

    /// Last *evaluated* mean tan θ — cheap NaN-sentinel rows are skipped
    /// (∞ if no iteration ever evaluated the error).
    pub fn final_tan_theta(&self) -> f64 {
        self.records
            .iter()
            .rev()
            .map(|r| r.mean_tan_theta)
            .find(|v| !v.is_nan())
            .unwrap_or(f64::INFINITY)
    }

    /// First iteration whose mean tanθ drops below `eps` and the
    /// cumulative communication at that point, if reached. Cheap rows
    /// never match (`NaN <= eps` is false).
    pub fn first_below(&self, eps: f64) -> Option<(usize, u64)> {
        self.records
            .iter()
            .find(|r| r.mean_tan_theta <= eps)
            .map(|r| (r.iter, r.comm_rounds))
    }

    /// Render the trace as CSV (matching the figure panels).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "iter,comm_rounds,s_deviation,w_deviation,mean_tan_theta,tan_theta_mean,elapsed_secs\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e}\n",
                r.iter,
                r.comm_rounds,
                r.s_deviation,
                r.w_deviation,
                r.mean_tan_theta,
                r.tan_theta_mean,
                r.elapsed_secs
            ));
        }
        out
    }
}

/// Final output of a decentralized run.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// Power iterations executed.
    pub iters: usize,
    /// Mean tan θ_k(U, W_j) at exit.
    pub final_tan_theta: f64,
    /// Communication totals.
    pub comm: CommStats,
    /// Final per-agent iterates.
    pub final_w: AgentStack,
    /// Wall time inside the algorithm.
    pub elapsed_secs: f64,
    /// True if the run tripped the divergence guard (non-finite iterates).
    pub diverged: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recorder_stride() {
        let rec = RunRecorder::with_stride(3);
        assert!(rec.should_record(0));
        assert!(!rec.should_record(1));
        assert!(rec.should_record(3));
    }

    #[test]
    fn record_and_csv() {
        let mut rng = Rng::seed_from(151);
        let u = Mat::rand_orthonormal(8, 2, &mut rng);
        let ws = AgentStack::replicate(3, &u);
        let mut rec = RunRecorder::every_iteration();
        let comm = CommStats::default();
        rec.record(0, &u, &ws, None, &comm, 0.01);
        assert_eq!(rec.records.len(), 1);
        assert!(rec.final_tan_theta() < 1e-10);
        let csv = rec.to_csv();
        assert!(csv.starts_with("iter,"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn first_below_finds_crossing() {
        let mut rec = RunRecorder::every_iteration();
        for (i, tan) in [1.0f64, 0.1, 0.01, 0.001].iter().enumerate() {
            rec.records.push(IterationRecord {
                iter: i,
                comm_rounds: (i as u64 + 1) * 8,
                s_deviation: 0.0,
                w_deviation: 0.0,
                mean_tan_theta: *tan,
                tan_theta_mean: *tan,
                elapsed_secs: 0.0,
            });
        }
        assert_eq!(rec.first_below(0.05), Some((2, 24)));
        assert_eq!(rec.first_below(1e-9), None);
    }

    #[test]
    fn empty_recorder_infinite() {
        let rec = RunRecorder::default();
        assert!(rec.final_tan_theta().is_infinite());
    }

    #[test]
    fn cheap_rows_carry_comm_but_not_errors() {
        // The stride regression: skipped iterations still get a row
        // (comm/elapsed), but the error accessors must see through the
        // NaN sentinels rather than reporting them.
        let mut rng = Rng::seed_from(153);
        let u = Mat::rand_orthonormal(8, 2, &mut rng);
        let ws = AgentStack::replicate(3, &u);
        let mut rec = RunRecorder::with_stride(3);
        let mut comm = CommStats::default();
        for t in 0..7 {
            comm.record_round(4, 8, 2);
            if rec.should_record(t) {
                rec.record(t, &u, &ws, None, &comm, t as f64);
            } else {
                rec.record_cheap(t, &comm, t as f64);
            }
        }
        assert_eq!(rec.records.len(), 7, "every iteration leaves a row");
        let evaluated: Vec<usize> = rec
            .records
            .iter()
            .filter(|r| !r.mean_tan_theta.is_nan())
            .map(|r| r.iter)
            .collect();
        assert_eq!(evaluated, vec![0, 3, 6]);
        // Cheap rows still carry per-iteration communication.
        for (t, r) in rec.records.iter().enumerate() {
            assert_eq!(r.comm_rounds, t as u64 + 1);
        }
        // Accessors skip the sentinels: the last *evaluated* error is
        // from iteration 6, not a NaN from a cheap row.
        assert!(rec.final_tan_theta() < 1e-10);
        assert_eq!(rec.first_below(0.5).map(|(t, _)| t), Some(0));
        // CSV still renders one line per iteration.
        assert_eq!(rec.to_csv().lines().count(), 8);
    }
}
