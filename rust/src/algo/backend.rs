//! Local-compute backends: where `A_j · W` actually runs.
//!
//! The power-step product is the only numerical heavy lifting an agent
//! does per iteration; everything else is communication and a thin QR.
//! Three interchangeable implementations:
//!
//! - [`RustBackend`] — in-process `Mat::matmul` (always available).
//! - [`ParallelBackend`] — same math, agents fanned out over scoped
//!   threads (the L3 perf path for sweeps; see EXPERIMENTS.md §Perf).
//! - `PjrtBackend` (in [`crate::runtime`]) — executes the AOT-compiled
//!   JAX/Pallas artifact through the PJRT C API. That is the production
//!   three-layer path; the Rust backends double as its test oracle.

use crate::consensus::AgentStack;
use crate::linalg::Mat;

/// Per-agent power-step provider.
///
/// Deliberately not `Send`/`Sync`-bounded: the PJRT client is `Rc`-based
/// and single-threaded, so PJRT-backed runs stay on the leader thread
/// while the pure-Rust backends parallelize internally.
pub trait PowerBackend {
    /// Number of agents.
    fn m(&self) -> usize;
    /// `A_j · w` for agent `j`.
    fn local_product(&self, agent: usize, w: &Mat) -> Mat;
    /// All agents' products for one iteration. Default: sequential loop;
    /// implementations may parallelize.
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        assert_eq!(ws.m(), self.m());
        AgentStack::new(
            (0..self.m())
                .map(|j| self.local_product(j, ws.slice(j)))
                .collect(),
        )
    }
    /// Short label for reports.
    fn label(&self) -> &'static str;
}

// Forwarding impl so a borrowed backend can be boxed into a solver
// (the deprecated `run_with` shims hand `&dyn PowerBackend` through the
// step-wise API). `local_products` is forwarded explicitly to preserve
// implementations' parallel overrides.
impl PowerBackend for &dyn PowerBackend {
    fn m(&self) -> usize {
        (**self).m()
    }
    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        (**self).local_product(agent, w)
    }
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        (**self).local_products(ws)
    }
    fn label(&self) -> &'static str {
        (**self).label()
    }
}

/// Sequential in-process backend over dense local matrices.
pub struct RustBackend<'a> {
    locals: &'a [Mat],
}

impl<'a> RustBackend<'a> {
    /// Borrow the problem's local matrices.
    pub fn new(locals: &'a [Mat]) -> Self {
        RustBackend { locals }
    }
}

impl PowerBackend for RustBackend<'_> {
    fn m(&self) -> usize {
        self.locals.len()
    }
    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        self.locals[agent].matmul(w)
    }
    fn label(&self) -> &'static str {
        "rust"
    }
}

/// Thread-parallel backend: one scoped thread per chunk of agents.
pub struct ParallelBackend<'a> {
    locals: &'a [Mat],
    threads: usize,
}

impl<'a> ParallelBackend<'a> {
    /// `threads = 0` → available_parallelism.
    pub fn new(locals: &'a [Mat], threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            threads
        };
        ParallelBackend { locals, threads }
    }
}

impl PowerBackend for ParallelBackend<'_> {
    fn m(&self) -> usize {
        self.locals.len()
    }

    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        self.locals[agent].matmul(w)
    }

    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        let m = self.m();
        assert_eq!(ws.m(), m);
        let nthreads = self.threads.min(m).max(1);
        let chunk = m.div_ceil(nthreads);
        let mut out: Vec<Option<Mat>> = (0..m).map(|_| None).collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(m);
                if lo >= hi {
                    break;
                }
                let locals = self.locals;
                let handle = scope.spawn(move || {
                    (lo..hi)
                        .map(|j| locals[j].matmul(ws.slice(j)))
                        .collect::<Vec<Mat>>()
                });
                handles.push((lo, handle));
            }
            for (lo, h) in handles {
                for (off, mat) in h.join().expect("backend thread panicked").into_iter().enumerate() {
                    out[lo + off] = Some(mat);
                }
            }
        });
        AgentStack::new(out.into_iter().map(Option::unwrap).collect())
    }

    fn label(&self) -> &'static str {
        "rust-parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn locals(m: usize, d: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::seed_from(seed);
        (0..m)
            .map(|_| {
                let g = Mat::randn(d, d, &mut rng);
                let mut a = g.t_matmul(&g);
                a.symmetrize();
                a
            })
            .collect()
    }

    #[test]
    fn rust_backend_products() {
        let ls = locals(4, 8, 131);
        let be = RustBackend::new(&ls);
        let mut rng = Rng::seed_from(132);
        let w = Mat::randn(8, 3, &mut rng);
        let got = be.local_product(2, &w);
        assert!((&got - &ls[2].matmul(&w)).fro_norm() < 1e-14);
    }

    #[test]
    fn parallel_matches_sequential() {
        let ls = locals(7, 10, 133);
        let seq = RustBackend::new(&ls);
        let par = ParallelBackend::new(&ls, 3);
        let mut rng = Rng::seed_from(134);
        let stack = AgentStack::new((0..7).map(|_| Mat::randn(10, 2, &mut rng)).collect());
        let a = seq.local_products(&stack);
        let b = par.local_products(&stack);
        assert!(a.distance(&b) < 1e-14);
    }

    #[test]
    fn parallel_more_threads_than_agents() {
        let ls = locals(2, 5, 135);
        let par = ParallelBackend::new(&ls, 16);
        let mut rng = Rng::seed_from(136);
        let stack = AgentStack::new((0..2).map(|_| Mat::randn(5, 2, &mut rng)).collect());
        let out = par.local_products(&stack);
        assert_eq!(out.m(), 2);
    }

    #[test]
    fn zero_threads_defaults() {
        let ls = locals(3, 4, 137);
        let par = ParallelBackend::new(&ls, 0);
        assert!(par.threads >= 1);
    }
}
