//! Local-compute backends: where `A_j · W` actually runs.
//!
//! The power-step product is the only numerical heavy lifting an agent
//! does per iteration; everything else is communication and a thin QR.
//! Two interchangeable implementations:
//!
//! - [`RustBackend`] — in-process `Mat::matmul` (always available).
//!   Parallelism is composed in, not baked in: give it an
//!   [`Executor`](crate::exec::Executor) and the per-agent products fan
//!   out over the persistent worker pool (bit-identical results for any
//!   thread count — each agent's product is computed by exactly the
//!   same kernel either way). This `backend × executor` composition
//!   replaced the old `ParallelBackend`, which re-spawned scoped
//!   threads on every call.
//! - `PjrtBackend` (in [`crate::runtime`]) — executes the AOT-compiled
//!   JAX/Pallas artifact through the PJRT C API. That is the production
//!   three-layer path; the Rust backends double as its test oracle.

use crate::consensus::AgentStack;
use crate::exec::Executor;
use crate::linalg::simd::PackBuf;
use crate::linalg::Mat;
use std::sync::{Arc, Mutex};

/// Per-agent power-step provider.
///
/// Deliberately not `Send`/`Sync`-bounded: the PJRT client is `Rc`-based
/// and single-threaded, so PJRT-backed runs stay on the leader thread
/// while the pure-Rust backends parallelize internally.
pub trait PowerBackend {
    /// Number of agents.
    fn m(&self) -> usize;
    /// `A_j · w` for agent `j`.
    fn local_product(&self, agent: usize, w: &Mat) -> Mat;
    /// `A_j · w` into a caller-owned buffer. The default routes through
    /// the allocating [`PowerBackend::local_product`]; the in-process
    /// Rust backend overrides it with `matmul_into` and the PJRT
    /// backend lowers it through the executable path so the solver hot
    /// loop avoids the intermediate copy.
    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        let p = self.local_product(agent, w);
        out.copy_from(&p);
    }
    /// All agents' products for one iteration. Default: sequential loop;
    /// implementations may parallelize.
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        assert_eq!(ws.m(), self.m());
        AgentStack::new(
            (0..self.m())
                .map(|j| self.local_product(j, ws.slice(j)))
                .collect(),
        )
    }
    /// All agents' products into a caller-owned stack (the solvers'
    /// steady-state path: `out` is a buffer the solver keeps across
    /// iterations). Default: sequential loop over
    /// [`PowerBackend::local_product_into`].
    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        assert_eq!(ws.m(), self.m());
        assert_eq!(out.m(), self.m());
        for j in 0..self.m() {
            self.local_product_into(j, ws.slice(j), out.slice_mut(j));
        }
    }
    /// Short label for reports.
    fn label(&self) -> &'static str;
}

// Forwarding impl so a borrowed backend can be boxed into a solver
// (external backends like PJRT hand `&dyn PowerBackend` through the
// step-wise API). The product methods are forwarded explicitly to
// preserve implementations' parallel / in-place overrides.
impl PowerBackend for &dyn PowerBackend {
    fn m(&self) -> usize {
        (**self).m()
    }
    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        (**self).local_product(agent, w)
    }
    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        (**self).local_product_into(agent, w, out)
    }
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        (**self).local_products(ws)
    }
    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        (**self).local_products_into(ws, out)
    }
    fn label(&self) -> &'static str {
        (**self).label()
    }
}

/// In-process backend over dense local matrices. Sequential by default;
/// compose with an [`Executor`] to fan the per-agent products over the
/// persistent worker pool.
pub struct RustBackend<'a> {
    locals: &'a [Mat],
    exec: Option<Arc<Executor>>,
    /// Per-agent cost prefix (`rows · cols` of each local, summed),
    /// built once at construction: the weight vector for the
    /// executor's cost-aware dispatch, so heterogeneous shard sizes
    /// split into chunks of comparable flops rather than equal agent
    /// counts. Empty for the sequential backend.
    cost_prefix: Vec<usize>,
    /// One packed-B scratch per worker chunk (slot 0 doubles as the
    /// sequential path's scratch), grown on first use and recycled
    /// forever after — the batched products run `matmul_packed_into`
    /// at zero steady-state allocations. Scratch contents never
    /// influence results (packing is re-done from B every product), so
    /// the chunk→slot mapping is determinism-neutral. Behind a `Mutex`
    /// only because the trait takes `&self`; the lock is uncontended
    /// (one batch at a time per backend).
    packs: Mutex<Vec<PackBuf>>,
}

impl<'a> RustBackend<'a> {
    /// Borrow the problem's local matrices (sequential products).
    pub fn new(locals: &'a [Mat]) -> Self {
        RustBackend {
            locals,
            exec: None,
            cost_prefix: Vec::new(),
            packs: Mutex::new(Vec::new()),
        }
    }

    /// Borrow the local matrices and run batched products on `exec`'s
    /// worker pool, chunked by per-agent flop weight (results
    /// bit-identical to the sequential path for any thread count — the
    /// chunk boundaries are a pure function of the shapes, never of
    /// measured timing).
    pub fn with_executor(locals: &'a [Mat], exec: Arc<Executor>) -> Self {
        let mut cost_prefix = Vec::with_capacity(locals.len() + 1);
        cost_prefix.push(0usize);
        for l in locals {
            let last = *cost_prefix.last().expect("seeded with 0");
            cost_prefix.push(last + l.rows() * l.cols());
        }
        RustBackend {
            locals,
            exec: Some(exec),
            cost_prefix,
            packs: Mutex::new(Vec::new()),
        }
    }
}

impl PowerBackend for RustBackend<'_> {
    fn m(&self) -> usize {
        self.locals.len()
    }
    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        self.locals[agent].matmul(w)
    }
    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        self.locals[agent].matmul_into(w, out);
    }
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        // Allocate the output stack once, then run the batch through the
        // (possibly pooled) in-place path — without this override a
        // pooled backend's allocating form would silently fall back to
        // the sequential trait default.
        assert_eq!(ws.m(), self.m());
        let (_, k) = ws.slice_shape();
        let mut out = AgentStack::replicate(self.m(), &Mat::zeros(self.locals[0].rows(), k));
        self.local_products_into(ws, &mut out);
        out
    }
    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        assert_eq!(ws.m(), self.m());
        assert_eq!(out.m(), self.m());
        let locals = self.locals;
        // Scratch contents don't affect results, so a poisoned lock
        // (a panic mid-batch elsewhere) is safe to take over.
        let mut packs = match self.packs.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        match &self.exec {
            Some(exec) => {
                let nchunks = exec.chunk_count(out.m());
                if packs.len() < nchunks {
                    packs.resize_with(nchunks, PackBuf::new);
                }
                exec.par_weighted_chunks_ctx(
                    out.slices_mut(),
                    &self.cost_prefix,
                    &mut packs,
                    |lo, chunk, pack| {
                        for (off, o) in chunk.iter_mut().enumerate() {
                            locals[lo + off].matmul_packed_into(ws.slice(lo + off), pack, o);
                        }
                    },
                );
            }
            None => {
                if packs.is_empty() {
                    packs.push(PackBuf::new());
                }
                let pack = &mut packs[0];
                for j in 0..self.m() {
                    locals[j].matmul_packed_into(ws.slice(j), pack, out.slice_mut(j));
                }
            }
        }
    }
    fn label(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn locals(m: usize, d: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::seed_from(seed);
        (0..m)
            .map(|_| {
                let g = Mat::randn(d, d, &mut rng);
                let mut a = g.t_matmul(&g);
                a.symmetrize();
                a
            })
            .collect()
    }

    #[test]
    fn rust_backend_products() {
        let ls = locals(4, 8, 131);
        let be = RustBackend::new(&ls);
        let mut rng = Rng::seed_from(132);
        let w = Mat::randn(8, 3, &mut rng);
        let got = be.local_product(2, &w);
        assert!((&got - &ls[2].matmul(&w)).fro_norm() < 1e-14);
    }

    #[test]
    fn executor_backend_bit_identical_to_sequential() {
        let ls = locals(7, 10, 133);
        let seq = RustBackend::new(&ls);
        let mut rng = Rng::seed_from(134);
        let stack = AgentStack::new((0..7).map(|_| Mat::randn(10, 2, &mut rng)).collect());
        let mut want = AgentStack::replicate(7, &Mat::zeros(10, 2));
        seq.local_products_into(&stack, &mut want);

        for threads in [1usize, 2, 3, 16] {
            let par = RustBackend::with_executor(&ls, Arc::new(Executor::new(threads)));
            let mut got = AgentStack::replicate(7, &Mat::zeros(10, 2));
            par.local_products_into(&stack, &mut got);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn into_forms_match_allocating_forms() {
        let ls = locals(5, 9, 138);
        let seq = RustBackend::new(&ls);
        let par = RustBackend::with_executor(&ls, Arc::new(Executor::new(3)));
        let mut rng = Rng::seed_from(139);
        let stack = AgentStack::new((0..5).map(|_| Mat::randn(9, 2, &mut rng)).collect());
        let want = seq.local_products(&stack);

        let mut out = AgentStack::replicate(5, &Mat::zeros(9, 2));
        seq.local_products_into(&stack, &mut out);
        assert_eq!(want, out, "sequential into vs allocating");

        let mut pout = AgentStack::replicate(5, &Mat::zeros(9, 2));
        par.local_products_into(&stack, &mut pout);
        assert_eq!(want, pout, "pooled into vs allocating");

        // The pooled allocating form routes through the in-place batch
        // (it must not fall back to the sequential trait default).
        assert_eq!(want, par.local_products(&stack), "pooled allocating form");
    }

    #[test]
    fn pool_larger_than_agent_count() {
        let ls = locals(2, 5, 135);
        let par = RustBackend::with_executor(&ls, Arc::new(Executor::new(16)));
        let mut rng = Rng::seed_from(136);
        let stack = AgentStack::new((0..2).map(|_| Mat::randn(5, 2, &mut rng)).collect());
        let mut out = AgentStack::replicate(2, &Mat::zeros(5, 2));
        par.local_products_into(&stack, &mut out);
        assert_eq!(out.m(), 2);
        assert!((out.slice(1) - &ls[1].matmul(stack.slice(1))).fro_norm() < 1e-14);
    }
}
