//! Local-compute backends: where `A_j · W` actually runs.
//!
//! The power-step product is the only numerical heavy lifting an agent
//! does per iteration; everything else is communication and a thin QR.
//! Three interchangeable implementations:
//!
//! - [`RustBackend`] — in-process `Mat::matmul` (always available).
//! - [`ParallelBackend`] — same math, agents fanned out over scoped
//!   threads (the L3 perf path for sweeps; see EXPERIMENTS.md §Perf).
//! - `PjrtBackend` (in [`crate::runtime`]) — executes the AOT-compiled
//!   JAX/Pallas artifact through the PJRT C API. That is the production
//!   three-layer path; the Rust backends double as its test oracle.

use crate::consensus::AgentStack;
use crate::linalg::Mat;

/// Per-agent power-step provider.
///
/// Deliberately not `Send`/`Sync`-bounded: the PJRT client is `Rc`-based
/// and single-threaded, so PJRT-backed runs stay on the leader thread
/// while the pure-Rust backends parallelize internally.
pub trait PowerBackend {
    /// Number of agents.
    fn m(&self) -> usize;
    /// `A_j · w` for agent `j`.
    fn local_product(&self, agent: usize, w: &Mat) -> Mat;
    /// `A_j · w` into a caller-owned buffer. The default routes through
    /// the allocating [`PowerBackend::local_product`] (external backends
    /// like PJRT materialize device output anyway); the in-process Rust
    /// backends override it with `matmul_into` so the solver hot loop is
    /// allocation-free.
    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        let p = self.local_product(agent, w);
        out.copy_from(&p);
    }
    /// All agents' products for one iteration. Default: sequential loop;
    /// implementations may parallelize.
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        assert_eq!(ws.m(), self.m());
        AgentStack::new(
            (0..self.m())
                .map(|j| self.local_product(j, ws.slice(j)))
                .collect(),
        )
    }
    /// All agents' products into a caller-owned stack (the solvers'
    /// steady-state path: `out` is a buffer the solver keeps across
    /// iterations). Default: sequential loop over
    /// [`PowerBackend::local_product_into`].
    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        assert_eq!(ws.m(), self.m());
        assert_eq!(out.m(), self.m());
        for j in 0..self.m() {
            self.local_product_into(j, ws.slice(j), out.slice_mut(j));
        }
    }
    /// Short label for reports.
    fn label(&self) -> &'static str;
}

// Forwarding impl so a borrowed backend can be boxed into a solver
// (external backends like PJRT hand `&dyn PowerBackend` through the
// step-wise API). The product methods are forwarded explicitly to
// preserve implementations' parallel / in-place overrides.
impl PowerBackend for &dyn PowerBackend {
    fn m(&self) -> usize {
        (**self).m()
    }
    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        (**self).local_product(agent, w)
    }
    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        (**self).local_product_into(agent, w, out)
    }
    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        (**self).local_products(ws)
    }
    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        (**self).local_products_into(ws, out)
    }
    fn label(&self) -> &'static str {
        (**self).label()
    }
}

/// Sequential in-process backend over dense local matrices.
pub struct RustBackend<'a> {
    locals: &'a [Mat],
}

impl<'a> RustBackend<'a> {
    /// Borrow the problem's local matrices.
    pub fn new(locals: &'a [Mat]) -> Self {
        RustBackend { locals }
    }
}

impl PowerBackend for RustBackend<'_> {
    fn m(&self) -> usize {
        self.locals.len()
    }
    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        self.locals[agent].matmul(w)
    }
    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        self.locals[agent].matmul_into(w, out);
    }
    fn label(&self) -> &'static str {
        "rust"
    }
}

/// Thread-parallel backend: one scoped thread per chunk of agents.
pub struct ParallelBackend<'a> {
    locals: &'a [Mat],
    threads: usize,
}

impl<'a> ParallelBackend<'a> {
    /// `threads = 0` → available_parallelism.
    pub fn new(locals: &'a [Mat], threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            threads
        };
        ParallelBackend { locals, threads }
    }
}

impl PowerBackend for ParallelBackend<'_> {
    fn m(&self) -> usize {
        self.locals.len()
    }

    fn local_product(&self, agent: usize, w: &Mat) -> Mat {
        self.locals[agent].matmul(w)
    }

    fn local_product_into(&self, agent: usize, w: &Mat, out: &mut Mat) {
        self.locals[agent].matmul_into(w, out);
    }

    fn local_products_into(&self, ws: &AgentStack, out: &mut AgentStack) {
        let m = self.m();
        assert_eq!(ws.m(), m);
        assert_eq!(out.m(), m);
        let nthreads = self.threads.min(m).max(1);
        let chunk = m.div_ceil(nthreads);
        let locals = self.locals;

        // Split the output stack into per-thread chunks so each thread
        // writes its agents' products in place (thread spawning itself
        // allocates — this backend trades that for parallel matmuls).
        std::thread::scope(|scope| {
            let mut rest = out.slices_mut();
            let mut base = 0usize;
            while !rest.is_empty() {
                let take = chunk.min(rest.len());
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                let lo = base;
                base += take;
                scope.spawn(move || {
                    for (off, o) in head.iter_mut().enumerate() {
                        locals[lo + off].matmul_into(ws.slice(lo + off), o);
                    }
                });
            }
        });
    }

    fn local_products(&self, ws: &AgentStack) -> AgentStack {
        let m = self.m();
        assert_eq!(ws.m(), m);
        let nthreads = self.threads.min(m).max(1);
        let chunk = m.div_ceil(nthreads);
        let mut out: Vec<Option<Mat>> = (0..m).map(|_| None).collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(m);
                if lo >= hi {
                    break;
                }
                let locals = self.locals;
                let handle = scope.spawn(move || {
                    (lo..hi)
                        .map(|j| locals[j].matmul(ws.slice(j)))
                        .collect::<Vec<Mat>>()
                });
                handles.push((lo, handle));
            }
            for (lo, h) in handles {
                for (off, mat) in h.join().expect("backend thread panicked").into_iter().enumerate() {
                    out[lo + off] = Some(mat);
                }
            }
        });
        AgentStack::new(out.into_iter().map(Option::unwrap).collect())
    }

    fn label(&self) -> &'static str {
        "rust-parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn locals(m: usize, d: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::seed_from(seed);
        (0..m)
            .map(|_| {
                let g = Mat::randn(d, d, &mut rng);
                let mut a = g.t_matmul(&g);
                a.symmetrize();
                a
            })
            .collect()
    }

    #[test]
    fn rust_backend_products() {
        let ls = locals(4, 8, 131);
        let be = RustBackend::new(&ls);
        let mut rng = Rng::seed_from(132);
        let w = Mat::randn(8, 3, &mut rng);
        let got = be.local_product(2, &w);
        assert!((&got - &ls[2].matmul(&w)).fro_norm() < 1e-14);
    }

    #[test]
    fn parallel_matches_sequential() {
        let ls = locals(7, 10, 133);
        let seq = RustBackend::new(&ls);
        let par = ParallelBackend::new(&ls, 3);
        let mut rng = Rng::seed_from(134);
        let stack = AgentStack::new((0..7).map(|_| Mat::randn(10, 2, &mut rng)).collect());
        let a = seq.local_products(&stack);
        let b = par.local_products(&stack);
        assert!(a.distance(&b) < 1e-14);
    }

    #[test]
    fn into_forms_match_allocating_forms() {
        let ls = locals(5, 9, 138);
        let seq = RustBackend::new(&ls);
        let par = ParallelBackend::new(&ls, 3);
        let mut rng = Rng::seed_from(139);
        let stack = AgentStack::new((0..5).map(|_| Mat::randn(9, 2, &mut rng)).collect());
        let want = seq.local_products(&stack);

        let mut out = AgentStack::replicate(5, &Mat::zeros(9, 2));
        seq.local_products_into(&stack, &mut out);
        assert_eq!(want, out, "sequential into vs allocating");

        let mut pout = AgentStack::replicate(5, &Mat::zeros(9, 2));
        par.local_products_into(&stack, &mut pout);
        assert_eq!(want, pout, "parallel into vs allocating");
    }

    #[test]
    fn parallel_more_threads_than_agents() {
        let ls = locals(2, 5, 135);
        let par = ParallelBackend::new(&ls, 16);
        let mut rng = Rng::seed_from(136);
        let stack = AgentStack::new((0..2).map(|_| Mat::randn(5, 2, &mut rng)).collect());
        let out = par.local_products(&stack);
        assert_eq!(out.m(), 2);
    }

    #[test]
    fn zero_threads_defaults() {
        let ls = locals(3, 4, 137);
        let par = ParallelBackend::new(&ls, 0);
        assert!(par.threads >= 1);
    }
}
