//! The paper's algorithms and baselines.
//!
//! - [`problem`] — the decentralized PCA problem instance: local Grams
//!   `A_j`, aggregate `A`, target rank k, exact ground truth `U`.
//! - [`backend`] — where the per-agent product `A_j·W` runs: pure Rust
//!   ([`backend::RustBackend`]), thread-parallel, or PJRT artifacts
//!   compiled from the JAX/Pallas layers ([`crate::runtime`]).
//! - [`sign_adjust`] — paper Algorithm 2.
//! - [`deepca`] — paper Algorithm 1 (subspace tracking + FastMix).
//! - [`depca`] — the Eqn. 3.4 baseline (local power + multi-consensus),
//!   with fixed or increasing consensus schedules.
//! - [`local_power`] — no-communication strawman (converges to local PCs).
//! - [`centralized`] — CPCA reference (exact power method on `A`).
//! - [`metrics`] — per-iteration records for the Figure 1–2 panels.

pub mod problem;
pub mod backend;
pub mod sign_adjust;
pub mod deepca;
pub mod depca;
pub mod local_power;
pub mod centralized;
pub mod rayleigh;
pub mod metrics;
