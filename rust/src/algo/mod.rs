//! The paper's algorithms and baselines, unified behind one step-wise
//! solver API.
//!
//! - [`solver`] — **the** algorithm interface: the [`solver::Solver`]
//!   trait (one power iteration per `step`), [`solver::StopCriteria`]
//!   (max iters / tol / stall, evaluated on freshly computed errors by
//!   the shared [`solver::drive`] loop), and the unified
//!   [`solver::SolveReport`]. Sessions are built with
//!   [`crate::coordinator::session::Session`].
//! - [`problem`] — the decentralized PCA problem instance: local Grams
//!   `A_j`, aggregate `A`, target rank k, exact ground truth `U`.
//! - [`backend`] — where the per-agent product `A_j·W` runs: pure Rust
//!   ([`backend::RustBackend`]), thread-parallel, or PJRT artifacts
//!   compiled from the JAX/Pallas layers ([`crate::runtime`]).
//! - [`sign_adjust`] — paper Algorithm 2.
//! - [`workspace`] — per-agent scratch buffers
//!   ([`workspace::SolverWorkspace`]) that make every solver's `step`
//!   allocation-free after warm-up.
//! - [`deepca`] — paper Algorithm 1 ([`deepca::DeepcaSolver`]:
//!   subspace tracking + FastMix).
//! - [`depca`] — the Eqn. 3.4 baseline ([`depca::DepcaSolver`]: local
//!   power + multi-consensus, fixed or increasing schedules).
//! - [`local_power`] — no-communication strawman
//!   ([`local_power::LocalPowerSolver`]: converges to local PCs).
//! - [`centralized`] — CPCA reference
//!   ([`centralized::CentralizedSolver`]: exact power method on `A`).
//! - [`rayleigh`] — Remark-4 eigenvalue estimation, composable as a
//!   session post-step.
//! - [`metrics`] — per-iteration records for the Figure 1–2 panels.

pub mod problem;
pub mod backend;
pub mod sign_adjust;
pub mod workspace;
pub mod solver;
pub mod deepca;
pub mod depca;
pub mod local_power;
pub mod centralized;
pub mod rayleigh;
pub mod metrics;
