//! Local-only power method — the no-communication strawman.
//!
//! Each agent power-iterates on its own `A_j` and never talks. §1 of the
//! paper observes this converges to the principal components *of the
//! local matrix*, not of the aggregate — the heterogeneity that forces
//! multi-consensus in the first place. We implement it to (a) quantify
//! that gap in the ablation bench and (b) measure the heterogeneity
//! floor `(1/m)Σ tanθ_k(U, U_j)` of a given partition.
//!
//! [`LocalPowerSolver`] implements the step-wise [`Solver`] API so the
//! strawman runs through the same driver/builder as everything else.

use super::backend::{PowerBackend, RustBackend};
use super::problem::Problem;
use super::solver::{mean_tan_theta, Solver, SolverState, StepReport};
use super::workspace::SolverWorkspace;
use crate::consensus::AgentStack;
use crate::exec::Executor;
use std::sync::Arc;

/// Local-only power method knobs.
#[derive(Clone, Debug)]
pub struct LocalPowerConfig {
    /// Power iterations to run.
    pub max_iters: usize,
    /// Seed for the shared initial `W⁰`.
    pub init_seed: u64,
}

impl Default for LocalPowerConfig {
    fn default() -> Self {
        LocalPowerConfig { max_iters: 60, init_seed: 2021 }
    }
}

/// Step-wise local-only power method (no communication at all).
pub struct LocalPowerSolver<'a> {
    problem: &'a Problem,
    backend: Box<dyn PowerBackend + 'a>,
    /// Persistent landing buffer for the per-agent products.
    products: AgentStack,
    /// Worker pool for the per-agent QR loop.
    exec: Arc<Executor>,
    /// Per-worker QR scratch (one slot per executor chunk; see
    /// [`SolverWorkspace`]).
    workspaces: Vec<SolverWorkspace>,
    state: SolverState,
}

impl<'a> LocalPowerSolver<'a> {
    /// Solver over an explicit backend.
    pub fn new(problem: &'a Problem, backend: Box<dyn PowerBackend + 'a>, cfg: LocalPowerConfig) -> Self {
        assert_eq!(backend.m(), problem.m(), "backend/problem agent count mismatch");
        let w0 = problem.initial_w(cfg.init_seed);
        let (d, k) = w0.shape();
        let w = AgentStack::replicate(problem.m(), &w0);
        LocalPowerSolver {
            problem,
            backend,
            products: w.clone(),
            exec: Arc::new(Executor::sequential()),
            workspaces: vec![SolverWorkspace::new(d, k)],
            state: SolverState::init(w, false),
        }
    }

    /// Run the per-agent QR loop on `exec`'s worker pool (fixed
    /// partitioning, one workspace slot per chunk — bit-identical
    /// results for any thread count).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        let (d, k) = self.products.slice_shape();
        self.workspaces = (0..exec.chunk_count(self.problem.m()))
            .map(|_| SolverWorkspace::new(d, k))
            .collect();
        self.exec = exec;
        self
    }

    /// Convenience: sequential Rust backend.
    pub fn dense(problem: &'a Problem, cfg: LocalPowerConfig) -> Self {
        let backend = Box::new(RustBackend::new(&problem.locals));
        Self::new(problem, backend, cfg)
    }
}

impl Solver for LocalPowerSolver<'_> {
    fn name(&self) -> &'static str {
        "local-power"
    }

    fn problem(&self) -> &Problem {
        self.problem
    }

    fn step(&mut self) -> StepReport {
        let t = self.state.iter;
        let _span_step = crate::trace_span!(Step, t as u64);
        let w = &mut self.state.w;
        {
            let _span = crate::trace_span!(LocalProduct, t as u64);
            self.backend.local_products_into(w, &mut self.products);
        }
        {
            let _span = crate::trace_span!(Qr, t as u64);
            let products = &self.products;
            self.exec
                .par_chunks_ctx(w.slices_mut(), &mut self.workspaces, |lo, chunk, ws| {
                    for (off, wj) in chunk.iter_mut().enumerate() {
                        let q = ws.orth_into(products.slice(lo + off), true);
                        wj.copy_from(q);
                    }
                });
        }
        self.state.iter = t + 1;
        StepReport {
            iter: t,
            // lint: allow(alloc, per-step stats snapshot for the report struct — tiny and off the data path)
            comm: self.state.stats.clone(),
            finite: self.state.w.is_finite(),
            mean_tan_theta: None,
        }
    }

    fn state(&self) -> &SolverState {
        &self.state
    }

    fn warm_start(&mut self, w: &AgentStack) {
        assert_eq!(w.m(), self.problem.m(), "warm-start agent count mismatch");
        // Refit the product buffer to the incoming shape (the workspace
        // refits itself on use).
        self.products = w.clone();
        self.state = SolverState::init(w.clone(), false);
    }
}

/// The heterogeneity floor of a partition: where local-only power
/// iterations level off (mean angle between local and global top-k).
pub fn heterogeneity_floor(problem: &Problem, iters: usize) -> f64 {
    let mut solver = LocalPowerSolver::dense(problem, LocalPowerConfig { max_iters: iters, init_seed: 2021 });
    for _ in 0..iters {
        solver.step();
    }
    mean_tan_theta(&problem.u(), &solver.state().w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::metrics::RunRecorder;
    use crate::algo::solver::{drive, StopCriteria};
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    /// Drive `iters` purely-local power iterations and return the
    /// per-iteration mean tan θ trace (vs the *global* U).
    fn mean_tan_trace(problem: &Problem, iters: usize, init_seed: u64) -> Vec<f64> {
        let cfg = LocalPowerConfig { max_iters: iters, init_seed };
        let mut solver = LocalPowerSolver::dense(problem, cfg);
        let mut rec = RunRecorder::every_iteration();
        let _ = drive(&mut solver, &StopCriteria::max_iters(iters), &mut rec, None);
        rec.records.iter().map(|r| r.mean_tan_theta).collect()
    }

    #[test]
    fn converges_to_local_not_global() {
        // Strong block drift: local PCs differ from global PCs.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1200,
                dim: 30,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 6,
                drift: 0.9,
            },
            &mut Rng::seed_from(191),
        );
        let p = Problem::from_dataset(&ds, 6, 2);
        let trace = mean_tan_trace(&p, 60, 2021);
        let floor = *trace.last().unwrap();
        assert!(
            floor > 1e-2,
            "local-only should NOT reach the global subspace, floor={floor}"
        );
        // And it stalls rather than keeps improving.
        let mid = trace[30];
        assert!(floor > 0.3 * mid, "unexpected continued convergence");
    }

    #[test]
    fn homogeneous_data_has_no_floor() {
        let mut rng = Rng::seed_from(192);
        let ds = synthetic::spiked_covariance(600, 10, &[9.0, 5.0], 0.1, &mut rng);
        let full = ds.features.t_matmul(&ds.features).scaled(1.0 / 600.0);
        let mut a = full;
        a.symmetrize();
        let p = Problem::new(vec![a; 4], 2, "homog");
        let floor = heterogeneity_floor(&p, 100);
        assert!(floor < 1e-9, "identical locals must converge, floor={floor}");
    }

    #[test]
    fn floor_increases_with_drift() {
        let mk = |drift: f64| {
            let ds = synthetic::sparse_binary(
                &synthetic::SparseBinaryParams {
                    rows: 1200,
                    dim: 24,
                    density: 0.2,
                    popularity_exponent: 0.8,
                    blocks: 4,
                    drift,
                },
                &mut Rng::seed_from(193),
            );
            let p = Problem::from_dataset(&ds, 4, 1);
            heterogeneity_floor(&p, 50)
        };
        let low = mk(0.1);
        let high = mk(0.9);
        assert!(high > low, "floor should grow with drift: {low} vs {high}");
    }

    #[test]
    fn solver_reports_no_communication() {
        let mut rng = Rng::seed_from(194);
        let ds = synthetic::spiked_covariance(200, 8, &[6.0], 0.2, &mut rng);
        let p = Problem::from_dataset(&ds, 4, 1);
        let mut solver = LocalPowerSolver::dense(&p, LocalPowerConfig::default());
        for _ in 0..10 {
            let rep = solver.step();
            assert_eq!(rep.comm.rounds, 0);
            assert_eq!(rep.comm.bytes_sent, 0);
        }
    }
}
