//! Local-only power method — the no-communication strawman.
//!
//! Each agent power-iterates on its own `A_j` and never talks. §1 of the
//! paper observes this converges to the principal components *of the
//! local matrix*, not of the aggregate — the heterogeneity that forces
//! multi-consensus in the first place. We implement it to (a) quantify
//! that gap in the ablation bench and (b) measure the heterogeneity
//! floor `(1/m)Σ tanθ_k(U, U_j)` of a given partition.

use super::problem::Problem;
use crate::consensus::AgentStack;
use crate::linalg::angles::tan_theta;
use crate::linalg::qr::orth;

/// Output of the local-only baseline.
#[derive(Clone, Debug)]
pub struct LocalPowerOutput {
    /// Final per-agent iterates (each ≈ top-k of its own A_j).
    pub final_w: AgentStack,
    /// Mean tan θ_k(U, W_j) vs the *global* U per iteration.
    pub mean_tan_trace: Vec<f64>,
}

/// Run `iters` purely-local power iterations.
pub fn run(problem: &Problem, iters: usize, init_seed: u64) -> LocalPowerOutput {
    let u = problem.u();
    let w0 = problem.initial_w(init_seed);
    let m = problem.m();
    let mut w = AgentStack::replicate(m, &w0);
    let mut mean_tan_trace = Vec::with_capacity(iters);
    for _ in 0..iters {
        for j in 0..m {
            let p = problem.locals[j].matmul(w.slice(j));
            *w.slice_mut(j) = orth(&p);
        }
        let mean = w.iter().map(|wj| tan_theta(&u, wj)).sum::<f64>() / m as f64;
        mean_tan_trace.push(mean);
    }
    LocalPowerOutput { final_w: w, mean_tan_trace }
}

/// The heterogeneity floor of a partition: where local-only power
/// iterations level off (mean angle between local and global top-k).
pub fn heterogeneity_floor(problem: &Problem, iters: usize) -> f64 {
    let out = run(problem, iters, 2021);
    *out.mean_tan_trace.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    #[test]
    fn converges_to_local_not_global() {
        // Strong block drift: local PCs differ from global PCs.
        let ds = synthetic::sparse_binary(
            &synthetic::SparseBinaryParams {
                rows: 1200,
                dim: 30,
                density: 0.15,
                popularity_exponent: 0.9,
                blocks: 6,
                drift: 0.9,
            },
            &mut Rng::seed_from(191),
        );
        let p = Problem::from_dataset(&ds, 6, 2);
        let out = run(&p, 60, 2021);
        let floor = *out.mean_tan_trace.last().unwrap();
        assert!(
            floor > 1e-2,
            "local-only should NOT reach the global subspace, floor={floor}"
        );
        // And it stalls rather than keeps improving.
        let mid = out.mean_tan_trace[30];
        assert!(floor > 0.3 * mid, "unexpected continued convergence");
    }

    #[test]
    fn homogeneous_data_has_no_floor() {
        let mut rng = Rng::seed_from(192);
        let ds = synthetic::spiked_covariance(600, 10, &[9.0, 5.0], 0.1, &mut rng);
        let full = ds.features.t_matmul(&ds.features).scaled(1.0 / 600.0);
        let mut a = full;
        a.symmetrize();
        let p = Problem::new(vec![a; 4], 2, "homog");
        let floor = heterogeneity_floor(&p, 100);
        assert!(floor < 1e-9, "identical locals must converge, floor={floor}");
    }

    #[test]
    fn floor_increases_with_drift() {
        let mk = |drift: f64| {
            let ds = synthetic::sparse_binary(
                &synthetic::SparseBinaryParams {
                    rows: 1200,
                    dim: 24,
                    density: 0.2,
                    popularity_exponent: 0.8,
                    blocks: 4,
                    drift,
                },
                &mut Rng::seed_from(193),
            );
            let p = Problem::from_dataset(&ds, 4, 1);
            heterogeneity_floor(&p, 50)
        };
        let low = mk(0.1);
        let high = mk(0.9);
        assert!(high > low, "floor should grow with drift: {low} vs {high}");
    }
}
