//! The unified step-wise solver API.
//!
//! Every PCA algorithm in this crate — DeEPCA (paper Algorithm 1), the
//! DePCA baseline (Eqn. 3.4), the local-only power method, and the
//! centralized CPCA reference — implements one trait, [`Solver`]:
//! construct it, call [`Solver::step`] to advance one power iteration,
//! inspect [`Solver::state`] between steps. One shared driver loop
//! ([`drive`]) owns iteration control: it evaluates [`StopCriteria`]
//! (max iterations, tolerance, stall detection) against a **freshly
//! computed** subspace error, feeds the [`RunRecorder`], invokes
//! observers, and assembles a [`DriveOutcome`].
//!
//! This fixes a class of bugs in the previous per-algorithm run loops
//! where the `tol` early-stop read the *recorder's* last value: with a
//! strided recorder the check compared against a stale (or never
//! recorded, hence infinite) error and either stopped late or never.
//! The driver decouples stopping from recording cadence.
//!
//! The fluent entry point is [`crate::coordinator::session::Session`]
//! (the `SolverBuilder`): pick an [`Algo`], an execution [`Engine`],
//! optional observers / warm start / Rayleigh eigenvalue post-step, and
//! get back one [`SolveReport`] shape regardless of algorithm.

use super::centralized::CentralizedConfig;
use super::deepca::DeepcaConfig;
use super::depca::DepcaConfig;
use super::local_power::LocalPowerConfig;
use super::metrics::{RunOutput, RunRecorder};
use super::problem::Problem;
use super::rayleigh::EigenEstimate;
use crate::consensus::metrics::CommStats;
use crate::consensus::simnet::SimConfig;
use crate::consensus::AgentStack;
use crate::linalg::angles::tan_theta_orthonormal;
use crate::linalg::Mat;
use crate::util::timer::Timer;

// ------------------------------------------------------------ selection

/// Which algorithm a session runs.
#[derive(Clone, Debug)]
pub enum Algo {
    /// Paper Algorithm 1: subspace tracking + FastMix + SignAdjust.
    Deepca(DeepcaConfig),
    /// Eqn. 3.4 baseline: local power step + multi-consensus.
    Depca(DepcaConfig),
    /// No-communication strawman (converges to the *local* PCs).
    LocalPower(LocalPowerConfig),
    /// Centralized power method on the aggregate (rate yardstick).
    Centralized(CentralizedConfig),
}

impl Algo {
    /// Short label for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Deepca(_) => "deepca",
            Algo::Depca(_) => "depca",
            Algo::LocalPower(_) => "local-power",
            Algo::Centralized(_) => "centralized",
        }
    }

    /// Stop criteria implied by the algorithm's config (max iterations
    /// and tolerance); a session-level [`StopCriteria`] overrides this.
    pub fn default_stop(&self) -> StopCriteria {
        match self {
            Algo::Deepca(c) => StopCriteria::max_iters(c.max_iters).with_tol(c.tol),
            Algo::Depca(c) => StopCriteria::max_iters(c.max_iters).with_tol(c.tol),
            Algo::LocalPower(c) => StopCriteria::max_iters(c.max_iters),
            Algo::Centralized(c) => StopCriteria::max_iters(c.max_iters).with_tol(c.tol),
        }
    }
}

/// Which execution engine carries a run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Engine {
    /// Single-process dense gossip. Per-agent parallelism (local
    /// products, gossip row blocks, QR loops) comes from the
    /// session-wide executor (`Session::threads` / `DEEPCA_THREADS`),
    /// with results bit-identical for any thread count.
    Dense,
    /// Legacy alias for [`Engine::Dense`]: parallelism is the
    /// executor's job now, so both variants build identical parts.
    DenseParallel,
    /// Real message-passing gossip (threads + channels).
    Threaded,
    /// Fully distributed: the whole loop inside per-agent threads
    /// (DeEPCA only; other algorithms fall back to `Threaded`).
    Distributed,
    /// Deterministic unreliable-network simulator
    /// ([`crate::consensus::simnet::SimNet`]): seeded packet drops,
    /// per-link latency on a virtual clock, payload noise, time-varying
    /// topologies. `SimConfig::ideal(_)` reproduces `Dense` bit-for-bit.
    Sim(SimConfig),
    /// Fleet-scale sparse gossip ([`crate::consensus::comm::SparseComm`]):
    /// Metropolis–Hastings CSR weights built straight from adjacency
    /// lists, λ₂ via a seeded Lanczos estimate — nothing dense in the
    /// agent count, O(edges · d · k) per round. Not bit-identical to
    /// `Dense` (different weight construction); at small agent counts
    /// the dense engine's exact spectrum mixes in fewer rounds.
    Sparse,
}

// ----------------------------------------------------------- state/step

/// Observable solver state between steps.
#[derive(Clone, Debug)]
pub struct SolverState {
    /// Power iterations completed so far.
    pub iter: usize,
    /// Per-agent iterates `W_j` (orthonormal after every step). The
    /// centralized solver uses a single-slice stack.
    pub w: AgentStack,
    /// The algorithm's consensus variable, if it has one: DeEPCA's
    /// tracked `S`, DePCA's pre-QR mixed iterate `P`. Present from
    /// construction (it reads as the initial iterate before the first
    /// step) and overwritten in place each step — it doubles as the
    /// solver's persistent consensus buffer.
    pub s: Option<AgentStack>,
    /// Cumulative communication.
    pub stats: CommStats,
}

impl SolverState {
    /// Fresh state from an initial per-agent iterate.
    pub fn init(w: AgentStack, tracked: bool) -> Self {
        let s = tracked.then(|| w.clone());
        SolverState { iter: 0, w, s, stats: CommStats::default() }
    }
}

/// What one [`Solver::step`] reports back.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// 0-based index of the iteration just completed.
    pub iter: usize,
    /// Cumulative communication after this step.
    pub comm: CommStats,
    /// False if the step produced non-finite iterates (divergence).
    pub finite: bool,
    /// Mean `tan θ_k(U, W_j)` — filled in by the driver on iterations
    /// where the error was evaluated (recording or stop checks), `None`
    /// otherwise. Solvers return `None`; ground-truth metrics are the
    /// driver's job.
    pub mean_tan_theta: Option<f64>,
}

// ----------------------------------------------------------------- trait

/// A step-wise PCA solver: one power iteration per [`step`](Solver::step).
///
/// Implementations own their full algorithm state (`S`, `W`, cached
/// products, K-schedules) so a run can be advanced, paused, observed, or
/// warm-started externally. Iteration control — stopping, recording,
/// callbacks — lives in [`drive`], not in the solver.
pub trait Solver {
    /// Short algorithm label for reports.
    fn name(&self) -> &'static str;

    /// The problem being solved (supplies the ground truth for metrics).
    fn problem(&self) -> &Problem;

    /// Advance one power iteration.
    fn step(&mut self) -> StepReport;

    /// Current state (iterates, consensus variable, communication).
    fn state(&self) -> &SolverState;

    /// Restart from the given per-agent iterate (warm start), resetting
    /// any derived state (tracked variable, cached products, iteration
    /// counter). Slices must be orthonormal `d×k` with the solver's `m`.
    fn warm_start(&mut self, w: &AgentStack);
}

// ------------------------------------------------------------- stopping

/// Why a driven run ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Fresh mean tan θ dropped to `tol`.
    Converged,
    /// Iteration budget exhausted.
    MaxIters,
    /// Stall detector fired: the error stopped improving.
    Stalled,
    /// Non-finite iterates.
    Diverged,
}

/// Stopping policy evaluated by [`drive`] **against freshly computed
/// errors**, independent of the recorder's cadence.
#[derive(Clone, Debug)]
pub struct StopCriteria {
    /// Maximum power iterations.
    pub max_iters: usize,
    /// Stop once mean tan θ ≤ tol (0 disables).
    pub tol: f64,
    /// Stall window in iterations (0 disables stall detection).
    pub stall_window: usize,
    /// Stall trigger: stalled when the current error exceeds
    /// `stall_decay ×` the error `stall_window` iterations ago. Values
    /// near 1.0 require barely-any progress to keep going; a genuinely
    /// linearly-converging run shrinks far faster and never triggers.
    pub stall_decay: f64,
}

impl Default for StopCriteria {
    fn default() -> Self {
        StopCriteria { max_iters: 100, tol: 0.0, stall_window: 0, stall_decay: 0.99 }
    }
}

impl StopCriteria {
    /// Budget-only criteria.
    pub fn max_iters(max_iters: usize) -> Self {
        StopCriteria { max_iters, ..Default::default() }
    }

    /// Add a tolerance (0 disables).
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Enable stall detection over a window of iterations.
    pub fn with_stall(mut self, window: usize, decay: f64) -> Self {
        self.stall_window = window;
        self.stall_decay = decay;
        self
    }

    /// Whether any criterion needs the error evaluated every iteration.
    pub fn needs_error(&self) -> bool {
        self.tol > 0.0 || self.stall_window > 0
    }
}

// --------------------------------------------------------------- driver

/// Mean subspace error `(1/m) Σ_j tan θ_k(U, W_j)` for orthonormal
/// per-agent iterates (the quantity the paper's third panel plots).
pub fn mean_tan_theta(u: &Mat, ws: &AgentStack) -> f64 {
    ws.iter().map(|w| tan_theta_orthonormal(u, w)).sum::<f64>() / ws.m() as f64
}

/// What [`drive`] hands back (the solver holds the final state).
#[derive(Clone, Debug)]
pub struct DriveOutcome {
    /// Iterations executed.
    pub iters: usize,
    /// Why the loop ended.
    pub reason: StopReason,
    /// Mean tan θ at exit, computed fresh from the final iterate (falls
    /// back to the last recorded value if the run diverged).
    pub final_tan_theta: f64,
    /// Wall time inside the loop.
    pub elapsed_secs: f64,
}

/// The shared driver loop: step the solver until [`StopCriteria`] fire,
/// recording into `recorder` at its own cadence and invoking `observer`
/// after every step.
///
/// Stop checks always use an error computed fresh from the current
/// iterate — never the recorder's (possibly stale) last record.
pub fn drive<'o>(
    solver: &mut dyn Solver,
    stop: &StopCriteria,
    recorder: &mut RunRecorder,
    mut observer: Option<&mut (dyn FnMut(&StepReport) + 'o)>,
) -> DriveOutcome {
    let u = solver.problem().u();
    let t0 = Timer::start();
    let mut reason = StopReason::MaxIters;
    let mut history: Vec<f64> = Vec::new();
    let mut iters = 0;

    for t in 0..stop.max_iters {
        let mut report = solver.step();
        iters = t + 1;
        if !report.finite {
            reason = StopReason::Diverged;
            break;
        }

        let record_now = recorder.should_record(t);
        if record_now {
            recorder.record(
                t,
                &u,
                &solver.state().w,
                solver.state().s.as_ref(),
                &report.comm,
                t0.elapsed_secs(),
            );
        } else {
            // Stride-skipped iterations still log the cheap facts
            // (communication, elapsed time) so traces keep a
            // per-iteration x-axis; the expensive tan-theta metrics stay
            // NaN sentinels that the accessors skip.
            recorder.record_cheap(t, &report.comm, t0.elapsed_secs());
        }
        // Error for the stop checks: freshly computed from the current
        // iterate. A record written *this iteration* is that same fresh
        // value, so reuse it instead of evaluating twice.
        let err = if record_now {
            recorder.records.last().map(|r| r.mean_tan_theta)
        } else if stop.needs_error() {
            Some(mean_tan_theta(&u, &solver.state().w))
        } else {
            None
        };
        report.mean_tan_theta = err;
        if let Some(f) = observer.as_mut() {
            f(&report);
        }

        if let Some(e) = err {
            if stop.tol > 0.0 && e <= stop.tol {
                reason = StopReason::Converged;
                break;
            }
            if stop.stall_window > 0 {
                history.push(e);
                if history.len() > stop.stall_window {
                    let then = history[history.len() - 1 - stop.stall_window];
                    if e >= stop.stall_decay * then {
                        reason = StopReason::Stalled;
                        break;
                    }
                }
            }
        }
    }

    let final_tan_theta = if solver.state().w.is_finite() {
        mean_tan_theta(&u, &solver.state().w)
    } else {
        recorder.final_tan_theta()
    };
    DriveOutcome { iters, reason, final_tan_theta, elapsed_secs: t0.elapsed_secs() }
}

// --------------------------------------------------------------- report

/// Unified result of a driven run — one shape for every algorithm and
/// engine.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Algorithm label.
    pub algo: &'static str,
    /// Engine that carried the run.
    pub engine: Engine,
    /// Power iterations executed.
    pub iters: usize,
    /// Why the run ended.
    pub reason: StopReason,
    /// Convenience mirror of `reason == StopReason::Diverged`.
    pub diverged: bool,
    /// Mean tan θ_k(U, W_j) at exit, computed fresh from `final_w`.
    pub final_tan_theta: f64,
    /// Communication totals.
    pub comm: CommStats,
    /// Final per-agent iterates.
    pub final_w: AgentStack,
    /// Per-iteration trace (at the recorder's cadence).
    pub trace: RunRecorder,
    /// Wall time inside the algorithm.
    pub elapsed_secs: f64,
    /// Remark-4 Rayleigh eigenvalue estimates, when the session ran the
    /// post-step.
    pub eigenvalues: Option<EigenEstimate>,
}

impl SolveReport {
    /// First iteration (and cumulative rounds) whose recorded error
    /// drops below `eps`.
    pub fn first_below(&self, eps: f64) -> Option<(usize, u64)> {
        self.trace.first_below(eps)
    }

    /// Virtual clock ticks the run consumed (SimNet engine only: one
    /// tick per gossip round plus per-link latencies; 0 elsewhere).
    pub fn virtual_time(&self) -> u64 {
        self.comm.virtual_time
    }

    /// Legacy [`RunOutput`] view (clones the final iterate and stats).
    pub fn to_run_output(&self) -> RunOutput {
        RunOutput {
            iters: self.iters,
            final_tan_theta: self.final_tan_theta,
            comm: self.comm.clone(),
            final_w: self.final_w.clone(),
            elapsed_secs: self.elapsed_secs,
            diverged: self.diverged,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_and_default_stop() {
        let a = Algo::Deepca(DeepcaConfig { max_iters: 42, tol: 1e-7, ..Default::default() });
        assert_eq!(a.name(), "deepca");
        let s = a.default_stop();
        assert_eq!(s.max_iters, 42);
        assert!((s.tol - 1e-7).abs() < 1e-20);
        assert_eq!(s.stall_window, 0);

        let c = Algo::Centralized(CentralizedConfig { max_iters: 9, ..Default::default() });
        assert_eq!(c.name(), "centralized");
        assert_eq!(c.default_stop().max_iters, 9);

        assert_eq!(Algo::LocalPower(LocalPowerConfig::default()).name(), "local-power");
        assert_eq!(Algo::Depca(DepcaConfig::default()).name(), "depca");
    }

    #[test]
    fn stop_criteria_builders() {
        let s = StopCriteria::max_iters(10);
        assert!(!s.needs_error());
        let s = s.with_tol(1e-6);
        assert!(s.needs_error());
        let s = StopCriteria::max_iters(10).with_stall(5, 0.9);
        assert!(s.needs_error());
        assert_eq!(s.stall_window, 5);
    }

    #[test]
    fn mean_tan_of_truth_is_zero() {
        let mut rng = crate::util::rng::Rng::seed_from(641);
        let u = Mat::rand_orthonormal(10, 2, &mut rng);
        let ws = AgentStack::replicate(4, &u);
        assert!(mean_tan_theta(&u, &ws) < 1e-10);
    }
}
