//! The decentralized PCA problem instance.

use crate::data::partition::{partition_gram, GramScaling, PartitionedGram};
use crate::data::Dataset;
use crate::linalg::eig::{eig_sym, EigSym};
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// A fully-specified instance: per-agent matrices, aggregate, rank, and
/// exact ground truth (for metrics only — no algorithm reads `truth`).
#[derive(Clone, Debug)]
pub struct Problem {
    /// Local symmetric matrices `A_j` (PSD in the paper's main setting).
    pub locals: Vec<Mat>,
    /// Aggregate `A = (1/m) Σ_j A_j`.
    pub aggregate: Mat,
    /// Target subspace dimension k.
    pub k: usize,
    /// Exact eigendecomposition of the aggregate (ground truth oracle).
    pub truth: EigSym,
    /// Spectral bound `L ≥ max_j ‖A_j‖₂`.
    pub spectral_bound: f64,
    /// Provenance for reports.
    pub name: String,
}

impl Problem {
    /// Build from per-agent matrices.
    pub fn new(locals: Vec<Mat>, k: usize, name: &str) -> Self {
        assert!(!locals.is_empty());
        let d = locals[0].rows();
        assert!(k >= 1 && k < d, "need 1 <= k < d");
        let m = locals.len();
        let mut aggregate = Mat::zeros(d, d);
        for a in &locals {
            assert_eq!(a.shape(), (d, d));
            aggregate.axpy(1.0 / m as f64, a);
        }
        aggregate.symmetrize();
        let truth = eig_sym(&aggregate);
        assert!(
            truth.values[k - 1] > truth.values[k] + 1e-12,
            "no eigengap at k={k}: λ_k={} λ_k+1={}",
            truth.values[k - 1],
            truth.values[k]
        );
        let spectral_bound = locals
            .iter()
            .map(|a| crate::linalg::norms::spectral_norm_power(a, 60))
            .fold(0.0f64, f64::max);
        Problem { locals, aggregate, k, truth, spectral_bound, name: name.to_string() }
    }

    /// Build from a partitioned Gram.
    pub fn from_partition(p: PartitionedGram, k: usize, name: &str) -> Self {
        // Reuse the already-computed aggregate/spectral bound.
        let truth = eig_sym(&p.aggregate);
        assert!(
            truth.values[k - 1] > truth.values[k] + 1e-12,
            "no eigengap at k={k}"
        );
        Problem {
            locals: p.locals,
            aggregate: p.aggregate,
            k,
            truth,
            spectral_bound: p.spectral_bound,
            name: name.to_string(),
        }
    }

    /// Paper Eqn. 5.1 placement: split `ds` over `m` agents, rank k.
    pub fn from_dataset(ds: &Dataset, m: usize, k: usize) -> Self {
        let p = partition_gram(ds, m, GramScaling::PerRow);
        Self::from_partition(p, k, &ds.name)
    }

    /// Number of agents m.
    pub fn m(&self) -> usize {
        self.locals.len()
    }

    /// Ambient dimension d.
    pub fn dim(&self) -> usize {
        self.aggregate.rows()
    }

    /// Ground-truth top-k subspace U (d×k, orthonormal).
    pub fn u(&self) -> Mat {
        self.truth.top_k(self.k)
    }

    /// λ_k of the aggregate.
    pub fn lambda_k(&self) -> f64 {
        self.truth.values[self.k - 1]
    }

    /// λ_{k+1} of the aggregate.
    pub fn lambda_k1(&self) -> f64 {
        self.truth.values[self.k]
    }

    /// The paper's convergence factor γ = 1 − (λ_k − λ_{k+1})/(2λ_k).
    pub fn gamma(&self) -> f64 {
        1.0 - (self.lambda_k() - self.lambda_k1()) / (2.0 * self.lambda_k())
    }

    /// Remark-2 heterogeneity `L²/(λ_k λ_{k+1})`.
    pub fn heterogeneity(&self) -> f64 {
        self.spectral_bound * self.spectral_bound / (self.lambda_k() * self.lambda_k1())
    }

    /// Shared initial iterate `W⁰`: random orthonormal d×k (all agents
    /// start identical, per Algorithm 1's initialization).
    pub fn initial_w(&self, seed: u64) -> Mat {
        Mat::rand_orthonormal(self.dim(), self.k, &mut Rng::seed_from(seed))
    }

    /// Theorem-1 iteration bound T(ε) (up to its constants).
    pub fn iteration_bound(&self, eps: f64, tan0: f64) -> f64 {
        let gap = (self.lambda_k() - self.lambda_k1()) / self.lambda_k();
        let a = (4.0 * tan0 / eps).ln();
        let b = (4.0 * (self.lambda_k() + 2.0 * self.spectral_bound) * tan0
            / ((self.m() as f64).sqrt() * (self.lambda_k() - self.lambda_k1()) * eps))
            .ln();
        2.0 / gap * a.max(b).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn problem() -> Problem {
        let ds = synthetic::spiked_covariance(240, 12, &[10.0, 6.0, 3.0], 0.2, &mut Rng::seed_from(121));
        Problem::from_dataset(&ds, 8, 2)
    }

    #[test]
    fn shapes_and_counts() {
        let p = problem();
        assert_eq!(p.m(), 8);
        assert_eq!(p.dim(), 12);
        assert_eq!(p.u().shape(), (12, 2));
    }

    #[test]
    fn eigen_order() {
        let p = problem();
        assert!(p.lambda_k() > p.lambda_k1());
        assert!(p.gamma() > 0.0 && p.gamma() < 1.0);
    }

    #[test]
    fn u_is_orthonormal_and_invariant() {
        let p = problem();
        let u = p.u();
        let g = u.t_matmul(&u);
        assert!((&g - &Mat::eye(2)).fro_norm() < 1e-10);
        // A·U ≈ U·Λ_k: U spans an invariant subspace.
        let au = p.aggregate.matmul(&u);
        let lam = Mat::diag(&[p.truth.values[0], p.truth.values[1]]);
        let ul = u.matmul(&lam);
        assert!((&au - &ul).fro_norm() < 1e-8 * p.aggregate.fro_norm());
    }

    #[test]
    fn initial_w_deterministic() {
        let p = problem();
        let a = p.initial_w(5);
        let b = p.initial_w(5);
        assert_eq!(a.data(), b.data());
        let g = a.t_matmul(&a);
        assert!((&g - &Mat::eye(2)).fro_norm() < 1e-10);
    }

    #[test]
    fn iteration_bound_scales_with_eps() {
        let p = problem();
        let t1 = p.iteration_bound(1e-3, 1.0);
        let t2 = p.iteration_bound(1e-9, 1.0);
        assert!(t2 > t1, "tighter ε needs more iterations");
    }

    #[test]
    #[should_panic(expected = "eigengap")]
    fn rejects_gapless_k() {
        // Two equal top eigenvalues → no gap at k=1.
        let locals = vec![Mat::diag(&[2.0, 2.0, 1.0]); 3];
        let _ = Problem::new(locals, 1, "gapless");
    }
}
