//! Decentralized eigenvalue estimation — the paper's Remark 4 extension.
//!
//! Once DeEPCA has produced the shared top-k basis `W`, the eigen*values*
//! follow decentralizedly: each agent forms its local Rayleigh block
//! `R_j = W_jᵀ A_j W_j` (k×k — tiny), the network FastMix-averages them
//! into `R̄ ≈ Wᵀ A W`, and every agent eigendecomposes its k×k copy.
//! For exact `W = U` this recovers λ₁..λ_k exactly; for an ε-accurate
//! subspace the eigenvalue error is O(ε²·λ) (quadratic Rayleigh bound).
//!
//! This turns DeEPCA into a full decentralized *eigendecomposition*:
//! subspace + spectrum, with one extra k²-sized consensus round-trip —
//! the "decentralized eigenvalue decomposition / spectral analysis"
//! direction the paper's conclusion sketches.

use super::metrics::RunOutput;
use super::problem::Problem;
use crate::consensus::comm::Communicator;
use crate::consensus::metrics::CommStats;
use crate::consensus::AgentStack;
use crate::linalg::eig::eig_sym;

/// Per-agent eigenvalue estimates after the consensus step.
#[derive(Clone, Debug)]
pub struct EigenEstimate {
    /// Estimated top-k eigenvalues (descending), one vector per agent.
    pub per_agent: Vec<Vec<f64>>,
    /// Communication spent on the k×k averaging.
    pub comm: CommStats,
}

impl EigenEstimate {
    /// The first agent's estimate (all agents agree to consensus error).
    pub fn values(&self) -> &[f64] {
        &self.per_agent[0]
    }

    /// Max disagreement of estimates across agents.
    pub fn max_disagreement(&self) -> f64 {
        let base = &self.per_agent[0];
        self.per_agent
            .iter()
            .flat_map(|v| v.iter().zip(base).map(|(a, b)| (a - b).abs()))
            .fold(0.0, f64::max)
    }
}

/// Estimate the top-k eigenvalues from a converged per-agent iterate.
///
/// `rounds` FastMix rounds average the k×k Rayleigh blocks (k² scalars
/// per message — negligible next to the d·k iterate traffic). This is
/// the [`crate::coordinator::session::Session`] builder's eigenvalue
/// post-step (paper Remark 4).
pub fn estimate_eigenvalues_from(
    problem: &Problem,
    final_w: &AgentStack,
    comm: &dyn Communicator,
    rounds: usize,
) -> EigenEstimate {
    let m = problem.m();
    assert_eq!(final_w.m(), m);
    // Local Rayleigh blocks R_j = W_jᵀ A_j W_j.
    let mut blocks = AgentStack::new(
        (0..m)
            .map(|j| {
                let w = final_w.slice(j);
                w.t_matmul(&problem.locals[j].matmul(w))
            })
            .collect(),
    );
    let mut stats = CommStats::default();
    comm.fastmix(&mut blocks, rounds, &mut stats);

    let per_agent = (0..m)
        .map(|j| {
            let mut r = blocks.slice(j).clone();
            r.symmetrize();
            eig_sym(&r).values
        })
        .collect();
    EigenEstimate { per_agent, comm: stats }
}

/// Estimate the top-k eigenvalues from a converged [`RunOutput`]
/// (legacy entry point; forwards to [`estimate_eigenvalues_from`]).
pub fn estimate_eigenvalues(
    problem: &Problem,
    run: &RunOutput,
    comm: &dyn Communicator,
    rounds: usize,
) -> EigenEstimate {
    estimate_eigenvalues_from(problem, &run.final_w, comm, rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::deepca::DeepcaConfig;
    use crate::algo::solver::Algo;
    use crate::consensus::comm::DenseComm;
    use crate::coordinator::session::Session;
    use crate::data::synthetic;
    use crate::graph::topology::Topology;
    use crate::util::rng::Rng;

    fn setup() -> (Problem, Topology, RunOutput) {
        let ds = synthetic::spiked_covariance(
            600,
            16,
            &[12.0, 8.0, 5.0],
            0.2,
            &mut Rng::seed_from(501),
        );
        let p = Problem::from_dataset(&ds, 6, 3);
        let topo = Topology::erdos_renyi(6, 0.6, &mut Rng::seed_from(502));
        let cfg = DeepcaConfig { consensus_rounds: 10, max_iters: 120, ..Default::default() };
        let out = Session::on(&p, &topo)
            .algo(Algo::Deepca(cfg))
            .solve()
            .to_run_output();
        assert!(out.final_tan_theta < 1e-9);
        (p, topo, out)
    }

    #[test]
    fn recovers_true_eigenvalues() {
        let (p, topo, out) = setup();
        let comm = DenseComm::from_topology(&topo);
        let est = estimate_eigenvalues(&p, &out, &comm, 30);
        for (got, want) in est.values().iter().zip(&p.truth.values[..3]) {
            assert!(
                (got - want).abs() < 1e-8 * want,
                "eigenvalue {got} vs truth {want}"
            );
        }
    }

    #[test]
    fn agents_agree_after_consensus() {
        let (p, topo, out) = setup();
        let comm = DenseComm::from_topology(&topo);
        let est = estimate_eigenvalues(&p, &out, &comm, 30);
        assert!(
            est.max_disagreement() < 1e-8,
            "disagreement {}",
            est.max_disagreement()
        );
    }

    #[test]
    fn no_consensus_leaves_local_bias() {
        let (p, topo, out) = setup();
        let comm = DenseComm::from_topology(&topo);
        // rounds=0: each agent sees only W_jᵀA_jW_j — heterogeneity shows.
        let est = estimate_eigenvalues(&p, &out, &comm, 0);
        assert!(
            est.max_disagreement() > 1e-4,
            "local Rayleigh blocks should disagree, got {}",
            est.max_disagreement()
        );
    }

    #[test]
    fn comm_cost_is_k_squared() {
        let (p, topo, out) = setup();
        let comm = DenseComm::from_topology(&topo);
        let est = estimate_eigenvalues(&p, &out, &comm, 5);
        // Payload per message is k×k = 9 scalars.
        assert_eq!(
            est.comm.scalars_sent,
            est.comm.messages * 9,
            "payload should be the k×k Rayleigh block"
        );
    }

    #[test]
    fn eigenvalue_error_quadratic_in_subspace_error() {
        // Run DeEPCA to moderate precision; eigenvalue error should be
        // ~ε² (Rayleigh), i.e. much smaller than ε itself.
        let ds = synthetic::spiked_covariance(
            600,
            16,
            &[12.0, 8.0, 5.0],
            0.2,
            &mut Rng::seed_from(503),
        );
        let p = Problem::from_dataset(&ds, 6, 3);
        let topo = Topology::erdos_renyi(6, 0.6, &mut Rng::seed_from(504));
        let cfg = DeepcaConfig {
            consensus_rounds: 10,
            max_iters: 4, // moderate ε (big λ₃/λ₄ gap converges fast)
            ..Default::default()
        };
        let out = Session::on(&p, &topo)
            .algo(Algo::Deepca(cfg))
            .solve()
            .to_run_output();
        let eps = out.final_tan_theta;
        assert!(eps > 1e-8 && eps < 1e-2, "want moderate ε, got {eps:.3e}");
        let comm = DenseComm::from_topology(&topo);
        let est = estimate_eigenvalues(&p, &out, &comm, 30);
        let rel_err = (est.values()[0] - p.truth.values[0]).abs() / p.truth.values[0];
        assert!(
            rel_err < 10.0 * eps * eps + 1e-9,
            "eigenvalue rel err {rel_err:.3e} not quadratic in ε={eps:.3e}"
        );
    }
}
