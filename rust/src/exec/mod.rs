//! `exec` — the deterministic persistent worker pool.
//!
//! One [`Executor`] is the single parallelism substrate for the whole
//! stack: the power-step backends fan the per-agent Gram products over
//! it, the Dense/Sim communication engines run their FastMix row blocks
//! on it, the decentralized solvers' per-agent QR/sign-adjust loops go
//! through it (the centralized reference has a single-slice iterate and
//! stays inline), and the streaming driver refreshes per-agent
//! covariances on it. The
//! threads are long-lived — spawned once at construction and fed work
//! through a condvar-protected job slot — so the per-iteration cost of
//! parallelism is a wake/join handshake, not a thread spawn (the
//! per-call `std::thread::scope` spawns this module replaces paid that
//! cost every power iteration).
//!
//! ## Determinism contract
//!
//! Results are **bit-identical to the sequential path and invariant
//! across thread counts**. The design makes this hold by construction:
//!
//! - **Fixed partitioning by index.** Work items (agents) are split into
//!   contiguous chunks by index — never work-stealing, never
//!   order-of-completion. Which *thread* computes an item changes with
//!   the thread count; the arithmetic performed on each item does not.
//!   The cost-aware variants ([`Executor::par_weighted`],
//!   [`Executor::par_weighted_chunks_ctx`]) keep this: chunk boundaries
//!   are a pure function of a caller-supplied weight prefix sum (e.g. a
//!   CSR `row_ptr`), never of measured timing.
//! - **No cross-item reductions inside parallel regions.** Every
//!   parallel callback writes only its own items; reductions (stack
//!   means, stats accumulation, the SimNet fault stream) stay on the
//!   caller thread in their original, fixed order.
//! - **Per-worker scratch is value-irrelevant.** Workspace slots handed
//!   to chunks ([`Executor::par_chunks_ctx`]) are pure scratch whose
//!   prior contents never influence outputs.
//!
//! ## Allocation contract
//!
//! Dispatching a parallel region performs **zero heap allocation**: the
//! job is published as a type-erased borrowed closure pointer through a
//! mutex/condvar handshake (no boxing, no channel nodes), so
//! `Solver::step` stays allocation-free in steady state with the pool
//! enabled (pinned by `rust/tests/alloc_free.rs`).
//!
//! ## Blocking tier
//!
//! [`Executor::scoped_blocking`] is a second, independent tier for tasks
//! that *block on each other* (the ThreadedNetwork agent threads, which
//! park on channel `recv` mid-gossip-round). Those can deadlock on a
//! fixed-size pool, so each gets a dedicated persistent thread, created
//! on demand and reused across calls. This tier exists even on a
//! `threads = 1` executor — "sequential" refers to the data-parallel
//! tier only.
//!
//! Parallel regions must not be nested: a callback running on the pool
//! must not dispatch another parallel region on the same executor (the
//! dispatch lock is not re-entrant). Nothing in this crate nests — the
//! solver loops, the backends, and the engines each run their regions
//! one after another on the caller thread.
//!
//! ## Model checking
//!
//! Every sync primitive here is imported through [`shim`] rather than
//! `std::sync` directly; building with `--features loom` swaps in the
//! vendored model checker, and `rust/tests/loom_exec.rs` exhaustively
//! interleaves the dispatch, shutdown, and panic-propagation protocols
//! (the places where a missed wakeup or double-claim would corrupt
//! results silently rather than crash).

pub mod shim;

use std::panic::{catch_unwind, AssertUnwindSafe};

/// Flight-recorder hooks for the dispatch path. Scheduling events
/// (which worker claimed which chunk, busy/idle transitions) are
/// timing-dependent by nature, so [`crate::obs::trace`] marks their
/// kinds non-deterministic and excludes them from replay comparison.
/// Under loom the recorder's globals (std statics and thread-locals)
/// live outside the model, so every hook compiles to a no-op there.
#[cfg(not(feature = "loom"))]
mod obs_hooks {
    use crate::obs::trace::{record, EventKind};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Process-wide job sequence number (the `a` payload of
    /// [`EventKind::JobPublish`]).
    static JOB_SEQ: AtomicU64 = AtomicU64::new(0);

    #[inline]
    pub fn job_publish(chunks: usize) {
        let seq = JOB_SEQ.fetch_add(1, Ordering::Relaxed);
        record(EventKind::JobPublish, seq, chunks as u64);
    }

    #[inline]
    pub fn chunk_claim(worker: usize, chunk: usize) {
        record(EventKind::ChunkClaim, worker as u64, chunk as u64);
    }

    #[inline]
    pub fn worker_busy(worker: usize, chunk: usize) {
        record(EventKind::WorkerBusy, worker as u64, chunk as u64);
    }

    #[inline]
    pub fn worker_idle(worker: usize, chunk: usize) {
        record(EventKind::WorkerIdle, worker as u64, chunk as u64);
    }
}

#[cfg(feature = "loom")]
mod obs_hooks {
    #[inline]
    pub fn job_publish(_chunks: usize) {}
    #[inline]
    pub fn chunk_claim(_worker: usize, _chunk: usize) {}
    #[inline]
    pub fn worker_busy(_worker: usize, _chunk: usize) {}
    #[inline]
    pub fn worker_idle(_worker: usize, _chunk: usize) {}
}

use shim::sync::atomic::{AtomicBool, Ordering};
use shim::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use shim::thread::JoinHandle;

/// Lock a mutex, recovering from poisoning (workers catch panics before
/// they can leave shared state torn, so a poisoned lock is still
/// consistent).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// The default worker count: `DEEPCA_THREADS` when set to a positive
/// integer, otherwise `available_parallelism`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DEEPCA_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Contiguous index range of `chunk` when `n` items are split into
/// `nchunks` fixed ceil-sized chunks. Empty for `chunk >= nchunks`.
pub fn chunk_range(chunk: usize, n: usize, nchunks: usize) -> (usize, usize) {
    let size = n.div_ceil(nchunks);
    ((chunk * size).min(n), ((chunk + 1) * size).min(n))
}

/// Contiguous index range of `chunk` when items are split into `nchunks`
/// chunks balanced by *cumulative cost* instead of item count.
///
/// `prefix` is an exclusive prefix sum of per-item weights with
/// `prefix.len() = n + 1`, `prefix[0] = 0`, and `prefix[n]` = total
/// weight (a CSR `row_ptr` is exactly this shape, which is why the
/// gossip engines can pass theirs without building anything). Chunk `c`
/// covers the items whose weight midpoint falls in the `c`-th fraction
/// of the total: boundaries are the smallest indices where
/// `prefix[i] · nchunks ≥ c · total` (computed in u128 so huge
/// weight × chunk products cannot wrap). Like [`chunk_range`] the
/// boundaries are a pure function of `(chunk, prefix, nchunks)` — no
/// measurement, no claim order — so weighted dispatch keeps the
/// determinism contract. Trailing zero-weight items are folded into the
/// last chunk; a zero total falls back to uniform [`chunk_range`].
pub fn weighted_chunk_range(chunk: usize, nchunks: usize, prefix: &[usize]) -> (usize, usize) {
    debug_assert!(!prefix.is_empty() && prefix[0] == 0, "prefix must start at 0");
    debug_assert!(prefix.windows(2).all(|w| w[0] <= w[1]), "prefix must be non-decreasing");
    let n = prefix.len() - 1;
    let total = prefix[n] as u128;
    if total == 0 {
        return chunk_range(chunk, n, nchunks);
    }
    if chunk >= nchunks {
        return (n, n);
    }
    let bound = |c: usize| -> usize {
        if c == 0 {
            return 0;
        }
        if c >= nchunks {
            return n;
        }
        let target = c as u128 * total; // compare against prefix[i] · nchunks
        prefix.partition_point(|&p| (p as u128) * nchunks as u128 < target)
    };
    (bound(chunk), bound(chunk + 1))
}

/// Type-erased pointer to the borrowed job closure. Only dereferenced
/// between dispatch and the dispatcher's completion wait, during which
/// the dispatcher is blocked inside [`Executor::run_job`] keeping the
/// borrow alive — the same discipline as a scoped thread pool.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync + 'static),
}

// SAFETY: the pointee is Sync and outlives every dereference (see Job).
unsafe impl Send for Job {}

struct State {
    job: Option<Job>,
    /// Chunk count of the current job; chunk 0 belongs to the caller.
    chunks: usize,
    /// Next unclaimed chunk index. Workers *claim* chunks under the
    /// lock — which worker executes a chunk is arbitrary (a fast worker
    /// may claim several), but the chunk → data mapping is a pure
    /// function of the index, so results do not depend on the claim
    /// order (determinism contract). Claiming also means a dispatch
    /// wakes only as many workers as there are chunks, not the whole
    /// pool. `next_chunk == chunks` doubles as the "no job live"
    /// predicate between dispatches.
    next_chunk: usize,
    /// Chunks claimed-or-claimable by workers but not yet completed
    /// (chunks 1..chunks of the current job).
    remaining: usize,
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch.
    work: Condvar,
    /// The dispatcher waits here for `remaining == 0`.
    done: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes dispatches from different caller threads (held for the
    /// whole region, including the completion wait).
    dispatch: Mutex<()>,
}

/// Armed the instant a chunk is claimed: its `Drop` performs the
/// completion accounting (decrement `remaining`, flag panics, signal
/// `done`), so the dispatcher's completion wait terminates even if the
/// code between claim and completion unwinds. Without it, a panic on a
/// worker after claiming would strand `remaining > 0` and deadlock the
/// dispatcher on the `done` condvar forever.
struct CompletionGuard<'a> {
    shared: &'a Shared,
    panicked: bool,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock(&self.shared.state);
        if self.panicked || std::thread::panicking() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.shared.done.notify_one();
        }
    }
}

fn worker_loop(idx: usize, shared: Arc<Shared>) {
    loop {
        let (job, chunk) = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                // Claim the next chunk of the live job, if any. No
                // missed-wakeup hazard: a worker only sleeps after
                // checking this predicate under the lock, and a worker
                // between jobs re-checks it before sleeping.
                if st.next_chunk < st.chunks {
                    let Some(job) = st.job else {
                        // Defensively unreachable (dispatch publishes
                        // the job before opening the claim window, under
                        // this same lock). Close the window and report
                        // instead of panicking while holding the lock —
                        // a worker must never die with chunks claimed.
                        let unclaimed = st.chunks - st.next_chunk;
                        st.next_chunk = st.chunks;
                        st.remaining -= unclaimed.min(st.remaining);
                        st.panicked = true;
                        shared.done.notify_one();
                        continue;
                    };
                    let c = st.next_chunk;
                    st.next_chunk += 1;
                    break (job, c);
                }
                st = match shared.work.wait(st) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
        };
        obs_hooks::chunk_claim(idx, chunk);
        // Completion accounting is owed from this point on, no matter
        // how the chunk exits.
        let mut guard = CompletionGuard { shared: &*shared, panicked: false };
        // SAFETY: the dispatcher blocks until `remaining == 0`, so the
        // closure (and everything it borrows) is alive for this call.
        let f = unsafe { &*job.f };
        obs_hooks::worker_busy(idx, chunk);
        let result = catch_unwind(AssertUnwindSafe(|| f(chunk)));
        obs_hooks::worker_idle(idx, chunk);
        guard.panicked = result.is_err();
        drop(guard);
    }
}

/// One one-shot blocking task, lifetime-erased (see
/// [`Executor::scoped_blocking`] for the discipline that makes the
/// erasure sound).
type BlockingJob = Box<dyn FnOnce() + Send + 'static>;

struct BlockingWorker {
    tx: mpsc::Sender<BlockingJob>,
    handle: JoinHandle<()>,
}

impl BlockingWorker {
    fn spawn(idx: usize) -> Self {
        let (tx, rx) = mpsc::channel::<BlockingJob>();
        let handle = shim::thread::spawn_named(format!("deepca-agent-{idx}"), move || {
            // Tasks arrive pre-wrapped in catch_unwind, so the loop
            // survives panicking tasks and the thread stays reusable.
            while let Ok(job) = rx.recv() {
                job();
            }
        });
        BlockingWorker { tx, handle }
    }
}

/// Persistent worker pool. See the module docs for the determinism and
/// allocation contracts.
pub struct Executor {
    threads: usize,
    /// `None` for `threads == 1`: the sequential fallback runs every
    /// chunk inline on the caller thread.
    pool: Option<Pool>,
    /// Dedicated-thread tier for mutually-blocking tasks, grown on
    /// demand and reused across calls.
    blocking: Mutex<Vec<BlockingWorker>>,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("threads", &self.threads)
            .finish_non_exhaustive()
    }
}

impl Executor {
    /// Pool with `threads` total workers (the caller thread counts as
    /// one; `threads - 1` OS threads are spawned). `0` resolves through
    /// [`default_threads`]. `1` is the sequential fallback: no threads,
    /// every region runs inline.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 { default_threads() } else { threads };
        let pool = (threads > 1).then(|| {
            let shared = Arc::new(Shared {
                state: Mutex::new(State {
                    job: None,
                    chunks: 0,
                    next_chunk: 0,
                    remaining: 0,
                    panicked: false,
                    shutdown: false,
                }),
                work: Condvar::new(),
                done: Condvar::new(),
            });
            let handles = (1..threads)
                .map(|idx| {
                    let shared = Arc::clone(&shared);
                    shim::thread::spawn_named(format!("deepca-worker-{idx}"), move || {
                        worker_loop(idx, shared)
                    })
                })
                .collect();
            Pool { shared, handles, dispatch: Mutex::new(()) }
        });
        Executor { threads, pool, blocking: Mutex::new(Vec::new()) }
    }

    /// The sequential fallback (`threads = 1`): no worker threads, every
    /// parallel region runs inline. The blocking tier is still available.
    pub fn sequential() -> Self {
        Executor::new(1)
    }

    /// Total worker count (including the caller thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of chunks `n` items are split into: `min(threads, n)`,
    /// at least 1. Sizes per-worker scratch banks.
    pub fn chunk_count(&self, n: usize) -> usize {
        n.min(self.threads).max(1)
    }

    /// Dispatch `f(chunk)` for chunks `0..nchunks` (chunk 0 on the
    /// caller thread, the rest claimed by pool workers) and wait for
    /// completion. Panics in any chunk propagate after every claimed
    /// chunk has finished, so borrows never outlive the region.
    fn run_job(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
        let Some(pool) = &self.pool else {
            for chunk in 0..nchunks {
                f(chunk);
            }
            return;
        };
        if nchunks <= 1 {
            f(0);
            return;
        }
        let _region = lock(&pool.dispatch);
        obs_hooks::job_publish(nchunks);
        let ptr: *const (dyn Fn(usize) + Sync) = f;
        // SAFETY: lifetime erasure only; the pointer is dereferenced
        // exclusively before this function returns (completion wait
        // below), while the borrow of `f` is alive.
        let job = Job { f: unsafe { std::mem::transmute(ptr) } };
        let worker_chunks = nchunks - 1; // chunk 0 runs on this thread
        {
            let mut st = lock(&pool.shared.state);
            st.job = Some(job);
            st.chunks = nchunks;
            st.next_chunk = 1;
            st.remaining = worker_chunks;
            st.panicked = false;
            // One wakeup per worker chunk (nchunks ≤ threads, so this
            // never exceeds the pool). Lost notifications are harmless:
            // they only occur when a worker is between jobs, and such a
            // worker re-checks the claim predicate before sleeping.
            for _ in 0..worker_chunks {
                pool.shared.work.notify_one();
            }
        }
        let caller = catch_unwind(AssertUnwindSafe(|| f(0)));
        // Help-drain: claim any chunks no worker has picked up yet and
        // run them here. The chunk → data mapping is a pure function of
        // the index, so results are identical whether a worker or the
        // dispatcher executes a chunk (determinism contract); this both
        // load-balances (the dispatcher never idles while work is
        // unclaimed) and makes completion independent of worker
        // availability. Skipped if the caller chunk panicked — the
        // region is already failing, so only the claimed chunks are
        // drained before propagating.
        if caller.is_ok() {
            loop {
                let chunk = {
                    let mut st = lock(&pool.shared.state);
                    if st.next_chunk >= st.chunks {
                        break;
                    }
                    let c = st.next_chunk;
                    st.next_chunk += 1;
                    c
                };
                obs_hooks::chunk_claim(0, chunk);
                let mut guard = CompletionGuard { shared: &*pool.shared, panicked: false };
                obs_hooks::worker_busy(0, chunk);
                let result = catch_unwind(AssertUnwindSafe(|| f(chunk)));
                obs_hooks::worker_idle(0, chunk);
                guard.panicked = result.is_err();
                drop(guard);
            }
        }
        let worker_panicked = {
            let mut st = lock(&pool.shared.state);
            while st.remaining > 0 {
                st = match pool.shared.done.wait(st) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
            st.job = None;
            st.panicked
        };
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("executor worker panicked during a parallel region");
        }
    }

    /// Run `f(j, &mut items[j])` for every item, partitioned into
    /// contiguous per-worker chunks fixed by index. Each item is visited
    /// by exactly one worker; `f` must not touch other items (it only
    /// receives its own). Bit-identical to the sequential loop for any
    /// thread count.
    pub fn par_for_each_agent<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let nchunks = self.chunk_count(n);
        let base = items.as_mut_ptr() as usize;
        let run = |chunk: usize| {
            let (lo, hi) = chunk_range(chunk, n, nchunks);
            let ptr = base as *mut T;
            for j in lo..hi {
                // SAFETY: chunks are disjoint index ranges over `items`,
                // so each element gets exactly one &mut.
                f(j, unsafe { &mut *ptr.add(j) });
            }
        };
        self.run_job(nchunks, &run);
    }

    /// Chunked variant with one mutable context per chunk (per-worker
    /// scratch, e.g. a QR workspace): `f(chunk_start, chunk_items,
    /// ctx)`. `ctxs` must hold at least [`Executor::chunk_count`]`(n)`
    /// slots; scratch contents must not influence results (determinism
    /// contract).
    pub fn par_chunks_ctx<T, C, F>(&self, items: &mut [T], ctxs: &mut [C], f: F)
    where
        T: Send,
        C: Send,
        F: Fn(usize, &mut [T], &mut C) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let nchunks = self.chunk_count(n);
        assert!(
            ctxs.len() >= nchunks,
            "need one ctx per chunk: {} < {nchunks}",
            ctxs.len()
        );
        let items_base = items.as_mut_ptr() as usize;
        let ctx_base = ctxs.as_mut_ptr() as usize;
        let run = |chunk: usize| {
            let (lo, hi) = chunk_range(chunk, n, nchunks);
            if lo >= hi {
                return;
            }
            // SAFETY: chunks are disjoint index ranges of `items`, so
            // each element is inside exactly one reconstituted slice.
            let slice = unsafe {
                std::slice::from_raw_parts_mut((items_base as *mut T).add(lo), hi - lo)
            };
            // SAFETY: chunk indices < nchunks ≤ ctxs.len() are pairwise
            // distinct, so each ctx slot gets exactly one &mut.
            let ctx = unsafe { &mut *(ctx_base as *mut C).add(chunk) };
            f(lo, slice, ctx);
        };
        self.run_job(nchunks, &run);
    }

    /// Cost-aware [`Executor::par_for_each_agent`]: run
    /// `f(j, &mut items[j])` for every item with chunk boundaries
    /// balanced by per-item weight instead of item count. `prefix` is an
    /// exclusive prefix sum of the weights (`prefix.len() = items.len()
    /// + 1`, `prefix[0] = 0` — a CSR `row_ptr` qualifies verbatim), so
    /// heterogeneous shards (a hub row with 10³ neighbors next to leaf
    /// rows with 2) split into chunks of comparable *work*. Boundaries
    /// come from [`weighted_chunk_range`] — a pure function of the
    /// prefix — so results stay bit-identical to the sequential loop for
    /// any thread count.
    pub fn par_weighted<T, F>(&self, items: &mut [T], prefix: &[usize], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        assert_eq!(prefix.len(), n + 1, "need one prefix entry per item plus the total");
        let nchunks = self.chunk_count(n);
        let base = items.as_mut_ptr() as usize;
        let run = |chunk: usize| {
            let (lo, hi) = weighted_chunk_range(chunk, nchunks, prefix);
            let ptr = base as *mut T;
            for j in lo..hi {
                // SAFETY: weighted chunks are disjoint index ranges over
                // `items` (see weighted_chunk_range: the boundaries are a
                // non-decreasing function of the chunk index covering
                // 0..n exactly once), so each element gets exactly one
                // &mut.
                f(j, unsafe { &mut *ptr.add(j) });
            }
        };
        self.run_job(nchunks, &run);
    }

    /// Cost-aware [`Executor::par_chunks_ctx`]: weighted chunk
    /// boundaries (see [`Executor::par_weighted`]) plus one mutable
    /// scratch context per chunk — `f(chunk_start, chunk_items, ctx)`.
    /// `ctxs` must hold at least [`Executor::chunk_count`]`(n)` slots
    /// and scratch contents must not influence results (determinism
    /// contract).
    pub fn par_weighted_chunks_ctx<T, C, F>(
        &self,
        items: &mut [T],
        prefix: &[usize],
        ctxs: &mut [C],
        f: F,
    ) where
        T: Send,
        C: Send,
        F: Fn(usize, &mut [T], &mut C) + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        assert_eq!(prefix.len(), n + 1, "need one prefix entry per item plus the total");
        let nchunks = self.chunk_count(n);
        assert!(
            ctxs.len() >= nchunks,
            "need one ctx per chunk: {} < {nchunks}",
            ctxs.len()
        );
        let items_base = items.as_mut_ptr() as usize;
        let ctx_base = ctxs.as_mut_ptr() as usize;
        let run = |chunk: usize| {
            let (lo, hi) = weighted_chunk_range(chunk, nchunks, prefix);
            if lo >= hi {
                return;
            }
            // SAFETY: weighted chunks are disjoint index ranges of
            // `items`, so each element is inside exactly one
            // reconstituted slice.
            let slice = unsafe {
                std::slice::from_raw_parts_mut((items_base as *mut T).add(lo), hi - lo)
            };
            // SAFETY: chunk indices < nchunks ≤ ctxs.len() are pairwise
            // distinct, so each ctx slot gets exactly one &mut.
            let ctx = unsafe { &mut *(ctx_base as *mut C).add(chunk) };
            f(lo, slice, ctx);
        };
        self.run_job(nchunks, &run);
    }

    /// Run one-shot tasks that may *block on each other* (channel
    /// `recv`), each on its own dedicated persistent thread. Blocks
    /// until every task completes; a panicking task is reported (by
    /// panicking here) only after all tasks have finished, so borrowed
    /// captures never outlive the call — which is what makes handing
    /// non-`'static` closures to the long-lived threads sound.
    ///
    /// Unlike the data-parallel tier this allocates (boxed tasks,
    /// channel nodes) — its callers (the threaded network engines)
    /// allocate per message by design.
    pub fn scoped_blocking<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let count = tasks.len();
        if count == 0 {
            return;
        }
        let sync = Arc::new((Mutex::new(count), Condvar::new(), AtomicBool::new(false)));
        {
            let mut workers = lock(&self.blocking);
            while workers.len() < count {
                workers.push(BlockingWorker::spawn(workers.len()));
            }
            for (i, task) in tasks.into_iter().enumerate() {
                let sync = Arc::clone(&sync);
                let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(task));
                    let (left, done, panicked) = &*sync;
                    if result.is_err() {
                        panicked.store(true, Ordering::SeqCst);
                    }
                    let mut n = lock(left);
                    *n -= 1;
                    if *n == 0 {
                        done.notify_all();
                    }
                });
                // SAFETY: lifetime erasure only — this call blocks until
                // every task has run, so 'env borrows stay alive.
                let wrapped: BlockingJob = unsafe { std::mem::transmute(wrapped) };
                workers[i].tx.send(wrapped).expect("blocking worker alive");
            }
        }
        let (left, done, panicked) = &*sync;
        let mut n = lock(left);
        while *n > 0 {
            n = match done.wait(n) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        drop(n);
        if panicked.load(Ordering::SeqCst) {
            panic!("executor blocking task panicked");
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            {
                let mut st = lock(&pool.shared.state);
                st.shutdown = true;
                pool.shared.work.notify_all();
            }
            for h in pool.handles {
                let _ = h.join();
            }
        }
        let workers = std::mem::take(&mut *lock(&self.blocking));
        for BlockingWorker { tx, handle } in workers {
            drop(tx); // disconnect: the worker's recv loop ends
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_and_are_disjoint() {
        for n in [1usize, 2, 5, 7, 16, 100] {
            for nchunks in 1..=8usize {
                let nchunks = nchunks.min(n);
                let mut covered = vec![0u8; n];
                for c in 0..nchunks {
                    let (lo, hi) = chunk_range(c, n, nchunks);
                    for j in lo..hi {
                        covered[j] += 1;
                    }
                }
                assert!(covered.iter().all(|&c| c == 1), "n={n} chunks={nchunks}");
                // Chunks past the count are empty.
                let (lo, hi) = chunk_range(nchunks, n, nchunks);
                assert!(lo >= hi);
            }
        }
    }

    #[test]
    fn weighted_chunk_ranges_cover_and_are_disjoint() {
        // Uniform, skewed, zero-weight, and hub-dominated profiles.
        let profiles: Vec<Vec<usize>> = vec![
            vec![1; 16],
            vec![1, 1, 1, 1000, 1, 1, 1, 1],
            vec![0, 0, 5, 0, 0, 7, 0, 0],
            vec![0; 9],
            (0..33).map(|i| i * i).collect(),
            vec![1000, 1, 1, 1, 1, 1, 1, 0, 0],
        ];
        for weights in profiles {
            let n = weights.len();
            let mut prefix = vec![0usize; n + 1];
            for (i, w) in weights.iter().enumerate() {
                prefix[i + 1] = prefix[i] + w;
            }
            for nchunks in 1..=8usize {
                let mut covered = vec![0u8; n];
                let mut prev_hi = 0usize;
                for c in 0..nchunks {
                    let (lo, hi) = weighted_chunk_range(c, nchunks, &prefix);
                    assert_eq!(lo, prev_hi, "chunks must be contiguous ({weights:?})");
                    prev_hi = hi;
                    for j in lo..hi {
                        covered[j] += 1;
                    }
                }
                assert_eq!(prev_hi, n, "chunks must cover every item ({weights:?})");
                assert!(covered.iter().all(|&c| c == 1), "{weights:?} chunks={nchunks}");
                // Chunks past the count are empty.
                let (lo, hi) = weighted_chunk_range(nchunks, nchunks, &prefix);
                assert!(lo >= hi);
            }
        }
    }

    #[test]
    fn weighted_chunk_boundaries_balance_heavy_items() {
        // One hub worth half the total weight: the hub's chunk should
        // not also absorb half the remaining items.
        let weights = [100usize, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1];
        let mut prefix = vec![0usize; weights.len() + 1];
        for (i, w) in weights.iter().enumerate() {
            prefix[i + 1] = prefix[i] + w;
        }
        let (lo0, hi0) = weighted_chunk_range(0, 4, &prefix);
        assert_eq!((lo0, hi0), (0, 1), "the hub alone fills chunk 0");
        // Uniform chunking would have put items 0..3 in chunk 0.
        let (_, hi_uniform) = chunk_range(0, weights.len(), 4);
        assert_eq!(hi_uniform, 3);
    }

    #[test]
    fn weighted_chunk_range_survives_huge_weights() {
        // prefix · nchunks overflows usize on 64-bit if computed
        // natively; the u128 comparison must not wrap.
        let big = usize::MAX / 4;
        let prefix = [0usize, big, 2 * big, 3 * big, 4 * big];
        let mut prev_hi = 0;
        for c in 0..8 {
            let (lo, hi) = weighted_chunk_range(c, 8, &prefix);
            assert_eq!(lo, prev_hi);
            prev_hi = hi;
        }
        assert_eq!(prev_hi, 4);
    }

    #[test]
    fn par_weighted_matches_sequential_for_every_thread_count() {
        let weights: Vec<usize> = (0..41).map(|i| (i * 7) % 13).collect();
        let mut prefix = vec![0usize; weights.len() + 1];
        for (i, w) in weights.iter().enumerate() {
            prefix[i + 1] = prefix[i] + w;
        }
        let base: Vec<f64> = (0..41).map(|i| i as f64 * 0.25).collect();
        let mut want = base.clone();
        for (j, v) in want.iter_mut().enumerate() {
            *v = v.cos() * j as f64;
        }
        for threads in [1usize, 2, 3, 8, 16] {
            let exec = Executor::new(threads);
            let mut got = base.clone();
            exec.par_weighted(&mut got, &prefix, |j, v| *v = v.cos() * j as f64);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn par_weighted_chunks_ctx_visits_every_item_once() {
        let exec = Executor::new(4);
        let weights = [9usize, 0, 0, 1, 1, 1, 1, 1, 20, 1];
        let mut prefix = vec![0usize; weights.len() + 1];
        for (i, w) in weights.iter().enumerate() {
            prefix[i + 1] = prefix[i] + w;
        }
        let mut items = vec![0usize; weights.len()];
        let nchunks = exec.chunk_count(items.len());
        let mut ctxs: Vec<Vec<usize>> = vec![Vec::new(); nchunks];
        exec.par_weighted_chunks_ctx(&mut items, &prefix, &mut ctxs, |lo, chunk, ctx| {
            for (off, it) in chunk.iter_mut().enumerate() {
                *it = lo + off;
                ctx.push(lo + off);
            }
        });
        assert_eq!(items, (0..weights.len()).collect::<Vec<_>>());
        let mut seen: Vec<usize> = ctxs.into_iter().flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..weights.len()).collect::<Vec<_>>());
    }

    #[test]
    fn par_for_each_matches_sequential_for_every_thread_count() {
        let base: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let mut want = base.clone();
        for (j, v) in want.iter_mut().enumerate() {
            *v = v.sin() + j as f64;
        }
        for threads in [1usize, 2, 3, 8, 16] {
            let exec = Executor::new(threads);
            let mut got = base.clone();
            exec.par_for_each_agent(&mut got, |j, v| *v = v.sin() + j as f64);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_ctx_gives_each_chunk_its_own_ctx() {
        let exec = Executor::new(4);
        let mut items = vec![0usize; 10];
        let nchunks = exec.chunk_count(items.len());
        let mut ctxs: Vec<Vec<usize>> = vec![Vec::new(); nchunks];
        exec.par_chunks_ctx(&mut items, &mut ctxs, |lo, chunk, ctx| {
            for (off, it) in chunk.iter_mut().enumerate() {
                *it = lo + off;
                ctx.push(lo + off);
            }
        });
        assert_eq!(items, (0..10).collect::<Vec<_>>());
        let mut seen: Vec<usize> = ctxs.into_iter().flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_many_dispatches() {
        // Scaled down under Miri: the interpreter runs every dispatch
        // handshake ~3 orders of magnitude slower than native.
        let rounds: u64 = if cfg!(miri) { 6 } else { 50 };
        let exec = Executor::new(4);
        let mut acc = vec![0u64; 23];
        for round in 0..rounds {
            exec.par_for_each_agent(&mut acc, |j, v| *v += round + j as u64);
        }
        let want: Vec<u64> =
            (0..23u64).map(|j| (0..rounds).map(|r| r + j).sum()).collect();
        assert_eq!(acc, want);
    }

    #[test]
    fn zero_resolves_to_a_positive_default() {
        let exec = Executor::new(0);
        assert!(exec.threads() >= 1);
    }

    #[test]
    fn empty_input_is_a_noop() {
        let exec = Executor::new(4);
        let mut items: Vec<u32> = Vec::new();
        exec.par_for_each_agent(&mut items, |_, _| panic!("must not be called"));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let exec = Executor::new(4);
        let mut items = vec![0i32; 16];
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.par_for_each_agent(&mut items, |j, _| {
                if j == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the dispatcher");
        // The pool is still functional afterwards.
        exec.par_for_each_agent(&mut items, |j, v| *v = j as i32);
        assert_eq!(items[15], 15);
    }

    #[test]
    fn caller_chunk_panic_propagates_and_pool_survives() {
        // Chunk 0 runs on the dispatcher thread itself; a panic there
        // takes a different path (resume_unwind after the completion
        // wait) than a worker-chunk panic.
        let exec = Executor::new(4);
        let mut items = vec![0i32; 16];
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.par_for_each_agent(&mut items, |j, _| {
                if j == 0 {
                    panic!("caller boom");
                }
            });
        }));
        assert!(result.is_err(), "caller-chunk panic must propagate");
        exec.par_for_each_agent(&mut items, |j, v| *v = j as i32);
        assert_eq!(items[15], 15);
    }

    #[test]
    fn panic_in_every_chunk_still_propagates_once() {
        let exec = Executor::new(4);
        let mut items = vec![0i32; 16];
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.par_for_each_agent(&mut items, |j, _| panic!("chunk {j} boom"));
        }));
        assert!(result.is_err());
        exec.par_for_each_agent(&mut items, |j, v| *v = j as i32);
        assert_eq!(items, (0..16).collect::<Vec<i32>>());
    }

    #[test]
    fn repeated_panics_never_wedge_the_pool() {
        // The regression this pins: completion accounting must survive
        // arbitrarily many panicking regions (a stranded `remaining`
        // count would deadlock the *next* dispatch's completion wait).
        let rounds = if cfg!(miri) { 3 } else { 10 };
        let exec = Executor::new(3);
        let mut items = vec![0u32; 9];
        for round in 0..rounds {
            let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                exec.par_for_each_agent(&mut items, |j, _| {
                    if j % 3 == round % 3 {
                        panic!("round {round} boom");
                    }
                });
            }));
            assert!(result.is_err(), "round {round}");
        }
        exec.par_for_each_agent(&mut items, |j, v| *v = j as u32 + 1);
        assert_eq!(items, (1..=9).collect::<Vec<u32>>());
    }

    #[test]
    fn scoped_blocking_runs_mutually_blocking_tasks() {
        // A ring of tasks each waiting on its predecessor's message —
        // deadlocks unless every task has a real thread.
        let exec = Executor::sequential(); // blocking tier is independent
        let n = 6;
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let (tx, rx) = mpsc::channel::<usize>();
            txs.push(tx);
            rxs.push(rx);
        }
        let mut results = vec![0usize; n];
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, (rx, slot)) in rxs.into_iter().zip(results.iter_mut()).enumerate() {
                let next = txs[(i + 1) % n].clone();
                tasks.push(Box::new(move || {
                    next.send(i).expect("ring peer alive");
                    *slot = rx.recv().expect("ring peer alive");
                }));
            }
            exec.scoped_blocking(tasks);
        }
        for (i, &got) in results.iter().enumerate() {
            assert_eq!(got, (i + n - 1) % n, "task {i} got the wrong message");
        }
        // Second call reuses the cached threads.
        let flag = AtomicBool::new(false);
        exec.scoped_blocking(vec![Box::new(|| flag.store(true, Ordering::SeqCst))]);
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn blocking_task_panic_propagates_after_all_tasks_finish() {
        let exec = Executor::sequential();
        let finished = Arc::new(AtomicBool::new(false));
        let fin = Arc::clone(&finished);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.scoped_blocking(vec![
                Box::new(|| panic!("task boom")),
                Box::new(move || fin.store(true, Ordering::SeqCst)),
            ]);
        }));
        assert!(result.is_err());
        assert!(finished.load(Ordering::SeqCst), "sibling task must still run");
    }

    #[test]
    fn many_more_chunks_requested_than_items() {
        let exec = Executor::new(16);
        let mut items = vec![1u32, 2, 3];
        exec.par_for_each_agent(&mut items, |_, v| *v *= 2);
        assert_eq!(items, vec![2, 4, 6]);
    }
}
