//! Sync-primitive shim: `std::sync` by default, `loom::sync` under the
//! `loom` feature.
//!
//! The executor's dispatch protocol (job-slot publish → chunk claim →
//! completion signal) is exactly the kind of code where a missed wakeup
//! or double-claim corrupts results silently instead of crashing. To
//! make it model-checkable, every primitive the executor touches is
//! imported from here rather than from `std` directly. Building with
//! `--features loom` swaps in the vendored model checker's dual-mode
//! primitives (`rust/vendor/loom`): inside `loom::model` each operation
//! becomes an explorable scheduling decision, outside it they degrade
//! to plain `std` behavior, so the ordinary test suite is unaffected by
//! the feature being enabled.
//!
//! `rust/tests/loom_exec.rs` (a `required-features = ["loom"]` test
//! target) is the consumer; `scripts/verify.sh` and CI run it as the
//! blocking loom gate.

#[cfg(not(feature = "loom"))]
pub mod sync {
    pub use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};

    pub mod atomic {
        pub use std::sync::atomic::{AtomicBool, Ordering};
    }
}

#[cfg(feature = "loom")]
pub mod sync {
    pub use loom::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};

    pub mod atomic {
        pub use loom::sync::atomic::{AtomicBool, Ordering};
    }
}

pub mod thread {
    #[cfg(not(feature = "loom"))]
    pub type JoinHandle<T> = std::thread::JoinHandle<T>;
    #[cfg(feature = "loom")]
    pub type JoinHandle<T> = loom::thread::JoinHandle<T>;

    /// Spawn a named worker thread. The name is diagnostic only; the
    /// modeled path drops it (loom threads are identified by id).
    pub fn spawn_named<F, T>(name: String, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        #[cfg(not(feature = "loom"))]
        {
            std::thread::Builder::new()
                .name(name)
                .spawn(f)
                .expect("spawn executor thread")
        }
        #[cfg(feature = "loom")]
        {
            let _ = name;
            loom::thread::spawn(f)
        }
    }
}
