//! Micro/macro benchmark harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and
//! drive this module: warmup, repeated timed runs, and a median/p10/p90
//! report. Used both for the §Perf microbenchmarks and as the scaffolding
//! around the figure-regeneration benches (where the "measurement" is the
//! experiment output itself plus its wall time).

use crate::util::format;
use std::time::Instant;

/// One benchmark's measured distribution (seconds).
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Sorted per-iteration seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Percentile (0..=100) by nearest-rank.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(!self.samples.is_empty());
        let idx = ((p / 100.0) * (self.samples.len() - 1) as f64).round() as usize;
        self.samples[idx.min(self.samples.len() - 1)]
    }

    /// Median seconds.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Mean seconds.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            self.name,
            format::secs(self.median()),
            format::secs(self.percentile(10.0)),
            format::secs(self.percentile(90.0)),
            self.samples.len()
        )
    }
}

/// Benchmark runner with warmup.
pub struct Bench {
    warmup: usize,
    iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10 }
    }
}

impl Bench {
    /// Custom warmup/iteration counts.
    pub fn new(warmup: usize, iters: usize) -> Self {
        assert!(iters > 0);
        Bench { warmup, iters }
    }

    /// Time `f`, returning the measurement (and printing the report).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement { name: name.to_string(), samples };
        println!("{}", m.report());
        m
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_percentiles() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.percentile(0.0), 1.0);
        assert_eq!(m.percentile(100.0), 5.0);
        assert!((m.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0usize;
        let b = Bench::new(1, 5);
        let m = b.run("counter", || {
            count += 1;
            count
        });
        assert_eq!(count, 6); // 1 warmup + 5 timed
        assert_eq!(m.samples.len(), 5);
    }

    #[test]
    fn samples_sorted() {
        let b = Bench::new(0, 8);
        let m = b.run("noop", || 1 + 1);
        for w in m.samples.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
