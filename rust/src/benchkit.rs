//! Micro/macro benchmark harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and
//! drive this module: warmup, repeated timed runs, and a median/p10/p90
//! report. Used both for the §Perf microbenchmarks and as the scaffolding
//! around the figure-regeneration benches (where the "measurement" is the
//! experiment output itself plus its wall time).
//!
//! Results are machine-readable: every [`Measurement`] serializes with
//! [`Measurement::to_json`], and a [`Suite`] collects a bench target's
//! measurements into one JSON document (`scripts/bench.sh` writes these
//! as `BENCH_<suite>.json` at the repo root; CI uploads them as
//! artifacts so the bench trajectory is diffable across commits).

use crate::util::format;
use std::path::Path;
use std::time::Instant;

/// One benchmark's measured distribution (seconds).
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration seconds. [`Measurement::new`] sorts these; the
    /// percentile accessors do not rely on the field being pre-sorted.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Build from raw samples (sorted on construction).
    pub fn new(name: &str, mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "measurement needs at least one sample");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Measurement { name: name.to_string(), samples }
    }

    /// Percentile (0..=100) by nearest-rank. Robust to unsorted
    /// `samples` (callers may build the struct literally): already-sorted
    /// data (everything [`Measurement::new`] built) is indexed directly;
    /// only unsorted literals pay for a sorted copy.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(!self.samples.is_empty());
        let idx =
            (((p / 100.0) * (self.samples.len() - 1) as f64).round() as usize).min(self.samples.len() - 1);
        if self.samples.windows(2).all(|w| w[0] <= w[1]) {
            return self.samples[idx];
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[idx]
    }

    /// Median seconds.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Mean seconds.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            self.name,
            format::secs(self.median()),
            format::secs(self.percentile(10.0)),
            format::secs(self.percentile(90.0)),
            self.samples.len()
        )
    }

    /// JSON object with the summary statistics and raw samples.
    pub fn to_json(&self) -> String {
        let samples: Vec<String> = self.samples.iter().map(|s| format!("{s}")).collect();
        format!(
            "{{\"name\":\"{}\",\"n\":{},\"mean\":{},\"median\":{},\"p10\":{},\"p90\":{},\"samples\":[{}]}}",
            json_escape(&self.name),
            self.samples.len(),
            self.mean(),
            self.median(),
            self.percentile(10.0),
            self.percentile(90.0),
            samples.join(",")
        )
    }
}

/// Minimal string escaping for the JSON emitters (labels are
/// code-controlled; quotes/backslashes/control chars only).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A named collection of measurements — one per bench target — with a
/// single JSON document for the whole run.
#[derive(Clone, Debug, Default)]
pub struct Suite {
    /// Suite label (becomes the `BENCH_<name>.json` stem).
    pub name: String,
    /// Run-level metadata (e.g. the selected SIMD kernel), emitted as a
    /// `"meta"` object in the JSON document. Insertion-ordered; later
    /// writes to the same key win at read time (JSON object semantics),
    /// so callers should set each key once.
    pub meta: Vec<(String, String)>,
    /// Collected measurements, in run order.
    pub measurements: Vec<Measurement>,
}

impl Suite {
    /// Empty suite.
    pub fn new(name: &str) -> Self {
        Suite { name: name.to_string(), meta: Vec::new(), measurements: Vec::new() }
    }

    /// Record one metadata key (stringly-typed by design: the consumers
    /// are `scripts/bench_diff` and human eyes on CI artifacts).
    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Add one measurement.
    pub fn push(&mut self, m: Measurement) {
        self.measurements.push(m);
    }

    /// The whole suite as one JSON document. The `"meta"` object is
    /// omitted when empty so pre-metadata suites serialize unchanged.
    pub fn to_json(&self) -> String {
        let results: Vec<String> = self.measurements.iter().map(|m| m.to_json()).collect();
        let meta = if self.meta.is_empty() {
            String::new()
        } else {
            let pairs: Vec<String> = self
                .meta
                .iter()
                .map(|(k, v)| format!("\"{}\":\"{}\"", json_escape(k), json_escape(v)))
                .collect();
            format!("\"meta\":{{{}}},", pairs.join(","))
        };
        format!(
            "{{\"suite\":\"{}\",{}\"results\":[{}]}}\n",
            json_escape(&self.name),
            meta,
            results.join(",")
        )
    }

    /// Write the JSON document to `path` (conventionally
    /// `BENCH_<suite>.json` at the repo root).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Benchmark runner with warmup.
pub struct Bench {
    warmup: usize,
    iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10 }
    }
}

impl Bench {
    /// Custom warmup/iteration counts.
    pub fn new(warmup: usize, iters: usize) -> Self {
        assert!(iters > 0);
        Bench { warmup, iters }
    }

    /// Time `f`, returning the measurement (and printing the report).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement::new(name, samples);
        println!("{}", m.report());
        m
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_percentiles() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.percentile(0.0), 1.0);
        assert_eq!(m.percentile(100.0), 5.0);
        assert!((m.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_survive_unsorted_samples() {
        // Regression: callers building the struct literally used to have
        // to pre-sort `samples` or silently get wrong percentiles.
        let m = Measurement {
            name: "unsorted".into(),
            samples: vec![5.0, 1.0, 4.0, 2.0, 3.0],
        };
        assert_eq!(m.median(), 3.0);
        assert_eq!(m.percentile(0.0), 1.0);
        assert_eq!(m.percentile(100.0), 5.0);
        // And the sorting constructor normalizes the field itself.
        let n = Measurement::new("sorted", vec![5.0, 1.0, 4.0, 2.0, 3.0]);
        assert_eq!(n.samples, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0usize;
        let b = Bench::new(1, 5);
        let m = b.run("counter", || {
            count += 1;
            count
        });
        assert_eq!(count, 6); // 1 warmup + 5 timed
        assert_eq!(m.samples.len(), 5);
    }

    #[test]
    fn samples_sorted() {
        let b = Bench::new(0, 8);
        let m = b.run("noop", || 1 + 1);
        for w in m.samples.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn json_roundtrip_shape() {
        let mut suite = Suite::new("unit");
        suite.push(Measurement::new("a \"quoted\" name", vec![2.0, 1.0, 3.0]));
        suite.push(Measurement::new("b", vec![0.5]));
        let json = suite.to_json();
        assert!(json.starts_with("{\"suite\":\"unit\""));
        assert!(json.contains("\\\"quoted\\\""));
        assert!(json.contains("\"median\":2"));
        assert!(json.contains("\"n\":3"));
        // Balanced braces/brackets (cheap well-formedness check).
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn suite_meta_is_emitted_and_omitted_when_empty() {
        let mut suite = Suite::new("m");
        suite.push(Measurement::new("x", vec![1.0]));
        assert!(
            !suite.to_json().contains("\"meta\""),
            "empty meta must serialize exactly like a pre-metadata suite"
        );
        suite.meta("simd_kernel", "avx2");
        suite.meta("odd \"key\"", "v");
        let json = suite.to_json();
        assert!(json.contains("\"meta\":{\"simd_kernel\":\"avx2\",\"odd \\\"key\\\"\":\"v\"}"));
        assert!(json.starts_with("{\"suite\":\"m\",\"meta\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn suite_write_json() {
        let mut suite = Suite::new("disk");
        suite.push(Measurement::new("x", vec![1.0, 2.0]));
        let path = std::env::temp_dir().join("deepca_bench_unit.json");
        suite.write_json(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, suite.to_json());
        let _ = std::fs::remove_file(&path);
    }
}
