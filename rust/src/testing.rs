//! Minimal property-testing harness (offline stand-in for `proptest`).
//!
//! A property is a closure from generated input to `Result<(), String>`.
//! The harness runs `cases` seeded cases; on the first failure it retries
//! the case a bounded number of times with "smaller" inputs if the
//! generator supports sizing (shrink-lite), then panics with the seed and
//! a `Debug` dump of the failing input so the case can be replayed
//! exactly (`Rng::seed_from(reported_seed)`).

use crate::util::rng::Rng;
use std::fmt::Debug;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of generated cases.
    pub cases: usize,
    /// Master seed; case i uses `seed_from(seed + i)`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xDEE9CA }
    }
}

/// Run a property over generated inputs; panics on the first failure.
pub fn check<T: Debug>(
    name: &str,
    cfg: PropConfig,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::seed_from(case_seed);
        let input = generate(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property `{name}` failed on case {case} (seed {case_seed}):\n  {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Generator helpers for the common shapes in this crate.
pub mod gen {
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    /// Dimension in [lo, hi].
    pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi + 1)
    }

    /// Random Gaussian matrix with rows in [rlo,rhi], cols in [clo,chi],
    /// cols ≤ rows enforced.
    pub fn tall_mat(rng: &mut Rng, rlo: usize, rhi: usize, clo: usize, chi: usize) -> Mat {
        let r = dim(rng, rlo, rhi);
        let c = dim(rng, clo, chi.min(r));
        Mat::randn(r, c, rng)
    }

    /// Random symmetric PSD matrix of size in [lo, hi].
    pub fn psd(rng: &mut Rng, lo: usize, hi: usize) -> Mat {
        let n = dim(rng, lo, hi);
        let g = Mat::randn(n + 2, n, rng);
        let mut a = g.t_matmul(&g);
        a.symmetrize();
        a
    }

    /// Random orthonormal d×k.
    pub fn orthonormal(rng: &mut Rng, d: usize, k: usize) -> Mat {
        Mat::rand_orthonormal(d, k, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            PropConfig { cases: 32, seed: 1 },
            |rng| (rng.below(100) as i64, rng.below(100) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports() {
        check(
            "always-fails",
            PropConfig { cases: 4, seed: 2 },
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        let mut first: Vec<usize> = Vec::new();
        check(
            "collect",
            PropConfig { cases: 8, seed: 3 },
            |rng| rng.below(1000),
            |&x| {
                first.push(x);
                Ok(())
            },
        );
        let mut second: Vec<usize> = Vec::new();
        check(
            "collect2",
            PropConfig { cases: 8, seed: 3 },
            |rng| rng.below(1000),
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::util::rng::Rng::seed_from(4);
        for _ in 0..50 {
            let m = gen::tall_mat(&mut rng, 3, 10, 1, 5);
            assert!(m.rows() >= m.cols());
            assert!(m.rows() >= 3 && m.rows() <= 10);
            let p = gen::psd(&mut rng, 2, 6);
            assert_eq!(p.rows(), p.cols());
        }
    }
}
