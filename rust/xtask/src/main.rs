//! `cargo xtask <command>` — repo tooling entry point.
//!
//! Commands:
//! - `lint [root]`: run the invariant lint over `rust/src` (see
//!   `xtask::lint_file` for the rules). Exits non-zero on findings;
//!   blocking in `scripts/verify.sh` and CI.

use std::path::PathBuf;
use std::process::ExitCode;

fn repo_root() -> PathBuf {
    // xtask lives at <root>/rust/xtask.
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..")
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let root = args
                .get(1)
                .map(PathBuf::from)
                .unwrap_or_else(repo_root);
            match xtask::lint_tree(&root) {
                Ok(report) => {
                    for f in &report.findings {
                        println!("{f}");
                    }
                    if report.findings.is_empty() {
                        println!(
                            "xtask lint: clean ({} files under rust/src)",
                            report.files_scanned
                        );
                        ExitCode::SUCCESS
                    } else {
                        eprintln!(
                            "xtask lint: {} finding(s) across {} files",
                            report.findings.len(),
                            report.files_scanned
                        );
                        ExitCode::FAILURE
                    }
                }
                Err(e) => {
                    eprintln!("xtask lint: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        Some(cmd) => {
            eprintln!("xtask: unknown command {cmd:?} (available: lint)");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint [repo-root]");
            ExitCode::FAILURE
        }
    }
}
