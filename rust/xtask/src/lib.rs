//! Source-level invariant lint for the deepca repo.
//!
//! The crate's headline contracts — zero steady-state allocation in
//! `Solver::step`, bit-identical results across thread counts — are
//! pinned dynamically by `alloc_free.rs` and `thread_determinism.rs`.
//! This lint enforces the *source patterns* behind those contracts, so
//! violations are caught at review time with a file:line, not as a
//! counter regression two layers away:
//!
//! 1. **`alloc`** — no allocating kernel calls (`.matmul(`, `qr::qr(`,
//!    `vec![`, `.clone()`, `Mat::zeros(`, …) inside the registered
//!    hot-path regions (all four `Solver::step` impls, the FastMix
//!    recursion and its engine callers, exec dispatch) unless the line
//!    carries `// lint: allow(alloc, <reason>)`.
//! 2. **`hash-iter`** — no iteration over `HashMap`/`HashSet` anywhere
//!    in result-producing code: iteration order is nondeterministic
//!    across runs and would silently break the bit-identity contract.
//!    (Keyed lookup and membership tests are fine.)
//! 3. **`thread-spawn`** — no `thread::spawn`/`thread::scope`/
//!    `thread::Builder` outside `exec/`: the executor is the single
//!    parallelism substrate, and ad-hoc threads bypass its determinism
//!    and reuse discipline.
//! 4. **`timing`** — no `Instant::now`/`SystemTime` outside
//!    `util/timer.rs` and `benchkit.rs`, so wall-clock reads stay
//!    behind one auditable seam.
//! 5. **`safety`** — every `unsafe` token is immediately preceded by
//!    (or carries) a `// SAFETY:` comment.
//!
//! The hot-region table is *closed over the repo*: if a registered
//! region stops matching (file renamed, fn renamed, impl moved), the
//! lint fails with `region-missing` rather than silently linting
//! nothing — table rot is itself a lint error.
//!
//! Deliberately line-based (comment- and string-stripped, brace-depth
//! tracked) rather than AST-based: the repo vendors no parser crates,
//! and every enforced pattern is lexically recognizable. The trade-off
//! is that the lint is advisory-grade precise, not compiler-grade; the
//! fixtures under `tests/fixtures/` pin its behavior on both sides.

use std::path::{Path, PathBuf};

/// Which invariant a finding violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Allocating call inside a registered hot region.
    HotAlloc,
    /// HashMap/HashSet iteration (nondeterministic order).
    HashIter,
    /// Thread primitives outside `exec/`.
    ThreadSpawn,
    /// Wall-clock reads outside the timing seam.
    Timing,
    /// `unsafe` without an immediately-preceding `// SAFETY:` comment.
    Safety,
    /// `core::arch` / CPU feature detection outside `linalg/simd.rs`.
    ArchScope,
    /// A registered hot region no longer matches any source.
    RegionMissing,
    /// Malformed `// lint: allow(...)` annotation.
    AllowSyntax,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HotAlloc => "alloc",
            Rule::HashIter => "hash-iter",
            Rule::ThreadSpawn => "thread-spawn",
            Rule::Timing => "timing",
            Rule::Safety => "safety",
            Rule::ArchScope => "arch",
            Rule::RegionMissing => "region-missing",
            Rule::AllowSyntax => "allow-syntax",
        }
    }
}

/// One lint violation, formatted as `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.name(),
            self.message
        )
    }
}

/// A registered hot-path region: the body of `fn_name` in any file
/// whose repo-relative path ends with `file_suffix`, optionally
/// restricted to the `impl` block whose header contains `impl_context`.
#[derive(Debug, Clone)]
pub struct Region {
    pub file_suffix: &'static str,
    pub impl_context: Option<&'static str>,
    pub fn_name: &'static str,
}

/// The repo's hot-region table. Every entry must match exactly one fn
/// in the tree (checked by `lint_tree`); see module docs for why
/// table rot is an error.
pub fn repo_regions() -> Vec<Region> {
    vec![
        Region {
            file_suffix: "algo/deepca.rs",
            impl_context: Some("Solver for DeepcaSolver"),
            fn_name: "step",
        },
        Region {
            file_suffix: "algo/depca.rs",
            impl_context: Some("Solver for DepcaSolver"),
            fn_name: "step",
        },
        Region {
            file_suffix: "algo/local_power.rs",
            impl_context: Some("Solver for LocalPowerSolver"),
            fn_name: "step",
        },
        Region {
            file_suffix: "algo/centralized.rs",
            impl_context: Some("Solver for CentralizedSolver"),
            fn_name: "step",
        },
        Region {
            file_suffix: "consensus/fastmix.rs",
            impl_context: None,
            fn_name: "chebyshev_row_update",
        },
        Region {
            file_suffix: "consensus/fastmix.rs",
            impl_context: None,
            fn_name: "chebyshev_row_update_sparse",
        },
        Region { file_suffix: "consensus/fastmix.rs", impl_context: None, fn_name: "mix" },
        Region {
            file_suffix: "graph/sparse.rs",
            impl_context: None,
            fn_name: "rebuild_metropolis",
        },
        Region {
            file_suffix: "graph/sparse.rs",
            impl_context: None,
            fn_name: "estimate_spectrum",
        },
        Region {
            file_suffix: "graph/dynamic.rs",
            impl_context: Some("MarkovChurn"),
            fn_name: "advance_one",
        },
        Region {
            file_suffix: "consensus/simnet.rs",
            impl_context: Some("Communicator for SimNet"),
            fn_name: "fastmix",
        },
        Region {
            file_suffix: "consensus/comm.rs",
            impl_context: Some("Communicator for DenseComm"),
            fn_name: "fastmix",
        },
        Region {
            file_suffix: "consensus/comm.rs",
            impl_context: Some("Communicator for SparseComm"),
            fn_name: "fastmix",
        },
        Region { file_suffix: "exec/mod.rs", impl_context: None, fn_name: "run_job" },
        Region {
            file_suffix: "exec/mod.rs",
            impl_context: None,
            fn_name: "par_for_each_agent",
        },
        Region { file_suffix: "exec/mod.rs", impl_context: None, fn_name: "par_chunks_ctx" },
        Region {
            file_suffix: "obs/trace.rs",
            impl_context: Some("Recorder"),
            fn_name: "push",
        },
        Region { file_suffix: "obs/trace.rs", impl_context: None, fn_name: "record" },
        Region { file_suffix: "obs/metrics.rs", impl_context: None, fn_name: "bump" },
        // Fault-plan SimNet: the per-round schedule build runs on the
        // caller thread between parallel regions — an allocation there
        // is paid every faulty round.
        Region {
            file_suffix: "consensus/simnet.rs",
            impl_context: Some("FaultPlan"),
            fn_name: "build",
        },
        // Cost-aware dispatch: boundary computation + chunk fan-out sit
        // on every pooled batch.
        Region { file_suffix: "exec/mod.rs", impl_context: None, fn_name: "par_weighted" },
        Region {
            file_suffix: "exec/mod.rs",
            impl_context: None,
            fn_name: "par_weighted_chunks_ctx",
        },
        // Packed-B matmul driver and the tiled Gram transpose product
        // (CovTracker / wide power steps run through these).
        Region {
            file_suffix: "linalg/matrix.rs",
            impl_context: None,
            fn_name: "matmul_packed_with",
        },
        Region {
            file_suffix: "linalg/matrix.rs",
            impl_context: None,
            fn_name: "t_matmul_blocked_into",
        },
        // SIMD dispatch seams: every solver-iteration flop funnels
        // through these, so an allocation here is paid per panel /
        // per row update.
        Region {
            file_suffix: "linalg/simd.rs",
            impl_context: Some("KernelDispatch"),
            fn_name: "matmul_panel_block",
        },
        Region {
            file_suffix: "linalg/simd.rs",
            impl_context: Some("KernelDispatch"),
            fn_name: "matmul_panel_packed",
        },
        Region {
            file_suffix: "linalg/simd.rs",
            impl_context: Some("KernelDispatch"),
            fn_name: "pack_panel",
        },
        Region {
            file_suffix: "linalg/simd.rs",
            impl_context: Some("KernelDispatch"),
            fn_name: "axpy",
        },
    ]
}

/// Call patterns that allocate (directly or via an allocating kernel)
/// and are therefore banned inside hot regions. Substring matches over
/// comment- and string-stripped code; the `_into` kernels do not match
/// their allocating counterparts (`matmul_into(` contains no `.matmul(`).
const ALLOC_PATTERNS: &[&str] = &[
    ".matmul(",
    ".t_matmul(",
    "qr::qr(",
    "thin_qr(",
    "thin_qr_with(",
    "orth(",
    "vec![",
    "Vec::new(",
    "Vec::with_capacity(",
    ".to_vec()",
    ".collect()",
    ".clone()",
    "Mat::zeros(",
    "Mat::from_vec(",
    "Mat::from_fn(",
    "Mat::randn(",
    "AgentStack::new(",
    "AgentStack::replicate(",
    "Box::new(",
    "format!(",
    ".to_string()",
    "String::new(",
];

const HASH_ITER_METHODS: &[&str] = &[".iter()", ".keys()", ".values()", ".drain(", ".into_iter()"];

const THREAD_PATTERNS: &[&str] = &["thread::spawn(", "thread::scope(", "thread::Builder"];

const TIMING_PATTERNS: &[&str] = &["Instant::now(", "SystemTime"];

/// Vendor-intrinsic and CPU-feature-detection surface. Confined to
/// `linalg/simd.rs` so exactly one file owns unsafe lane code and the
/// kernel-selection purity contract; everything else must go through
/// `KernelDispatch`.
const ARCH_PATTERNS: &[&str] = &[
    "core::arch",
    "std::arch",
    "is_x86_feature_detected",
    "is_aarch64_feature_detected",
    "target_feature(",
];

const KNOWN_ALLOW_RULES: &[&str] = &["alloc", "hash-iter", "thread-spawn", "timing", "arch"];

/// One source line after lexical preprocessing.
struct Line {
    /// The line with comments removed and string/char literal contents
    /// blanked — what the pattern rules scan.
    code: String,
    /// The comment text (if any) — where annotations live.
    comment: String,
    /// True when `code` is all whitespace (comment-only or blank line).
    comment_only: bool,
    /// Inside a `#[cfg(test)] mod` block.
    in_test_mod: bool,
}

/// Strip comments and blank out string/char literals, line by line,
/// carrying block-comment state across lines. Rust raw strings are
/// handled for the common `r"…"`/`r#"…"#` forms.
fn preprocess(src: &str) -> Vec<Line> {
    let mut lines = Vec::new();
    let mut in_block_comment = false;
    for raw in src.lines() {
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let bytes: Vec<char> = raw.chars().collect();
        let mut i = 0;
        while i < bytes.len() {
            if in_block_comment {
                if bytes[i] == '*' && i + 1 < bytes.len() && bytes[i + 1] == '/' {
                    in_block_comment = false;
                    i += 2;
                } else {
                    comment.push(bytes[i]);
                    i += 1;
                }
                continue;
            }
            match bytes[i] {
                '/' if i + 1 < bytes.len() && bytes[i + 1] == '/' => {
                    comment.extend(bytes[i..].iter().copied());
                    break;
                }
                '/' if i + 1 < bytes.len() && bytes[i + 1] == '*' => {
                    in_block_comment = true;
                    i += 2;
                }
                '"' => {
                    // Blank the string literal body.
                    code.push('"');
                    i += 1;
                    while i < bytes.len() {
                        if bytes[i] == '\\' && i + 1 < bytes.len() {
                            code.push(' ');
                            code.push(' ');
                            i += 2;
                        } else if bytes[i] == '"' {
                            code.push('"');
                            i += 1;
                            break;
                        } else {
                            code.push(' ');
                            i += 1;
                        }
                    }
                }
                'r' if i + 1 < bytes.len() && (bytes[i + 1] == '"' || bytes[i + 1] == '#') => {
                    // Raw string r"…" or r#"…"#: blank to the matching
                    // terminator.
                    let mut hashes = 0;
                    let mut j = i + 1;
                    while j < bytes.len() && bytes[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < bytes.len() && bytes[j] == '"' {
                        code.push('r');
                        for _ in 0..hashes {
                            code.push('#');
                        }
                        code.push('"');
                        j += 1;
                        'raw: while j < bytes.len() {
                            if bytes[j] == '"' {
                                let mut k = 0;
                                while k < hashes
                                    && j + 1 + k < bytes.len()
                                    && bytes[j + 1 + k] == '#'
                                {
                                    k += 1;
                                }
                                if k == hashes {
                                    code.push('"');
                                    for _ in 0..hashes {
                                        code.push('#');
                                    }
                                    j += 1 + hashes;
                                    break 'raw;
                                }
                            }
                            code.push(' ');
                            j += 1;
                        }
                        i = j;
                    } else {
                        code.push(bytes[i]);
                        i += 1;
                    }
                }
                '\'' => {
                    // Char literal or lifetime. Treat 'x' / '\n' as a
                    // literal; anything else (lifetime) passes through.
                    if i + 2 < bytes.len() && bytes[i + 1] != '\\' && bytes[i + 2] == '\'' {
                        code.push_str("' '");
                        i += 3;
                    } else if i + 3 < bytes.len() && bytes[i + 1] == '\\' && bytes[i + 3] == '\'' {
                        code.push_str("'  '");
                        i += 4;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                }
                c => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        let comment_only = code.trim().is_empty();
        lines.push(Line { code, comment, comment_only, in_test_mod: false });
    }
    mark_test_mods(&mut lines);
    lines
}

/// Mark the body lines of every `#[cfg(test)] mod …` block.
fn mark_test_mods(lines: &mut [Line]) {
    let n = lines.len();
    let mut i = 0;
    while i < n {
        if lines[i].code.trim().starts_with("#[cfg(test)]") {
            // The mod header is on one of the next few lines (other
            // attributes may sit in between).
            let mut j = i + 1;
            while j < n
                && (lines[j].comment_only || lines[j].code.trim().starts_with("#["))
            {
                j += 1;
            }
            if j < n && lines[j].code.trim_start().starts_with("mod ") {
                if let Some(end) = brace_span_end(lines, j) {
                    for line in lines.iter_mut().take(end + 1).skip(i) {
                        line.in_test_mod = true;
                    }
                    i = end + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
}

/// Index of the line closing the brace block that opens at or after
/// `start` (inclusive), by depth counting over stripped code.
fn brace_span_end(lines: &[Line], start: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut opened = false;
    for (idx, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some(idx);
        }
    }
    None
}

/// Parse `lint: allow(rule, reason)` out of a comment. Returns
/// `Some((rule, reason))` when the marker is present (reason may be
/// empty — the caller validates it).
fn parse_allow(comment: &str) -> Option<(String, String)> {
    let at = comment.find("lint: allow(")?;
    let rest = &comment[at + "lint: allow(".len()..];
    let close = rest.rfind(')')?;
    let inside = &rest[..close];
    match inside.split_once(',') {
        Some((rule, reason)) => Some((rule.trim().to_string(), reason.trim().to_string())),
        None => Some((inside.trim().to_string(), String::new())),
    }
}

/// Is `rule` allowed at `idx`? An annotation counts when it sits on the
/// same line or on the comment line(s) immediately above.
fn allowed(lines: &[Line], idx: usize, rule: &str) -> bool {
    if let Some((r, reason)) = parse_allow(&lines[idx].comment) {
        if r == rule && !reason.is_empty() {
            return true;
        }
    }
    let mut j = idx;
    while j > 0 && lines[j - 1].comment_only {
        j -= 1;
        if let Some((r, reason)) = parse_allow(&lines[j].comment) {
            return r == rule && !reason.is_empty();
        }
    }
    false
}

fn is_exec_file(label: &str) -> bool {
    label.contains("/exec/") || label.ends_with("exec/mod.rs")
}

fn is_timing_seam(label: &str) -> bool {
    label.ends_with("util/timer.rs") || label.ends_with("benchkit.rs")
}

fn is_simd_seam(label: &str) -> bool {
    label.ends_with("linalg/simd.rs")
}

/// Identifier character test for pattern-boundary checks.
fn ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Find `fn <name>` (followed by `(` or `<`) on a stripped code line.
fn is_fn_decl(code: &str, name: &str) -> bool {
    let mut search = 0;
    while let Some(pos) = code[search..].find("fn ") {
        let at = search + pos;
        // `fn` must be its own token (not e.g. `extern "C" fnx`).
        let before_ok = at == 0 || !ident_char(code[..at].chars().next_back().unwrap_or(' '));
        let after = &code[at + 3..];
        let after = after.trim_start();
        if before_ok && after.starts_with(name) {
            let rest = &after[name.len()..];
            if rest.starts_with('(') || rest.starts_with('<') {
                return true;
            }
        }
        search = at + 3;
    }
    false
}

/// Locate a region's body span `(first_line, last_line)` in this file.
fn find_region_span(lines: &[Line], region: &Region) -> Option<(usize, usize)> {
    for (idx, line) in lines.iter().enumerate() {
        if line.in_test_mod || !is_fn_decl(&line.code, region.fn_name) {
            continue;
        }
        if let Some(ctx) = region.impl_context {
            // The nearest `impl` header above must mention the context.
            let mut found = false;
            for prev in lines[..idx].iter().rev() {
                let t = prev.code.trim_start();
                if t.starts_with("impl ") || t.starts_with("impl<") {
                    found = prev.code.contains(ctx);
                    break;
                }
            }
            if !found {
                continue;
            }
        }
        let end = brace_span_end(lines, idx)?;
        return Some((idx, end));
    }
    None
}

/// All single-file rules. `path_label` is the repo-relative path (used
/// for the exec/, timer, and region-table scoping); `regions` is the
/// hot-region table to apply (pass `repo_regions()` for the real tree).
pub fn lint_file(path_label: &str, src: &str, regions: &[Region]) -> Vec<Finding> {
    let lines = preprocess(src);
    let mut findings = Vec::new();
    let finding = |line: usize, rule: Rule, message: String| Finding {
        file: path_label.to_string(),
        line: line + 1,
        rule,
        message,
    };

    // Annotation syntax: every `lint: allow` marker must name a known
    // rule and carry a reason.
    for (idx, line) in lines.iter().enumerate() {
        if let Some((rule, reason)) = parse_allow(&line.comment) {
            if !KNOWN_ALLOW_RULES.contains(&rule.as_str()) {
                findings.push(finding(
                    idx,
                    Rule::AllowSyntax,
                    format!(
                        "unknown lint rule {rule:?} in allow annotation \
                         (known: {KNOWN_ALLOW_RULES:?})"
                    ),
                ));
            } else if reason.is_empty() {
                findings.push(finding(
                    idx,
                    Rule::AllowSyntax,
                    format!("allow({rule}) annotation without a reason — write \
                             `// lint: allow({rule}, <why this is sound>)`"),
                ));
            }
        }
    }

    // Rule: SAFETY comments. Applies everywhere, including test mods —
    // unsafe is unsafe no matter where it lives.
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        let mut search = 0;
        let mut hit = false;
        while let Some(pos) = code[search..].find("unsafe") {
            let at = search + pos;
            let before_ok =
                at == 0 || !ident_char(code[..at].chars().next_back().unwrap_or(' '));
            let after = code[at + "unsafe".len()..].chars().next().unwrap_or(' ');
            if before_ok && !ident_char(after) {
                hit = true;
                break;
            }
            search = at + "unsafe".len();
        }
        if !hit {
            continue;
        }
        let same_line = line.comment.contains("SAFETY:");
        let mut above = false;
        let mut j = idx;
        while j > 0 && lines[j - 1].comment_only {
            j -= 1;
            if lines[j].comment.contains("SAFETY:") {
                above = true;
                break;
            }
        }
        if !(same_line || above) {
            findings.push(finding(
                idx,
                Rule::Safety,
                "`unsafe` without an immediately-preceding `// SAFETY:` comment \
                 stating why the invariants hold"
                    .to_string(),
            ));
        }
    }

    // Rule: thread primitives outside exec/.
    if !is_exec_file(path_label) {
        for (idx, line) in lines.iter().enumerate() {
            if line.in_test_mod {
                continue;
            }
            for pat in THREAD_PATTERNS {
                if line.code.contains(pat) && !allowed(&lines, idx, "thread-spawn") {
                    findings.push(finding(
                        idx,
                        Rule::ThreadSpawn,
                        format!(
                            "`{pat}` outside exec/ — all parallelism must go through \
                             the Executor (determinism + reuse contracts)"
                        ),
                    ));
                }
            }
        }
    }

    // Rule: vendor intrinsics / feature detection outside the SIMD seam.
    if !is_simd_seam(path_label) {
        for (idx, line) in lines.iter().enumerate() {
            if line.in_test_mod {
                continue;
            }
            for pat in ARCH_PATTERNS {
                if line.code.contains(pat) && !allowed(&lines, idx, "arch") {
                    findings.push(finding(
                        idx,
                        Rule::ArchScope,
                        format!(
                            "`{pat}` outside linalg/simd.rs — all vendor intrinsics \
                             and CPU feature detection must live behind \
                             KernelDispatch (kernel-selection purity contract)"
                        ),
                    ));
                }
            }
        }
    }

    // Rule: wall-clock reads outside the timing seam.
    if !is_timing_seam(path_label) {
        for (idx, line) in lines.iter().enumerate() {
            if line.in_test_mod {
                continue;
            }
            for pat in TIMING_PATTERNS {
                if line.code.contains(pat) && !allowed(&lines, idx, "timing") {
                    findings.push(finding(
                        idx,
                        Rule::Timing,
                        format!(
                            "`{pat}` outside util/timer.rs and benchkit — route \
                             wall-clock reads through util::timer"
                        ),
                    ));
                }
            }
        }
    }

    // Rule: HashMap/HashSet iteration. Track locals bound to hash
    // collections, then flag order-dependent consumption of them.
    {
        let mut hash_vars: Vec<String> = Vec::new();
        for line in &lines {
            let code = line.code.trim_start();
            if let Some(rest) = code.strip_prefix("let ") {
                let rest = rest.trim_start_matches("mut ").trim_start();
                let name: String = rest.chars().take_while(|&c| ident_char(c)).collect();
                if !name.is_empty()
                    && (code.contains("HashMap") || code.contains("HashSet"))
                    && !hash_vars.contains(&name)
                {
                    hash_vars.push(name);
                }
            }
        }
        for (idx, line) in lines.iter().enumerate() {
            if line.in_test_mod {
                continue;
            }
            for var in &hash_vars {
                let direct = HASH_ITER_METHODS
                    .iter()
                    .any(|m| line.code.contains(&format!("{var}{m}")));
                let for_loop = [
                    format!(" in {var} "),
                    format!(" in {var} {{"),
                    format!(" in &{var} "),
                    format!(" in &{var} {{"),
                    format!(" in &mut {var} "),
                    format!(" in &mut {var} {{"),
                ]
                .iter()
                .any(|p| line.code.contains(p.as_str()))
                    && line.code.contains("for ");
                if (direct || for_loop) && !allowed(&lines, idx, "hash-iter") {
                    findings.push(finding(
                        idx,
                        Rule::HashIter,
                        format!(
                            "iteration over hash collection `{var}` — order is \
                             nondeterministic and breaks the bit-identity contract \
                             (use a sorted Vec or index by key)"
                        ),
                    ));
                }
            }
        }
    }

    // Rule: allocations inside hot regions.
    for region in regions {
        if !path_label.ends_with(region.file_suffix) {
            continue;
        }
        let Some((start, end)) = find_region_span(&lines, region) else {
            findings.push(finding(
                0,
                Rule::RegionMissing,
                format!(
                    "registered hot region `fn {}`{} not found in this file — \
                     update the region table in rust/xtask/src/lib.rs",
                    region.fn_name,
                    region
                        .impl_context
                        .map(|c| format!(" (impl context {c:?})"))
                        .unwrap_or_default(),
                ),
            ));
            continue;
        };
        for idx in start..=end {
            let line = &lines[idx];
            for pat in ALLOC_PATTERNS {
                if line.code.contains(pat) && !allowed(&lines, idx, "alloc") {
                    findings.push(finding(
                        idx,
                        Rule::HotAlloc,
                        format!(
                            "allocating call `{pat}` inside hot region `fn {}` — \
                             use the workspace-backed `_into` kernels, or annotate \
                             `// lint: allow(alloc, <reason>)` if provably cold",
                            region.fn_name
                        ),
                    ));
                }
            }
        }
    }

    findings
}

/// Tree-level report: findings plus the number of files scanned.
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<_> = entries
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the crate sources under `<root>/rust/src` against the repo
/// region table. Also fails when a registered region's file suffix
/// matches no scanned file at all (table rot).
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    let src_root = root.join("rust").join("src");
    if !src_root.is_dir() {
        return Err(format!("{} is not a directory", src_root.display()));
    }
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)?;
    let regions = repo_regions();
    let mut findings = Vec::new();
    let mut suffix_seen = vec![false; regions.len()];
    for path in &files {
        let label = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        for (i, region) in regions.iter().enumerate() {
            if label.ends_with(region.file_suffix) {
                suffix_seen[i] = true;
            }
        }
        let src =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        findings.extend(lint_file(&label, &src, &regions));
    }
    for (i, region) in regions.iter().enumerate() {
        if !suffix_seen[i] {
            findings.push(Finding {
                file: region.file_suffix.to_string(),
                line: 0,
                rule: Rule::RegionMissing,
                message: format!(
                    "no scanned file matches registered hot-region suffix \
                     {:?} — update the region table in rust/xtask/src/lib.rs",
                    region.file_suffix
                ),
            });
        }
    }
    Ok(LintReport { findings, files_scanned: files.len() })
}
