// Fixture: malformed allow annotations.

impl Solver for FakeSolver<'_> {
    fn step(&mut self) {
        // lint: allow(alloc)
        self.scratch = vec![0.0; 4]; // reason missing: allow-syntax + alloc
        // lint: allow(bogus-rule, some reason)
        self.w = self.data.matmul(&self.w); // unknown rule: allow-syntax + alloc
    }
}
