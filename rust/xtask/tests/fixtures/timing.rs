// Fixture: wall-clock reads outside util/timer.rs and benchkit.
// Linted with label "coordinator/fake.rs".

fn measure() -> f64 {
    let t0 = std::time::Instant::now(); // violation: Instant::now(
    let _ = std::time::SystemTime::UNIX_EPOCH; // violation: SystemTime
    t0.elapsed().as_secs_f64()
}
