// Fixture: a file whose registered hot region has rotted away (the
// region table expects `fn step` in `impl Solver for FakeSolver`, but
// the fn was renamed).

impl Solver for FakeSolver<'_> {
    fn advance(&mut self) {
        self.iter += 1;
    }
}
