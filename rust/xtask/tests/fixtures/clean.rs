// Fixture: a file that must produce zero findings. The hot region uses
// only workspace-backed `_into` kernels; its one allocation is
// annotated with a reasoned allow; hash collections are used for keyed
// lookup only; no threads, no clocks, and SAFETY-commented unsafe.

use std::collections::HashSet;

impl Solver for FakeSolver<'_> {
    fn step(&mut self) -> StepReport {
        self.data.matmul_into(&self.w, &mut self.g);
        qr_into(&self.g, true, &mut self.q, &mut self.r, &mut self.ws);
        if self.shape_changed {
            // lint: allow(alloc, one-time cold-path rebuild when the problem shape changes)
            self.scratch = Mat::zeros(self.d, self.k);
        }
        StepReport { finite: self.q.is_finite() }
    }
}

fn dedupe_in_order(xs: &[u64]) -> Vec<u64> {
    let mut seen: HashSet<u64> = HashSet::new();
    let mut out = Vec::new();
    for &x in xs {
        if seen.insert(x) {
            out.push(x); // order comes from `xs`, not the set
        }
    }
    out
}

fn strings_do_not_confuse_the_scanner() -> &'static str {
    // Pattern text inside string literals must not trip the lint:
    "call .matmul( or vec![ or Instant::now( or unsafe here"
}

fn write_through(p: *mut u8) {
    // SAFETY: `p` comes from a live &mut u8 upheld by the caller.
    unsafe {
        *p = 3;
    }
}

#[cfg(test)]
mod tests {
    // Test modules are exempt from the hot-path and discipline rules.
    #[test]
    fn scratch_allocations_are_fine_here() {
        let v = vec![1u8, 2, 3];
        assert_eq!(v.clone().len(), 3);
    }
}
