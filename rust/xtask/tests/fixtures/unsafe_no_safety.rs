// Fixture: `unsafe` without a SAFETY comment.

fn bad(p: *mut u8) {
    unsafe {
        // violation: no SAFETY comment on or above the unsafe line
        *p = 1;
    }
}

fn good(p: *mut u8) {
    // SAFETY: the caller guarantees `p` is valid, aligned, and
    // exclusively borrowed for the duration of this call.
    unsafe {
        *p = 2;
    }
}

// A comment merely *mentioning* unsafe code is not flagged.
fn commentary() {}
