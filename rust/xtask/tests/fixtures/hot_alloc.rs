// Fixture: allocating kernel calls inside a registered hot region.
// Linted with label "algo/fake.rs" and a region table registering
// `fn step` inside `impl Solver for FakeSolver`. Never compiled.

impl Solver for FakeSolver<'_> {
    fn step(&mut self) -> StepReport {
        let g = self.data.matmul(&self.w); // violation: .matmul(
        let q = qr::orth(&g); // violation: orth(
        self.scratch = vec![0.0; 4]; // violation: vec![
        let label = String::new(); // violation: String::new(
        StepReport { w: q.clone(), label } // violation: .clone()
    }
}

// Outside the region: allocation is fine here.
fn cold_rebuild() -> Vec<f64> {
    let mut out = Vec::new();
    out.push(1.0);
    out
}
