//! Fixture: vendor intrinsics and CPU feature detection outside the
//! SIMD seam. Both the feature probe and the target_feature attribute
//! must be flagged anywhere but linalg/simd.rs.

pub fn probe() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[target_feature(enable = "avx2")]
// SAFETY: fixture only; callers check availability.
pub unsafe fn lane_kernel(x: &mut [f64]) {
    use core::arch::x86_64::*;
    // SAFETY: fixture only.
    unsafe {
        let v = _mm256_set1_pd(2.0);
        _mm256_storeu_pd(x.as_mut_ptr(), v);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn detection_in_test_mod_is_permitted() {
        let _ = std::arch::is_x86_feature_detected!("avx2");
    }
}
