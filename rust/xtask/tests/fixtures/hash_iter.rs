// Fixture: HashMap/HashSet iteration in result-producing code.
// Keyed insert/contains are fine; ordered consumption is flagged.

use std::collections::{HashMap, HashSet};

fn produce(xs: &[u64]) -> Vec<u64> {
    let mut seen: HashSet<u64> = HashSet::new();
    for &x in xs {
        seen.insert(x); // fine: keyed operation
    }
    let mut out = Vec::new();
    for v in &seen {
        // violation above: iteration order is nondeterministic
        out.push(*v);
    }
    let counts: HashMap<u64, u64> = HashMap::new();
    out.extend(counts.values()); // violation: .values()
    out
}

fn membership_only(xs: &[u64]) -> bool {
    let mut seen: HashSet<u64> = HashSet::new();
    for &x in xs {
        if seen.contains(&x) {
            return true;
        }
        seen.insert(x);
    }
    false
}
