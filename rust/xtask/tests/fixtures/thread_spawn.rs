// Fixture: ad-hoc thread primitives outside exec/. Linted with label
// "coordinator/fake.rs" (not under exec/).

fn run_workers() {
    let h = std::thread::spawn(|| 1 + 1); // violation: thread::spawn(
    let _ = h.join();
    std::thread::scope(|s| {
        // violation above: thread::scope(
        let _ = s;
    });
}
