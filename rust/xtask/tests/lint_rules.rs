//! Fixture tests pinning the lint on both sides: every violation
//! fixture must be flagged with exactly the expected rule(s), the clean
//! fixture must pass, and the real tree must lint clean.

use std::path::Path;

use xtask::{lint_file, lint_tree, Region, Rule};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()))
}

fn fake_solver_region(file_suffix: &'static str) -> Vec<Region> {
    vec![Region {
        file_suffix,
        impl_context: Some("Solver for FakeSolver"),
        fn_name: "step",
    }]
}

fn rules_of(findings: &[xtask::Finding]) -> Vec<Rule> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn hot_alloc_fixture_flags_every_allocating_call_in_the_region() {
    let src = fixture("hot_alloc.rs");
    let findings =
        lint_file("rust/src/algo/fake.rs", &src, &fake_solver_region("algo/fake.rs"));
    assert!(
        findings.iter().all(|f| f.rule == Rule::HotAlloc),
        "only alloc findings expected, got: {findings:?}"
    );
    // .matmul(, orth(, vec![, String::new(, .clone() — five distinct calls.
    assert_eq!(findings.len(), 5, "findings: {findings:?}");
    // The allocation in cold_rebuild (outside the region) is not flagged.
    let region_end = src.lines().position(|l| l.trim() == "}").unwrap() + 2;
    assert!(
        findings.iter().all(|f| f.line <= region_end),
        "cold-path allocation was flagged: {findings:?}"
    );
}

#[test]
fn hash_iter_fixture_flags_iteration_but_not_keyed_access() {
    let src = fixture("hash_iter.rs");
    let findings = lint_file("rust/src/consensus/fake.rs", &src, &[]);
    assert_eq!(rules_of(&findings), vec![Rule::HashIter, Rule::HashIter], "{findings:?}");
    // The two findings are the `for v in &seen` loop and `counts.values()`,
    // not the insert/contains lines.
    let flagged: Vec<&str> =
        findings.iter().map(|f| src.lines().nth(f.line - 1).unwrap().trim()).collect();
    assert!(flagged[0].starts_with("for v in &seen"), "{flagged:?}");
    assert!(flagged[1].contains("counts.values()"), "{flagged:?}");
}

#[test]
fn thread_spawn_fixture_is_flagged_outside_exec() {
    let src = fixture("thread_spawn.rs");
    let findings = lint_file("rust/src/coordinator/fake.rs", &src, &[]);
    assert_eq!(
        rules_of(&findings),
        vec![Rule::ThreadSpawn, Rule::ThreadSpawn],
        "{findings:?}"
    );
}

#[test]
fn thread_primitives_are_permitted_under_exec() {
    let src = fixture("thread_spawn.rs");
    let findings = lint_file("rust/src/exec/fake.rs", &src, &[]);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn timing_fixture_is_flagged_outside_the_timer_seam() {
    let src = fixture("timing.rs");
    let findings = lint_file("rust/src/coordinator/fake.rs", &src, &[]);
    assert_eq!(rules_of(&findings), vec![Rule::Timing, Rule::Timing], "{findings:?}");
}

#[test]
fn wall_clock_reads_are_permitted_in_the_timer_seam() {
    let src = fixture("timing.rs");
    assert!(lint_file("rust/src/util/timer.rs", &src, &[]).is_empty());
    assert!(lint_file("rust/src/util/benchkit.rs", &src, &[]).is_empty());
}

#[test]
fn unsafe_without_safety_comment_is_flagged_once() {
    let src = fixture("unsafe_no_safety.rs");
    let findings = lint_file("rust/src/util/fake.rs", &src, &[]);
    assert_eq!(rules_of(&findings), vec![Rule::Safety], "{findings:?}");
    let flagged = src.lines().nth(findings[0].line - 1).unwrap();
    assert!(flagged.contains("unsafe"), "flagged line: {flagged:?}");
}

#[test]
fn arch_intrinsics_fixture_is_flagged_outside_the_simd_seam() {
    let src = fixture("arch_intrinsics.rs");
    let findings = lint_file("rust/src/linalg/fake.rs", &src, &[]);
    // The probe line matches both the std::arch and the detection-macro
    // patterns, plus the target_feature attribute and the core::arch
    // use — the test-mod probe is not flagged.
    assert_eq!(
        rules_of(&findings),
        vec![Rule::ArchScope; 4],
        "{findings:?}"
    );
    assert!(
        findings.iter().all(|f| f.message.contains("linalg/simd.rs")),
        "{findings:?}"
    );
}

#[test]
fn arch_intrinsics_are_permitted_in_the_simd_seam() {
    let src = fixture("arch_intrinsics.rs");
    let findings = lint_file("rust/src/linalg/simd.rs", &src, &[]);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn arch_allow_annotation_suppresses_with_reason() {
    let src = "// lint: allow(arch, build-time probe, no lane code)\n\
               pub fn ok() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
    let findings = lint_file("rust/src/config.rs", src, &[]);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn malformed_allow_annotations_are_flagged_and_do_not_suppress() {
    let src = fixture("allow_syntax.rs");
    let findings =
        lint_file("rust/src/algo/fake.rs", &src, &fake_solver_region("algo/fake.rs"));
    let allow_syntax = findings.iter().filter(|f| f.rule == Rule::AllowSyntax).count();
    let hot_alloc = findings.iter().filter(|f| f.rule == Rule::HotAlloc).count();
    // A reason-less allow and an unknown-rule allow are each flagged,
    // and neither suppresses the allocation it sits above.
    assert_eq!((allow_syntax, hot_alloc), (2, 2), "{findings:?}");
}

#[test]
fn rotted_region_table_is_flagged_as_region_missing() {
    let src = fixture("region_missing.rs");
    let findings =
        lint_file("rust/src/algo/fake.rs", &src, &fake_solver_region("algo/fake.rs"));
    assert_eq!(rules_of(&findings), vec![Rule::RegionMissing], "{findings:?}");
}

#[test]
fn clean_fixture_passes_every_rule() {
    let src = fixture("clean.rs");
    let findings = lint_file(
        "rust/src/algo/fake_clean.rs",
        &src,
        &fake_solver_region("algo/fake_clean.rs"),
    );
    assert!(findings.is_empty(), "clean fixture flagged: {findings:?}");
}

#[test]
fn pattern_text_inside_strings_and_comments_is_ignored() {
    let src = r#"
fn describe() -> &'static str {
    // .matmul( vec![ Instant::now( thread::spawn( unsafe
    /* SystemTime .clone() */
    "thread::spawn( Instant::now( unsafe { }"
}
"#;
    let findings = lint_file("rust/src/util/fake.rs", src, &[]);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn repo_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let report = lint_tree(&root).expect("lint_tree on the repo root");
    assert!(
        report.findings.is_empty(),
        "the real tree must lint clean; findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 10, "suspiciously few files scanned");
}
