//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The crate builds in environments with no registry access, so this
//! vendored shim provides exactly the surface the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! `Error` keeps a context chain (outermost first). `{e}` prints the
//! outermost message, `{e:#}` the full `a: b: c` chain — matching the
//! real crate's Display behavior closely enough for CLI output.

use std::fmt;

/// Dynamic error with a chain of context messages.
pub struct Error {
    /// Outermost context first, root cause last. Never empty.
    chain: Vec<String>,
}

impl Error {
    /// Error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like the real crate, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent (no overlap with the reflexive `From<Error> for Error`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Attach a context message to the error/none case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_display() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
    }

    #[test]
    fn result_and_option_context() {
        let r: Result<()> = Err(io_err()).context("step one");
        assert!(format!("{:#}", r.unwrap_err()).starts_with("step one"));
        let o: Result<i32> = None.with_context(|| format!("no {}", "value"));
        assert_eq!(format!("{}", o.unwrap_err()), "no value");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative input -2");
        let e = anyhow!("plain {} message", 7);
        assert_eq!(format!("{e}"), "plain 7 message");
    }
}
